//! Build script: wire the `DSPCA_ANALYZE` environment variable to the
//! `dspca_analyze` cfg flag.
//!
//! `DSPCA_ANALYZE=1 cargo test` compiles the instrumented sync shim
//! (`crate::sync`) with the lock-order/IO-section detectors enabled; a
//! plain build compiles the shim down to bare `std::sync` wrappers with
//! no extra state (see `src/sync/mod.rs` for the zero-overhead
//! contract). A cfg flag — not a cargo feature — so the switch cannot
//! be enabled transitively by a dependent crate and never appears in
//! the public feature surface.

fn main() {
    // Declare the custom cfg so `cargo check`'s unexpected_cfgs lint
    // knows it is ours.
    println!("cargo:rustc-check-cfg=cfg(dspca_analyze)");
    println!("cargo:rerun-if-env-changed=DSPCA_ANALYZE");
    let on = match std::env::var("DSPCA_ANALYZE") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    };
    if on {
        println!("cargo:rustc-cfg=dspca_analyze");
    }
}
