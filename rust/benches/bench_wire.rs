//! E10 bench — the wire layer: codec encode/decode throughput per
//! format (plain widths, low-bit quantizers, top-s sparsifier),
//! collective round-trip latency under each codec including the
//! stateful error-feedback streams, plus the full error-vs-bytes sweep
//! at reduced size.

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec, QuantBits, WireCodec, WireFormat, WirePrecision};
use dspca::data::CovModel;
use dspca::experiments::wire::{run, WireConfig};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();

    // format microbench: the in-place quantize (encode→decode loss
    // without frame materialization) of a payload — the per-message CPU
    // tax each frame format adds
    let len = if fast_mode() { 1024 } else { 8192 };
    let mut rng = dspca::rng::Pcg64::new(3);
    let payload = rng.gaussian_vec(len);
    let formats = [
        WireFormat::Plain(WirePrecision::F64),
        WireFormat::Plain(WirePrecision::F32),
        WireFormat::Plain(WirePrecision::Bf16),
        WireFormat::Quant(QuantBits::Q8),
        WireFormat::Quant(QuantBits::Q4),
        WireFormat::TopS { s: 32, bits: QuantBits::Q8 },
    ];
    for format in formats {
        let mut buf = payload.clone();
        b.bench(&format!("codec/transcode/{}/{len}", format.label()), || {
            buf.copy_from_slice(&payload);
            format.quantize(&mut buf, 1)
        });
    }

    // collective latency under each codec: the quantization tax on a
    // full leader->workers->leader round — the +ef rows also pay the
    // leader- and worker-side residual accumulators every round
    let (d, m, n) = if fast_mode() { (32usize, 4usize, 100usize) } else { (64, 8, 400) };
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    let cluster = Cluster::generate_with(&dist, m, n, 11, OracleSpec::Native)?;
    let session = cluster.session();
    let v = rng.gaussian_vec(d);
    let _ = session.dist_matvec(&v)?; // warm
    let sweep = [
        WireCodec::lossless(),
        WireCodec::new(WirePrecision::F32),
        WireCodec::new(WirePrecision::Bf16),
        WireCodec::quant(QuantBits::Q8),
        WireCodec::quant(QuantBits::Q4).with_feedback(),
        WireCodec::top_s(4, QuantBits::Q8).with_feedback(),
        WireCodec::quant(QuantBits::Q8).with_adaptive(),
    ];
    for codec in sweep {
        // set_codec resets the stream, so each series starts from a
        // fresh residual — run-to-run comparable
        session.set_codec(codec);
        b.bench(&format!("dist_matvec/{}/m={m}/{n}x{d}", codec.label()), || {
            session.dist_matvec(&v).unwrap()
        });
    }
    // no codec restore needed: the codec is session-local state now,
    // and this session is done

    // the E10 sweep itself, reduced
    let cfg = WireConfig {
        d: if fast_mode() { 16 } else { 40 },
        m: 4,
        n: if fast_mode() { 100 } else { 300 },
        runs: scaled(4).max(2),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("wire/sweep", vec![t0.elapsed().as_secs_f64()]);
    table.write("results/bench_wire.csv")?;
    println!("wrote results/bench_wire.csv");
    b.write_json("wire", &[("d", d as f64), ("m", m as f64), ("n", n as f64)])?;
    Ok(())
}
