//! E10 bench — the wire layer: codec encode/decode throughput per
//! precision, collective round-trip latency under each codec, plus the
//! full error-vs-bytes sweep at reduced size.

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec, WireCodec};
use dspca::data::CovModel;
use dspca::experiments::wire::{run, WireConfig, PRECISIONS};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();

    // codec microbench: transcode (encode + decode + writeback) of a
    // payload — the per-message overhead the wire layer adds
    let len = if fast_mode() { 1024 } else { 8192 };
    let mut rng = dspca::rng::Pcg64::new(3);
    let payload = rng.gaussian_vec(len);
    for prec in PRECISIONS {
        let codec = WireCodec::new(prec);
        let mut buf = payload.clone();
        b.bench(&format!("codec/transcode/{}/{len}", prec.label()), || {
            buf.copy_from_slice(&payload);
            codec.transcode(&mut buf)
        });
    }

    // collective latency under each codec: the quantization tax on a
    // full leader->workers->leader round
    let (d, m, n) = if fast_mode() { (32usize, 4usize, 100usize) } else { (64, 8, 400) };
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    let cluster = Cluster::generate_with(&dist, m, n, 11, OracleSpec::Native)?;
    let session = cluster.session();
    let v = rng.gaussian_vec(d);
    let _ = session.dist_matvec(&v)?; // warm
    for prec in PRECISIONS {
        session.set_codec(WireCodec::new(prec));
        b.bench(&format!("dist_matvec/{}/m={m}/{n}x{d}", prec.label()), || {
            session.dist_matvec(&v).unwrap()
        });
    }
    // no codec restore needed: the codec is session-local state now,
    // and this session is done

    // the E10 sweep itself, reduced
    let cfg = WireConfig {
        d: if fast_mode() { 16 } else { 40 },
        m: 4,
        n: if fast_mode() { 100 } else { 300 },
        runs: scaled(4).max(2),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("wire/sweep", vec![t0.elapsed().as_secs_f64()]);
    table.write("results/bench_wire.csv")?;
    println!("wrote results/bench_wire.csv");
    b.write_json("wire", &[("d", d as f64), ("m", m as f64), ("n", n as f64)])?;
    Ok(())
}
