//! E3 bench: the Table-1 rows (error + rounds per method).

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::OracleSpec;
use dspca::data::Distribution;
use dspca::experiments::table1::{render_rows, run, Table1Config};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let (d, m, n) = if fast_mode() { (30, 6, 150) } else { (120, 25, 400) };
    let cfg = Table1Config { d, m, n, runs: scaled(8), seed: 0x7a, oracle: OracleSpec::Native };
    let t0 = std::time::Instant::now();
    let (rows, table) = run(&cfg)?;
    b.record("table1/all-methods", vec![t0.elapsed().as_secs_f64()]);
    let dist = dspca::data::CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x7a).gaussian();
    println!("{}", render_rows(&rows, dist.eps_erm(cfg.m, cfg.n, 0.25)));
    table.write("results/bench_table1.csv")?;
    b.write_json("table1", &[("d", d as f64), ("m", m as f64), ("n", n as f64)])?;
    Ok(())
}
