//! E1/E2 bench: regenerate the Figure-1 series (reduced size by default;
//! `DSPCA_RUNS` / `DSPCA_BENCH_FAST` scale it).

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::OracleSpec;
use dspca::experiments::figure1::{run, Fig1Config, Fig1Dist};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let (d, m) = if fast_mode() { (40, 8) } else { (120, 25) };
    for dist in [Fig1Dist::Gaussian, Fig1Dist::ScaledUniform] {
        let cfg = Fig1Config {
            d,
            m,
            n_list: vec![50, 100, 200, 400],
            runs: scaled(24),
            seed: 0xf1,
            dist,
            oracle: OracleSpec::Native,
            transport: dspca::transport::TransportSpec::InProc,
        };
        let t0 = std::time::Instant::now();
        let table = run(&cfg)?;
        b.record(&format!("figure1/{dist:?}/sweep"), vec![t0.elapsed().as_secs_f64()]);
        table.write(format!("results/bench_figure1_{dist:?}.csv").to_lowercase())?;
    }
    println!("series CSVs in results/ — compare shape against the paper's Figure 1");
    b.write_json("figure1", &[("d", d as f64), ("m", m as f64)])?;
    Ok(())
}
