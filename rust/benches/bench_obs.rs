//! Observability overhead bench — pins the flight recorder's cost
//! model (DESIGN.md §12): metric mutation is a relaxed atomic RMW,
//! a disabled `obs_trace!` is one relaxed load, and the enabled trace
//! path buffers thread-locally. In full mode (no `DSPCA_BENCH_FAST=1`)
//! the disabled-path medians are **gated**: if a lock, allocation, or
//! format ever creeps onto the always-on path, this bench fails rather
//! than silently taxing every collective round.

use std::time::Instant;

use dspca::bench_harness::{fast_mode, Bencher};
use dspca::cluster::{Cluster, OracleSpec};
use dspca::data::CovModel;

/// Full-mode ceiling for the always-on / disabled paths, in
/// nanoseconds. A relaxed atomic is single-digit ns; a mutex, format,
/// or allocation is hundreds — the gate sits between the two regimes
/// with headroom for noisy hosts.
const DISABLED_PATH_CEILING_NS: f64 = 250.0;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();

    // -- always-on metric mutation: one relaxed RMW per event --
    let counter_ns = {
        let r = b.bench("obs/counter_inc", || dspca::obs_inc!(SOLVER_ITERATIONS_TOTAL));
        r.summary().median * 1e9
    };
    let gauge_ns = {
        let r = b.bench("obs/gauge_set", || dspca::obs_gauge!(SERVE_QUEUE_DEPTH, 3));
        r.summary().median * 1e9
    };
    let hist_ns = {
        let r = b.bench("obs/hist_observe", || dspca::obs_hist!(SUBMIT_BYTES, 4096));
        r.summary().median * 1e9
    };

    // -- disabled tracing: the macro's whole cost is one relaxed load;
    // field expressions must not even be evaluated --
    assert!(!dspca::obs::trace::enabled(), "bench must start with tracing off");
    let trace_off_ns = {
        let r = b.bench("obs/trace_disabled", || {
            dspca::obs_trace!("bench_ev", seq = 7u64, bytes = 128u64)
        });
        r.summary().median * 1e9
    };

    // -- enabled tracing into the in-memory sink: serialize + buffer,
    // flushing to the sink every batch boundary. Fixed iteration count
    // (not calibrated) so the captured event volume stays bounded. --
    dspca::obs::trace::install_memory();
    let per_sample = 5_000u64;
    let mut samples = Vec::new();
    for _ in 0..8 {
        let t = Instant::now();
        for i in 0..per_sample {
            dspca::obs_trace!("bench_ev", seq = i, bytes = 128u64);
        }
        samples.push(t.elapsed().as_secs_f64() / per_sample as f64);
    }
    let captured = dspca::obs::trace::finish()?.map_or(0, |lines| lines.len());
    anyhow::ensure!(
        captured as u64 >= 8 * per_sample,
        "memory sink lost events: {captured} captured"
    );
    b.record("obs/trace_enabled_memory", samples);

    // -- snapshot cost: every registered metric, relaxed loads only --
    b.bench("obs/snapshot", || dspca::obs::metrics::snapshot());
    b.bench("obs/snapshot_to_json", || dspca::obs::metrics::snapshot().to_json());

    // -- an instrumented collective round end to end: the absolute
    // cost the counters ride on (metrics are always on, so this *is*
    // the instrumented number; the gates above bound the delta) --
    let (d, m, n) = if fast_mode() { (16usize, 3usize, 60usize) } else { (64, 4, 300) };
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    let cluster = Cluster::generate_with(&dist, m, n, 11, OracleSpec::Native)?;
    let session = cluster.session();
    let v = dspca::rng::Pcg64::new(3).gaussian_vec(d);
    let _ = session.dist_matvec(&v)?; // warm
    b.bench(&format!("obs/dist_matvec_instrumented/m={m}/{n}x{d}"), || {
        session.dist_matvec(&v).unwrap()
    });

    // -- the full-mode gate: the always-on paths must stay in the
    // atomic-op regime (CI smoke runs under DSPCA_BENCH_FAST=1 record
    // the trajectory without gating; the full run enforces it) --
    if !fast_mode() {
        for (name, ns) in [
            ("counter_inc", counter_ns),
            ("gauge_set", gauge_ns),
            ("hist_observe", hist_ns),
            ("trace_disabled", trace_off_ns),
        ] {
            anyhow::ensure!(
                ns < DISABLED_PATH_CEILING_NS,
                "obs/{name} median {ns:.1}ns exceeds the {DISABLED_PATH_CEILING_NS}ns \
                 always-on ceiling: something heavier than a relaxed atomic is on the hot path"
            );
        }
        println!(
            "obs gate OK: counter {counter_ns:.1}ns, gauge {gauge_ns:.1}ns, \
             hist {hist_ns:.1}ns, disabled trace {trace_off_ns:.1}ns \
             (< {DISABLED_PATH_CEILING_NS}ns)"
        );
    }

    b.write_json(
        "obs",
        &[
            ("d", d as f64),
            ("m", m as f64),
            ("n", n as f64),
            ("trace_events_captured", captured as f64),
        ],
    )?;
    Ok(())
}
