//! E6 bench — Theorem 6 scaling: Shift-and-Invert distributed matvecs vs
//! `n` (expected to *decrease*, `~n^{-1/4}` regime) and vs `m`, with
//! distributed Lanczos as the n-independent baseline.

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::experiments::scaling::{run_m_sweep, run_n_sweep, ScalingConfig};
use dspca::util::stats::loglog_slope;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let cfg = ScalingConfig {
        d: if fast_mode() { 40 } else { 120 },
        m: 8,
        n_list: if fast_mode() { vec![250, 1000, 4000] } else { vec![250, 500, 1000, 2000, 4000, 8000] },
        m_list: vec![2, 4, 8, 16],
        n_for_m_sweep: 1000,
        runs: scaled(4).max(2),
        ..Default::default()
    };

    let t0 = std::time::Instant::now();
    let tn = run_n_sweep(&cfg)?;
    b.record("scaling/n-sweep", vec![t0.elapsed().as_secs_f64()]);
    tn.write("results/bench_scaling_n.csv")?;
    // fitted slope of S&I matvecs in n
    let rows: Vec<Vec<f64>> = tn
        .render()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
        .collect();
    let ns: Vec<f64> = rows.iter().map(|r| r[0]).collect();
    let sni: Vec<f64> = rows.iter().map(|r| r[1]).collect();
    let lan: Vec<f64> = rows.iter().map(|r| r[2]).collect();
    println!(
        "S&I matvecs slope in n: {:+.2} (theory trend negative, toward -1/4); Lanczos: {:+.2} (theory ~0)",
        loglog_slope(&ns, &sni),
        loglog_slope(&ns, &lan)
    );

    let t1 = std::time::Instant::now();
    let tm = run_m_sweep(&cfg)?;
    b.record("scaling/m-sweep", vec![t1.elapsed().as_secs_f64()]);
    tm.write("results/bench_scaling_m.csv")?;
    println!("wrote results/bench_scaling_{{n,m}}.csv");
    b.write_json("scaling", &[("d", cfg.d as f64), ("runs", cfg.runs as f64)])?;
    Ok(())
}
