//! E4/E5 bench: Thm-3 and Thm-5 lower-bound scaling plus fitted slopes.

use dspca::bench_harness::{scaled, Bencher};
use dspca::experiments::lower_bounds::{run_thm3, run_thm5, LowerBoundConfig};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let cfg = LowerBoundConfig {
        n_list: vec![90, 270, 810, 2430],
        m_list: vec![4, 32, 128],
        runs: scaled(80),
        seed: 0x1b,
        delta: 0.4,
    };
    let t0 = std::time::Instant::now();
    let (t3, slopes) = run_thm3(&cfg)?;
    b.record("lower_bounds/thm3", vec![t0.elapsed().as_secs_f64()]);
    println!("thm3 slopes per m (lower bound -1; measured flat, m-independent): {slopes:.2?}");
    t3.write("results/bench_thm3.csv")?;

    let t1 = std::time::Instant::now();
    let (t5, slope) = run_thm5(&cfg)?;
    b.record("lower_bounds/thm5", vec![t1.elapsed().as_secs_f64()]);
    println!("thm5 slope (theory -> -2): {slope:.2}");
    t5.write("results/bench_thm5.csv")?;
    b.write_json("lower_bounds", &[("runs", cfg.runs as f64), ("delta", cfg.delta)])?;
    Ok(())
}
