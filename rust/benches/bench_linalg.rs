//! L3 hot-path microbenchmarks: the dense kernels every communication
//! round leans on (gemv/syrk/eigensolve/preconditioner application),
//! plus the ISSUE-6 shard-kernel contrast — scalar vs threaded
//! `cov_matmat`, the f32-accumulate fast path, and the CSR streaming
//! kernel. This is the profile target for the §Perf optimization loop.

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::coordinator::precond::Preconditioner;
use dspca::data::{Distribution, Shard, SparseDiag};
use dspca::linalg::{Matrix, SymEigen};
use dspca::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(1);

    // the paper's shapes: d = 300, per-machine n = 400
    let d = 300;
    let n = scaled(400).max(64);
    let shard = Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect());
    let v: Vec<f64> = rng.gaussian_vec(d);

    let mut scratch = Vec::new();
    let mut out = vec![0.0; d];
    b.bench(&format!("shard_cov_matvec_stream/{n}x{d}"), || {
        shard.cov_matvec_into(&v, &mut scratch, &mut out);
        out[0]
    });

    let gram = shard.empirical_covariance().clone();
    b.bench(&format!("gram_matvec/{d}"), || gram.matvec(&v));

    b.bench(&format!("syrk/{n}x{d}"), || shard.matrix().syrk_t());

    b.bench(&format!("sym_eigen/{d}"), || SymEigen::new(&gram).lambda1());

    let pc = Preconditioner::new(&gram, 0.05);
    let lambda = pc.lambda1_local() + 0.1;
    let mut pout = vec![0.0; d];
    b.bench(&format!("precond_apply_inv/{d}"), || {
        pc.apply_inv(lambda, &v, &mut pout);
        pout[0]
    });

    // square GEMM reference point for the blocked kernel
    let a = Matrix::from_vec(d, d, (0..d * d).map(|_| rng.next_f64()).collect());
    b.bench(&format!("gemm/{d}x{d}"), || a.matmul(&gram));

    let dot_a = rng.gaussian_vec(4096);
    let dot_b = rng.gaussian_vec(4096);
    b.bench("dot/4096", || dspca::linalg::vec_ops::dot(&dot_a, &dot_b));

    b.bench("gaussian_vec/8192", || rng.gaussian_vec(8192));

    let dist_fig1 = dspca::data::CovModel::paper_fig1(300, 3).gaussian();
    b.bench("sample_shard_fig1/400x300", || dist_fig1.sample_shard(&mut rng, 400).n());

    // ISSUE 6 tentpole contrast at d = 512, k = 8: scalar vs threaded
    // blocked cov_matmat, the f32-accumulate fast path, and the CSR
    // streaming kernel on a 5% sparse shard of the same shape
    let (d2, k2) = (512usize, 8usize);
    let n2 = scaled(400).max(64);
    let shard2 = Shard::new(n2, d2, (0..n2 * d2).map(|_| rng.next_gaussian()).collect());
    let vmat = Matrix::from_vec(d2, k2, (0..d2 * k2).map(|_| rng.next_gaussian()).collect());
    let mut scratch_nk = Vec::new();
    let mut out_mat = Matrix::zeros(d2, k2);
    let scalar_median = b
        .bench(&format!("cov_matmat_scalar/{n2}x{d2}xk{k2}"), || {
            shard2.cov_matmat_into_threads(&vmat, &mut scratch_nk, &mut out_mat, 1);
            out_mat.get(0, 0)
        })
        .summary()
        .median;
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let t = cores.clamp(2, 8);
    let threaded_median = b
        .bench(&format!("cov_matmat_threads{t}/{n2}x{d2}xk{k2}"), || {
            shard2.cov_matmat_into_threads(&vmat, &mut scratch_nk, &mut out_mat, t);
            out_mat.get(0, 0)
        })
        .summary()
        .median;
    b.bench(&format!("cov_matmat_f32/{n2}x{d2}xk{k2}"), || shard2.cov_matmat_f32(&vmat).get(0, 0));

    let sparse_dist = SparseDiag::paper_fig1(d2, 0.05);
    let csr = sparse_dist.sample_shard(&mut rng, n2);
    assert!(csr.is_sparse());
    b.bench(&format!("cov_matmat_csr_rho0.05/{n2}x{d2}xk{k2}"), || {
        csr.cov_matmat_into_threads(&vmat, &mut scratch_nk, &mut out_mat, 1);
        out_mat.get(0, 0)
    });

    // acceptance gate (full mode, >= 4 cores): the threaded kernel must
    // beat scalar by >= 2x at the tentpole shape — the bills are
    // bit-identical by construction (kernels never touch the wire)
    if !fast_mode() && cores >= 4 {
        let speedup = scalar_median / threaded_median.max(1e-12);
        assert!(
            speedup >= 2.0,
            "threaded cov_matmat speedup {speedup:.2}x < 2x at {n2}x{d2} k={k2} ({cores} cores)"
        );
        println!("threaded cov_matmat speedup: {speedup:.2}x on {cores} cores");
    }

    let _ = b.write_json(
        "linalg",
        &[("d", d as f64), ("n", n as f64), ("d2", d2 as f64), ("k2", k2 as f64)],
    );
}
