//! L3 hot-path microbenchmarks: the dense kernels every communication
//! round leans on (gemv/syrk/eigensolve/preconditioner application).
//! This is the profile target for the §Perf optimization loop.

use dspca::bench_harness::{scaled, Bencher};
use dspca::coordinator::precond::Preconditioner;
use dspca::data::Shard;
use dspca::linalg::{Matrix, SymEigen};
use dspca::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(1);

    // the paper's shapes: d = 300, per-machine n = 400
    let d = 300;
    let n = scaled(400).max(64);
    let shard = Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect());
    let v: Vec<f64> = rng.gaussian_vec(d);

    let mut scratch = Vec::new();
    let mut out = vec![0.0; d];
    b.bench(&format!("shard_cov_matvec_stream/{n}x{d}"), || {
        shard.cov_matvec_into(&v, &mut scratch, &mut out);
        out[0]
    });

    let gram = shard.empirical_covariance().clone();
    b.bench(&format!("gram_matvec/{d}"), || gram.matvec(&v));

    b.bench(&format!("syrk/{n}x{d}"), || shard.matrix().syrk_t());

    b.bench(&format!("sym_eigen/{d}"), || SymEigen::new(&gram).lambda1());

    let pc = Preconditioner::new(&gram, 0.05);
    let lambda = pc.lambda1_local() + 0.1;
    let mut pout = vec![0.0; d];
    b.bench(&format!("precond_apply_inv/{d}"), || {
        pc.apply_inv(lambda, &v, &mut pout);
        pout[0]
    });

    // square GEMM reference point for the blocked kernel
    let a = Matrix::from_vec(d, d, (0..d * d).map(|_| rng.next_f64()).collect());
    b.bench(&format!("gemm/{d}x{d}"), || a.matmul(&gram));

    let dot_a = rng.gaussian_vec(4096);
    let dot_b = rng.gaussian_vec(4096);
    b.bench("dot/4096", || dspca::linalg::vec_ops::dot(&dot_a, &dot_b));

    b.bench("gaussian_vec/8192", || rng.gaussian_vec(8192));

    let dist_fig1 = dspca::data::CovModel::paper_fig1(300, 3).gaussian();
    b.bench("sample_shard_fig1/400x300", || {
        use dspca::data::Distribution;
        dist_fig1.sample_shard(&mut rng, 400).n()
    });

    let _ = b.write_json("linalg", &[("d", d as f64), ("n", n as f64)]);
}
