//! E11 bench — the multi-tenant session layer: session-creation
//! overhead, collective latency through the session view, serve-batch
//! throughput at 1 vs N tenants (with per-QoS-class latency and a
//! round-fusion batch), and the E11 sweep at reduced size.

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec};
use dspca::data::CovModel;
use dspca::experiments::serve::{job_mix, run, ServeConfig};
use dspca::serve::{serve, QosClass};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();

    let (d, m, n) = if fast_mode() { (16usize, 3usize, 60usize) } else { (60, 8, 400) };
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    let cluster = Cluster::generate_with(&dist, m, n, 11, OracleSpec::Native)?;

    // the per-query fixed cost the session layer adds: two mutexes
    // behind an Arc
    b.bench("session/create", || cluster.session());

    // one collective through the session view (includes the wire-lock
    // critical section)
    let session = cluster.session();
    let v = dspca::rng::Pcg64::new(3).gaussian_vec(d);
    let _ = session.dist_matvec(&v)?; // warm
    b.bench(&format!("session/dist_matvec/m={m}/{n}x{d}"), || {
        session.dist_matvec(&v).unwrap()
    });

    // batch throughput: the same heterogeneous job mix at 1 tenant
    // (sequential) and at N tenants (concurrent leaders whose rounds
    // overlap on the split-phase wire, one shared cluster) — seconds
    // per job, with the batch's wire bytes attached
    let jobs_n = scaled(8).max(4);
    for tenants in [1usize, 4] {
        let report = serve(&cluster, job_mix(jobs_n), tenants)?;
        // samples are seconds per job, so the attached wire cost is
        // bytes per job too
        b.record_with_bytes(
            &format!("serve/jobs={jobs_n}/tenants={tenants}"),
            vec![report.wall.as_secs_f64() / jobs_n as f64],
            report.bills_sum.bytes / jobs_n as u64,
        );
        if tenants == 4 {
            // per-QoS-class latency samples at the concurrent point:
            // the weighted-fair scheduler's class separation, tracked
            // as a JSON trajectory (job_mix rotates classes i % 3, so
            // every class has jobs from 4 up)
            for q in QosClass::ALL {
                let lat: Vec<f64> = report
                    .jobs
                    .iter()
                    .filter(|j| j.qos == q)
                    .map(|j| j.latency.as_secs_f64())
                    .collect();
                if !lat.is_empty() {
                    b.record(&format!("serve/tenants=4/qos={}", q.label()), lat);
                }
            }
        }
    }

    // the same batch with round fusion on: compatible tenant rounds
    // coalesce into stacked carriers (bills unchanged by construction —
    // the serve scheduler re-verifies Σ bills == aggregate), and the
    // engagement counters ride out in the JSON params
    cluster.enable_fusion(std::time::Duration::from_millis(2), 8)?;
    let fused = serve(&cluster, job_mix(jobs_n), 4)?;
    b.record_with_bytes(
        &format!("serve/jobs={jobs_n}/tenants=4/fused"),
        vec![fused.wall.as_secs_f64() / jobs_n as f64],
        fused.bills_sum.bytes / jobs_n as u64,
    );
    let (fused_carriers, fused_members) = cluster.fusion_counters();

    // the E11 sweep itself, reduced — overlap measured via the
    // speedup_vs_1 column, not gated (CI smoke hosts vary)
    let cfg = ServeConfig {
        d: if fast_mode() { 12 } else { 40 },
        m: 4,
        n: if fast_mode() { 80 } else { 300 },
        jobs: scaled(8).max(4),
        tenants_list: vec![1, 2, 4],
        assert_overlap: None,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("serve/sweep", vec![t0.elapsed().as_secs_f64()]);
    table.write("results/bench_serve.csv")?;
    println!("wrote results/bench_serve.csv");
    b.write_json(
        "serve",
        &[
            ("d", d as f64),
            ("m", m as f64),
            ("n", n as f64),
            ("jobs", jobs_n as f64),
            ("fused_carriers", fused_carriers as f64),
            ("fused_members", fused_members as f64),
        ],
    )?;
    Ok(())
}
