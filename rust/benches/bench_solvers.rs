//! E7 bench — §4.2 ablation: CG vs preconditioned CG vs Nesterov AGD vs
//! plain GD on the shifted system `(lambda I - Xhat) z = w`, sweeping the
//! per-machine sample size `n` (which drives `mu ~ n^{-1/2}` and hence
//! the Lemma-6 condition number).
//!
//! Reported: operator applications (== communication rounds) to reach a
//! fixed residual, per solver, for (a) a spread spectrum where worst-case
//! bounds bind and (b) the paper's clustered Figure-1 spectrum where CG
//! converges superlinearly (see EXPERIMENTS.md E7 discussion).

use dspca::bench_harness::{scaled, Bencher};
use dspca::coordinator::precond::Preconditioner;
use dspca::coordinator::solvers::{agd::agd, agd::gd, cg::pcg};
use dspca::data::{CovModel, Distribution};
use dspca::linalg::{Matrix, SymEigen};
use dspca::rng::Pcg64;
use dspca::util::csv::CsvTable;

fn spectrum(d: usize, delta: f64, spread: bool) -> Vec<f64> {
    let mut sigma = vec![1.0, 1.0 - delta];
    for j in 2..d {
        if spread {
            sigma.push((1.0 - delta) * (1.0 - (j as f64 - 1.0) / d as f64));
        } else {
            let p = sigma[j - 1];
            sigma.push(0.9 * p);
        }
    }
    sigma
}

fn main() -> anyhow::Result<()> {
    let mut bench = Bencher::new();
    let d = 100;
    let m = 6;
    let delta = 0.05;
    let mut table =
        CsvTable::new(&["spectrum", "n", "cg_iters", "pcg_iters", "agd_iters", "gd_iters", "mu", "kappa_bound"]);
    for spread in [true, false] {
        for n in [500usize, 2000, 8000] {
            let n = scaled(n).max(200);
            let dist = CovModel::with_spectrum(spectrum(d, delta, spread), seed_for(spread)).gaussian();
            let mut rng = Pcg64::new(17);
            let shards: Vec<_> = (0..m).map(|_| dist.sample_shard(&mut rng, n)).collect();
            let mut pooled = Matrix::zeros(d, d);
            for s in &shards {
                pooled.axpy_mat(1.0 / m as f64, s.empirical_covariance());
            }
            let eig = SymEigen::new(&pooled);
            let lambda = eig.lambda1() + 0.25 * eig.eigengap();
            let local = shards[0].empirical_covariance().clone();
            let mu = 2.0 * pooled.sub(&local).sym_spectral_norm();
            let pc = Preconditioner::new(&local, mu);
            let mut mmat = Matrix::identity(d).scale(lambda);
            mmat.axpy_mat(-1.0, &pooled);
            let mut rhs = rng.gaussian_vec(d);
            dspca::linalg::vec_ops::normalize(&mut rhs);
            let tol = 1e-9;
            let max = 100_000;

            let (_, cg_rep) =
                pcg(|v| mmat.matvec(v), |r, out| out.copy_from_slice(r), &rhs, None, tol, max);
            let (_, pcg_rep) =
                pcg(|v| mmat.matvec(v), |r, out| pc.apply_inv(lambda, r, out), &rhs, None, tol, max);
            let meig = SymEigen::new(&mmat);
            let (beta, alpha) = (meig.lambda1(), *meig.values().last().unwrap());
            let (_, agd_rep) = agd(|v| mmat.matvec(v), &rhs, None, alpha.max(1e-12), beta, tol, max);
            let (_, gd_rep) = gd(|v| mmat.matvec(v), &rhs, None, beta, tol, max);
            let kappa = pc.kappa_bound(lambda, eig.lambda1());
            let name = if spread { "spread" } else { "fig1" };
            println!(
                "{name:>6} n={n:>5}: cg={:>5} pcg={:>5} agd={:>6} gd={:>6}  (mu={mu:.2e}, Lemma-6 kappa<={kappa:.1})",
                cg_rep.iters, pcg_rep.iters, agd_rep.iters, gd_rep.iters
            );
            table.push_row(vec![
                name.into(),
                n.to_string(),
                cg_rep.iters.to_string(),
                pcg_rep.iters.to_string(),
                agd_rep.iters.to_string(),
                gd_rep.iters.to_string(),
                format!("{mu:.4e}"),
                format!("{kappa:.2}"),
            ]);
        }
    }
    table.write("results/bench_solvers.csv")?;
    bench.record("solvers/ablation-total", vec![0.0]);
    println!("wrote results/bench_solvers.csv");
    bench.write_json("solvers", &[("d", d as f64), ("m", m as f64), ("delta", delta)])?;
    Ok(())
}

/// tiny helper to vary seeds per branch without magic numbers scattered
fn seed_for(spread: bool) -> u64 {
    if spread {
        0x51ab
    } else {
        0xf1b1
    }
}
