//! E9 bench — top-`k` block-protocol scaling: the full top-`k` family
//! swept over `k` (error vs rounds vs k) on the dense §5 model and the
//! 5%-dense sparse model (CSR shards, streaming kernels), plus a direct
//! block-vs-column round-trip latency contrast at k = 8.

use dspca::bench_harness::{fast_mode, results_dir, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec};
use dspca::data::CovModel;
use dspca::experiments::topk::{run, TopkConfig};
use dspca::linalg::Matrix;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let cfg = TopkConfig {
        d: if fast_mode() { 24 } else { 60 },
        m: 8,
        n: if fast_mode() { 150 } else { 400 },
        k_list: vec![1, 2, 4, 8],
        runs: scaled(8).max(2),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("topk/sweep", vec![t0.elapsed().as_secs_f64()]);
    let csv_path = results_dir().join("bench_topk.csv");
    table.write(&csv_path)?;

    // the same sweep on CSR shards (ISSUE 6): the sparse workload E9
    // exists for, timed end to end through the streaming kernels
    let sparse_cfg = TopkConfig { density: Some(0.05), ..cfg.clone() };
    let t0 = std::time::Instant::now();
    let _ = run(&sparse_cfg)?;
    b.record("topk/sweep_sparse_rho0.05", vec![t0.elapsed().as_secs_f64()]);

    // block protocol vs column-wise loop: same numerical product, one
    // round vs k rounds — measured wall clock per full exchange
    let (d, m, n, k) = (64usize, 8usize, 400usize, 8usize);
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    let cluster = Cluster::generate_with(&dist, m, n, 11, OracleSpec::Native)?;
    let session = cluster.session();
    let mut rng = dspca::rng::Pcg64::new(13);
    let v = Matrix::from_vec(d, k, (0..d * k).map(|_| rng.next_gaussian()).collect());
    let _ = session.dist_matmat(&v)?; // warm
    b.bench(&format!("dist_matmat/1-round/k={k}/m={m}/{n}x{d}"), || {
        session.dist_matmat(&v).unwrap()
    });
    b.bench(&format!("dist_matvec-loop/{k}-rounds/m={m}/{n}x{d}"), || {
        for c in 0..k {
            session.dist_matvec(&v.col(c)).unwrap();
        }
    });
    // attach the wire cost of one k-column loop (k rounds of B(d)·(m+1))
    session.reset_stats();
    for c in 0..k {
        session.dist_matvec(&v.col(c)).unwrap();
    }
    b.set_last_bytes(session.stats().bytes);
    println!("wrote {}", csv_path.display());
    b.write_json(
        "topk",
        &[("d", cfg.d as f64), ("m", cfg.m as f64), ("n", cfg.n as f64), ("k", k as f64)],
    )?;
    Ok(())
}
