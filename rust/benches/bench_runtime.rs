//! E8 bench: PJRT artifact path vs native Rust path, per worker
//! operation and per full communication round.
//!
//! Requires `make artifacts`. Skips gracefully when artifacts are absent
//! (prints a notice) so `cargo bench` stays green in a fresh checkout.

use dspca::bench_harness::Bencher;
use dspca::cluster::{Cluster, ComputeOracle, NativeOracle, OracleSpec};
use dspca::data::{CovModel, Shard};
use dspca::rng::Pcg64;
use dspca::runtime::{default_artifact_dir, PjrtOracle};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: artifacts missing at {} — run `make artifacts` first", dir.display());
        return Ok(());
    }
    let mut b = Bencher::new();
    let (n, d) = (400usize, 64usize);
    let mut rng = Pcg64::new(3);
    let shard = Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect());
    let v = rng.gaussian_vec(d);

    let mut native = NativeOracle::default();
    b.bench(&format!("native/cov_matvec/{n}x{d}"), || native.cov_matvec(&shard, &v).unwrap());

    let mut pjrt = PjrtOracle::new(&dir)?;
    let _ = pjrt.cov_matvec(&shard, &v)?; // compile + upload once
    b.bench(&format!("pjrt/cov_matvec/{n}x{d}"), || pjrt.cov_matvec(&shard, &v).unwrap());

    b.bench(&format!("native/gram/{n}x{d}"), || {
        // fresh shard clone defeats the gram cache so the kernel runs
        let s = shard.clone();
        s.empirical_covariance().get(0, 0)
    });
    b.bench(&format!("pjrt/gram/{n}x{d}"), || pjrt.gram(&shard).unwrap().get(0, 0));

    b.bench(&format!("native/local_eig/{n}x{d}"), || {
        let s = shard.clone();
        s.local_top_eigvec()
    });
    b.bench(&format!("pjrt/local_eig/{n}x{d}"), || pjrt.local_top_eigvec(&shard).unwrap());

    // full distributed round: m workers behind channels
    let dist = CovModel::paper_fig1(d, 5).gaussian();
    for (tag, spec) in [
        ("native", OracleSpec::Native),
        ("pjrt", OracleSpec::Pjrt { artifact_dir: dir.to_string_lossy().into_owned() }),
    ] {
        let cluster = Cluster::generate_with(&dist, 4, n, 9, spec)?;
        let session = cluster.session();
        let _ = session.dist_matvec(&v)?; // warm
        b.bench(&format!("{tag}/dist_matvec_round/m=4/{n}x{d}"), || {
            session.dist_matvec(&v).unwrap()
        });
    }
    b.write_json("runtime", &[("d", d as f64), ("n", n as f64)])?;
    Ok(())
}
