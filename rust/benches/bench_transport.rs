//! E12 bench — the transport layer: per-round collective latency on the
//! in-proc backend vs real TCP loopback sockets, across dimension `d`
//! and wire-codec width, plus the reduced E12 sweep (which itself
//! asserts bills are backend-invariant).

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec, WireCodec, WirePrecision};
use dspca::data::CovModel;
use dspca::experiments::transport::{run, TransportConfig};
use dspca::transport::{LoopbackWorkers, TransportSpec};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let (m, n) = if fast_mode() { (3usize, 60usize) } else { (8, 300) };
    let d_list: Vec<usize> = if fast_mode() { vec![32] } else { vec![64, 256] };
    let mut rng = dspca::rng::Pcg64::new(0x7c);

    for &d in &d_list {
        let dist = CovModel::paper_fig1(d, 5).gaussian();
        let v = rng.gaussian_vec(d);
        for backend in ["inproc", "tcp"] {
            let loopback =
                (backend == "tcp").then(|| LoopbackWorkers::spawn(m, 1)).transpose()?;
            let spec = loopback.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
            let cluster = Cluster::generate_on(&dist, m, n, 11, OracleSpec::Native, &spec)?;
            let session = cluster.session();
            let _ = session.dist_matvec(&v)?; // warm (connections, caches)
            for prec in [WirePrecision::F64, WirePrecision::Bf16] {
                session.set_codec(WireCodec::new(prec));
                session.reset_stats();
                b.bench(&format!("dist_matvec/{backend}/{}/m={m}/d={d}", prec.label()), || {
                    session.dist_matvec(&v).unwrap()
                });
                let st = session.stats();
                b.set_last_bytes(st.bytes / st.rounds.max(1));
            }
            // split-phase: the same round with 8 tickets in flight —
            // the overlap win the E12 driver gates on, here as a
            // trackable series
            session.set_codec(WireCodec::new(WirePrecision::F64));
            b.bench(&format!("dist_matvec_pipe8/{backend}/f64/m={m}/d={d}"), || {
                let mut window = std::collections::VecDeque::with_capacity(8);
                for _ in 0..8 {
                    window.push_back(session.dist_matvec_submit(&v).unwrap());
                }
                while let Some(t) = window.pop_front() {
                    t.complete().unwrap();
                }
            });
            drop(session);
            drop(cluster);
            if let Some(w) = loopback {
                w.join()?;
            }
        }
    }

    // the E12 sweep itself, reduced — asserts bill invariance and the
    // pipelined-bill identity inside; the TCP pipeline-win gate stays
    // off at smoke sizes
    let cfg = TransportConfig {
        d_list: if fast_mode() { vec![12] } else { vec![24, 96] },
        m: if fast_mode() { 2 } else { 4 },
        n: if fast_mode() { 50 } else { 200 },
        rounds: scaled(16).max(4),
        assert_pipeline_win: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("transport/sweep", vec![t0.elapsed().as_secs_f64()]);
    table.write("results/bench_transport.csv")?;
    println!("wrote results/bench_transport.csv");
    b.write_json("transport", &[("m", m as f64), ("n", n as f64)])?;
    Ok(())
}
