//! E12 bench — the transport layer: per-round collective latency on the
//! in-proc backend vs real TCP loopback sockets, across dimension `d`
//! and wire-codec width, plus the reduced E12 sweep (which itself
//! asserts bills are backend-invariant).

use dspca::bench_harness::{fast_mode, scaled, Bencher};
use dspca::cluster::{Cluster, OracleSpec, WireCodec, WirePrecision};
use dspca::data::CovModel;
use dspca::experiments::transport::{run, TransportConfig};
use dspca::transport::{LoopbackWorkers, TransportSpec};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let (m, n) = if fast_mode() { (3usize, 60usize) } else { (8, 300) };
    let d_list: Vec<usize> = if fast_mode() { vec![32] } else { vec![64, 256] };
    let mut rng = dspca::rng::Pcg64::new(0x7c);

    for &d in &d_list {
        let dist = CovModel::paper_fig1(d, 5).gaussian();
        let v = rng.gaussian_vec(d);
        for backend in ["inproc", "tcp"] {
            let loopback =
                (backend == "tcp").then(|| LoopbackWorkers::spawn(m, 1)).transpose()?;
            let spec = loopback.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
            let cluster = Cluster::generate_on(&dist, m, n, 11, OracleSpec::Native, &spec)?;
            let session = cluster.session();
            let _ = session.dist_matvec(&v)?; // warm (connections, caches)
            for prec in [WirePrecision::F64, WirePrecision::Bf16] {
                session.set_codec(WireCodec::new(prec));
                b.bench(&format!("dist_matvec/{backend}/{}/m={m}/d={d}", prec.label()), || {
                    session.dist_matvec(&v).unwrap()
                });
            }
            drop(session);
            drop(cluster);
            if let Some(w) = loopback {
                w.join()?;
            }
        }
    }

    // the E12 sweep itself, reduced — asserts bill invariance inside
    let cfg = TransportConfig {
        d_list: if fast_mode() { vec![12] } else { vec![24, 96] },
        m: if fast_mode() { 2 } else { 4 },
        n: if fast_mode() { 50 } else { 200 },
        rounds: scaled(16).max(4),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let table = run(&cfg)?;
    b.record("transport/sweep", vec![t0.elapsed().as_secs_f64()]);
    table.write("results/bench_transport.csv")?;
    println!("wrote results/bench_transport.csv");
    Ok(())
}
