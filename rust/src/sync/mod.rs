//! Instrumented synchronization shim — the only module allowed to name
//! `std::sync::Mutex`/`Condvar` (enforced by `dspca lint`, rule
//! `raw-sync-import`).
//!
//! Two compilation modes, selected by the `dspca_analyze` cfg that
//! `build.rs` derives from the `DSPCA_ANALYZE` environment variable:
//!
//! * **Release / default** (`cfg(not(dspca_analyze))`): every type here
//!   is a transparent newtype over its `std::sync` counterpart with no
//!   extra fields, no `Drop` impl, and `#[inline]` forwarding — the
//!   zero-overhead contract from ISSUE 7. The only behavioral delta vs.
//!   raw `std::sync` is centralized poison *recovery*: `lock()` /
//!   `try_lock()` / `get_mut()` / `into_inner()` return the inner data
//!   even if a holder panicked (`PoisonError::into_inner`), which is
//!   exactly the policy the cluster already applied call-site by
//!   call-site (a poisoned bill ledger is still the best available
//!   accounting record). This is what lets the repo-wide
//!   `.lock().unwrap()` count drop to zero.
//!
//! * **Analyze** (`cfg(dspca_analyze)`): the same API backed by
//!   [`analyze`]'s lockdep-style instrumentation — per-thread
//!   lock-acquisition stacks feed a global lock-*class* order graph;
//!   the process fails fast (panics with the witness chain) the moment
//!   an acquisition would close a cycle in that graph (lock-order
//!   inversion ⇒ potential deadlock), and [`check_io`] panics if any
//!   non-IO lock is held across a `Transport::send` / `recv_reply`
//!   boundary.
//!
//! Lock classes are *names*, shared by every instance constructed with
//! the same [`Mutex::named`] string (all `session.stats` mutexes are one
//! class, like Linux lockdep). [`Mutex::new`] gives the instance its own
//! anonymous class. [`Mutex::named_io`] additionally marks the class as
//! legitimately held across transport I/O (the cluster's `sender` and
//! the router's `rx` — see DESIGN.md §11 for the lock hierarchy).
//!
//! `try_lock` acquisitions record **no incoming order edge**: a try-lock
//! cannot block, so it cannot participate in a deadlock cycle as the
//! waiting edge (this is what makes the router's cooperative driver
//! election — `state` held, `try_lock(rx)` — legal while the elected
//! driver takes `rx` then `state` in the opposite order). A try-locked
//! guard still emits *outgoing* edges for locks acquired under it.

use std::time::Duration;

pub use std::sync::WaitTimeoutResult;
// Atomics and channels need no instrumentation (atomics cannot deadlock;
// mpsc blocking is covered by the model checker, not the shim) — re-export
// so call sites still route every `std::sync` use through this module.
pub use std::sync::{atomic, mpsc};

#[cfg(dspca_analyze)]
mod analyze;

#[cfg(dspca_analyze)]
pub use analyze::{check_io, Condvar, Mutex, MutexGuard};

#[cfg(not(dspca_analyze))]
mod release {
    use std::ops::{Deref, DerefMut};
    use std::sync::{PoisonError, TryLockError, WaitTimeoutResult};
    use std::time::Duration;

    /// Transparent `std::sync::Mutex` wrapper (release mode): poison is
    /// recovered, never propagated. See the module docs for the analyze
    /// variant this stands in for.
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        #[inline]
        pub fn new(value: T) -> Self {
            Self(std::sync::Mutex::new(value))
        }

        /// Same as [`Mutex::new`]; the class name only matters to the
        /// analyze build.
        #[inline]
        pub fn named(value: T, _class: &'static str) -> Self {
            Self::new(value)
        }

        /// Same as [`Mutex::new`]; the IO-ok marking only matters to the
        /// analyze build.
        #[inline]
        pub fn named_io(value: T, _class: &'static str) -> Self {
            Self::new(value)
        }

        #[inline]
        pub fn lock(&self) -> MutexGuard<'_, T> {
            MutexGuard(self.0.lock().unwrap_or_else(PoisonError::into_inner))
        }

        /// `Some(guard)` if the lock was free (poison recovered), `None`
        /// if another thread holds it.
        #[inline]
        pub fn try_lock(&self) -> Option<MutexGuard<'_, T>> {
            match self.0.try_lock() {
                Ok(g) => Some(MutexGuard(g)),
                Err(TryLockError::Poisoned(p)) => Some(MutexGuard(p.into_inner())),
                Err(TryLockError::WouldBlock) => None,
            }
        }

        #[inline]
        pub fn get_mut(&mut self) -> &mut T {
            self.0.get_mut().unwrap_or_else(PoisonError::into_inner)
        }

        #[inline]
        pub fn into_inner(self) -> T {
            self.0.into_inner().unwrap_or_else(PoisonError::into_inner)
        }
    }

    pub struct MutexGuard<'a, T>(std::sync::MutexGuard<'a, T>);

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;
        #[inline]
        fn deref(&self) -> &T {
            &self.0
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        #[inline]
        fn deref_mut(&mut self) -> &mut T {
            &mut self.0
        }
    }

    /// Transparent `std::sync::Condvar` wrapper (release mode).
    #[derive(Default)]
    pub struct Condvar(std::sync::Condvar);

    impl Condvar {
        #[inline]
        pub fn new() -> Self {
            Self(std::sync::Condvar::new())
        }

        #[inline]
        pub fn notify_all(&self) {
            self.0.notify_all();
        }

        #[inline]
        pub fn notify_one(&self) {
            self.0.notify_one();
        }

        /// Wait (with timeout) on the condvar; poison on wakeup is
        /// recovered like everywhere else in the shim.
        #[inline]
        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
            let (inner, res) =
                self.0.wait_timeout(guard.0, dur).unwrap_or_else(PoisonError::into_inner);
            (MutexGuard(inner), res)
        }
    }

    /// IO-section marker: a no-op in release builds. The analyze build
    /// panics here if the calling thread holds any lock not constructed
    /// with [`Mutex::named_io`] — holding an ordinary lock across a
    /// blocking transport call stalls every other session on that lock
    /// for a network round-trip (or forever, if the peer is gone).
    #[inline(always)]
    pub fn check_io(_site: &str) {}
}

#[cfg(not(dspca_analyze))]
pub use release::{check_io, Condvar, Mutex, MutexGuard};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    // These run in BOTH modes (tier-1 exercises the release wrappers;
    // the DSPCA_ANALYZE=1 CI job exercises the instrumented path with
    // legal lock orders).

    #[test]
    fn lock_roundtrip_and_try_lock_contention() {
        let m = Mutex::named(7usize, "test.sync.roundtrip");
        {
            let mut g = m.lock();
            *g += 1;
            // same-thread try_lock while held must refuse, not deadlock
            assert!(m.try_lock().is_none());
        }
        assert_eq!(*m.lock(), 8);
        assert_eq!(*m.try_lock().expect("free lock"), 8);
    }

    #[test]
    fn get_mut_and_into_inner() {
        let mut m = Mutex::new(vec![1, 2]);
        m.get_mut().push(3);
        assert_eq!(m.into_inner(), vec![1, 2, 3]);
    }

    #[test]
    fn condvar_wakes_waiter() {
        let pair = Arc::new((Mutex::named(false, "test.sync.cv"), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            *m.lock() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !*g {
            let (back, _timed_out) = cv.wait_timeout(g, Duration::from_millis(50));
            g = back;
            assert!(std::time::Instant::now() < deadline, "condvar wakeup lost");
        }
        drop(g);
        h.join().expect("signaller panicked");
    }

    #[test]
    fn poison_is_recovered_not_propagated() {
        let m = Arc::new(Mutex::named(41usize, "test.sync.poison"));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        // a panicked holder must not take the accounting data with it
        let mut g = m.lock();
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn check_io_is_clean_with_no_locks_held() {
        check_io("test.sync.no_locks");
    }
}
