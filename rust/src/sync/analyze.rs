//! `cfg(dspca_analyze)` backend of the sync shim: lockdep-style
//! lock-order tracking and IO-section checking.
//!
//! Every mutex belongs to a lock *class* (shared by name via
//! [`Mutex::named`], or per-instance for anonymous [`Mutex::new`]).
//! A global registry keeps a directed graph over classes: acquiring
//! class `B` while holding class `A` (via a *blocking* `lock()`)
//! records the edge `A -> B`. The moment a new edge closes a directed
//! cycle, the acquisition panics with the witness chain — a lock-order
//! inversion that could deadlock under some interleaving, caught on the
//! first run that exhibits both orders, no actual deadlock required.
//!
//! `try_lock` records no incoming edge (it cannot block, so it cannot
//! be the waiting edge of a deadlock cycle) but the guard still sits on
//! the per-thread held stack, so locks acquired *under* it produce
//! outgoing edges as usual.
//!
//! [`check_io`] is called by the transport layer at every
//! `Transport::send` / `recv_reply` entry: holding any lock whose class
//! was not declared IO-ok ([`Mutex::named_io`]) across those boundaries
//! panics with the held-lock list.
//!
//! Panic hygiene: detector panics are raised *after* the registry guard
//! is dropped, and the held-stack bookkeeping is unwind-safe (guards
//! pop their class in `Drop`), so `catch_unwind`-based self-tests leave
//! the instrumentation consistent.

use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::{OnceLock, PoisonError, TryLockError, WaitTimeoutResult};
use std::time::Duration;

/// Index into [`Registry::classes`].
type ClassId = usize;

struct ClassInfo {
    name: String,
    io_ok: bool,
}

#[derive(Default)]
struct Registry {
    classes: Vec<ClassInfo>,
    by_name: HashMap<&'static str, ClassId>,
    /// Adjacency: `edges[from]` = classes acquired while `from` was held.
    edges: HashMap<ClassId, Vec<ClassId>>,
}

impl Registry {
    fn intern_named(&mut self, name: &'static str, io_ok: bool) -> ClassId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.classes.len();
        self.classes.push(ClassInfo { name: name.to_string(), io_ok });
        self.by_name.insert(name, id);
        id
    }

    fn intern_anon(&mut self) -> ClassId {
        let id = self.classes.len();
        self.classes.push(ClassInfo { name: format!("mutex#{id}"), io_ok: false });
        id
    }

    /// Add `from -> to` if absent; returns whether it was new.
    fn add_edge(&mut self, from: ClassId, to: ClassId) -> bool {
        let out = self.edges.entry(from).or_default();
        if out.contains(&to) {
            false
        } else {
            out.push(to);
            true
        }
    }

    /// DFS path `from ->* to`, returned as the class-id chain (including
    /// both endpoints) if one exists.
    fn find_path(&self, from: ClassId, to: ClassId) -> Option<Vec<ClassId>> {
        let mut stack = vec![vec![from]];
        let mut visited = vec![false; self.classes.len()];
        visited[from] = true;
        while let Some(path) = stack.pop() {
            let &last = path.last()?;
            if last == to {
                return Some(path);
            }
            if let Some(outs) = self.edges.get(&last) {
                for &next in outs {
                    if !visited[next] {
                        visited[next] = true;
                        let mut p = path.clone();
                        p.push(next);
                        stack.push(p);
                    }
                }
            }
        }
        None
    }

    fn name(&self, id: ClassId) -> &str {
        &self.classes[id].name
    }
}

fn registry() -> std::sync::MutexGuard<'static, Registry> {
    static REGISTRY: OnceLock<std::sync::Mutex<Registry>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| std::sync::Mutex::new(Registry::default()))
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
}

thread_local! {
    /// Classes of locks this thread currently holds, in acquisition order.
    static HELD: RefCell<Vec<ClassId>> = const { RefCell::new(Vec::new()) };
}

/// Record order edges `held -> class` for every lock the thread holds,
/// panicking (with the witness chain) if any edge closes a cycle. Call
/// only for acquisitions that can block.
fn before_blocking_acquire(class: ClassId) {
    let held = HELD.with(|h| h.borrow().clone());
    if held.is_empty() {
        return;
    }
    let mut violation: Option<String> = None;
    {
        let mut reg = registry();
        for &from in &held {
            if reg.add_edge(from, class) {
                // new edge: a pre-existing path class ->* from now closes
                // a cycle (some thread acquires in the opposite order)
                if let Some(path) = reg.find_path(class, from) {
                    let chain: Vec<&str> = path.iter().map(|&c| reg.name(c)).collect();
                    violation = Some(format!(
                        "lock-order inversion: acquiring '{}' while holding '{}', \
                         but the recorded order is {} -> '{}' — potential deadlock",
                        reg.name(class),
                        reg.name(from),
                        chain.join(" -> "),
                        reg.name(class),
                    ));
                    break;
                }
            }
        }
    } // registry guard dropped before panicking
    if let Some(msg) = violation {
        panic!("dspca_analyze: {msg}");
    }
}

fn push_held(class: ClassId) {
    HELD.with(|h| h.borrow_mut().push(class));
}

fn pop_held(class: ClassId) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        // pop the *last* occurrence: guards may be released out of
        // acquisition order, and one class can be held twice (distinct
        // instances) on the way to a detector panic
        if let Some(pos) = held.iter().rposition(|&c| c == class) {
            held.remove(pos);
        }
    });
}

/// Panic if the calling thread holds any lock not declared IO-ok. The
/// transport layer calls this at every `Transport::send` and
/// `recv_reply` entry.
pub fn check_io(site: &str) {
    let offenders: Vec<String> = HELD.with(|h| {
        let held = h.borrow();
        if held.is_empty() {
            return Vec::new();
        }
        let reg = registry();
        held.iter()
            .filter(|&&c| !reg.classes[c].io_ok)
            .map(|&c| reg.name(c).to_string())
            .collect()
    });
    if !offenders.is_empty() {
        panic!(
            "dspca_analyze: lock(s) [{}] held across blocking transport I/O at {site} — \
             a slow or dead peer would stall every thread contending on them",
            offenders.join(", "),
        );
    }
}

/// Instrumented mutex (analyze mode). Same API as the release wrapper
/// in `sync/mod.rs`.
pub struct Mutex<T> {
    class: ClassId,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        let class = registry().intern_anon();
        Self { class, inner: std::sync::Mutex::new(value) }
    }

    pub fn named(value: T, class: &'static str) -> Self {
        let class = registry().intern_named(class, false);
        Self { class, inner: std::sync::Mutex::new(value) }
    }

    pub fn named_io(value: T, class: &'static str) -> Self {
        let class = registry().intern_named(class, true);
        Self { class, inner: std::sync::Mutex::new(value) }
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        before_blocking_acquire(self.class);
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        push_held(self.class);
        MutexGuard { class: self.class, inner: Some(inner) }
    }

    pub fn try_lock(&self) -> Option<MutexGuard<'_, T>> {
        // no before_blocking_acquire: a try-lock cannot wait, so it
        // cannot be the blocking edge of a deadlock cycle
        let inner = match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => return None,
        };
        push_held(self.class);
        Some(MutexGuard { class: self.class, inner: Some(inner) })
    }

    pub fn get_mut(&mut self) -> &mut T {
        // exclusive access: no lock is taken, nothing to record
        self.inner.get_mut().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

pub struct MutexGuard<'a, T> {
    class: ClassId,
    /// `Some` except transiently inside `Condvar::wait_timeout` (the
    /// inner guard moves through the std condvar) and in `Drop`.
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            Some(g) => g,
            // unreachable by construction: `inner` is only `None` after
            // the guard has been consumed or dropped
            None => unreachable!("dspca_analyze: guard used after release"),
        }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            Some(g) => g,
            None => unreachable!("dspca_analyze: guard used after release"),
        }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            pop_held(self.class);
        }
    }
}

/// Instrumented condvar (analyze mode): the wait releases the guard's
/// class from the held stack for its duration and re-records the
/// reacquisition as a blocking acquire.
#[derive(Default)]
pub struct Condvar(std::sync::Condvar);

impl Condvar {
    pub fn new() -> Self {
        Self(std::sync::Condvar::new())
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let class = guard.class;
        let inner = match guard.inner.take() {
            Some(g) => g,
            None => unreachable!("dspca_analyze: wait on a released guard"),
        };
        pop_held(class); // the lock is released while waiting
        drop(guard); // inner already taken: Drop sees None and pops nothing
        let (back, res) = self.0.wait_timeout(inner, dur).unwrap_or_else(PoisonError::into_inner);
        before_blocking_acquire(class); // reacquisition can block
        push_held(class);
        (MutexGuard { class, inner: Some(back) }, res)
    }
}

#[cfg(test)]
mod tests {
    //! Detector self-tests (ISSUE 7 satellite: guard against false
    //! negatives). Only compiled under `dspca_analyze`, i.e. the
    //! `DSPCA_ANALYZE=1` CI job. Each test uses its own class names —
    //! the registry is process-global and tests run concurrently.

    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn abba_inversion_is_flagged() {
        let a = Mutex::named(0u32, "test.abba.A");
        let b = Mutex::named(0u32, "test.abba.B");
        {
            let _ga = a.lock();
            let _gb = b.lock(); // records A -> B
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock(); // B -> A closes the cycle: must panic
        }));
        let msg = match r {
            Ok(()) => panic!("seeded ABBA inversion was not flagged"),
            Err(e) => match e.downcast::<String>() {
                Ok(s) => *s,
                Err(_) => panic!("detector panicked without a message"),
            },
        };
        assert!(msg.contains("lock-order inversion"), "unexpected message: {msg}");
        assert!(msg.contains("test.abba.A") && msg.contains("test.abba.B"));
        // unwinding dropped the guards: the held stack must be clean
        HELD.with(|h| assert!(h.borrow().is_empty(), "held stack leaked after panic"));
    }

    #[test]
    fn transitive_cycle_is_flagged() {
        // A -> B and B -> C recorded, then C -> A must be rejected even
        // though no direct A/C pair was ever nested before.
        let a = Mutex::named(0u32, "test.chain.A");
        let b = Mutex::named(0u32, "test.chain.B");
        let c = Mutex::named(0u32, "test.chain.C");
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        {
            let _gb = b.lock();
            let _gc = c.lock();
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _gc = c.lock();
            let _ga = a.lock();
        }));
        assert!(r.is_err(), "transitive inversion (A->B->C vs C->A) was not flagged");
    }

    #[test]
    fn try_lock_is_not_a_blocking_edge() {
        // The router's driver-election pattern: thread holds `state` and
        // try_locks `rx`, while the driver holds `rx` and blocks on
        // `state`. Legal — the try_lock side cannot wait.
        let state = Mutex::named(0u32, "test.election.state");
        let rx = Mutex::named(0u32, "test.election.rx");
        {
            let _gs = state.lock();
            let _gr = rx.try_lock().expect("uncontended"); // NO state -> rx edge
        }
        {
            let _gr = rx.lock();
            let _gs = state.lock(); // rx -> state: fine, no cycle
        }
        // and the recorded rx -> state order keeps working
        let _gr = rx.lock();
        let _gs = state.lock();
    }

    #[test]
    fn outgoing_edges_under_a_try_locked_guard_still_count() {
        let a = Mutex::named(0u32, "test.tryout.A");
        let b = Mutex::named(0u32, "test.tryout.B");
        {
            let _ga = a.try_lock().expect("uncontended");
            let _gb = b.lock(); // records A -> B even though A came from try_lock
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock();
        }));
        assert!(r.is_err(), "B -> A after a try-lock-recorded A -> B must still be a cycle");
    }

    #[test]
    fn io_section_rejects_ordinary_lock() {
        let m = Mutex::named(0u32, "test.io.plain");
        let _g = m.lock();
        let r = catch_unwind(AssertUnwindSafe(|| check_io("test.io.site")));
        let msg = match r {
            Ok(()) => panic!("check_io accepted an ordinary lock held across I/O"),
            Err(e) => match e.downcast::<String>() {
                Ok(s) => *s,
                Err(_) => panic!("check_io panicked without a message"),
            },
        };
        assert!(msg.contains("test.io.plain") && msg.contains("test.io.site"));
    }

    #[test]
    fn io_section_accepts_io_ok_lock() {
        let m = Mutex::named_io(0u32, "test.io.sender");
        let _g = m.lock();
        check_io("test.io.site2"); // must not panic
    }

    #[test]
    fn condvar_wait_releases_class_for_its_duration() {
        use std::sync::Arc;
        let pair = Arc::new((Mutex::named(false, "test.cvheld.m"), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            *m.lock() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        while !*g {
            let (back, _) = cv.wait_timeout(g, Duration::from_millis(50));
            g = back;
            // while waiting, the class must NOT appear held; after
            // reacquisition it must appear exactly once
            HELD.with(|held| {
                assert_eq!(
                    held.borrow().iter().filter(|&&c| c == back_class(m)).count(),
                    1,
                    "class held count wrong after condvar reacquire"
                );
            });
        }
        drop(g);
        HELD.with(|held| assert!(held.borrow().is_empty()));
        h.join().expect("signaller panicked");
    }

    fn back_class<T>(m: &Mutex<T>) -> ClassId {
        m.class
    }
}
