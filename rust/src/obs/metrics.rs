//! Statically-registered metrics: counters, gauges, and log2-bucket
//! histograms over plain atomics — no deps, no allocation, no locks.
//!
//! Every metric is a `static` declared once in the [`define_metrics!`]
//! table below; there is no dynamic registration, so a metric cannot
//! appear at runtime that the snapshot (and DESIGN.md §12) does not
//! document. Mutation goes through the crate-root instrumentation
//! macros (`obs_inc!`, `obs_add!`, `obs_gauge!`, `obs_hist!`), which
//! expand to the `obs_raw_*` entry points defined here — `dspca lint`
//! rule `obs-confinement` confines that raw surface to `src/obs/`, so
//! an instrumentation site elsewhere in the tree can only speak
//! through the macros and the counters cannot drift from their
//! documented meanings.
//!
//! Cost model: metrics are **always on** and each event is one relaxed
//! atomic RMW (two for a histogram: bucket + the index math). There is
//! no "enabled" branch to mispredict; `bench_obs` pins the per-event
//! cost. Observation never touches `CommStats` — the bill and the
//! metrics are independent ledgers, which is what lets the trace layer
//! (`obs::trace`) cross-check one against the other.

use std::collections::BTreeMap;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::util::json::Json;

/// Monotonic event counter.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Counter {
        Counter { name, help, value: AtomicU64::new(0) }
    }

    /// Raw mutation entry point — call through `obs_inc!` / `obs_add!`
    /// (lint rule `obs-confinement` keeps this name inside `src/obs/`).
    #[doc(hidden)]
    #[inline]
    pub fn obs_raw_add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// Last-write-wins instantaneous value.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Gauge {
        Gauge { name, help, value: AtomicU64::new(0) }
    }

    /// Raw mutation entry point — call through `obs_gauge!`.
    #[doc(hidden)]
    #[inline]
    pub fn obs_raw_set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// Bucket count for the log2 histograms: bucket 0 holds zeros, bucket
/// `i >= 1` holds values in `[2^(i-1), 2^i)`, and the last bucket
/// absorbs everything at or above `2^(HIST_BUCKETS-2)`.
pub const HIST_BUCKETS: usize = 33;

/// Map a value onto its log2 bucket.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

/// Log2-bucket histogram.
pub struct Hist {
    name: &'static str,
    help: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Hist {
    pub const fn new(name: &'static str, help: &'static str) -> Hist {
        Hist { name, help, buckets: [ATOMIC_ZERO; HIST_BUCKETS] }
    }

    /// Raw mutation entry point — call through `obs_hist!`.
    #[doc(hidden)]
    #[inline]
    pub fn obs_raw_observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn buckets_snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// Increment a registered counter by 1.
#[macro_export]
macro_rules! obs_inc {
    ($m:ident) => {
        $crate::obs::metrics::$m.obs_raw_add(1)
    };
}

/// Increment a registered counter by `n`.
#[macro_export]
macro_rules! obs_add {
    ($m:ident, $n:expr) => {
        $crate::obs::metrics::$m.obs_raw_add($n)
    };
}

/// Set a registered gauge.
#[macro_export]
macro_rules! obs_gauge {
    ($m:ident, $v:expr) => {
        $crate::obs::metrics::$m.obs_raw_set($v)
    };
}

/// Record one observation into a registered log2 histogram.
#[macro_export]
macro_rules! obs_hist {
    ($m:ident, $v:expr) => {
        $crate::obs::metrics::$m.obs_raw_observe($v)
    };
}

/// The one metrics table. Adding a metric means adding a row here —
/// snapshot, text table, JSON, and `dspca stats` all follow from it.
macro_rules! define_metrics {
    (
        counters { $($c:ident => $chelp:expr;)* }
        gauges { $($g:ident => $ghelp:expr;)* }
        hists { $($h:ident => $hhelp:expr;)* }
    ) => {
        $( pub static $c: Counter = Counter::new(stringify!($c), $chelp); )*
        $( pub static $g: Gauge = Gauge::new(stringify!($g), $ghelp); )*
        $( pub static $h: Hist = Hist::new(stringify!($h), $hhelp); )*

        /// Read every registered metric at once (relaxed loads; the
        /// snapshot is per-metric atomic, not globally atomic).
        pub fn snapshot() -> MetricsSnapshot {
            MetricsSnapshot {
                counters: vec![ $( ($c.name(), $c.help(), $c.get()), )* ],
                gauges: vec![ $( ($g.name(), $g.help(), $g.get()), )* ],
                hists: vec![ $( ($h.name(), $h.help(), $h.buckets_snapshot()), )* ],
            }
        }
    };
}

define_metrics! {
    counters {
        CLUSTER_SUBMITS_TOTAL =>
            "collective rounds submitted (solo and fused members)";
        CLUSTER_COMPLETES_TOTAL =>
            "collective tickets completed (replies collected)";
        CLUSTER_REPLIES_TOTAL =>
            "replies routed and billed (open slots and stragglers)";
        CLUSTER_STRAGGLER_REPLIES_TOTAL =>
            "late replies routed via a retired exchange's straggler record";
        CLUSTER_ORPHAN_REPLIES_TOTAL =>
            "replies dropped unattributable (record aged out or unknown seq)";
        BYTES_F64_TOTAL =>
            "billed wire bytes moved under the lossless f64 codec";
        BYTES_F32_TOTAL =>
            "billed wire bytes moved under the f32 codec";
        BYTES_BF16_TOTAL =>
            "billed wire bytes moved under the bf16 codec";
        BYTES_Q8_TOTAL =>
            "billed wire bytes moved under the 8-bit quantized format";
        BYTES_Q4_TOTAL =>
            "billed wire bytes moved under the 4-bit quantized format";
        BYTES_TOPS_TOTAL =>
            "billed wire bytes moved under the top-s sparse format";
        CODEC_WIDENINGS_TOTAL =>
            "adaptive codec transitions q4 -> q8 (residual too large)";
        CODEC_NARROWINGS_TOTAL =>
            "adaptive codec transitions q8 -> q4 (residual comfortably small)";
        FUSION_CARRIERS_TOTAL =>
            "fused carrier rounds put on the wire";
        FUSION_MEMBERS_TOTAL =>
            "member rounds coalesced into carriers";
        FUSION_DISPLACEMENTS_TOTAL =>
            "pending fusion batches displaced by an incompatible submit";
        FUSION_DEADLINE_FLUSHES_TOTAL =>
            "fusion batches flushed by a completer's window deadline";
        TCP_REACTOR_SWEEPS_TOTAL =>
            "reactor poll sweeps over the peer set";
        TCP_REASSEMBLY_STALLS_TOTAL =>
            "reactor sweeps that left a partial frame in a peer buffer";
        TCP_WRITE_RETRIES_TOTAL =>
            "deadline-bounded socket writes parked on WouldBlock";
        TCP_HANDSHAKES_OK_TOTAL =>
            "leader->worker Init handshakes completed";
        TCP_HANDSHAKES_FAILED_TOTAL =>
            "leader->worker connects or handshakes that failed";
        SERVE_REJECTS_INTERACTIVE_TOTAL =>
            "Interactive-class jobs rejected at admission";
        SERVE_REJECTS_STANDARD_TOTAL =>
            "Standard-class jobs rejected at admission";
        SERVE_REJECTS_BATCH_TOTAL =>
            "Batch-class jobs rejected at admission";
        SERVE_RATE_LIMIT_WAITS_TOTAL =>
            "scheduler waits with only rate-limited jobs queued";
        SOLVER_ITERATIONS_TOTAL =>
            "solver iterations across all coordinator runs";
        SOLVER_OVERLAP_HITS_TOTAL =>
            "solver iterations that overlapped QR with an in-flight round";
    }
    gauges {
        TCP_REACTOR_IDLE_US =>
            "current reactor idle-backoff level in microseconds";
        SERVE_QUEUE_DEPTH =>
            "jobs currently admitted and waiting in the serve queue";
        SERVE_VTIME_LAG_X1000 =>
            "weighted-fair virtual-time spread across lanes (x1000)";
        SOLVER_LAST_DRIFT_NANOS =>
            "last observed solver subspace drift (x1e9)";
        CODEC_RESIDUAL_X1000 =>
            "last leader-side error-feedback relative residual norm (x1000)";
        CODEC_COMPRESSION_X1000 =>
            "last submit's billed-vs-f64 frame size ratio (x1000)";
    }
    hists {
        SUBMIT_BYTES =>
            "billed broadcast bytes per submitted round (log2 buckets)";
        REPLY_BYTES =>
            "billed bytes per routed reply (log2 buckets)";
        FUSION_BATCH_COLS =>
            "stacked columns per fused carrier (log2 buckets)";
    }
}

/// Point-in-time copy of every registered metric, renderable as a text
/// table (`dspca stats`) or JSON (`--json`, bench reports).
pub struct MetricsSnapshot {
    pub counters: Vec<(&'static str, &'static str, u64)>,
    pub gauges: Vec<(&'static str, &'static str, u64)>,
    pub hists: Vec<(&'static str, &'static str, Vec<u64>)>,
}

impl MetricsSnapshot {
    /// Human-readable table: one metric per row, histograms as their
    /// non-empty `2^k` buckets.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<36} {:>12}  {}\n", "metric", "value", "meaning"));
        out.push_str(&format!("{}\n", "-".repeat(92)));
        for (name, help, v) in &self.counters {
            out.push_str(&format!("{:<36} {:>12}  {}\n", name.to_ascii_lowercase(), v, help));
        }
        for (name, help, v) in &self.gauges {
            out.push_str(&format!("{:<36} {:>12}  {}\n", name.to_ascii_lowercase(), v, help));
        }
        for (name, help, buckets) in &self.hists {
            let total: u64 = buckets.iter().sum();
            out.push_str(&format!("{:<36} {:>12}  {}\n", name.to_ascii_lowercase(), total, help));
            let cells: Vec<String> = buckets
                .iter()
                .enumerate()
                .filter(|(_, n)| **n > 0)
                .map(|(i, n)| {
                    if i == 0 {
                        format!("0:{n}")
                    } else {
                        format!("<2^{i}:{n}")
                    }
                })
                .collect();
            if !cells.is_empty() {
                out.push_str(&format!("{:<36} {:>12}  [{}]\n", "", "", cells.join(" ")));
            }
        }
        out
    }

    /// Machine-readable form:
    /// `{"counters": {..}, "gauges": {..}, "hists": {name: {"total", "buckets"}}}`.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, _, v) in &self.counters {
            counters.insert(name.to_ascii_lowercase(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, _, v) in &self.gauges {
            gauges.insert(name.to_ascii_lowercase(), Json::Num(*v as f64));
        }
        let mut hists = BTreeMap::new();
        for (name, _, buckets) in &self.hists {
            let mut h = BTreeMap::new();
            h.insert("total".to_string(), Json::Num(buckets.iter().sum::<u64>() as f64));
            h.insert(
                "buckets".to_string(),
                Json::Arr(buckets.iter().map(|b| Json::Num(*b as f64)).collect()),
            );
            hists.insert(name.to_ascii_lowercase(), Json::Obj(h));
        }
        let mut obj = BTreeMap::new();
        obj.insert("counters".to_string(), Json::Obj(counters));
        obj.insert("gauges".to_string(), Json::Obj(gauges));
        obj.insert("hists".to_string(), Json::Obj(hists));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_log2_with_zero_bucket() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        // the last bucket absorbs the tail
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn counter_gauge_hist_roundtrip() {
        static C: Counter = Counter::new("C_TEST", "test counter");
        static G: Gauge = Gauge::new("G_TEST", "test gauge");
        static H: Hist = Hist::new("H_TEST", "test hist");
        C.obs_raw_add(1);
        C.obs_raw_add(2);
        assert_eq!(C.get(), 3);
        G.obs_raw_set(7);
        G.obs_raw_set(4);
        assert_eq!(G.get(), 4);
        H.obs_raw_observe(0);
        H.obs_raw_observe(5);
        H.obs_raw_observe(5);
        assert_eq!(H.total(), 3);
        let b = H.buckets_snapshot();
        assert_eq!(b[0], 1);
        assert_eq!(b[bucket_index(5)], 2);
    }

    #[test]
    fn snapshot_renders_text_and_json() {
        // the registry is process-global and other tests increment it;
        // assert structure, not exact values
        crate::obs_inc!(CLUSTER_SUBMITS_TOTAL);
        crate::obs_hist!(SUBMIT_BYTES, 256);
        let snap = snapshot();
        let text = snap.to_text();
        assert!(text.contains("cluster_submits_total"));
        assert!(text.contains("submit_bytes"));
        let j = snap.to_json();
        let back = Json::parse(&j.to_string()).expect("snapshot json parses");
        assert!(
            back.get("counters")
                .and_then(|c| c.get("cluster_submits_total"))
                .and_then(|v| v.as_f64())
                .is_some_and(|v| v >= 1.0)
        );
        let h = back.get("hists").and_then(|h| h.get("submit_bytes")).expect("hist present");
        assert!(h.get("total").and_then(|t| t.as_f64()).is_some_and(|t| t >= 1.0));
        assert_eq!(
            h.get("buckets").and_then(|b| b.as_arr()).map(|b| b.len()),
            Some(HIST_BUCKETS)
        );
    }

    #[test]
    fn macros_compile_against_the_real_registry() {
        let before = CLUSTER_COMPLETES_TOTAL.get();
        crate::obs_inc!(CLUSTER_COMPLETES_TOTAL);
        crate::obs_add!(CLUSTER_COMPLETES_TOTAL, 2);
        assert!(CLUSTER_COMPLETES_TOTAL.get() >= before + 3);
        crate::obs_gauge!(SERVE_QUEUE_DEPTH, 5);
        crate::obs_hist!(REPLY_BYTES, 64);
    }
}
