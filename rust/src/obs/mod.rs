//! Flight recorder: metrics registry + event tracing + trace reports.
//!
//! Two faces over the same instrumentation points (DESIGN.md §12):
//!
//! - [`metrics`] — always-on counters/gauges/log2 histograms over
//!   relaxed atomics. Mutated only through the crate-root macros
//!   (`obs_inc!`, `obs_add!`, `obs_gauge!`, `obs_hist!`); the raw
//!   `obs_raw_*` surface is confined to this directory by the
//!   `dspca lint` `obs-confinement` rule. Snapshot with
//!   [`metrics::snapshot`], render via `dspca stats` or embed the JSON
//!   into bench reports.
//! - [`trace`] — opt-in JSONL event stream (`DSPCA_TRACE=<path>`,
//!   `--trace`, or [`trace::install_memory`] in tests), one relaxed
//!   atomic load per site when disabled. Byte events are emitted at
//!   the billing sites in `cluster/session.rs`, so the stream mirrors
//!   the `CommStats` ledger event-for-event.
//! - [`report`] — parses the JSONL, prints per-tenant timelines,
//!   enforces Σ traced bytes == bill (`dspca trace-report`), and
//!   exports Chrome trace-event JSON for `chrome://tracing`/Perfetto.
//!
//! Invariant: observation never touches `CommStats` or any decision
//! the system makes — bills and estimates are bit-identical with the
//! recorder on or off (propchecked in `tests/concurrency_stress.rs`).

pub mod metrics;
pub mod report;
pub mod trace;

pub use metrics::MetricsSnapshot;
pub use report::TraceReport;
