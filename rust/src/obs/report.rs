//! Trace post-processing: parse the JSONL emitted by [`crate::obs::trace`],
//! print per-tenant round timelines, cross-check the trace against the
//! billing ledger, and export to Chrome trace-event format.
//!
//! The cross-check is the point: byte events are emitted at the billing
//! sites themselves, so for every session that closed,
//! **Σ traced bytes (submit + fused_submit + reply) == `CommStats.bytes`**
//! and **Σ billed round events == `CommStats.rounds`** — the trace is a
//! second, independently-plumbed copy of the bill, and `dspca
//! trace-report` fails loudly if the two ledgers ever disagree.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One session's view of the trace, paired with its closing bill.
pub struct SessionRow {
    pub sid: u64,
    pub label: String,
    pub traced_bytes: u64,
    pub traced_rounds: u64,
    pub bill_bytes: Option<u64>,
    pub bill_rounds: Option<u64>,
    pub first_us: u64,
    pub last_us: u64,
    pub events: usize,
}

impl SessionRow {
    /// Does the trace agree with the bill? `None` when the session
    /// never closed (no `session_bill` event to compare against).
    pub fn check(&self) -> Option<bool> {
        match (self.bill_bytes, self.bill_rounds) {
            (Some(b), Some(r)) => Some(b == self.traced_bytes && r == self.traced_rounds),
            _ => None,
        }
    }
}

/// Parsed trace: per-session rows plus global counts.
pub struct TraceReport {
    pub total_events: usize,
    pub sessions: Vec<SessionRow>,
    /// Events that carry no `sid` (reactor, scheduler, log lines, ...).
    pub unattributed: usize,
}

fn get_u64(j: &Json, key: &str) -> Option<u64> {
    j.get(key).and_then(|v| v.as_f64()).map(|v| v as u64)
}

/// Parse JSONL trace lines into a report. Fails on a malformed line —
/// the trace doubles as a correctness oracle, so silent skips would
/// defeat it.
pub fn parse_lines<'a>(lines: impl IntoIterator<Item = &'a str>) -> Result<TraceReport> {
    let mut sessions: BTreeMap<u64, SessionRow> = BTreeMap::new();
    let mut total_events = 0usize;
    let mut unattributed = 0usize;
    for (idx, line) in lines.into_iter().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("trace line {} is not valid JSON", idx + 1))?;
        let Some(ev) = j.get("ev").and_then(|v| v.as_str()).map(|s| s.to_string()) else {
            bail!("trace line {} has no \"ev\" field", idx + 1);
        };
        if j.get("ts_us").and_then(|v| v.as_f64()).is_none() {
            bail!("trace line {} has no \"ts_us\" field", idx + 1);
        }
        total_events += 1;
        let Some(sid) = get_u64(&j, "sid") else {
            unattributed += 1;
            continue;
        };
        let ts = get_u64(&j, "ts_us").unwrap_or(0);
        let row = sessions.entry(sid).or_insert_with(|| SessionRow {
            sid,
            label: String::new(),
            traced_bytes: 0,
            traced_rounds: 0,
            bill_bytes: None,
            bill_rounds: None,
            first_us: ts,
            last_us: ts,
            events: 0,
        });
        row.events += 1;
        row.first_us = row.first_us.min(ts);
        row.last_us = row.last_us.max(ts);
        let bytes = get_u64(&j, "bytes").unwrap_or(0);
        match ev.as_str() {
            "submit" | "fused_submit" => {
                row.traced_bytes += bytes;
                if bytes > 0 {
                    row.traced_rounds += 1;
                }
            }
            "reply" => row.traced_bytes += bytes,
            "session_bill" => {
                row.bill_bytes = Some(bytes);
                row.bill_rounds = Some(get_u64(&j, "rounds").unwrap_or(0));
                if let Some(label) = j.get("label").and_then(|v| v.as_str()) {
                    if !label.is_empty() {
                        row.label = label.to_string();
                    }
                }
            }
            _ => {}
        }
    }
    Ok(TraceReport { total_events, sessions: sessions.into_values().collect(), unattributed })
}

/// Parse a trace file written by `DSPCA_TRACE` / `--trace`.
pub fn report_from_file(path: &str) -> Result<TraceReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("cannot read trace file {path}"))?;
    parse_lines(text.lines())
}

impl TraceReport {
    /// Enforce the Σ-traced-bytes == bill identity for every session
    /// that closed. Returns the number of sessions checked.
    pub fn crosscheck(&self) -> Result<usize> {
        let mut checked = 0usize;
        for row in &self.sessions {
            match row.check() {
                Some(true) => checked += 1,
                Some(false) => bail!(
                    "bill-vs-trace mismatch for session {} ({}): traced {} bytes / {} rounds, \
                     billed {} bytes / {} rounds",
                    row.sid,
                    if row.label.is_empty() { "unlabeled" } else { &row.label },
                    row.traced_bytes,
                    row.traced_rounds,
                    row.bill_bytes.unwrap_or(0),
                    row.bill_rounds.unwrap_or(0),
                ),
                None => {}
            }
        }
        Ok(checked)
    }

    /// Per-tenant round timeline plus the cross-check verdict column.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace report: {} events over {} sessions ({} unattributed)\n",
            self.total_events,
            self.sessions.len(),
            self.unattributed
        ));
        out.push_str(&format!(
            "{:>5} {:<14} {:>7} {:>14} {:>12} {:>10} {:>7}  {}\n",
            "sid", "tenant", "rounds", "traced_bytes", "bill_bytes", "span_ms", "events", "check"
        ));
        for row in &self.sessions {
            let verdict = match row.check() {
                Some(true) => "OK",
                Some(false) => "MISMATCH",
                None => "UNCLOSED",
            };
            out.push_str(&format!(
                "{:>5} {:<14} {:>7} {:>14} {:>12} {:>10.2} {:>7}  {}\n",
                row.sid,
                if row.label.is_empty() { "-" } else { &row.label },
                row.traced_rounds,
                row.traced_bytes,
                row.bill_bytes.map_or_else(|| "-".to_string(), |b| b.to_string()),
                (row.last_us.saturating_sub(row.first_us)) as f64 / 1e3,
                row.events,
                verdict
            ));
        }
        let closed = self.sessions.iter().filter(|r| r.check().is_some()).count();
        let ok = self.sessions.iter().filter(|r| r.check() == Some(true)).count();
        out.push_str(&format!(
            "cross-check: {}/{} closed sessions have sigma(traced bytes) == CommStats.bytes\n",
            ok, closed
        ));
        out
    }
}

/// Export trace lines to the Chrome trace-event format
/// (`chrome://tracing` / Perfetto "JSON Object Format"). Each
/// `submit`/`complete` pair for a `(sid, seq)` becomes a complete
/// (`"ph":"X"`) span on that session's track; everything else becomes
/// an instant (`"ph":"i"`).
pub fn chrome_export<'a>(lines: impl IntoIterator<Item = &'a str>) -> Result<Json> {
    let mut events: Vec<Json> = Vec::new();
    // (sid, seq) -> (ts_us, codec, bytes) of the pending submit
    let mut open: BTreeMap<(u64, u64), (u64, String, u64)> = BTreeMap::new();
    let mut instants: Vec<(String, u64, u64)> = Vec::new();
    for (idx, line) in lines.into_iter().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("trace line {} is not valid JSON", idx + 1))?;
        let ev = j.get("ev").and_then(|v| v.as_str()).unwrap_or("event").to_string();
        let ts = get_u64(&j, "ts_us").unwrap_or(0);
        let sid = get_u64(&j, "sid").unwrap_or(0);
        let seq = get_u64(&j, "seq");
        match (ev.as_str(), seq) {
            ("submit" | "fused_submit", Some(seq)) => {
                let codec =
                    j.get("codec").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let bytes = get_u64(&j, "bytes").unwrap_or(0);
                open.insert((sid, seq), (ts, codec, bytes));
            }
            ("complete", Some(seq)) => match open.remove(&(sid, seq)) {
                Some((t_submit, codec, bytes)) => {
                    let mut args = BTreeMap::new();
                    args.insert("seq".to_string(), Json::Num(seq as f64));
                    args.insert("codec".to_string(), Json::Str(codec));
                    args.insert("bytes".to_string(), Json::Num(bytes as f64));
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str("round".to_string()));
                    o.insert("ph".to_string(), Json::Str("X".to_string()));
                    o.insert("ts".to_string(), Json::Num(t_submit as f64));
                    o.insert(
                        "dur".to_string(),
                        Json::Num(ts.saturating_sub(t_submit) as f64),
                    );
                    o.insert("pid".to_string(), Json::Num(1.0));
                    o.insert("tid".to_string(), Json::Num(sid as f64));
                    o.insert("args".to_string(), Json::Obj(args));
                    events.push(Json::Obj(o));
                }
                None => instants.push((ev, ts, sid)),
            },
            _ => instants.push((ev, ts, sid)),
        }
    }
    // unpaired submits (still in flight at trace end) also become instants
    for ((sid, _), (ts, _, _)) in open {
        instants.push(("submit".to_string(), ts, sid));
    }
    for (name, ts, sid) in instants {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name));
        o.insert("ph".to_string(), Json::Str("i".to_string()));
        o.insert("ts".to_string(), Json::Num(ts as f64));
        o.insert("pid".to_string(), Json::Num(1.0));
        o.insert("tid".to_string(), Json::Num(sid as f64));
        o.insert("s".to_string(), Json::Str("t".to_string()));
        events.push(Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    let out = Json::Obj(top);
    validate_chrome(&out)?;
    Ok(out)
}

/// In-tree schema check for the Chrome trace-event export: the shape
/// `chrome://tracing` / Perfetto actually requires to load the file.
pub fn validate_chrome(j: &Json) -> Result<()> {
    let Some(events) = j.get("traceEvents").and_then(|e| e.as_arr()) else {
        bail!("chrome export: top-level \"traceEvents\" array missing");
    };
    for (i, ev) in events.iter().enumerate() {
        let ctx = |field: &str| format!("chrome export: event {i} missing/invalid \"{field}\"");
        if ev.get("name").and_then(|v| v.as_str()).is_none() {
            bail!(ctx("name"));
        }
        for num_field in ["ts", "pid", "tid"] {
            if ev.get(num_field).and_then(|v| v.as_f64()).is_none() {
                bail!(ctx(num_field));
            }
        }
        match ev.get("ph").and_then(|v| v.as_str()) {
            Some("X") => {
                if !ev.get("dur").and_then(|v| v.as_f64()).is_some_and(|d| d >= 0.0) {
                    bail!(ctx("dur"));
                }
            }
            Some("i") => {
                if ev.get("s").and_then(|v| v.as_str()).is_none() {
                    bail!(ctx("s"));
                }
            }
            Some("M") => {}
            _ => bail!(ctx("ph")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(fields: &str) -> String {
        format!("{{{fields}}}")
    }

    #[test]
    fn crosscheck_passes_on_consistent_trace() {
        let lines = [
            ev(r#""ev": "submit", "ts_us": 10, "tid": 0, "sid": 1, "seq": 5, "codec": "f64", "bytes": 100"#),
            ev(r#""ev": "reply", "ts_us": 20, "tid": 0, "sid": 1, "seq": 5, "codec": "f64", "bytes": 60"#),
            ev(r#""ev": "complete", "ts_us": 25, "tid": 0, "sid": 1, "seq": 5"#),
            ev(r#""ev": "session_bill", "ts_us": 30, "tid": 0, "sid": 1, "label": "tenant0", "bytes": 160, "rounds": 1"#),
        ];
        let rep = parse_lines(lines.iter().map(|s| s.as_str())).expect("parses");
        assert_eq!(rep.total_events, 4);
        assert_eq!(rep.sessions.len(), 1);
        assert_eq!(rep.crosscheck().expect("crosscheck"), 1);
        let row = &rep.sessions[0];
        assert_eq!(row.label, "tenant0");
        assert_eq!(row.traced_bytes, 160);
        assert_eq!(row.traced_rounds, 1);
        assert!(rep.render().contains("OK"));
    }

    #[test]
    fn crosscheck_fails_on_byte_mismatch() {
        let lines = [
            ev(r#""ev": "submit", "ts_us": 10, "tid": 0, "sid": 2, "seq": 1, "codec": "f32", "bytes": 50"#),
            ev(r#""ev": "session_bill", "ts_us": 30, "tid": 0, "sid": 2, "bytes": 999, "rounds": 1"#),
        ];
        let rep = parse_lines(lines.iter().map(|s| s.as_str())).expect("parses");
        let err = rep.crosscheck().expect_err("mismatch must fail");
        assert!(err.to_string().contains("mismatch"));
        assert!(rep.render().contains("MISMATCH"));
    }

    #[test]
    fn unclosed_sessions_are_reported_not_failed() {
        let lines =
            [ev(r#""ev": "submit", "ts_us": 1, "tid": 0, "sid": 3, "seq": 1, "bytes": 10"#)];
        let rep = parse_lines(lines.iter().map(|s| s.as_str())).expect("parses");
        assert_eq!(rep.crosscheck().expect("no closed sessions to fail"), 0);
        assert!(rep.render().contains("UNCLOSED"));
    }

    #[test]
    fn malformed_lines_fail_parse() {
        assert!(parse_lines(["not json"]).is_err());
        assert!(parse_lines([r#"{"ts_us": 1}"#]).is_err());
        assert!(parse_lines([r#"{"ev": "x"}"#]).is_err());
    }

    #[test]
    fn chrome_export_pairs_spans_and_validates() {
        let lines = [
            ev(r#""ev": "submit", "ts_us": 10, "tid": 0, "sid": 1, "seq": 5, "codec": "f64", "bytes": 100"#),
            ev(r#""ev": "complete", "ts_us": 35, "tid": 0, "sid": 1, "seq": 5"#),
            ev(r#""ev": "log", "ts_us": 40, "tid": 0, "level": "warn", "msg": "hi""#),
        ];
        let j = chrome_export(lines.iter().map(|s| s.as_str())).expect("export");
        validate_chrome(&j).expect("schema-valid");
        let evs = j.get("traceEvents").and_then(|e| e.as_arr()).expect("events");
        assert_eq!(evs.len(), 2);
        let span = evs
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .expect("one span");
        assert_eq!(span.get("dur").and_then(|d| d.as_f64()), Some(25.0));
        // round-trips through the serializer
        let text = j.to_string();
        let back = Json::parse(&text).expect("reparse");
        validate_chrome(&back).expect("still valid");
    }

    #[test]
    fn chrome_validator_rejects_bad_shapes() {
        let bad = Json::parse(r#"{"traceEvents": [{"name": "x", "ph": "X", "ts": 1, "pid": 1, "tid": 1}]}"#)
            .expect("parse");
        assert!(validate_chrome(&bad).is_err(), "X without dur must fail");
        let bad2 = Json::parse(r#"{"notTraceEvents": []}"#).expect("parse");
        assert!(validate_chrome(&bad2).is_err());
    }
}
