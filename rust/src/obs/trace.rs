//! Event tracing: the second face of the flight recorder.
//!
//! When a sink is installed (`DSPCA_TRACE=<path>`, `--trace`, or
//! [`install_memory`] in tests) the instrumentation points emit
//! timestamped JSONL events — one JSON object per line — into
//! per-thread buffers that flush to the shared sink in batches.
//! When no sink is installed the entire layer is **one relaxed atomic
//! load** per event site (`enabled()`), so tracing costs nothing in
//! normal runs; `bench_obs` pins that disabled-path cost.
//!
//! Event schema (every event):
//!   `{"ts_us": u64, "tid": u64, "ev": str, ...fields}`
//! where `ts_us` is microseconds since the first sink install and
//! `tid` is a small per-thread ordinal. Collective events additionally
//! carry `sid` (session id), `seq`, `codec`, and `bytes` — the byte
//! events are emitted **at the billing sites themselves** (all in
//! `cluster/session.rs`), which is what makes Σ traced bytes per
//! session a faithful mirror of that session's `CommStats` bill
//! (checked by `obs::report` and `dspca trace-report`).
//!
//! Lock discipline: the shared sink sits behind
//! `Mutex::named(.., "obs.sink")`, a **leaf** in the DESIGN.md §11
//! hierarchy — it is only ever taken with no other obs lock held, and
//! only on buffer flush (every [`FLUSH_AT`] events or at thread exit),
//! never per event. Observation never touches `CommStats`: the trace
//! is bill-invariant by construction.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Mutex;
use crate::util::json::Json;

/// Buffered events per thread before a sink flush.
pub const FLUSH_AT: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Bumped on every install; stale thread buffers from a previous sink
/// generation are discarded instead of leaking into the new sink.
static EPOCH: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

/// Fast-path gate: one relaxed load. Instrumentation sites check this
/// (via the `obs_trace!` macro) before building any event.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

enum SinkDest {
    File(std::io::BufWriter<std::fs::File>),
    Memory(Vec<String>),
}

struct SinkState {
    dest: Option<SinkDest>,
    epoch: u64,
}

fn sink() -> &'static Mutex<SinkState> {
    static SINK: OnceLock<Mutex<SinkState>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::named(SinkState { dest: None, epoch: 0 }, "obs.sink"))
}

fn t0() -> &'static Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now)
}

struct ThreadBuf {
    lines: Vec<String>,
    epoch: u64,
    tid: u64,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.lines.is_empty() {
            return;
        }
        let mut st = sink().lock();
        if st.epoch == self.epoch {
            match st.dest.as_mut() {
                Some(SinkDest::File(w)) => {
                    for line in &self.lines {
                        // a failed trace write must never fail the run;
                        // drop the line and keep going
                        let _ = writeln!(w, "{line}");
                    }
                }
                Some(SinkDest::Memory(lines)) => lines.append(&mut self.lines),
                None => {}
            }
        }
        self.lines.clear();
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        lines: Vec::new(),
        epoch: 0,
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
    });
}

fn install(dest: SinkDest) {
    // stamp t0 before enabling so ts_us is monotone from install
    let _ = t0();
    let epoch = EPOCH.fetch_add(1, Ordering::Relaxed) + 1;
    {
        let mut st = sink().lock();
        st.dest = Some(dest);
        st.epoch = epoch;
    }
    ENABLED.store(true, Ordering::Relaxed);
}

/// Start tracing to a JSONL file (truncates any existing file).
pub fn install_file(path: &str) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("obs: cannot create trace file {path}"))?;
    install(SinkDest::File(std::io::BufWriter::new(file)));
    Ok(())
}

/// Start tracing into an in-memory line buffer (tests and benches —
/// no filesystem, no env vars).
pub fn install_memory() {
    install(SinkDest::Memory(Vec::new()));
}

/// Stop tracing: disable the gate, flush the calling thread's buffer
/// and the sink, and return the captured lines for a memory sink.
/// Threads that emitted events must have exited or gone quiet before
/// this call for their tails to be included (their buffers flush on
/// thread exit).
pub fn finish() -> Result<Option<Vec<String>>> {
    ENABLED.store(false, Ordering::Relaxed);
    flush_current_thread();
    let taken = {
        let mut st = sink().lock();
        st.dest.take()
    };
    match taken {
        Some(SinkDest::File(mut w)) => {
            w.flush().context("obs: flushing trace file")?;
            Ok(None)
        }
        Some(SinkDest::Memory(lines)) => Ok(Some(lines)),
        None => Ok(None),
    }
}

/// Push the calling thread's buffered events down to the sink now.
pub fn flush_current_thread() {
    BUF.with(|b| b.borrow_mut().flush());
}

/// A trace event field value.
pub enum Val {
    U(u64),
    F(f64),
    S(String),
}

impl From<u64> for Val {
    fn from(v: u64) -> Val {
        Val::U(v)
    }
}
impl From<u32> for Val {
    fn from(v: u32) -> Val {
        Val::U(v as u64)
    }
}
impl From<usize> for Val {
    fn from(v: usize) -> Val {
        Val::U(v as u64)
    }
}
impl From<f64> for Val {
    fn from(v: f64) -> Val {
        Val::F(v)
    }
}
impl From<&str> for Val {
    fn from(v: &str) -> Val {
        Val::S(v.to_string())
    }
}
impl From<String> for Val {
    fn from(v: String) -> Val {
        Val::S(v)
    }
}

/// Builder for one trace event. Construct through `obs_trace!` so the
/// `enabled()` gate is checked before any allocation happens.
pub struct Ev {
    obj: BTreeMap<String, Json>,
}

impl Ev {
    pub fn new(name: &'static str) -> Ev {
        let mut obj = BTreeMap::new();
        obj.insert("ev".to_string(), Json::Str(name.to_string()));
        obj.insert("ts_us".to_string(), Json::Num(t0().elapsed().as_micros() as f64));
        obj
            .insert("tid".to_string(), Json::Num(BUF.with(|b| b.borrow().tid) as f64));
        Ev { obj }
    }

    pub fn field(mut self, key: &'static str, v: Val) -> Ev {
        let j = match v {
            Val::U(u) => Json::Num(u as f64),
            Val::F(f) => Json::Num(f),
            Val::S(s) => Json::Str(s),
        };
        self.obj.insert(key.to_string(), j);
        self
    }

    /// Serialize into the calling thread's buffer; flush the batch to
    /// the sink when it reaches [`FLUSH_AT`].
    pub fn emit(self) {
        let line = Json::Obj(self.obj).to_string();
        let epoch = EPOCH.load(Ordering::Relaxed);
        BUF.with(|b| {
            let mut buf = b.borrow_mut();
            if buf.epoch != epoch {
                // previous sink generation: drop stale tail, re-tag
                buf.lines.clear();
                buf.epoch = epoch;
            }
            buf.lines.push(line);
            if buf.lines.len() >= FLUSH_AT {
                buf.flush();
            }
        });
    }
}

/// Emit one trace event iff a sink is installed. The `enabled()` check
/// happens before any field expression is evaluated or allocated, so a
/// disabled site costs one relaxed atomic load.
#[macro_export]
macro_rules! obs_trace {
    ($name:expr $(, $k:ident = $v:expr)* $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::Ev::new($name)
                $(.field(stringify!($k), $crate::obs::trace::Val::from($v)))*
                .emit();
        }
    };
}

/// Route one logger line into the timeline (satellite of ISSUE 9):
/// called by `util::logger` when tracing is active. Flushes
/// immediately — log lines are rare and must not sit in a buffer while
/// a crash is being diagnosed.
pub fn emit_log(level: &str, msg: &str) {
    if !enabled() {
        return;
    }
    Ev::new("log")
        .field("level", Val::from(level))
        .field("msg", Val::from(msg))
        .emit();
    flush_current_thread();
}

#[cfg(test)]
mod tests {
    use super::*;

    // trace state is process-global; serialize the tests that install sinks
    fn guard() -> crate::sync::MutexGuard<'static, ()> {
        static G: OnceLock<Mutex<()>> = OnceLock::new();
        G.get_or_init(|| Mutex::named((), "obs.test")).lock()
    }

    #[test]
    fn disabled_gate_emits_nothing() {
        let _g = guard();
        assert!(!enabled());
        crate::obs_trace!("never", x = 1u64);
        install_memory();
        let lines = finish().expect("finish").expect("memory sink");
        assert!(lines.iter().all(|l| !l.contains("\"never\"")));
    }

    #[test]
    fn events_roundtrip_through_memory_sink() {
        let _g = guard();
        install_memory();
        crate::obs_trace!("unit_ev", sid = 7u64, codec = "f32", drift = 0.5f64);
        flush_current_thread();
        let lines = finish().expect("finish").expect("memory sink");
        let ours: Vec<&String> =
            lines.iter().filter(|l| l.contains("\"unit_ev\"")).collect();
        assert_eq!(ours.len(), 1);
        let j = Json::parse(ours[0]).expect("event line parses");
        assert_eq!(j.get("ev").and_then(|v| v.as_str()), Some("unit_ev"));
        assert_eq!(j.get("sid").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("codec").and_then(|v| v.as_str()), Some("f32"));
        assert_eq!(j.get("drift").and_then(|v| v.as_f64()), Some(0.5));
        assert!(j.get("ts_us").and_then(|v| v.as_f64()).is_some());
        assert!(j.get("tid").and_then(|v| v.as_f64()).is_some());
        assert!(!enabled());
    }

    #[test]
    fn emit_log_lands_in_timeline_only_when_enabled() {
        let _g = guard();
        emit_log("warn", "dropped before install");
        install_memory();
        emit_log("warn", "hello from the logger");
        let lines = finish().expect("finish").expect("memory sink");
        let logs: Vec<&String> = lines.iter().filter(|l| l.contains("\"log\"")).collect();
        assert_eq!(logs.len(), 1);
        assert!(logs[0].contains("hello from the logger"));
        assert!(!logs[0].contains("dropped before install"));
    }

    #[test]
    fn buffer_flushes_at_batch_boundary() {
        let _g = guard();
        install_memory();
        for i in 0..(FLUSH_AT + 3) {
            crate::obs_trace!("batch_ev", i = i);
        }
        let lines = finish().expect("finish").expect("memory sink");
        let n = lines.iter().filter(|l| l.contains("\"batch_ev\"")).count();
        assert_eq!(n, FLUSH_AT + 3);
    }
}
