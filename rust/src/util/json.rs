//! Minimal JSON parser + writer.
//!
//! Only what the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and the experiment reports need: objects,
//! arrays, strings, numbers, booleans, null. No serde available offline.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or("truncated utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"entries":[{"d":300,"file":"cov_matvec_400x300.hlo.txt","n":400}],"version":1}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        let j2 = Json::parse(&printed).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }
}
