//! ASCII log-log plotter for terminal output of the figure experiments.
//!
//! `examples/figure1` prints its series with this (in addition to the CSV
//! files), so the paper's Figure 1 shape is visible straight from the
//! terminal.

use std::fmt::Write as _;

/// One named series of `(x, y)` points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
    pub glyph: char,
}

impl Series {
    pub fn new(name: &str, glyph: char) -> Self {
        Series { name: name.to_string(), points: Vec::new(), glyph }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render series on a log-log grid of `width x height` characters.
/// Non-positive values are dropped (log scale).
pub fn loglog(series: &[Series], width: usize, height: usize, title: &str) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter())
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .cloned()
        .collect();
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    if pts.is_empty() {
        let _ = writeln!(out, "(no positive data)");
        return out;
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &pts {
        xmin = xmin.min(x.ln());
        xmax = xmax.max(x.ln());
        ymin = ymin.min(y.ln());
        ymax = ymax.max(y.ln());
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for (x, y) in &s.points {
            if *x <= 0.0 || *y <= 0.0 {
                continue;
            }
            let cx = ((x.ln() - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y.ln() - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let r = height - 1 - cy;
            grid[r][cx] = s.glyph;
        }
    }
    let _ = writeln!(out, "y: {:.2e} .. {:.2e} (log)", ymin.exp(), ymax.exp());
    for row in grid {
        let _ = writeln!(out, "|{}|", row.into_iter().collect::<String>());
    }
    let _ = writeln!(out, "x: {:.2e} .. {:.2e} (log)", xmin.exp(), xmax.exp());
    for s in series {
        let _ = writeln!(out, "  {} = {}", s.glyph, s.name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_without_panic() {
        let mut s = Series::new("test", '*');
        for i in 1..=5 {
            s.push(i as f64 * 10.0, 1.0 / i as f64);
        }
        let text = loglog(&[s], 40, 10, "demo");
        assert!(text.contains("demo"));
        assert!(text.contains('*'));
    }

    #[test]
    fn empty_series_handled() {
        let text = loglog(&[Series::new("e", 'x')], 10, 4, "empty");
        assert!(text.contains("no positive data"));
    }

    #[test]
    fn drops_nonpositive_points() {
        let mut s = Series::new("mixed", 'o');
        s.push(-1.0, 2.0);
        s.push(10.0, 1.0);
        s.push(20.0, 0.0);
        let text = loglog(&[s], 20, 5, "m");
        assert!(text.contains('o'));
    }
}
