//! Summary statistics for experiment and bench reporting.

/// Summary of a sample of `f64` observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    /// Standard error of the mean.
    pub sem: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std,
            sem: std / (n as f64).sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: quantile_sorted(&sorted, 0.5),
            p95: quantile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated quantile of a **sorted** sample, `q` in `[0,1]`.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Ordinary least squares fit of `log y = a + s * log x`; returns the
/// slope `s`. Used to verify scaling laws (e.g. the Thm 5 `n^{-2}` bias
/// term or the Thm 6 `n^{-1/4}` round count).
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let sorted = [0.0, 1.0];
        assert!((quantile_sorted(&sorted, 0.5) - 0.5).abs() < 1e-15);
        assert_eq!(quantile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 1.0);
    }

    #[test]
    fn loglog_slope_recovers_power_law() {
        let xs = [10.0, 100.0, 1000.0];
        let ys: Vec<f64> = xs.iter().map(|x: &f64| 5.0 * x.powf(-2.0)).collect();
        assert!((loglog_slope(&xs, &ys) + 2.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_slope_noisy_power_law() {
        let xs: Vec<f64> = (1..=8).map(|i| (1 << i) as f64).collect();
        let ys: Vec<f64> =
            xs.iter().enumerate().map(|(i, x)| x.powf(-0.5) * (1.0 + 0.02 * (i as f64).sin())).collect();
        let s = loglog_slope(&xs, &ys);
        assert!((s + 0.5).abs() < 0.05, "slope={s}");
    }

    #[test]
    fn median_even_length() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 10.0]);
        assert!((s.median - 2.5).abs() < 1e-15);
    }
}
