//! Small infrastructure substrates: JSON (the offline image has no
//! serde), CSV reports, summary statistics, ASCII plotting and a tiny
//! env-driven logger.

pub mod csv;
pub mod json;
pub mod logger;
pub mod plot;
pub mod stats;
