//! Env-driven logger: `DSPCA_LOG=debug|info|warn|off` (default `info`).
//! The offline image has no `log`/`env_logger` facade wiring worth
//! pulling in; this covers what the launcher and experiments need.
//!
//! Unknown `DSPCA_LOG` values fall back to `info`, but loudly: a
//! one-time stderr warning names the accepted values, so a typo like
//! `DSPCA_LOG=trace` is visible instead of silently ignored. When the
//! trace sink is active ([`crate::obs::trace`]), every emitted line is
//! mirrored as a `"log"` event so operator messages land on the same
//! timeline as the rounds they annotate.

use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: OnceLock<Level> = OnceLock::new();
static START: OnceLock<Instant> = OnceLock::new();

/// Parse a `DSPCA_LOG` value. `Err(())` means "not an accepted value".
fn parse_level(s: &str) -> Result<Level, ()> {
    match s {
        "off" => Ok(Level::Off),
        "warn" => Ok(Level::Warn),
        "info" => Ok(Level::Info),
        "debug" => Ok(Level::Debug),
        _ => Err(()),
    }
}

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("DSPCA_LOG").as_deref() {
        Ok(raw) => parse_level(raw).unwrap_or_else(|()| {
            // once: LEVEL is a OnceLock, so this init closure runs at
            // most one time per process
            eprintln!(
                "[dspca] unknown DSPCA_LOG value {raw:?}; falling back to \"info\" \
                 (accepted: off, warn, info, debug)"
            );
            Level::Info
        }),
        Err(_) => Level::Info,
    })
}

pub fn log(lvl: Level, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() && level() != Level::Off {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let tag = tag(lvl);
        eprintln!("[{t:9.3}s {tag}] {msg}");
        if crate::obs::trace::enabled() {
            crate::obs::trace::emit_log(tag.trim_end(), &msg.to_string());
        }
    }
}

fn tag(lvl: Level) -> &'static str {
    match lvl {
        Level::Off => "off",
        Level::Warn => "WARN",
        Level::Info => "info",
        Level::Debug => "dbg ",
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Off < Level::Warn);
    }

    #[test]
    fn parse_level_accepts_documented_values_only() {
        assert_eq!(parse_level("off"), Ok(Level::Off));
        assert_eq!(parse_level("warn"), Ok(Level::Warn));
        assert_eq!(parse_level("info"), Ok(Level::Info));
        assert_eq!(parse_level("debug"), Ok(Level::Debug));
        assert_eq!(parse_level("trace"), Err(()));
        assert_eq!(parse_level("INFO"), Err(()));
        assert_eq!(parse_level(""), Err(()));
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::info!("macro {}", 1);
        crate::debug!("macro {}", 2);
        crate::warn!("macro {}", 3);
    }
}
