//! Env-driven logger: `DSPCA_LOG=debug|info|warn|off` (default `info`).
//! The offline image has no `log`/`env_logger` facade wiring worth
//! pulling in; this covers what the launcher and experiments need.

use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: OnceLock<Level> = OnceLock::new();
static START: OnceLock<Instant> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("DSPCA_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() && level() != Level::Off {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {}] {msg}", tag(lvl));
    }
}

fn tag(lvl: Level) -> &'static str {
    match lvl {
        Level::Off => "off",
        Level::Warn => "WARN",
        Level::Info => "info",
        Level::Debug => "dbg ",
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Off < Level::Warn);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::info!("macro {}", 1);
        crate::debug!("macro {}", 2);
        crate::warn!("macro {}", 3);
    }
}
