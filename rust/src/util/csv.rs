//! Tiny CSV writer for experiment outputs (`results/*.csv`). The figures
//! in `EXPERIMENTS.md` are regenerated from these files.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row of already-formatted cells. Panics on arity mismatch.
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(cells);
    }

    /// Append a row of numbers (formatted with full precision).
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_row(cells.iter().map(|v| format!("{v:.12e}")).collect());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a CSV string (quoting cells containing commas/quotes).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let esc = |cell: &str| -> String {
            if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write to disk, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = CsvTable::new(&["n", "err"]);
        t.push_row(vec!["100".into(), "0.5".into()]);
        let s = t.render();
        assert_eq!(s, "n,err\n100,0.5\n");
    }

    #[test]
    fn quoting() {
        let mut t = CsvTable::new(&["a"]);
        t.push_row(vec!["x,y\"z".into()]);
        assert_eq!(t.render(), "a\n\"x,y\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push_row(vec!["1".into()]);
    }

    #[test]
    fn push_nums_formats() {
        let mut t = CsvTable::new(&["x", "y"]);
        t.push_nums(&[1.0, 0.25]);
        let s = t.render();
        assert!(s.contains("1.000000000000e0"));
        assert!(s.contains("2.500000000000e-1"));
    }
}
