//! Wire layer: the codec every leader<->worker payload passes through.
//!
//! The paper's whole contribution is measured in communication cost, so
//! the bytes column of [`CommStats`] must be *real*: instead of each
//! collective hand-computing `8 * d * ...`, every tenant session owns a
//! [`WireCodec`] and bills every message from the size of the frame the
//! codec actually encodes ([`Frame::wire_bytes`]). The default codec is
//! lossless f64 — encode/decode is a bit-exact roundtrip, so all
//! accounting and numerics match the original `8·d` model verbatim —
//! while the lossy codecs ([`WirePrecision::F32`], [`WirePrecision::Bf16`])
//! both shrink the frames *and* degrade the payload exactly the way a
//! real quantized wire would (cf. the quantized-communication line of
//! work the paper's §1 contrasts with its round model).
//!
//! [`CommStats`]: super::CommStats
//!
//! Since ISSUE 4 this module also defines the **whole-message frame
//! format** the byte-shipping transports use ([`encode_request`] /
//! [`decode_request`], [`encode_response`] / [`decode_response`]):
//! envelope fields (kind, sequence number, precision, variant tag,
//! shapes, hyperparameters) as little-endian integers, f64 payloads as
//! the materialized codec output, the whole body length-prefixed on the
//! wire by the transport. Only the codec-encoded *payload* section is
//! billed (`B(w)` in the accounting table); the envelope rides free,
//! consistent with the paper's cost model counting `R^d` vector
//! traffic. Decoding is fully defensive: truncated, length-mismatched,
//! or malformed frames return an error, never a panic.
//!
//! Format notes:
//!
//! - `F64`: 8 bytes/entry, little-endian IEEE-754 binary64. Bit-exact.
//! - `F32`: 4 bytes/entry; each entry rounds to the nearest binary32
//!   (relative error <= 2^-24).
//! - `Bf16`: 2 bytes/entry, true bfloat16 — 1 sign + 8 exponent + 7
//!   explicit mantissa bits. Conversion goes f64 → f32 (RNE) → bf16
//!   (RNE), the same double-rounding composition real hardware without a
//!   direct f64→bf16 path performs, so the relative error is at most
//!   half an ulp plus the f32 term: `2^-8 + 2^-24`, within the 4e-3
//!   bound the tests assert. (The pre-wire-layer code masked the f64
//!   mantissa to 8 explicit bits, a 20-bit format it billed at 2 bytes;
//!   the codec makes the 2 bytes honest.)

use anyhow::{bail, ensure, Context, Result};

use super::message::{Request, Response};

/// Per-entry precision of every f64 that crosses the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WirePrecision {
    /// Full f64 (the baseline model of the paper). Lossless.
    F64,
    /// Round every entry to the nearest f32.
    F32,
    /// True bfloat16: 8-bit exponent, 7 explicit mantissa bits,
    /// round-to-nearest-even via f32 — relative error <= 2^-8 + 2^-24.
    Bf16,
}

impl WirePrecision {
    /// Bytes per f64 payload word on the wire.
    pub fn bytes_per_entry(&self) -> usize {
        match self {
            WirePrecision::F64 => 8,
            WirePrecision::F32 => 4,
            WirePrecision::Bf16 => 2,
        }
    }

    /// Short label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            WirePrecision::F64 => "f64",
            WirePrecision::F32 => "f32",
            WirePrecision::Bf16 => "bf16",
        }
    }

    /// Apply the precision loss to a vector in place — implemented *as*
    /// the encode→decode roundtrip of the matching codec, so quantized
    /// values and shipped values cannot diverge.
    pub fn quantize(&self, v: &mut [f64]) {
        WireCodec::new(*self).transcode(v);
    }
}

/// f64 -> bfloat16 bits: round to nearest f32 first (exact for every
/// value a bf16 can represent), then round-to-nearest-even on the 16
/// mantissa bits bf16 drops. The two rounding steps can land one bf16
/// ulp-tie differently than a single direct rounding would (classic
/// double rounding, bounded by an extra 2^-24 relative) — kept
/// deliberately, as it matches hardware f64→f32→bf16 conversion chains.
/// Overflow saturates to the signed infinity, NaN stays NaN (quietened,
/// payload kept non-zero).
fn f64_to_bf16(x: f64) -> u16 {
    let f = x as f32;
    let bits = f.to_bits();
    if f.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    ((bits + 0x7FFF + lsb) >> 16) as u16
}

/// bfloat16 bits -> f64 (exact: every bf16 value is an f32, every f32 is
/// an f64).
fn bf16_to_f64(b: u16) -> f64 {
    f32::from_bits((b as u32) << 16) as f64
}

/// An encoded payload: the bytes that would cross a real network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    precision: WirePrecision,
    entries: usize,
    bytes: Vec<u8>,
}

impl Frame {
    /// Precision the frame was encoded with.
    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Number of f64 payload words the frame carries.
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Payload size in bytes — what [`CommStats::bytes`] bills.
    ///
    /// [`CommStats::bytes`]: super::CommStats::bytes
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Raw encoded bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Encoder/decoder for wire payloads. Each tenant
/// [`Session`](super::Session) owns one (default: lossless) and passes
/// every request/response payload it ships through it; `CommStats.bytes`
/// is the sum of the encoded frames' sizes, never per-collective
/// `8 * d` arithmetic. Per-session ownership means a lossy tenant
/// cannot degrade a concurrent lossless tenant's traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireCodec {
    precision: WirePrecision,
}

impl Default for WireCodec {
    fn default() -> Self {
        Self::lossless()
    }
}

impl WireCodec {
    pub fn new(precision: WirePrecision) -> Self {
        WireCodec { precision }
    }

    /// The default codec: full f64, bit-exact roundtrip.
    pub fn lossless() -> Self {
        Self::new(WirePrecision::F64)
    }

    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Size in bytes of the frame [`WireCodec::encode`] would produce
    /// for a payload of `words` f64 words. Frames are fixed-width, so
    /// this is exact; the equivalence with `encode` is pinned by the
    /// codec tests and the propcheck byte property.
    pub fn frame_bytes(&self, words: usize) -> usize {
        words * self.precision.bytes_per_entry()
    }

    /// Encode a payload into the bytes that would cross the wire.
    pub fn encode(&self, payload: &[f64]) -> Frame {
        let bpe = self.precision.bytes_per_entry();
        let mut bytes = Vec::with_capacity(payload.len() * bpe);
        match self.precision {
            WirePrecision::F64 => {
                for x in payload {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            WirePrecision::F32 => {
                for x in payload {
                    bytes.extend_from_slice(&(*x as f32).to_le_bytes());
                }
            }
            WirePrecision::Bf16 => {
                for x in payload {
                    bytes.extend_from_slice(&f64_to_bf16(*x).to_le_bytes());
                }
            }
        }
        Frame { precision: self.precision, entries: payload.len(), bytes }
    }

    /// Decode a frame back into f64 words. Panics on a precision
    /// mismatch — a frame is only meaningful to the codec that wrote it.
    pub fn decode(&self, frame: &Frame) -> Vec<f64> {
        assert_eq!(
            frame.precision, self.precision,
            "codec/frame precision mismatch: frame is {:?}, codec is {:?}",
            frame.precision, self.precision
        );
        decode_raw(self.precision, &frame.bytes)
    }

    /// Pass a payload through encode→decode in place — exactly what
    /// shipping the frame does to the numbers — and return the frame's
    /// size in bytes. This is the cluster's per-message billing
    /// primitive: for lossy codecs the byte count comes from the
    /// materialized frame itself, so billed bytes and shipped bytes
    /// cannot diverge. The lossless F64 codec skips materialization
    /// (the roundtrip is bit-exact and the frame size is `8·len`;
    /// both facts are pinned by `f64_codec_roundtrips_bit_exactly` and
    /// the propcheck byte property, which use [`WireCodec::encode`]
    /// directly) so the default path stays allocation-free.
    pub fn transcode(&self, payload: &mut [f64]) -> usize {
        if self.precision == WirePrecision::F64 {
            return self.frame_bytes(payload.len());
        }
        let frame = self.encode(payload);
        let decoded = self.decode(&frame);
        payload.copy_from_slice(&decoded);
        frame.wire_bytes()
    }
}

/// Decode raw fixed-width payload bytes at the given precision. The
/// slice length must be a multiple of the precision's entry width
/// (callers validate it; a ragged tail would be silently dropped by
/// `chunks_exact`, so every call site checks first).
fn decode_raw(prec: WirePrecision, raw: &[u8]) -> Vec<f64> {
    match prec {
        WirePrecision::F64 => {
            raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
        }
        WirePrecision::F32 => raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
            .collect(),
        WirePrecision::Bf16 => raw
            .chunks_exact(2)
            .map(|c| bf16_to_f64(u16::from_le_bytes(c.try_into().unwrap())))
            .collect(),
    }
}

// ---------------------------------------------------------------------
// Whole-message frames (ISSUE 4): the byte representation the TCP
// transport ships. Body layout (the transport adds a u32 length
// prefix):
//
//   u8 kind (request / response) | u64 seq | u8 precision | u8 tag |
//   variant fields...
//
// Counts and shapes are u64 LE; hyperparameters are raw f64 bits
// (lossless — they are envelope, not payload); strings are u32 length +
// UTF-8; f64 payload sections are `u64 word count` + the codec-encoded
// bytes (`words * bytes_per_entry` of them). The payload section is the
// only billed part of the frame.
// ---------------------------------------------------------------------

const MSG_REQUEST: u8 = 0xA1;
const MSG_RESPONSE: u8 = 0xA2;

const REQ_COV_MATVEC: u8 = 1;
const REQ_COV_MATMAT: u8 = 2;
const REQ_LOCAL_TOP_EIGVEC: u8 = 3;
const REQ_GRAM: u8 = 4;
const REQ_LOCAL_TOP_K: u8 = 5;
const REQ_OJA_PASS: u8 = 6;
const REQ_SHUTDOWN: u8 = 7;

const RESP_VECTOR: u8 = 1;
const RESP_MAT: u8 = 2;
const RESP_ERR: u8 = 3;

fn prec_tag(p: WirePrecision) -> u8 {
    match p {
        WirePrecision::F64 => 0,
        WirePrecision::F32 => 1,
        WirePrecision::Bf16 => 2,
    }
}

fn prec_from_tag(t: u8) -> Result<WirePrecision> {
    match t {
        0 => Ok(WirePrecision::F64),
        1 => Ok(WirePrecision::F32),
        2 => Ok(WirePrecision::Bf16),
        other => bail!("unknown wire precision tag {other}"),
    }
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_payload(out: &mut Vec<u8>, codec: WireCodec, payload: &[f64]) {
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(codec.encode(payload).bytes());
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked reader over a frame body. Every accessor returns an
/// error on underrun — a truncated or corrupt frame can never panic the
/// decoder — and [`Cursor::finish`] rejects trailing bytes, so a frame
/// whose length prefix disagrees with its content is an error too.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "truncated frame: need {n} bytes at offset {}, only {} left",
                    self.pos,
                    self.buf.len().saturating_sub(self.pos)
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("count does not fit this platform's usize")
    }

    /// A payload section: `u64` word count + codec-encoded bytes at
    /// `prec`. The byte count is validated *before* any allocation.
    pub(crate) fn payload(&mut self, prec: WirePrecision) -> Result<Vec<f64>> {
        let words = self.usize()?;
        let nbytes = words
            .checked_mul(prec.bytes_per_entry())
            .ok_or_else(|| anyhow::anyhow!("payload word count {words} overflows"))?;
        let raw = self.take(nbytes)?;
        Ok(decode_raw(prec, raw))
    }

    pub(crate) fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).context("invalid UTF-8 in frame string")
    }

    pub(crate) fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "length mismatch: {} trailing bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Encode a whole request as a frame body: the byte representation the
/// TCP transport ships (payload section encoded through `codec`).
pub fn encode_request(seq: u64, codec: WireCodec, req: &Request) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(48 + req.payload().map_or(0, |p| codec.frame_bytes(p.len())));
    out.push(MSG_REQUEST);
    put_u64(&mut out, seq);
    out.push(prec_tag(codec.precision()));
    match req {
        Request::CovMatVec(v) => {
            out.push(REQ_COV_MATVEC);
            put_payload(&mut out, codec, v);
        }
        Request::CovMatMat { rows, cols, data } => {
            out.push(REQ_COV_MATMAT);
            put_u64(&mut out, *rows as u64);
            put_u64(&mut out, *cols as u64);
            put_payload(&mut out, codec, data);
        }
        Request::LocalTopEigvec { unbiased_signs } => {
            out.push(REQ_LOCAL_TOP_EIGVEC);
            out.push(u8::from(*unbiased_signs));
        }
        Request::Gram => out.push(REQ_GRAM),
        Request::LocalTopK { k } => {
            out.push(REQ_LOCAL_TOP_K);
            put_u64(&mut out, *k as u64);
        }
        Request::OjaPass { w, eta0, t0, t_start } => {
            out.push(REQ_OJA_PASS);
            put_u64(&mut out, eta0.to_bits());
            put_u64(&mut out, t0.to_bits());
            put_u64(&mut out, *t_start);
            put_payload(&mut out, codec, w);
        }
        Request::Shutdown => out.push(REQ_SHUTDOWN),
    }
    out
}

/// Decode a request frame body. Returns the sequence number, the
/// precision its payload shipped under (workers echo it on the reply),
/// and the reconstructed request. Truncated, trailing-byte,
/// shape-mismatched, or unknown-tag frames are errors — never panics.
pub fn decode_request(body: &[u8]) -> Result<(u64, WirePrecision, Request)> {
    let mut c = Cursor::new(body);
    let kind = c.u8()?;
    ensure!(kind == MSG_REQUEST, "not a request frame (kind 0x{kind:02x})");
    let seq = c.u64()?;
    let prec = prec_from_tag(c.u8()?)?;
    let req = match c.u8()? {
        REQ_COV_MATVEC => Request::CovMatVec(c.payload(prec)?),
        REQ_COV_MATMAT => {
            let rows = c.usize()?;
            let cols = c.usize()?;
            let data = c.payload(prec)?;
            ensure!(
                rows.checked_mul(cols) == Some(data.len()),
                "cov_matmat frame: payload of {} words != {rows}x{cols}",
                data.len()
            );
            Request::CovMatMat { rows, cols, data }
        }
        REQ_LOCAL_TOP_EIGVEC => {
            let b = c.u8()?;
            ensure!(b <= 1, "bad bool byte {b} in frame");
            Request::LocalTopEigvec { unbiased_signs: b == 1 }
        }
        REQ_GRAM => Request::Gram,
        REQ_LOCAL_TOP_K => Request::LocalTopK { k: c.usize()? },
        REQ_OJA_PASS => {
            let eta0 = f64::from_bits(c.u64()?);
            let t0 = f64::from_bits(c.u64()?);
            let t_start = c.u64()?;
            let w = c.payload(prec)?;
            Request::OjaPass { w, eta0, t0, t_start }
        }
        REQ_SHUTDOWN => Request::Shutdown,
        other => bail!("unknown request tag {other}"),
    };
    c.finish()?;
    Ok((seq, prec, req))
}

/// Encode a whole response as a frame body (payload section encoded
/// through `codec` — workers reply at the precision the request frame
/// carried, so the leader's decode/transcode is value-preserving).
pub fn encode_response(seq: u64, codec: WireCodec, resp: &Response) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(48 + resp.payload().map_or(0, |p| codec.frame_bytes(p.len())));
    out.push(MSG_RESPONSE);
    put_u64(&mut out, seq);
    out.push(prec_tag(codec.precision()));
    match resp {
        Response::Vector(v) => {
            out.push(RESP_VECTOR);
            put_payload(&mut out, codec, v);
        }
        Response::Mat { rows, cols, data } => {
            out.push(RESP_MAT);
            put_u64(&mut out, *rows as u64);
            put_u64(&mut out, *cols as u64);
            put_payload(&mut out, codec, data);
        }
        Response::Err(msg) => {
            out.push(RESP_ERR);
            put_string(&mut out, msg);
        }
    }
    out
}

/// Decode a response frame body (counterpart of [`encode_response`];
/// same defensive guarantees as [`decode_request`]).
pub fn decode_response(body: &[u8]) -> Result<(u64, WirePrecision, Response)> {
    let mut c = Cursor::new(body);
    let kind = c.u8()?;
    ensure!(kind == MSG_RESPONSE, "not a response frame (kind 0x{kind:02x})");
    let seq = c.u64()?;
    let prec = prec_from_tag(c.u8()?)?;
    let resp = match c.u8()? {
        RESP_VECTOR => Response::Vector(c.payload(prec)?),
        RESP_MAT => {
            let rows = c.usize()?;
            let cols = c.usize()?;
            let data = c.payload(prec)?;
            ensure!(
                rows.checked_mul(cols) == Some(data.len()),
                "mat frame: payload of {} words != {rows}x{cols}",
                data.len()
            );
            Response::Mat { rows, cols, data }
        }
        RESP_ERR => Response::Err(c.string()?),
        other => bail!("unknown response tag {other}"),
    };
    c.finish()?;
    Ok((seq, prec, resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_payload() -> Vec<f64> {
        vec![
            1.0,
            -0.3333333333333333,
            1e-8,
            12345.6789,
            -0.0,
            f64::MIN_POSITIVE, // subnormal territory after f32 cast -> 0
            3.5e38,
            -1.25,
        ]
    }

    #[test]
    fn f64_codec_roundtrips_bit_exactly() {
        let codec = WireCodec::lossless();
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 8 * v.len());
        assert_eq!(frame.entries(), v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 codec must be bit-exact");
        }
    }

    #[test]
    fn f32_codec_matches_f32_cast() {
        let codec = WireCodec::new(WirePrecision::F32);
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 4 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(*b, *a as f32 as f64);
        }
    }

    #[test]
    fn bf16_codec_error_is_at_most_half_ulp_plus_f32_term() {
        let codec = WireCodec::new(WirePrecision::Bf16);
        let mut rng = crate::rng::Pcg64::new(0xbf16);
        let v: Vec<f64> = (0..256).map(|_| rng.next_gaussian() * 10.0).collect();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 2 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            // 7 explicit mantissa bits + RNE: relative error <= 2^-8 +
            // 2^-24 (the f32 double-rounding term) < 4e-3
            assert!((a - b).abs() <= 4e-3 * a.abs().max(1e-30), "{a} vs {b}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2^-8 sits exactly halfway between bf16(1.0) and the next
        // value up; ties go to the even mantissa, i.e. down to 1.0
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 1.0 / 256.0)), 1.0);
        // 1 + 3*2^-8 is halfway with an odd lower neighbor; ties go up
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 3.0 / 256.0)), 1.0 + 4.0 / 256.0);
        // exactly representable values pass through
        for x in [0.0, -0.0, 1.0, -2.5, 0.15625, 2.0f64.powi(127)] {
            assert_eq!(bf16_to_f64(f64_to_bf16(x)), x, "{x} is bf16-representable");
        }
    }

    #[test]
    fn bf16_handles_nonfinite_and_overflow() {
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::INFINITY)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::NEG_INFINITY)), f64::NEG_INFINITY);
        assert!(bf16_to_f64(f64_to_bf16(f64::NAN)).is_nan());
        // beyond f32/bf16 range saturates to infinity rather than garbage
        assert_eq!(bf16_to_f64(f64_to_bf16(1e300)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(-1e300)), f64::NEG_INFINITY);
    }

    #[test]
    fn quantize_is_the_encode_decode_roundtrip() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            let mut quantized = sample_payload();
            prec.quantize(&mut quantized);
            let shipped = codec.decode(&codec.encode(&sample_payload()));
            assert_eq!(quantized, shipped, "{prec:?}: quantize != ship");
        }
    }

    #[test]
    fn transcode_returns_frame_size_and_applies_roundtrip() {
        for (prec, bpe) in
            [(WirePrecision::F64, 8), (WirePrecision::F32, 4), (WirePrecision::Bf16, 2)]
        {
            let codec = WireCodec::new(prec);
            let mut v = sample_payload();
            let bytes = codec.transcode(&mut v);
            assert_eq!(bytes, bpe * v.len());
            let mut want = sample_payload();
            prec.quantize(&mut want);
            assert_eq!(v, want);
        }
    }

    #[test]
    #[should_panic(expected = "precision mismatch")]
    fn decode_rejects_foreign_frames() {
        let frame = WireCodec::new(WirePrecision::F32).encode(&[1.0, 2.0]);
        let _ = WireCodec::lossless().decode(&frame);
    }

    #[test]
    fn frame_bytes_matches_encode() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            for words in [0usize, 1, 7, 64] {
                let payload = vec![0.25; words];
                assert_eq!(codec.frame_bytes(words), codec.encode(&payload).wire_bytes());
            }
        }
    }

    #[test]
    fn default_codec_is_lossless() {
        assert_eq!(WireCodec::default(), WireCodec::lossless());
        assert_eq!(WireCodec::default().precision(), WirePrecision::F64);
        assert_eq!(WirePrecision::F64.bytes_per_entry(), 8);
        assert_eq!(WirePrecision::F32.label(), "f32");
    }

    // -- whole-message frames ------------------------------------------

    fn all_requests(prec: WirePrecision) -> Vec<Request> {
        // payloads pre-quantized to the codec grid so the roundtrip is
        // bit-exact under every precision
        let q = |mut v: Vec<f64>| {
            prec.quantize(&mut v);
            v
        };
        vec![
            Request::CovMatVec(q(sample_payload())),
            Request::CovMatMat { rows: 4, cols: 2, data: q(sample_payload()) },
            Request::LocalTopEigvec { unbiased_signs: true },
            Request::LocalTopEigvec { unbiased_signs: false },
            Request::Gram,
            Request::LocalTopK { k: 3 },
            Request::OjaPass { w: q(sample_payload()), eta0: 0.37, t0: 10.0, t_start: 42 },
            Request::Shutdown,
        ]
    }

    fn all_responses(prec: WirePrecision) -> Vec<Response> {
        let q = |mut v: Vec<f64>| {
            prec.quantize(&mut v);
            v
        };
        vec![
            Response::Vector(q(sample_payload())),
            Response::Mat { rows: 2, cols: 4, data: q(sample_payload()) },
            Response::Err("worker 3 failed: bad rank 99 for d=8".to_string()),
        ]
    }

    #[test]
    fn every_request_variant_roundtrips_under_every_precision() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            for (i, req) in all_requests(prec).iter().enumerate() {
                let body = encode_request(1000 + i as u64, codec, req);
                let (seq, p, back) = decode_request(&body).unwrap();
                assert_eq!(seq, 1000 + i as u64);
                assert_eq!(p, prec);
                assert_eq!(&back, req, "{prec:?} request {i} changed across the wire");
            }
        }
    }

    #[test]
    fn every_response_variant_roundtrips_under_every_precision() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            for (i, resp) in all_responses(prec).iter().enumerate() {
                let body = encode_response(7 + i as u64, codec, resp);
                let (seq, p, back) = decode_response(&body).unwrap();
                assert_eq!(seq, 7 + i as u64);
                assert_eq!(p, prec);
                assert_eq!(&back, resp, "{prec:?} response {i} changed across the wire");
            }
        }
    }

    #[test]
    fn decode_rejects_truncated_and_length_mismatched_frames() {
        let codec = WireCodec::lossless();
        let body = encode_request(9, codec, &Request::CovMatVec(sample_payload()));
        // every strict prefix errors out instead of panicking
        for cut in 0..body.len() {
            assert!(decode_request(&body[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // trailing garbage is a length mismatch, not a silent accept
        let mut longer = body.clone();
        longer.push(0);
        let err = decode_request(&longer).unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "{err}");
        // same on the response side
        let rbody = encode_response(9, codec, &Response::Vector(sample_payload()));
        for cut in 0..rbody.len() {
            assert!(decode_response(&rbody[..cut]).is_err());
        }
    }

    #[test]
    fn decode_rejects_wrong_kind_bad_tags_and_shape_mismatches() {
        let codec = WireCodec::lossless();
        let req = encode_request(1, codec, &Request::Gram);
        let resp = encode_response(1, codec, &Response::Err("x".into()));
        assert!(decode_response(&req).is_err(), "request frame is not a response");
        assert!(decode_request(&resp).is_err(), "response frame is not a request");
        // unknown variant tag
        let mut bad = req.clone();
        let tag_at = bad.len() - 1; // Gram body: kind|seq|prec|tag
        bad[tag_at] = 99;
        assert!(decode_request(&bad).unwrap_err().to_string().contains("unknown request tag"));
        // a CovMatMat whose declared shape disagrees with its payload
        let mismatched = encode_request(
            2,
            codec,
            &Request::CovMatMat { rows: 3, cols: 3, data: vec![0.5; 5] },
        );
        let err = decode_request(&mismatched).unwrap_err().to_string();
        assert!(err.contains("!= 3x3"), "{err}");
        // and a bad precision tag
        let mut badprec = encode_request(3, codec, &Request::Gram);
        badprec[9] = 7; // kind (1) + seq (8) -> precision byte
        assert!(decode_request(&badprec)
            .unwrap_err()
            .to_string()
            .contains("unknown wire precision"));
    }

    #[test]
    fn frame_payload_section_is_exactly_the_codec_frame() {
        // the billed bytes and the shipped bytes are the same bytes:
        // the payload section of a message frame is the codec's encoded
        // frame, verbatim
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            let payload = sample_payload();
            let frame = codec.encode(&payload);
            let body = encode_request(5, codec, &Request::CovMatVec(payload.clone()));
            let tail = &body[body.len() - frame.wire_bytes()..];
            assert_eq!(tail, frame.bytes(), "{prec:?}: payload section != codec frame");
        }
    }
}
