//! Wire layer: the codec every leader<->worker payload passes through.
//!
//! The paper's whole contribution is measured in communication cost, so
//! the bytes column of [`CommStats`] must be *real*: instead of each
//! collective hand-computing `8 * d * ...`, every tenant session owns a
//! [`WireCodec`] and bills every message from the size of the frame the
//! codec actually encodes ([`Frame::wire_bytes`]). The default codec is
//! lossless f64 — encode/decode is a bit-exact roundtrip, so all
//! accounting and numerics match the original `8·d` model verbatim —
//! while the lossy codecs both shrink the frames *and* degrade the
//! payload exactly the way a real quantized wire would (cf. the
//! quantized-communication line of work the paper's §1 contrasts with
//! its round model).
//!
//! [`CommStats`]: super::CommStats
//!
//! Since ISSUE 10 the codec family is **stateful**. A codec is described
//! by [`WireCodec`] — a [`CodecKind`] plus two orthogonal switches:
//!
//! - `feedback`: an **error-feedback accumulator** per stream. The
//!   quantization residual of round `t` is added to the payload of
//!   round `t+1` on the same (session, direction) stream, so the
//!   *time-averaged* signal the receiver integrates is unbiased even
//!   under 4-bit quantization (the EF-SGD argument of the distributed
//!   PCA compression literature). Streams are keyed per direction:
//!   the leader keeps one outbound accumulator per session (the
//!   broadcast payload is identical for every peer, so one stream per
//!   session *is* one stream per (session, peer)); each worker keeps
//!   its own reply accumulator per session id ([`ReplyBank`]) — no
//!   handshake ships state, both sides evolve theirs from the frames
//!   they already see.
//! - `adaptive`: a per-session controller that widens/narrows the
//!   quantizer between Q4 and Q8 from the measured relative residual
//!   norm ([`CodecState::adapt`]); the width a round actually shipped
//!   under is resolved at submit time into a concrete [`WireFormat`],
//!   stamped into the message envelope, echoed on replies, and billed.
//!
//! Because a round's bytes depend on the resolved format, billing is a
//! pure function [`WireFormat::frame_bytes`] of (format, payload words,
//! payload columns) — deterministic from shape, hence identical across
//! backends and concurrency schedules.
//!
//! Format notes (per payload of `w` f64 words in `c` columns):
//!
//! - `F64`: 8 bytes/entry, little-endian IEEE-754 binary64. Bit-exact.
//! - `F32`: 4 bytes/entry; each entry rounds to the nearest binary32
//!   (relative error <= 2^-24).
//! - `Bf16`: 2 bytes/entry, true bfloat16 — 1 sign + 8 exponent + 7
//!   explicit mantissa bits, f64 → f32 (RNE) → bf16 (RNE) double
//!   rounding like real hardware; relative error <= 2^-8 + 2^-24.
//! - `Q8`: uniform 8-bit, scale-per-column: one f32 scale per column
//!   (`maxabs/127`, f32-rounded) + one signed byte level per word.
//!   `4c + w` bytes.
//! - `Q4`: as Q8 with levels in −7..7, two nibble-packed levels per
//!   byte. `4c + ceil(w/2)` bytes.
//! - `TopS{s}`: keep the `s' = min(s, w)` largest-magnitude words; one
//!   u32 count + one f32 scale over the kept values + `s'` u32 indices
//!   + `s'` levels at the active bit width. `8 + 4s' + s'` (Q8) or
//!   `8 + 4s' + ceil(s'/2)` (Q4) bytes. Dropped mass enters the
//!   feedback accumulator like quantization error.
//!
//! The quantizers are **re-encode idempotent**: the scale is stored and
//! *applied* as the f32 it ships as, so quantized values re-encode to
//! exactly themselves. That is what lets the TCP transport encode the
//! leader-pre-quantized payload without a second loss, keeping in-proc
//! and TCP runs value- and bill-identical.
//!
//! Since ISSUE 4 this module also defines the **whole-message frame
//! format** the byte-shipping transports use ([`encode_request`] /
//! [`decode_request`], [`encode_response`] / [`decode_response`]):
//! envelope fields (kind, sequence number, wire format, feedback flag +
//! session id on requests, variant tag, shapes, hyperparameters) as
//! little-endian integers, f64 payloads as the materialized codec
//! output, the whole body length-prefixed on the wire by the transport.
//! Only the codec-encoded *payload* section is billed (`B(w)` in the
//! accounting table); the envelope rides free, consistent with the
//! paper's cost model counting `R^d` vector traffic. Decoding is fully
//! defensive: truncated, length-mismatched, or malformed frames return
//! an error, never a panic.

use anyhow::{bail, ensure, Context, Result};

use super::message::{Request, Response};

/// Per-entry precision of the fixed-width (stateless) wire formats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WirePrecision {
    /// Full f64 (the baseline model of the paper). Lossless.
    F64,
    /// Round every entry to the nearest f32.
    F32,
    /// True bfloat16: 8-bit exponent, 7 explicit mantissa bits,
    /// round-to-nearest-even via f32 — relative error <= 2^-8 + 2^-24.
    Bf16,
}

impl WirePrecision {
    /// Bytes per f64 payload word on the wire.
    pub fn bytes_per_entry(&self) -> usize {
        match self {
            WirePrecision::F64 => 8,
            WirePrecision::F32 => 4,
            WirePrecision::Bf16 => 2,
        }
    }

    /// Short label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            WirePrecision::F64 => "f64",
            WirePrecision::F32 => "f32",
            WirePrecision::Bf16 => "bf16",
        }
    }

    /// Apply the precision loss to a vector in place — implemented *as*
    /// the encode→decode roundtrip of the matching codec, so quantized
    /// values and shipped values cannot diverge.
    pub fn quantize(&self, v: &mut [f64]) {
        WireCodec::new(*self).transcode(v);
    }
}

/// Bit width of the low-bit uniform quantizers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantBits {
    /// Signed 8-bit levels in −127..127, 1 byte/word.
    Q8,
    /// Signed 4-bit levels in −7..7, nibble-packed, 1 byte/2 words.
    Q4,
}

impl QuantBits {
    /// Largest level magnitude the width can represent.
    pub fn qmax(&self) -> f64 {
        match self {
            QuantBits::Q8 => 127.0,
            QuantBits::Q4 => 7.0,
        }
    }

    /// Short label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            QuantBits::Q8 => "q8",
            QuantBits::Q4 => "q4",
        }
    }

    /// Bytes the packed levels of `n` words occupy.
    fn level_bytes(&self, n: usize) -> usize {
        match self {
            QuantBits::Q8 => n,
            QuantBits::Q4 => (n + 1) / 2,
        }
    }
}

/// What family a [`WireCodec`] belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecKind {
    /// One of the fixed-width per-entry formats (f64 / f32 / bf16).
    Stateless(WirePrecision),
    /// Low-bit uniform quantizer, scale-per-column.
    Quant(QuantBits),
    /// Top-`s` coordinate sparsification; kept values quantized at
    /// `bits`.
    TopS { s: u32, bits: QuantBits },
}

/// The concrete format one round's payload ships under. For a
/// non-adaptive codec this is determined by the codec alone; for an
/// adaptive codec it is resolved per round from the controller state
/// and stamped into the envelope (and the bill) so replies, stragglers
/// and traces all see the width that actually shipped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Fixed-width per-entry encoding.
    Plain(WirePrecision),
    /// Uniform quantizer at the given width.
    Quant(QuantBits),
    /// Top-`s` sparse frame with kept values at `bits`.
    TopS { s: u32, bits: QuantBits },
}

impl WireFormat {
    /// Billed payload bytes for `words` f64 words in `cols` row-major
    /// columns. A pure function of shape — this is the cluster's billing
    /// primitive, identical on every backend; equivalence with the
    /// materialized [`WireFormat::encode`] frame is pinned by
    /// `frame_bytes_matches_encode_for_every_format`.
    pub fn frame_bytes(&self, words: usize, cols: usize) -> usize {
        match self {
            WireFormat::Plain(p) => words * p.bytes_per_entry(),
            WireFormat::Quant(b) => 4 * cols.max(1) + b.level_bytes(words),
            WireFormat::TopS { s, bits } => {
                let kept = (*s as usize).min(words);
                8 + 4 * kept + bits.level_bytes(kept)
            }
        }
    }

    /// Short label for CSV columns, traces and the obs byte counters.
    pub fn label(&self) -> String {
        match self {
            WireFormat::Plain(p) => p.label().to_string(),
            WireFormat::Quant(b) => b.label().to_string(),
            WireFormat::TopS { s, bits } => format!("top{s}-{}", bits.label()),
        }
    }

    /// Apply the format's loss to a payload in place — identical to the
    /// encode→decode roundtrip (pinned by the roundtrip tests) without
    /// materializing the frame. `cols` is the row-major column count
    /// scale-per-column quantizers key on (1 for vectors).
    pub fn quantize(&self, payload: &mut [f64], cols: usize) {
        match self {
            WireFormat::Plain(WirePrecision::F64) => {}
            WireFormat::Plain(WirePrecision::F32) => {
                for x in payload.iter_mut() {
                    *x = *x as f32 as f64;
                }
            }
            WireFormat::Plain(WirePrecision::Bf16) => {
                for x in payload.iter_mut() {
                    *x = bf16_to_f64(f64_to_bf16(*x));
                }
            }
            WireFormat::Quant(bits) => {
                let scales = col_scales(payload, cols, bits.qmax());
                for (i, x) in payload.iter_mut().enumerate() {
                    let s = scales[i % cols.max(1)];
                    *x = dequant(level_of(*x, s, bits.qmax()), s);
                }
            }
            WireFormat::TopS { s, bits } => {
                let (kept, scale) = top_s_plan(payload, *s as usize, bits.qmax());
                let mut out = vec![0.0; payload.len()];
                for &i in &kept {
                    out[i] = dequant(level_of(payload[i], scale, bits.qmax()), scale);
                }
                payload.copy_from_slice(&out);
            }
        }
    }

    /// Encode a payload into the bytes that would cross the wire.
    pub fn encode(&self, payload: &[f64], cols: usize) -> Frame {
        let cols = cols.max(1);
        assert!(
            payload.is_empty() || payload.len() % cols == 0,
            "payload of {} words is not {cols} row-major columns",
            payload.len()
        );
        let mut bytes = Vec::with_capacity(self.frame_bytes(payload.len(), cols));
        match self {
            WireFormat::Plain(WirePrecision::F64) => {
                for x in payload {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            WireFormat::Plain(WirePrecision::F32) => {
                for x in payload {
                    bytes.extend_from_slice(&(*x as f32).to_le_bytes());
                }
            }
            WireFormat::Plain(WirePrecision::Bf16) => {
                for x in payload {
                    bytes.extend_from_slice(&f64_to_bf16(*x).to_le_bytes());
                }
            }
            WireFormat::Quant(bits) => {
                let scales = col_scales(payload, cols, bits.qmax());
                for s in &scales {
                    bytes.extend_from_slice(&s.to_bits().to_le_bytes());
                }
                let levels: Vec<i8> = payload
                    .iter()
                    .enumerate()
                    .map(|(i, x)| level_of(*x, scales[i % cols], bits.qmax()))
                    .collect();
                bytes.extend_from_slice(&pack_levels(*bits, &levels));
            }
            WireFormat::TopS { s, bits } => {
                let (kept, scale) = top_s_plan(payload, *s as usize, bits.qmax());
                bytes.extend_from_slice(&(kept.len() as u32).to_le_bytes());
                bytes.extend_from_slice(&scale.to_bits().to_le_bytes());
                for &i in &kept {
                    bytes.extend_from_slice(&(i as u32).to_le_bytes());
                }
                let levels: Vec<i8> =
                    kept.iter().map(|&i| level_of(payload[i], scale, bits.qmax())).collect();
                bytes.extend_from_slice(&pack_levels(*bits, &levels));
            }
        }
        Frame { format: *self, entries: payload.len(), cols, bytes }
    }

    /// Decode a frame back into f64 words (counterpart of `encode`).
    pub fn decode(&self, frame: &Frame) -> Vec<f64> {
        assert_eq!(
            frame.format, *self,
            "codec/frame format mismatch: frame is {:?}, codec is {:?}",
            frame.format, self
        );
        match self {
            WireFormat::Plain(p) => decode_raw(*p, &frame.bytes),
            WireFormat::Quant(bits) => {
                let cols = frame.cols;
                let mut scales = Vec::with_capacity(cols);
                for c in 0..cols {
                    let mut a = [0u8; 4];
                    a.copy_from_slice(&frame.bytes[4 * c..4 * c + 4]);
                    scales.push(f32::from_bits(u32::from_le_bytes(a)));
                }
                let levels = unpack_levels(*bits, &frame.bytes[4 * cols..], frame.entries);
                levels.iter().enumerate().map(|(i, &l)| dequant(l, scales[i % cols])).collect()
            }
            WireFormat::TopS { bits, .. } => {
                let mut a = [0u8; 4];
                a.copy_from_slice(&frame.bytes[0..4]);
                let kept = u32::from_le_bytes(a) as usize;
                a.copy_from_slice(&frame.bytes[4..8]);
                let scale = f32::from_bits(u32::from_le_bytes(a));
                let mut out = vec![0.0; frame.entries];
                let levels = unpack_levels(*bits, &frame.bytes[8 + 4 * kept..], kept);
                for (j, &l) in levels.iter().enumerate() {
                    a.copy_from_slice(&frame.bytes[8 + 4 * j..12 + 4 * j]);
                    let i = u32::from_le_bytes(a) as usize;
                    out[i] = dequant(l, scale);
                }
                out
            }
        }
    }
}

/// f64 -> bfloat16 bits: round to nearest f32 first (exact for every
/// value a bf16 can represent), then round-to-nearest-even on the 16
/// mantissa bits bf16 drops. The two rounding steps can land one bf16
/// ulp-tie differently than a single direct rounding would (classic
/// double rounding, bounded by an extra 2^-24 relative) — kept
/// deliberately, as it matches hardware f64→f32→bf16 conversion chains.
/// Overflow saturates to the signed infinity, NaN stays NaN (quietened,
/// payload kept non-zero).
fn f64_to_bf16(x: f64) -> u16 {
    let f = x as f32;
    let bits = f.to_bits();
    if f.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    ((bits + 0x7FFF + lsb) >> 16) as u16
}

/// bfloat16 bits -> f64 (exact: every bf16 value is an f32, every f32 is
/// an f64).
fn bf16_to_f64(b: u16) -> f64 {
    f32::from_bits((b as u32) << 16) as f64
}

/// Per-column scale `maxabs/qmax`, **f32-rounded** — the rounding is
/// applied before any level is computed, so re-encoding the quantized
/// values reproduces the same scale and the same levels (the idempotency
/// the byte-shipping transport relies on).
fn col_scales(payload: &[f64], cols: usize, qmax: f64) -> Vec<f32> {
    let cols = cols.max(1);
    let mut maxabs = vec![0.0f64; cols];
    for (i, x) in payload.iter().enumerate() {
        let a = x.abs();
        if a > maxabs[i % cols] {
            maxabs[i % cols] = a;
        }
    }
    maxabs.iter().map(|m| (m / qmax) as f32).collect()
}

/// Signed level of `x` at scale `s`, clamped to ±qmax. A zero (or
/// non-finite) scale maps everything to level 0; NaN inputs also map
/// to 0 (the `as i8` saturating cast), so the decoder never sees a
/// level it cannot invert.
fn level_of(x: f64, s: f32, qmax: f64) -> i8 {
    if s == 0.0 || !s.is_finite() {
        return 0;
    }
    (x / s as f64).round().clamp(-qmax, qmax) as i8
}

/// Invert a level. Level 0 is exactly 0.0 regardless of scale, so a
/// degenerate (zero/overflowed) scale cannot manufacture NaNs.
fn dequant(l: i8, s: f32) -> f64 {
    if l == 0 {
        0.0
    } else {
        l as f64 * s as f64
    }
}

/// The top-`s` plan for a payload: kept indices (largest magnitude
/// first ranked, returned sorted ascending for a canonical frame) and
/// the shared f32 scale over the kept values. Ties break by lower
/// index, so the plan is deterministic.
fn top_s_plan(payload: &[f64], s: usize, qmax: f64) -> (Vec<usize>, f32) {
    let kept_n = s.min(payload.len());
    let mut idx: Vec<usize> = (0..payload.len()).collect();
    idx.sort_by(|&a, &b| payload[b].abs().total_cmp(&payload[a].abs()).then(a.cmp(&b)));
    let mut kept: Vec<usize> = idx[..kept_n].to_vec();
    let maxabs = kept.first().map_or(0.0, |&i| payload[i].abs());
    kept.sort_unstable();
    (kept, (maxabs / qmax) as f32)
}

fn pack_levels(bits: QuantBits, levels: &[i8]) -> Vec<u8> {
    match bits {
        QuantBits::Q8 => levels.iter().map(|&l| l as u8).collect(),
        QuantBits::Q4 => {
            // two levels per byte, each stored biased by +7 (−7..7 → 0..14)
            let mut out = Vec::with_capacity((levels.len() + 1) / 2);
            for pair in levels.chunks(2) {
                let lo = (pair[0] + 7) as u8;
                let hi = if pair.len() == 2 { (pair[1] + 7) as u8 } else { 0 };
                out.push(lo | (hi << 4));
            }
            out
        }
    }
}

fn unpack_levels(bits: QuantBits, raw: &[u8], n: usize) -> Vec<i8> {
    match bits {
        QuantBits::Q8 => raw.iter().take(n).map(|&b| b as i8).collect(),
        QuantBits::Q4 => {
            let mut out = Vec::with_capacity(n);
            for (i, b) in raw.iter().enumerate() {
                if out.len() < n {
                    out.push(((b & 0x0F) as i8) - 7);
                }
                if out.len() < n {
                    out.push((((b >> 4) & 0x0F) as i8) - 7);
                }
                let _ = i;
            }
            out
        }
    }
}

/// An encoded payload: the bytes that would cross a real network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    format: WireFormat,
    entries: usize,
    cols: usize,
    bytes: Vec<u8>,
}

impl Frame {
    /// Wire format the frame was encoded with.
    pub fn format(&self) -> WireFormat {
        self.format
    }

    /// Number of f64 payload words the frame carries.
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Payload size in bytes — what [`CommStats::bytes`] bills.
    ///
    /// [`CommStats::bytes`]: super::CommStats::bytes
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Raw encoded bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Per-tenant codec description. Each [`Session`](super::Session) owns
/// one (default: lossless) plus a [`CodecState`] stream; every
/// request/response payload passes through it and `CommStats.bytes` is
/// the sum of the encoded frames' sizes, never per-collective `8 * d`
/// arithmetic. Per-session ownership means a lossy tenant cannot
/// degrade a concurrent lossless tenant's traffic — and per-session
/// *state* means a feedback tenant's residual stream cannot be polluted
/// by a neighbor either.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireCodec {
    kind: CodecKind,
    feedback: bool,
    adaptive: bool,
}

impl Default for WireCodec {
    fn default() -> Self {
        Self::lossless()
    }
}

impl WireCodec {
    /// A stateless fixed-width codec (the pre-ISSUE-10 family).
    pub fn new(precision: WirePrecision) -> Self {
        WireCodec { kind: CodecKind::Stateless(precision), feedback: false, adaptive: false }
    }

    /// The default codec: full f64, bit-exact roundtrip.
    pub fn lossless() -> Self {
        Self::new(WirePrecision::F64)
    }

    /// Low-bit uniform quantizer at a fixed width.
    pub fn quant(bits: QuantBits) -> Self {
        WireCodec { kind: CodecKind::Quant(bits), feedback: false, adaptive: false }
    }

    /// Top-`s` sparsifier with kept values at `bits`.
    pub fn top_s(s: u32, bits: QuantBits) -> Self {
        WireCodec { kind: CodecKind::TopS { s, bits }, feedback: false, adaptive: false }
    }

    /// Turn on the error-feedback accumulator.
    pub fn with_feedback(mut self) -> Self {
        self.feedback = true;
        self
    }

    /// Turn on the adaptive bit-width controller (Q4↔Q8 ladder; no-op
    /// for stateless kinds). Adaptive implies residual tracking even
    /// without feedback — the controller's input is the residual norm.
    pub fn with_adaptive(mut self) -> Self {
        self.adaptive = true;
        self
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    pub fn feedback(&self) -> bool {
        self.feedback
    }

    pub fn adaptive(&self) -> bool {
        self.adaptive
    }

    /// Whether submits under this codec may coalesce into a fused
    /// carrier. Only the stateless fixed-width codecs fuse: a feedback
    /// residual stream or an adaptive controller is keyed per session,
    /// and a carrier frame is shared — a stateful member entering a
    /// fusion window must displace the batch, never join it.
    pub fn fuses(&self) -> bool {
        matches!(self.kind, CodecKind::Stateless(_)) && !self.feedback && !self.adaptive
    }

    /// Whether this codec carries per-session stream state (the
    /// complement of [`WireCodec::fuses`]).
    pub fn is_stateful(&self) -> bool {
        !self.fuses()
    }

    /// The width the codec starts at (None for stateless kinds).
    pub fn base_bits(&self) -> Option<QuantBits> {
        match self.kind {
            CodecKind::Stateless(_) => None,
            CodecKind::Quant(b) => Some(b),
            CodecKind::TopS { bits, .. } => Some(bits),
        }
    }

    /// Resolve the concrete format the next round ships under, reading
    /// the adaptive controller's current width from `state`.
    pub fn resolve(&self, state: &CodecState) -> WireFormat {
        let bits = state.active_bits.or_else(|| self.base_bits());
        match self.kind {
            CodecKind::Stateless(p) => WireFormat::Plain(p),
            CodecKind::Quant(b) => WireFormat::Quant(bits.unwrap_or(b)),
            CodecKind::TopS { s, bits: b } => WireFormat::TopS { s, bits: bits.unwrap_or(b) },
        }
    }

    /// The format ignoring any adaptive state (base width).
    pub fn default_format(&self) -> WireFormat {
        match self.kind {
            CodecKind::Stateless(p) => WireFormat::Plain(p),
            CodecKind::Quant(b) => WireFormat::Quant(b),
            CodecKind::TopS { s, bits } => WireFormat::TopS { s, bits },
        }
    }

    /// Size in bytes of the frame [`WireCodec::encode`] would produce
    /// for a single-column payload of `words` f64 words at the base
    /// format.
    pub fn frame_bytes(&self, words: usize) -> usize {
        self.default_format().frame_bytes(words, 1)
    }

    /// Encode a single-column payload at the base format.
    pub fn encode(&self, payload: &[f64]) -> Frame {
        self.default_format().encode(payload, 1)
    }

    /// Decode a frame back into f64 words. Panics on a format mismatch
    /// — a frame is only meaningful to the codec that wrote it.
    pub fn decode(&self, frame: &Frame) -> Vec<f64> {
        self.default_format().decode(frame)
    }

    /// Pass a single-column payload through the base format's loss in
    /// place — **without** feedback state (the stateless billing
    /// primitive; stream-stateful encoding goes through
    /// [`CodecState::step`]) — and return the frame's size in bytes.
    /// The lossless F64 codec is a no-op on the values.
    pub fn transcode(&self, payload: &mut [f64]) -> usize {
        let format = self.default_format();
        format.quantize(payload, 1);
        format.frame_bytes(payload.len(), 1)
    }

    /// Label for CSV columns and CLI reports, e.g. `q4+ef` or
    /// `top8-q8+ef+ad`.
    pub fn label(&self) -> String {
        let mut l = self.default_format().label();
        if self.feedback {
            l.push_str("+ef");
        }
        if self.adaptive {
            l.push_str("+ad");
        }
        l
    }
}

/// Adaptive controller thresholds: widen when the relative residual of
/// a round exceeds [`WIDEN_ABOVE`], narrow when it drops below
/// [`NARROW_BELOW`]. The dead band between them keeps the ladder from
/// oscillating on a flat residual trajectory.
pub const WIDEN_ABOVE: f64 = 0.25;
pub const NARROW_BELOW: f64 = 0.02;

/// One direction's codec stream state: the error-feedback residual, the
/// adaptive controller's current width, and the last measured relative
/// residual norm. Owned per session (leader→workers, in the session's
/// codec lane) and per (worker, session id) (worker→leader, in the
/// worker's [`ReplyBank`]). **The only mutation entry points are
/// [`CodecState::step`] and [`CodecState::adapt`]** — the lint's
/// `codec-state-mutation` rule confines both (and all field writes) to
/// `cluster/wire.rs` + `cluster/session.rs`.
#[derive(Clone, Debug, Default)]
pub struct CodecState {
    residual: Vec<f64>,
    active_bits: Option<QuantBits>,
    last_rel: f64,
    widenings: u64,
    narrowings: u64,
}

impl CodecState {
    /// Fresh state for a codec (adaptive width starts at the base).
    pub fn for_codec(codec: &WireCodec) -> Self {
        CodecState { active_bits: codec.base_bits(), ..CodecState::default() }
    }

    /// Relative residual norm of the last stepped payload (0 until a
    /// tracked payload has been encoded).
    pub fn last_residual_norm(&self) -> f64 {
        self.last_rel
    }

    /// The adaptive controller's current width, if the codec has one.
    pub fn active_bits(&self) -> Option<QuantBits> {
        self.active_bits
    }

    /// (widenings, narrowings) the adaptive controller has performed on
    /// this stream.
    pub fn transitions(&self) -> (u64, u64) {
        (self.widenings, self.narrowings)
    }

    /// One stream step: add the carried residual (if `feedback`),
    /// quantize the payload at `format` in place, store the new
    /// residual and its relative norm (if `feedback || track`), and
    /// return the billed frame bytes. The residual resets when the
    /// payload length changes — a stream is only a stream while its
    /// shape is stable.
    pub fn step(
        &mut self,
        format: WireFormat,
        feedback: bool,
        track: bool,
        payload: &mut [f64],
        cols: usize,
    ) -> usize {
        let tracked = feedback || track;
        if feedback {
            if self.residual.len() != payload.len() {
                self.residual = vec![0.0; payload.len()];
            }
            for (x, e) in payload.iter_mut().zip(&self.residual) {
                *x += *e;
            }
        }
        let pre: Vec<f64> = if tracked { payload.to_vec() } else { Vec::new() };
        format.quantize(payload, cols);
        if tracked {
            if self.residual.len() != payload.len() {
                self.residual = vec![0.0; payload.len()];
            }
            let mut rn = 0.0;
            let mut pn = 0.0;
            for i in 0..payload.len() {
                let e = pre[i] - payload[i];
                self.residual[i] = e;
                rn += e * e;
                pn += pre[i] * pre[i];
            }
            self.last_rel = if pn > 0.0 { (rn / pn).sqrt() } else { 0.0 };
        }
        format.frame_bytes(payload.len(), cols.max(1))
    }

    /// Adaptive ladder step from the last residual norm: Q4→Q8 when the
    /// residual is too large, Q8→Q4 when it is comfortably small.
    /// Returns (widened, narrowed). No-op unless `codec.adaptive()`,
    /// the codec has a quantized width to move, and at least one
    /// payload has been stepped (a fresh stream's `last_rel` of 0 is
    /// absence of evidence, not evidence of a clean channel).
    pub fn adapt(&mut self, codec: &WireCodec) -> (bool, bool) {
        if !codec.adaptive() || self.residual.is_empty() {
            return (false, false);
        }
        let Some(bits) = self.active_bits else {
            return (false, false);
        };
        if bits == QuantBits::Q4 && self.last_rel > WIDEN_ABOVE {
            self.active_bits = Some(QuantBits::Q8);
            self.widenings += 1;
            return (true, false);
        }
        if bits == QuantBits::Q8 && self.last_rel < NARROW_BELOW {
            self.active_bits = Some(QuantBits::Q4);
            self.narrowings += 1;
            return (false, true);
        }
        (false, false)
    }
}

/// Per-round wire descriptor: the resolved format a round ships under,
/// whether its reply stream runs error feedback, and the issuing
/// session id that keys the worker-side accumulator. Rides the request
/// envelope (unbilled) so workers need no handshake to keep their
/// stream state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireDesc {
    pub format: WireFormat,
    pub feedback: bool,
    pub sid: u64,
}

impl WireDesc {
    /// Control-plane frames (shutdown, fused carriers): lossless, no
    /// stream.
    pub fn lossless() -> Self {
        WireDesc::plain(WirePrecision::F64)
    }

    /// A stateless fixed-width descriptor with no stream key.
    pub fn plain(prec: WirePrecision) -> Self {
        WireDesc { format: WireFormat::Plain(prec), feedback: false, sid: 0 }
    }
}

/// Worker-side reply compressor: one [`CodecState`] per session id,
/// evicted deterministic-LRU at [`ReplyBank::CAP`] streams so the
/// eviction sequence — and therefore every residual trajectory — is
/// identical on both backends. Workers build their state purely from
/// the request envelopes they see; nothing is shipped or handshaken.
#[derive(Debug, Default)]
pub struct ReplyBank {
    // most-recently-used first
    streams: Vec<(u64, CodecState)>,
}

impl ReplyBank {
    /// Max concurrent feedback streams a worker tracks.
    pub const CAP: usize = 64;

    pub fn new() -> Self {
        ReplyBank::default()
    }

    /// Number of live streams (for tests).
    pub fn streams(&self) -> usize {
        self.streams.len()
    }

    /// Compress a response payload in place at the request's descriptor:
    /// stateless quantize when feedback is off, a [`CodecState::step`]
    /// on the session's stream when it is on.
    pub fn compress(&mut self, desc: &WireDesc, resp: &mut Response) {
        let cols = resp.payload_cols();
        let Some(p) = resp.payload_mut() else {
            return;
        };
        if !desc.feedback {
            desc.format.quantize(p, cols);
            return;
        }
        if let Some(pos) = self.streams.iter().position(|(sid, _)| *sid == desc.sid) {
            let entry = self.streams.remove(pos);
            self.streams.insert(0, entry);
        } else {
            self.streams.insert(0, (desc.sid, CodecState::default()));
            self.streams.truncate(Self::CAP);
        }
        self.streams[0].1.step(desc.format, true, false, p, cols);
    }
}

/// Decode raw fixed-width payload bytes at the given precision. The
/// slice length must be a multiple of the precision's entry width
/// (callers validate it; a ragged tail would be silently dropped by
/// `chunks_exact`, so every call site checks first).
fn decode_raw(prec: WirePrecision, raw: &[u8]) -> Vec<f64> {
    match prec {
        WirePrecision::F64 => {
            raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
        }
        WirePrecision::F32 => raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
            .collect(),
        WirePrecision::Bf16 => raw
            .chunks_exact(2)
            .map(|c| bf16_to_f64(u16::from_le_bytes(c.try_into().unwrap())))
            .collect(),
    }
}

// ---------------------------------------------------------------------
// Whole-message frames (ISSUE 4): the byte representation the TCP
// transport ships. Body layout (the transport adds a u32 length
// prefix):
//
//   request:  u8 kind | u64 seq | format tag(s) | u8 feedback | u64 sid
//             | u8 tag | variant fields...
//   response: u8 kind | u64 seq | format tag(s) | u8 tag | fields...
//
// The format tag is one byte (0=f64, 1=f32, 2=bf16, 3=q8, 4=q4,
// 5=top-s@q8, 6=top-s@q4), followed by a u32 `s` for the top-s tags.
// Counts and shapes are u64 LE; hyperparameters are raw f64 bits
// (lossless — they are envelope, not payload); strings are u32 length +
// UTF-8; f64 payload sections are `u64 word count` + the format-encoded
// bytes (quantized sections additionally carry their u32 column count
// as envelope). The format-encoded payload section is the only billed
// part of the frame.
// ---------------------------------------------------------------------

const MSG_REQUEST: u8 = 0xA1;
const MSG_RESPONSE: u8 = 0xA2;

const REQ_COV_MATVEC: u8 = 1;
const REQ_COV_MATMAT: u8 = 2;
const REQ_LOCAL_TOP_EIGVEC: u8 = 3;
const REQ_GRAM: u8 = 4;
const REQ_LOCAL_TOP_K: u8 = 5;
const REQ_OJA_PASS: u8 = 6;
const REQ_SHUTDOWN: u8 = 7;

const RESP_VECTOR: u8 = 1;
const RESP_MAT: u8 = 2;
const RESP_ERR: u8 = 3;

fn prec_tag(p: WirePrecision) -> u8 {
    match p {
        WirePrecision::F64 => 0,
        WirePrecision::F32 => 1,
        WirePrecision::Bf16 => 2,
    }
}

fn put_format(out: &mut Vec<u8>, f: WireFormat) {
    match f {
        WireFormat::Plain(p) => out.push(prec_tag(p)),
        WireFormat::Quant(QuantBits::Q8) => out.push(3),
        WireFormat::Quant(QuantBits::Q4) => out.push(4),
        WireFormat::TopS { s, bits } => {
            out.push(match bits {
                QuantBits::Q8 => 5,
                QuantBits::Q4 => 6,
            });
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
}

fn format_from(c: &mut Cursor) -> Result<WireFormat> {
    Ok(match c.u8()? {
        0 => WireFormat::Plain(WirePrecision::F64),
        1 => WireFormat::Plain(WirePrecision::F32),
        2 => WireFormat::Plain(WirePrecision::Bf16),
        3 => WireFormat::Quant(QuantBits::Q8),
        4 => WireFormat::Quant(QuantBits::Q4),
        5 => WireFormat::TopS { s: c.u32()?, bits: QuantBits::Q8 },
        6 => WireFormat::TopS { s: c.u32()?, bits: QuantBits::Q4 },
        other => bail!("unknown wire format tag {other}"),
    })
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_payload(out: &mut Vec<u8>, format: WireFormat, payload: &[f64], cols: usize) {
    put_u64(out, payload.len() as u64);
    if let WireFormat::Quant(_) = format {
        out.extend_from_slice(&(cols.max(1) as u32).to_le_bytes());
    }
    out.extend_from_slice(format.encode(payload, cols).bytes());
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked reader over a frame body. Every accessor returns an
/// error on underrun — a truncated or corrupt frame can never panic the
/// decoder — and [`Cursor::finish`] rejects trailing bytes, so a frame
/// whose length prefix disagrees with its content is an error too.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "truncated frame: need {n} bytes at offset {}, only {} left",
                    self.pos,
                    self.buf.len().saturating_sub(self.pos)
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("count does not fit this platform's usize")
    }

    /// A payload section: `u64` word count + format-encoded bytes
    /// (quantized sections carry their column count). Every byte count
    /// is validated *before* any allocation, and sparse frames validate
    /// their index list (in range, strictly ascending, canonical count)
    /// so a corrupt frame cannot scatter out of bounds.
    pub(crate) fn payload(&mut self, format: WireFormat) -> Result<Vec<f64>> {
        let words = self.usize()?;
        match format {
            WireFormat::Plain(prec) => {
                let nbytes = words
                    .checked_mul(prec.bytes_per_entry())
                    .ok_or_else(|| anyhow::anyhow!("payload word count {words} overflows"))?;
                let raw = self.take(nbytes)?;
                Ok(decode_raw(prec, raw))
            }
            WireFormat::Quant(bits) => {
                let cols = self.u32()? as usize;
                ensure!(cols >= 1, "quantized payload with zero columns");
                ensure!(
                    words % cols == 0,
                    "quantized payload of {words} words is not {cols} columns"
                );
                let mut scales = Vec::with_capacity(cols.min(words.max(1)));
                for _ in 0..cols {
                    scales.push(f32::from_bits(self.u32()?));
                }
                let raw = self.take(bits.level_bytes(words))?;
                let levels = unpack_levels(bits, raw, words);
                Ok(levels.iter().enumerate().map(|(i, &l)| dequant(l, scales[i % cols])).collect())
            }
            WireFormat::TopS { s, bits } => {
                let kept = self.u32()? as usize;
                ensure!(
                    kept == (s as usize).min(words),
                    "top-s frame keeps {kept} of {words} words, expected min({s}, {words})"
                );
                let scale = f32::from_bits(self.u32()?);
                let mut out = vec![0.0; words];
                let mut idxs = Vec::with_capacity(kept);
                let mut prev: Option<usize> = None;
                for _ in 0..kept {
                    let i = self.u32()? as usize;
                    ensure!(i < words, "top-s index {i} out of range for {words} words");
                    ensure!(
                        prev.map_or(true, |p| i > p),
                        "top-s indices not strictly ascending"
                    );
                    prev = Some(i);
                    idxs.push(i);
                }
                let raw = self.take(bits.level_bytes(kept))?;
                let levels = unpack_levels(bits, raw, kept);
                for (j, &i) in idxs.iter().enumerate() {
                    out[i] = dequant(levels[j], scale);
                }
                Ok(out)
            }
        }
    }

    pub(crate) fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).context("invalid UTF-8 in frame string")
    }

    pub(crate) fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "length mismatch: {} trailing bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Encode a whole request as a frame body: the byte representation the
/// TCP transport ships (payload section encoded at `desc.format` —
/// idempotently, since the leader already quantized the values).
pub fn encode_request(seq: u64, desc: WireDesc, req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        64 + req.payload().map_or(0, |p| desc.format.frame_bytes(p.len(), req.payload_cols())),
    );
    out.push(MSG_REQUEST);
    put_u64(&mut out, seq);
    put_format(&mut out, desc.format);
    out.push(u8::from(desc.feedback));
    put_u64(&mut out, desc.sid);
    match req {
        Request::CovMatVec(v) => {
            out.push(REQ_COV_MATVEC);
            put_payload(&mut out, desc.format, v, 1);
        }
        Request::CovMatMat { rows, cols, data } => {
            out.push(REQ_COV_MATMAT);
            put_u64(&mut out, *rows as u64);
            put_u64(&mut out, *cols as u64);
            put_payload(&mut out, desc.format, data, *cols);
        }
        Request::LocalTopEigvec { unbiased_signs } => {
            out.push(REQ_LOCAL_TOP_EIGVEC);
            out.push(u8::from(*unbiased_signs));
        }
        Request::Gram => out.push(REQ_GRAM),
        Request::LocalTopK { k } => {
            out.push(REQ_LOCAL_TOP_K);
            put_u64(&mut out, *k as u64);
        }
        Request::OjaPass { w, eta0, t0, t_start } => {
            out.push(REQ_OJA_PASS);
            put_u64(&mut out, eta0.to_bits());
            put_u64(&mut out, t0.to_bits());
            put_u64(&mut out, *t_start);
            put_payload(&mut out, desc.format, w, 1);
        }
        Request::Shutdown => out.push(REQ_SHUTDOWN),
    }
    out
}

/// Decode a request frame body. Returns the sequence number, the wire
/// descriptor its payload shipped under (workers echo the format on the
/// reply and key their feedback stream on the sid), and the
/// reconstructed request. Truncated, trailing-byte, shape-mismatched,
/// or unknown-tag frames are errors — never panics.
pub fn decode_request(body: &[u8]) -> Result<(u64, WireDesc, Request)> {
    let mut c = Cursor::new(body);
    let kind = c.u8()?;
    ensure!(kind == MSG_REQUEST, "not a request frame (kind 0x{kind:02x})");
    let seq = c.u64()?;
    let format = format_from(&mut c)?;
    let fb = c.u8()?;
    ensure!(fb <= 1, "bad feedback byte {fb} in frame");
    let sid = c.u64()?;
    let desc = WireDesc { format, feedback: fb == 1, sid };
    let req = match c.u8()? {
        REQ_COV_MATVEC => Request::CovMatVec(c.payload(format)?),
        REQ_COV_MATMAT => {
            let rows = c.usize()?;
            let cols = c.usize()?;
            let data = c.payload(format)?;
            ensure!(
                rows.checked_mul(cols) == Some(data.len()),
                "cov_matmat frame: payload of {} words != {rows}x{cols}",
                data.len()
            );
            Request::CovMatMat { rows, cols, data }
        }
        REQ_LOCAL_TOP_EIGVEC => {
            let b = c.u8()?;
            ensure!(b <= 1, "bad bool byte {b} in frame");
            Request::LocalTopEigvec { unbiased_signs: b == 1 }
        }
        REQ_GRAM => Request::Gram,
        REQ_LOCAL_TOP_K => Request::LocalTopK { k: c.usize()? },
        REQ_OJA_PASS => {
            let eta0 = f64::from_bits(c.u64()?);
            let t0 = f64::from_bits(c.u64()?);
            let t_start = c.u64()?;
            let w = c.payload(format)?;
            Request::OjaPass { w, eta0, t0, t_start }
        }
        REQ_SHUTDOWN => Request::Shutdown,
        other => bail!("unknown request tag {other}"),
    };
    c.finish()?;
    Ok((seq, desc, req))
}

/// Encode a whole response as a frame body (payload section encoded at
/// `format` — workers reply at the format the request frame carried, so
/// the leader's decode is value-preserving).
pub fn encode_response(seq: u64, format: WireFormat, resp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        48 + resp.payload().map_or(0, |p| format.frame_bytes(p.len(), resp.payload_cols())),
    );
    out.push(MSG_RESPONSE);
    put_u64(&mut out, seq);
    put_format(&mut out, format);
    match resp {
        Response::Vector(v) => {
            out.push(RESP_VECTOR);
            put_payload(&mut out, format, v, 1);
        }
        Response::Mat { rows, cols, data } => {
            out.push(RESP_MAT);
            put_u64(&mut out, *rows as u64);
            put_u64(&mut out, *cols as u64);
            put_payload(&mut out, format, data, *cols);
        }
        Response::Err(msg) => {
            out.push(RESP_ERR);
            put_string(&mut out, msg);
        }
    }
    out
}

/// Decode a response frame body (counterpart of [`encode_response`];
/// same defensive guarantees as [`decode_request`]).
pub fn decode_response(body: &[u8]) -> Result<(u64, WireFormat, Response)> {
    let mut c = Cursor::new(body);
    let kind = c.u8()?;
    ensure!(kind == MSG_RESPONSE, "not a response frame (kind 0x{kind:02x})");
    let seq = c.u64()?;
    let format = format_from(&mut c)?;
    let resp = match c.u8()? {
        RESP_VECTOR => Response::Vector(c.payload(format)?),
        RESP_MAT => {
            let rows = c.usize()?;
            let cols = c.usize()?;
            let data = c.payload(format)?;
            ensure!(
                rows.checked_mul(cols) == Some(data.len()),
                "mat frame: payload of {} words != {rows}x{cols}",
                data.len()
            );
            Response::Mat { rows, cols, data }
        }
        RESP_ERR => Response::Err(c.string()?),
        other => bail!("unknown response tag {other}"),
    };
    c.finish()?;
    Ok((seq, format, resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_payload() -> Vec<f64> {
        vec![
            1.0,
            -0.3333333333333333,
            1e-8,
            12345.6789,
            -0.0,
            f64::MIN_POSITIVE, // subnormal territory after f32 cast -> 0
            3.5e37,
            -1.25,
        ]
    }

    fn all_formats() -> Vec<WireFormat> {
        vec![
            WireFormat::Plain(WirePrecision::F64),
            WireFormat::Plain(WirePrecision::F32),
            WireFormat::Plain(WirePrecision::Bf16),
            WireFormat::Quant(QuantBits::Q8),
            WireFormat::Quant(QuantBits::Q4),
            WireFormat::TopS { s: 3, bits: QuantBits::Q8 },
            WireFormat::TopS { s: 3, bits: QuantBits::Q4 },
            WireFormat::TopS { s: 64, bits: QuantBits::Q8 },
        ]
    }

    #[test]
    fn f64_codec_roundtrips_bit_exactly() {
        let codec = WireCodec::lossless();
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 8 * v.len());
        assert_eq!(frame.entries(), v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 codec must be bit-exact");
        }
    }

    #[test]
    fn f32_codec_matches_f32_cast() {
        let codec = WireCodec::new(WirePrecision::F32);
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 4 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(*b, *a as f32 as f64);
        }
    }

    #[test]
    fn bf16_codec_error_is_at_most_half_ulp_plus_f32_term() {
        let codec = WireCodec::new(WirePrecision::Bf16);
        let mut rng = crate::rng::Pcg64::new(0xbf16);
        let v: Vec<f64> = (0..256).map(|_| rng.next_gaussian() * 10.0).collect();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 2 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            // 7 explicit mantissa bits + RNE: relative error <= 2^-8 +
            // 2^-24 (the f32 double-rounding term) < 4e-3
            assert!((a - b).abs() <= 4e-3 * a.abs().max(1e-30), "{a} vs {b}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2^-8 sits exactly halfway between bf16(1.0) and the next
        // value up; ties go to the even mantissa, i.e. down to 1.0
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 1.0 / 256.0)), 1.0);
        // 1 + 3*2^-8 is halfway with an odd lower neighbor; ties go up
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 3.0 / 256.0)), 1.0 + 4.0 / 256.0);
        // exactly representable values pass through
        for x in [0.0, -0.0, 1.0, -2.5, 0.15625, 2.0f64.powi(127)] {
            assert_eq!(bf16_to_f64(f64_to_bf16(x)), x, "{x} is bf16-representable");
        }
    }

    #[test]
    fn bf16_handles_nonfinite_and_overflow() {
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::INFINITY)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::NEG_INFINITY)), f64::NEG_INFINITY);
        assert!(bf16_to_f64(f64_to_bf16(f64::NAN)).is_nan());
        // beyond f32/bf16 range saturates to infinity rather than garbage
        assert_eq!(bf16_to_f64(f64_to_bf16(1e300)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(-1e300)), f64::NEG_INFINITY);
    }

    #[test]
    fn quantize_is_the_encode_decode_roundtrip() {
        for format in all_formats() {
            let mut quantized = sample_payload();
            format.quantize(&mut quantized, 1);
            let shipped = format.decode(&format.encode(&sample_payload(), 1));
            assert_eq!(quantized, shipped, "{format:?}: quantize != ship");
        }
        // the multi-column path too (8 words as 2 columns)
        for format in [WireFormat::Quant(QuantBits::Q8), WireFormat::Quant(QuantBits::Q4)] {
            let mut quantized = sample_payload();
            format.quantize(&mut quantized, 2);
            let shipped = format.decode(&format.encode(&sample_payload(), 2));
            assert_eq!(quantized, shipped, "{format:?}/cols=2: quantize != ship");
        }
    }

    #[test]
    fn transcode_returns_frame_size_and_applies_roundtrip() {
        for (prec, bpe) in
            [(WirePrecision::F64, 8), (WirePrecision::F32, 4), (WirePrecision::Bf16, 2)]
        {
            let codec = WireCodec::new(prec);
            let mut v = sample_payload();
            let bytes = codec.transcode(&mut v);
            assert_eq!(bytes, bpe * v.len());
            let mut want = sample_payload();
            prec.quantize(&mut want);
            assert_eq!(v, want);
        }
        // the quantized family: billed bytes match the B(w) table
        let mut v = sample_payload();
        assert_eq!(WireCodec::quant(QuantBits::Q8).transcode(&mut v), 4 + 8);
        let mut v = sample_payload();
        assert_eq!(WireCodec::quant(QuantBits::Q4).transcode(&mut v), 4 + 4);
        let mut v = sample_payload();
        assert_eq!(WireCodec::top_s(3, QuantBits::Q8).transcode(&mut v), 8 + 12 + 3);
        let mut v = sample_payload();
        assert_eq!(WireCodec::top_s(3, QuantBits::Q4).transcode(&mut v), 8 + 12 + 2);
    }

    #[test]
    #[should_panic(expected = "format mismatch")]
    fn decode_rejects_foreign_frames() {
        let frame = WireCodec::new(WirePrecision::F32).encode(&[1.0, 2.0]);
        let _ = WireCodec::lossless().decode(&frame);
    }

    #[test]
    fn frame_bytes_matches_encode_for_every_format() {
        for format in all_formats() {
            for words in [0usize, 1, 7, 64] {
                let payload: Vec<f64> = (0..words).map(|i| (i as f64) - 2.5).collect();
                assert_eq!(
                    format.frame_bytes(words, 1),
                    format.encode(&payload, 1).wire_bytes(),
                    "{format:?} x {words} words"
                );
            }
        }
        // column counts change quantized frames (one scale per column)
        let payload = vec![0.25; 12];
        for cols in [1usize, 2, 3, 4, 6] {
            for format in [WireFormat::Quant(QuantBits::Q8), WireFormat::Quant(QuantBits::Q4)] {
                assert_eq!(
                    format.frame_bytes(12, cols),
                    format.encode(&payload, cols).wire_bytes(),
                    "{format:?} x {cols} cols"
                );
            }
        }
    }

    #[test]
    fn quantizers_are_reencode_idempotent() {
        // quantize once, then encode→decode the quantized values: the
        // TCP transport's second pass must be lossless (this is what
        // keeps in-proc and TCP bills + numerics identical)
        let mut rng = crate::rng::Pcg64::new(0x1de);
        for format in all_formats() {
            for cols in [1usize, 2] {
                if cols == 2 && matches!(format, WireFormat::TopS { .. }) {
                    continue; // top-s is column-blind
                }
                let mut v: Vec<f64> = (0..32).map(|_| rng.next_gaussian()).collect();
                format.quantize(&mut v, cols);
                let back = format.decode(&format.encode(&v, cols));
                for (a, b) in v.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{format:?}/cols={cols} not idempotent");
                }
            }
        }
    }

    #[test]
    fn q8_quantization_error_is_bounded_by_half_step() {
        let mut rng = crate::rng::Pcg64::new(0x88);
        let v: Vec<f64> = (0..128).map(|_| rng.next_gaussian()).collect();
        let maxabs = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let mut q = v.clone();
        WireFormat::Quant(QuantBits::Q8).quantize(&mut q, 1);
        let step = maxabs / 127.0;
        for (a, b) in v.iter().zip(&q) {
            assert!((a - b).abs() <= 0.51 * step, "{a} vs {b} (step {step})");
        }
    }

    #[test]
    fn top_s_keeps_the_largest_magnitudes() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.0, 4.0, 0.05];
        let mut q = v.clone();
        WireFormat::TopS { s: 3, bits: QuantBits::Q8 }.quantize(&mut q, 1);
        // indices 1 (−5), 6 (4), 3 (3) survive; everything else is zero
        for (i, x) in q.iter().enumerate() {
            if [1usize, 3, 6].contains(&i) {
                assert!((x - v[i]).abs() <= 0.03, "kept coordinate {i} moved: {x} vs {}", v[i]);
            } else {
                assert_eq!(*x, 0.0, "coordinate {i} should be dropped");
            }
        }
    }

    #[test]
    fn error_feedback_recovers_dropped_mass_over_rounds() {
        // a constant signal through a 4-bit feedback stream: the sum of
        // the shipped payloads converges to the sum of the true signal
        // (the EF telescoping identity: shipped_sum = true_sum − e_T)
        let signal = vec![0.7, -0.31, 0.05, 0.002, -0.9, 0.44, 0.013, -0.27];
        let codec = WireCodec::quant(QuantBits::Q4).with_feedback();
        let mut state = CodecState::for_codec(&codec);
        let mut shipped_sum = vec![0.0; signal.len()];
        let rounds = 64;
        for _ in 0..rounds {
            let mut p = signal.clone();
            state.step(codec.resolve(&state), true, false, &mut p, 1);
            for (s, x) in shipped_sum.iter_mut().zip(&p) {
                *s += x;
            }
        }
        let maxabs = signal.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        for (s, x) in shipped_sum.iter().zip(&signal) {
            // the residual is bounded by one quantization step, so the
            // *averaged* error vanishes like 1/rounds
            let avg_err = (s / rounds as f64 - x).abs();
            assert!(avg_err <= 2.0 * maxabs / 7.0 / rounds as f64 + 1e-12, "avg err {avg_err}");
        }
    }

    #[test]
    fn feedback_residual_resets_on_payload_length_change() {
        let codec = WireCodec::quant(QuantBits::Q4).with_feedback();
        let mut state = CodecState::for_codec(&codec);
        let mut a = vec![0.5; 8];
        state.step(codec.resolve(&state), true, false, &mut a, 1);
        assert!(state.last_residual_norm() > 0.0 || a == vec![0.5; 8]);
        // a different length starts a fresh stream — no panic, no
        // stale residual bleeding in
        let mut b = vec![0.25; 4];
        state.step(codec.resolve(&state), true, false, &mut b, 1);
        assert_eq!(state.residual.len(), 4);
    }

    #[test]
    fn adaptive_ladder_widens_and_narrows_on_thresholds() {
        let codec = WireCodec::quant(QuantBits::Q4).with_feedback().with_adaptive();
        let mut state = CodecState::for_codec(&codec);
        assert_eq!(state.active_bits(), Some(QuantBits::Q4));
        // a fresh stream has measured nothing: the controller holds
        state.last_rel = WIDEN_ABOVE * 2.0;
        assert_eq!(state.adapt(&codec), (false, false));
        let mut p = vec![0.7, -0.3, 0.1, 0.9];
        state.step(WireFormat::Quant(QuantBits::Q4), true, true, &mut p, 1);
        state.last_rel = WIDEN_ABOVE * 2.0;
        assert_eq!(state.adapt(&codec), (true, false));
        assert_eq!(state.active_bits(), Some(QuantBits::Q8));
        // in the dead band: nothing moves
        state.last_rel = 0.1;
        assert_eq!(state.adapt(&codec), (false, false));
        state.last_rel = NARROW_BELOW / 2.0;
        assert_eq!(state.adapt(&codec), (false, true));
        assert_eq!(state.active_bits(), Some(QuantBits::Q4));
        assert_eq!(state.transitions(), (1, 1));
        // resolve() ships the controller's width, not the base width
        state.active_bits = Some(QuantBits::Q8);
        assert_eq!(codec.resolve(&state), WireFormat::Quant(QuantBits::Q8));
        // stateless codecs never adapt
        let f64c = WireCodec::lossless().with_adaptive();
        let mut s2 = CodecState::for_codec(&f64c);
        s2.last_rel = 1.0;
        assert_eq!(s2.adapt(&f64c), (false, false));
    }

    #[test]
    fn reply_bank_keys_streams_by_sid_and_evicts_lru() {
        let mut bank = ReplyBank::new();
        let desc = |sid: u64| WireDesc {
            format: WireFormat::Quant(QuantBits::Q4),
            feedback: true,
            sid,
        };
        // fill past the cap; the oldest stream is evicted, deterministically
        for sid in 0..(ReplyBank::CAP as u64 + 3) {
            let mut r = Response::Vector(vec![0.3; 4]);
            bank.compress(&desc(sid), &mut r);
        }
        assert_eq!(bank.streams(), ReplyBank::CAP);
        assert!(bank.streams.iter().all(|(sid, _)| *sid >= 3), "oldest sids evicted first");
        // touching a stream moves it to the front (LRU order)
        let mut r = Response::Vector(vec![0.3; 4]);
        bank.compress(&desc(10), &mut r);
        assert_eq!(bank.streams[0].0, 10);
        // stateless descriptors never allocate a stream
        let mut bank2 = ReplyBank::new();
        let mut r = Response::Vector(vec![0.3; 4]);
        bank2.compress(&WireDesc::plain(WirePrecision::Bf16), &mut r);
        assert_eq!(bank2.streams(), 0);
        assert_eq!(r.payload().unwrap()[0], {
            let mut v = [0.3];
            WirePrecision::Bf16.quantize(&mut v);
            v[0]
        });
    }

    #[test]
    fn codec_family_predicates() {
        assert_eq!(WireCodec::default(), WireCodec::lossless());
        assert!(WireCodec::lossless().fuses());
        assert!(WireCodec::new(WirePrecision::Bf16).fuses());
        assert!(!WireCodec::new(WirePrecision::Bf16).with_feedback().fuses());
        assert!(!WireCodec::quant(QuantBits::Q8).fuses());
        assert!(!WireCodec::top_s(8, QuantBits::Q4).fuses());
        assert!(!WireCodec::lossless().with_adaptive().fuses());
        assert!(WireCodec::quant(QuantBits::Q4).is_stateful());
        assert_eq!(WireCodec::quant(QuantBits::Q4).with_feedback().label(), "q4+ef");
        assert_eq!(
            WireCodec::top_s(8, QuantBits::Q8).with_feedback().with_adaptive().label(),
            "top8-q8+ef+ad"
        );
        assert_eq!(WirePrecision::F64.bytes_per_entry(), 8);
        assert_eq!(WirePrecision::F32.label(), "f32");
    }

    // -- whole-message frames ------------------------------------------

    fn all_requests(format: WireFormat) -> Vec<Request> {
        // payloads pre-quantized to the format grid so the roundtrip is
        // bit-exact under every format (idempotency)
        let q = |mut v: Vec<f64>, cols: usize| {
            format.quantize(&mut v, cols);
            v
        };
        vec![
            Request::CovMatVec(q(sample_payload(), 1)),
            Request::CovMatMat { rows: 4, cols: 2, data: q(sample_payload(), 2) },
            Request::LocalTopEigvec { unbiased_signs: true },
            Request::LocalTopEigvec { unbiased_signs: false },
            Request::Gram,
            Request::LocalTopK { k: 3 },
            Request::OjaPass { w: q(sample_payload(), 1), eta0: 0.37, t0: 10.0, t_start: 42 },
            Request::Shutdown,
        ]
    }

    fn all_responses(format: WireFormat) -> Vec<Response> {
        let q = |mut v: Vec<f64>, cols: usize| {
            format.quantize(&mut v, cols);
            v
        };
        vec![
            Response::Vector(q(sample_payload(), 1)),
            Response::Mat { rows: 2, cols: 4, data: q(sample_payload(), 4) },
            Response::Err("worker 3 failed: bad rank 99 for d=8".to_string()),
        ]
    }

    #[test]
    fn every_request_variant_roundtrips_under_every_format() {
        for format in all_formats() {
            for feedback in [false, true] {
                let desc = WireDesc { format, feedback, sid: 0xD5 };
                for (i, req) in all_requests(format).iter().enumerate() {
                    let body = encode_request(1000 + i as u64, desc, req);
                    let (seq, d, back) = decode_request(&body).unwrap();
                    assert_eq!(seq, 1000 + i as u64);
                    assert_eq!(d, desc);
                    assert_eq!(&back, req, "{format:?} request {i} changed across the wire");
                }
            }
        }
    }

    #[test]
    fn every_response_variant_roundtrips_under_every_format() {
        for format in all_formats() {
            for (i, resp) in all_responses(format).iter().enumerate() {
                let body = encode_response(7 + i as u64, format, resp);
                let (seq, f, back) = decode_response(&body).unwrap();
                assert_eq!(seq, 7 + i as u64);
                assert_eq!(f, format);
                assert_eq!(&back, resp, "{format:?} response {i} changed across the wire");
            }
        }
    }

    #[test]
    fn decode_rejects_truncated_and_length_mismatched_frames() {
        for format in all_formats() {
            let desc = WireDesc { format, feedback: true, sid: 7 };
            let mut payload = sample_payload();
            format.quantize(&mut payload, 1);
            let body = encode_request(9, desc, &Request::CovMatVec(payload.clone()));
            // every strict prefix errors out instead of panicking
            for cut in 0..body.len() {
                assert!(
                    decode_request(&body[..cut]).is_err(),
                    "{format:?}: prefix of {cut} bytes accepted"
                );
            }
            // trailing garbage is a length mismatch, not a silent accept
            let mut longer = body.clone();
            longer.push(0);
            let err = decode_request(&longer).unwrap_err().to_string();
            assert!(err.contains("length mismatch"), "{err}");
            // same on the response side
            let rbody = encode_response(9, format, &Response::Vector(payload));
            for cut in 0..rbody.len() {
                assert!(decode_response(&rbody[..cut]).is_err());
            }
        }
    }

    #[test]
    fn decode_rejects_wrong_kind_bad_tags_and_shape_mismatches() {
        let desc = WireDesc::lossless();
        let req = encode_request(1, desc, &Request::Gram);
        let resp = encode_response(1, desc.format, &Response::Err("x".into()));
        assert!(decode_response(&req).is_err(), "request frame is not a response");
        assert!(decode_request(&resp).is_err(), "response frame is not a request");
        // unknown variant tag
        let mut bad = req.clone();
        let tag_at = bad.len() - 1; // Gram body: kind|seq|fmt|fb|sid|tag
        bad[tag_at] = 99;
        assert!(decode_request(&bad).unwrap_err().to_string().contains("unknown request tag"));
        // a CovMatMat whose declared shape disagrees with its payload
        let mismatched = encode_request(
            2,
            desc,
            &Request::CovMatMat { rows: 5, cols: 1, data: vec![0.5; 5] },
        );
        let mut broken = mismatched.clone();
        // rows field sits right after kind|seq|fmt|fb|sid|tag = 20 bytes
        broken[20] = 3;
        let err = decode_request(&broken).unwrap_err().to_string();
        assert!(err.contains("!= 3x1"), "{err}");
        // and a bad format tag
        let mut badprec = encode_request(3, desc, &Request::Gram);
        badprec[9] = 7; // kind (1) + seq (8) -> format byte
        assert!(decode_request(&badprec)
            .unwrap_err()
            .to_string()
            .contains("unknown wire format"));
    }

    #[test]
    fn sparse_frames_reject_corrupt_index_lists() {
        let format = WireFormat::TopS { s: 3, bits: QuantBits::Q8 };
        let mut payload = sample_payload();
        format.quantize(&mut payload, 1);
        let good = encode_request(4, WireDesc { format, feedback: false, sid: 0 }, &Request::CovMatVec(payload));
        // locate the first index (kind 1 + seq 8 + fmt 1 + s 4 + fb 1 +
        // sid 8 + tag 1 + words 8 + count 4 + scale 4 = 40)
        let idx_at = 40;
        // out-of-range index
        let mut oob = good.clone();
        oob[idx_at..idx_at + 4].copy_from_slice(&900u32.to_le_bytes());
        let err = decode_request(&oob).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // non-ascending index list (second index duplicates the first)
        let mut dup = good.clone();
        let first = dup[idx_at..idx_at + 4].to_vec();
        dup[idx_at + 4..idx_at + 8].copy_from_slice(&first);
        let err = decode_request(&dup).unwrap_err().to_string();
        assert!(err.contains("ascending"), "{err}");
        // non-canonical kept count
        let mut short = good.clone();
        short[idx_at - 8..idx_at - 4].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_request(&short).is_err());
    }

    #[test]
    fn frame_payload_section_is_exactly_the_codec_frame() {
        // the billed bytes and the shipped bytes are the same bytes:
        // the payload section of a message frame is the format's encoded
        // frame, verbatim
        for format in all_formats() {
            let mut payload = sample_payload();
            format.quantize(&mut payload, 1);
            let frame = format.encode(&payload, 1);
            let desc = WireDesc { format, feedback: false, sid: 0 };
            let body = encode_request(5, desc, &Request::CovMatVec(payload.clone()));
            let tail = &body[body.len() - frame.wire_bytes()..];
            assert_eq!(tail, frame.bytes(), "{format:?}: payload section != codec frame");
        }
    }
}
