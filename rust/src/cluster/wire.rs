//! Wire layer: the codec every leader<->worker payload passes through.
//!
//! The paper's whole contribution is measured in communication cost, so
//! the bytes column of [`CommStats`] must be *real*: instead of each
//! collective hand-computing `8 * d * ...`, every tenant session owns a
//! [`WireCodec`] and bills every message from the size of the frame the
//! codec actually encodes ([`Frame::wire_bytes`]). The default codec is
//! lossless f64 — encode/decode is a bit-exact roundtrip, so all
//! accounting and numerics match the original `8·d` model verbatim —
//! while the lossy codecs ([`WirePrecision::F32`], [`WirePrecision::Bf16`])
//! both shrink the frames *and* degrade the payload exactly the way a
//! real quantized wire would (cf. the quantized-communication line of
//! work the paper's §1 contrasts with its round model).
//!
//! [`CommStats`]: super::CommStats
//!
//! Format notes:
//!
//! - `F64`: 8 bytes/entry, little-endian IEEE-754 binary64. Bit-exact.
//! - `F32`: 4 bytes/entry; each entry rounds to the nearest binary32
//!   (relative error <= 2^-24).
//! - `Bf16`: 2 bytes/entry, true bfloat16 — 1 sign + 8 exponent + 7
//!   explicit mantissa bits. Conversion goes f64 → f32 (RNE) → bf16
//!   (RNE), the same double-rounding composition real hardware without a
//!   direct f64→bf16 path performs, so the relative error is at most
//!   half an ulp plus the f32 term: `2^-8 + 2^-24`, within the 4e-3
//!   bound the tests assert. (The pre-wire-layer code masked the f64
//!   mantissa to 8 explicit bits, a 20-bit format it billed at 2 bytes;
//!   the codec makes the 2 bytes honest.)

/// Per-entry precision of every f64 that crosses the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WirePrecision {
    /// Full f64 (the baseline model of the paper). Lossless.
    F64,
    /// Round every entry to the nearest f32.
    F32,
    /// True bfloat16: 8-bit exponent, 7 explicit mantissa bits,
    /// round-to-nearest-even via f32 — relative error <= 2^-8 + 2^-24.
    Bf16,
}

impl WirePrecision {
    /// Bytes per f64 payload word on the wire.
    pub fn bytes_per_entry(&self) -> usize {
        match self {
            WirePrecision::F64 => 8,
            WirePrecision::F32 => 4,
            WirePrecision::Bf16 => 2,
        }
    }

    /// Short label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            WirePrecision::F64 => "f64",
            WirePrecision::F32 => "f32",
            WirePrecision::Bf16 => "bf16",
        }
    }

    /// Apply the precision loss to a vector in place — implemented *as*
    /// the encode→decode roundtrip of the matching codec, so quantized
    /// values and shipped values cannot diverge.
    pub fn quantize(&self, v: &mut [f64]) {
        WireCodec::new(*self).transcode(v);
    }
}

/// f64 -> bfloat16 bits: round to nearest f32 first (exact for every
/// value a bf16 can represent), then round-to-nearest-even on the 16
/// mantissa bits bf16 drops. The two rounding steps can land one bf16
/// ulp-tie differently than a single direct rounding would (classic
/// double rounding, bounded by an extra 2^-24 relative) — kept
/// deliberately, as it matches hardware f64→f32→bf16 conversion chains.
/// Overflow saturates to the signed infinity, NaN stays NaN (quietened,
/// payload kept non-zero).
fn f64_to_bf16(x: f64) -> u16 {
    let f = x as f32;
    let bits = f.to_bits();
    if f.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    ((bits + 0x7FFF + lsb) >> 16) as u16
}

/// bfloat16 bits -> f64 (exact: every bf16 value is an f32, every f32 is
/// an f64).
fn bf16_to_f64(b: u16) -> f64 {
    f32::from_bits((b as u32) << 16) as f64
}

/// An encoded payload: the bytes that would cross a real network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    precision: WirePrecision,
    entries: usize,
    bytes: Vec<u8>,
}

impl Frame {
    /// Precision the frame was encoded with.
    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Number of f64 payload words the frame carries.
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Payload size in bytes — what [`CommStats::bytes`] bills.
    ///
    /// [`CommStats::bytes`]: super::CommStats::bytes
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Raw encoded bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Encoder/decoder for wire payloads. Each tenant
/// [`Session`](super::Session) owns one (default: lossless) and passes
/// every request/response payload it ships through it; `CommStats.bytes`
/// is the sum of the encoded frames' sizes, never per-collective
/// `8 * d` arithmetic. Per-session ownership means a lossy tenant
/// cannot degrade a concurrent lossless tenant's traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireCodec {
    precision: WirePrecision,
}

impl Default for WireCodec {
    fn default() -> Self {
        Self::lossless()
    }
}

impl WireCodec {
    pub fn new(precision: WirePrecision) -> Self {
        WireCodec { precision }
    }

    /// The default codec: full f64, bit-exact roundtrip.
    pub fn lossless() -> Self {
        Self::new(WirePrecision::F64)
    }

    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Size in bytes of the frame [`WireCodec::encode`] would produce
    /// for a payload of `words` f64 words. Frames are fixed-width, so
    /// this is exact; the equivalence with `encode` is pinned by the
    /// codec tests and the propcheck byte property.
    pub fn frame_bytes(&self, words: usize) -> usize {
        words * self.precision.bytes_per_entry()
    }

    /// Encode a payload into the bytes that would cross the wire.
    pub fn encode(&self, payload: &[f64]) -> Frame {
        let bpe = self.precision.bytes_per_entry();
        let mut bytes = Vec::with_capacity(payload.len() * bpe);
        match self.precision {
            WirePrecision::F64 => {
                for x in payload {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            WirePrecision::F32 => {
                for x in payload {
                    bytes.extend_from_slice(&(*x as f32).to_le_bytes());
                }
            }
            WirePrecision::Bf16 => {
                for x in payload {
                    bytes.extend_from_slice(&f64_to_bf16(*x).to_le_bytes());
                }
            }
        }
        Frame { precision: self.precision, entries: payload.len(), bytes }
    }

    /// Decode a frame back into f64 words. Panics on a precision
    /// mismatch — a frame is only meaningful to the codec that wrote it.
    pub fn decode(&self, frame: &Frame) -> Vec<f64> {
        assert_eq!(
            frame.precision, self.precision,
            "codec/frame precision mismatch: frame is {:?}, codec is {:?}",
            frame.precision, self.precision
        );
        match self.precision {
            WirePrecision::F64 => frame
                .bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            WirePrecision::F32 => frame
                .bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
                .collect(),
            WirePrecision::Bf16 => frame
                .bytes
                .chunks_exact(2)
                .map(|c| bf16_to_f64(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
        }
    }

    /// Pass a payload through encode→decode in place — exactly what
    /// shipping the frame does to the numbers — and return the frame's
    /// size in bytes. This is the cluster's per-message billing
    /// primitive: for lossy codecs the byte count comes from the
    /// materialized frame itself, so billed bytes and shipped bytes
    /// cannot diverge. The lossless F64 codec skips materialization
    /// (the roundtrip is bit-exact and the frame size is `8·len`;
    /// both facts are pinned by `f64_codec_roundtrips_bit_exactly` and
    /// the propcheck byte property, which use [`WireCodec::encode`]
    /// directly) so the default path stays allocation-free.
    pub fn transcode(&self, payload: &mut [f64]) -> usize {
        if self.precision == WirePrecision::F64 {
            return self.frame_bytes(payload.len());
        }
        let frame = self.encode(payload);
        let decoded = self.decode(&frame);
        payload.copy_from_slice(&decoded);
        frame.wire_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_payload() -> Vec<f64> {
        vec![
            1.0,
            -0.3333333333333333,
            1e-8,
            12345.6789,
            -0.0,
            f64::MIN_POSITIVE, // subnormal territory after f32 cast -> 0
            3.5e38,
            -1.25,
        ]
    }

    #[test]
    fn f64_codec_roundtrips_bit_exactly() {
        let codec = WireCodec::lossless();
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 8 * v.len());
        assert_eq!(frame.entries(), v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 codec must be bit-exact");
        }
    }

    #[test]
    fn f32_codec_matches_f32_cast() {
        let codec = WireCodec::new(WirePrecision::F32);
        let v = sample_payload();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 4 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(*b, *a as f32 as f64);
        }
    }

    #[test]
    fn bf16_codec_error_is_at_most_half_ulp_plus_f32_term() {
        let codec = WireCodec::new(WirePrecision::Bf16);
        let mut rng = crate::rng::Pcg64::new(0xbf16);
        let v: Vec<f64> = (0..256).map(|_| rng.next_gaussian() * 10.0).collect();
        let frame = codec.encode(&v);
        assert_eq!(frame.wire_bytes(), 2 * v.len());
        let back = codec.decode(&frame);
        for (a, b) in v.iter().zip(&back) {
            // 7 explicit mantissa bits + RNE: relative error <= 2^-8 +
            // 2^-24 (the f32 double-rounding term) < 4e-3
            assert!((a - b).abs() <= 4e-3 * a.abs().max(1e-30), "{a} vs {b}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2^-8 sits exactly halfway between bf16(1.0) and the next
        // value up; ties go to the even mantissa, i.e. down to 1.0
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 1.0 / 256.0)), 1.0);
        // 1 + 3*2^-8 is halfway with an odd lower neighbor; ties go up
        assert_eq!(bf16_to_f64(f64_to_bf16(1.0 + 3.0 / 256.0)), 1.0 + 4.0 / 256.0);
        // exactly representable values pass through
        for x in [0.0, -0.0, 1.0, -2.5, 0.15625, 2.0f64.powi(127)] {
            assert_eq!(bf16_to_f64(f64_to_bf16(x)), x, "{x} is bf16-representable");
        }
    }

    #[test]
    fn bf16_handles_nonfinite_and_overflow() {
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::INFINITY)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(f64::NEG_INFINITY)), f64::NEG_INFINITY);
        assert!(bf16_to_f64(f64_to_bf16(f64::NAN)).is_nan());
        // beyond f32/bf16 range saturates to infinity rather than garbage
        assert_eq!(bf16_to_f64(f64_to_bf16(1e300)), f64::INFINITY);
        assert_eq!(bf16_to_f64(f64_to_bf16(-1e300)), f64::NEG_INFINITY);
    }

    #[test]
    fn quantize_is_the_encode_decode_roundtrip() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            let mut quantized = sample_payload();
            prec.quantize(&mut quantized);
            let shipped = codec.decode(&codec.encode(&sample_payload()));
            assert_eq!(quantized, shipped, "{prec:?}: quantize != ship");
        }
    }

    #[test]
    fn transcode_returns_frame_size_and_applies_roundtrip() {
        for (prec, bpe) in
            [(WirePrecision::F64, 8), (WirePrecision::F32, 4), (WirePrecision::Bf16, 2)]
        {
            let codec = WireCodec::new(prec);
            let mut v = sample_payload();
            let bytes = codec.transcode(&mut v);
            assert_eq!(bytes, bpe * v.len());
            let mut want = sample_payload();
            prec.quantize(&mut want);
            assert_eq!(v, want);
        }
    }

    #[test]
    #[should_panic(expected = "precision mismatch")]
    fn decode_rejects_foreign_frames() {
        let frame = WireCodec::new(WirePrecision::F32).encode(&[1.0, 2.0]);
        let _ = WireCodec::lossless().decode(&frame);
    }

    #[test]
    fn frame_bytes_matches_encode() {
        for prec in [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16] {
            let codec = WireCodec::new(prec);
            for words in [0usize, 1, 7, 64] {
                let payload = vec![0.25; words];
                assert_eq!(codec.frame_bytes(words), codec.encode(&payload).wire_bytes());
            }
        }
    }

    #[test]
    fn default_codec_is_lossless() {
        assert_eq!(WireCodec::default(), WireCodec::lossless());
        assert_eq!(WireCodec::default().precision(), WirePrecision::F64);
        assert_eq!(WirePrecision::F64.bytes_per_entry(), 8);
        assert_eq!(WirePrecision::F32.label(), "f32");
    }
}
