//! Per-tenant session: the billing and collective API of the cluster.
//!
//! A [`Session`] is one tenant's view of a shared [`Cluster`]: it owns
//! its own [`CommStats`] bill, its own [`WireCodec`] (a lossy tenant
//! cannot degrade a concurrent lossless tenant's traffic), and the
//! sequence numbers it draws from the cluster-wide namespace. Every
//! collective primitive lives here; the cluster itself only routes
//! messages, tracks worker liveness, and keeps the monotonic aggregate
//! bill ([`Cluster::aggregate_stats`]).
//!
//! **Concurrency model — split-phase collectives.** `Cluster` is
//! `Sync`, so any number of leader threads may hold sessions on one
//! cluster. A collective is two phases: [`Session::submit`] sends one
//! request to each worker under the cluster's **send lock** — held only
//! while the requests go out — and returns a [`Ticket`];
//! [`Ticket::complete`] collects the replies from the cluster's reply
//! **router**, which drains the shared reply stream on behalf of every
//! open ticket and routes each response by its echoed sequence number.
//! Nothing holds the wire across a reply wait, so concurrent tenants'
//! rounds overlap on the wire, and a single algorithm can keep several
//! independent rounds in flight at once (the split-phase collective
//! wrappers [`Session::dist_matvec_submit`] /
//! [`Session::dist_matmat_submit`] are the pipelining hooks the
//! coordinator hot loops use). `exchange` — submit immediately followed
//! by complete — is still what every one-round collective compiles to,
//! so nothing changes for serial callers. Overlap changes *when* a
//! round's messages move, never what they cost: every session's bill is
//! identical to the bill the same query would produce running alone —
//! the multi-tenant accounting invariant the propcheck properties in
//! `tests/integration.rs` and `tests/concurrency_stress.rs` assert.
//!
//! **Billing.** Outbound traffic (round, request messages, broadcast
//! frame) is billed at submit time; each response message is billed by
//! the router as it arrives, to the session whose ticket it answers —
//! both always applied twice, to the session's own stats and to the
//! cluster aggregate, so the aggregate is always the sum of everything
//! ever billed to any session — and equals the sum of the current
//! session bills whenever none has been reset (stragglers from a closed
//! session are dropped unbilled on both sides — see the router in
//! `cluster/mod.rs`).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::Shard;
use crate::linalg::Matrix;
use crate::sync::atomic::Ordering;
use crate::sync::Mutex;

use super::comm::CommStats;
use super::message::{Request, Response};
use super::wire::{CodecState, QuantBits, WireCodec, WireDesc, WireFormat, WirePrecision};
use super::{prune_inflight, Cluster, FuseMember, Slot};

/// Process-unique session ids: stamped into every trace event a session
/// emits so `dspca trace-report` can reassemble per-session timelines
/// and match them against closing bills.
static NEXT_SID: crate::sync::atomic::AtomicU64 = crate::sync::atomic::AtomicU64::new(0);

/// Mirror billed bytes into the per-format observability counter. Pure
/// observation — the `CommStats` ledgers are never touched from here.
fn obs_codec_bytes(format: WireFormat, bytes: u64) {
    match format {
        WireFormat::Plain(WirePrecision::F64) => crate::obs_add!(BYTES_F64_TOTAL, bytes),
        WireFormat::Plain(WirePrecision::F32) => crate::obs_add!(BYTES_F32_TOTAL, bytes),
        WireFormat::Plain(WirePrecision::Bf16) => crate::obs_add!(BYTES_BF16_TOTAL, bytes),
        WireFormat::Quant(QuantBits::Q8) => crate::obs_add!(BYTES_Q8_TOTAL, bytes),
        WireFormat::Quant(QuantBits::Q4) => crate::obs_add!(BYTES_Q4_TOTAL, bytes),
        WireFormat::TopS { .. } => crate::obs_add!(BYTES_TOPS_TOTAL, bytes),
    }
}

/// One session's codec lane: the installed [`WireCodec`] plus the
/// leader→workers [`CodecState`] stream (error-feedback residual,
/// adaptive width). Guarded together — the adapt→resolve→step sequence
/// in [`Session::submit`] must see a consistent pair. The worker→leader
/// direction's twin lives in each worker's
/// [`ReplyBank`](super::wire::ReplyBank), keyed by this session's sid.
pub(super) struct CodecLane {
    pub(super) codec: WireCodec,
    pub(super) state: CodecState,
}

/// The session state shared with the cluster's straggler-routing table:
/// inflight records hold a `Weak` to this, so a late reply can be billed
/// to the tenant that issued its sequence number — or dropped cleanly if
/// that tenant is gone.
pub(super) struct SessionCore {
    pub(super) stats: Mutex<CommStats>,
    pub(super) codec: Mutex<CodecLane>,
    /// Process-unique id, stamped into trace events (never billed).
    pub(super) sid: u64,
    /// Tenant label for the trace timeline (empty until
    /// [`Session::set_trace_label`]); read only on the close path.
    pub(super) label: Mutex<String>,
}

impl SessionCore {
    /// Bill one routed reply to this session **and** the cluster
    /// aggregate. This is the inbound half of the billing contract (the
    /// outbound half is [`Session::bill`]); the router calls it with the
    /// router-state lock held, so the lock order is
    /// `router.state → session.stats` and
    /// `router.state → cluster.aggregate` — and every `CommStats`
    /// mutation stays in this file (lint rule `commstats-mutation`).
    pub(super) fn bill_reply_arrival(
        &self,
        aggregate: &Mutex<CommStats>,
        bytes: u64,
        seq: u64,
        format: WireFormat,
    ) {
        {
            let mut stats = self.stats.lock();
            stats.responses_received += 1;
            stats.bytes += bytes;
        }
        {
            let mut agg = aggregate.lock();
            agg.responses_received += 1;
            agg.bytes += bytes;
        }
        // observation only, after both ledgers are settled: the trace
        // event mirrors exactly what was just billed, which is what
        // makes the Σ-traced-bytes == bill cross-check an identity
        crate::obs_inc!(CLUSTER_REPLIES_TOTAL);
        crate::obs_hist!(REPLY_BYTES, bytes);
        obs_codec_bytes(format, bytes);
        crate::obs_trace!(
            "reply",
            sid = self.sid,
            seq = seq,
            codec = format.label(),
            bytes = bytes
        );
    }

    /// Bill a member round's outbound traffic at fusion-flush time:
    /// `sent` request messages plus — if anything moved — one round and
    /// one broadcast frame of `req_bytes`. This is exactly what the
    /// same round bills in [`Session::submit`] (where the increments
    /// happen per send; the net effect is identical, including the
    /// partial-send-failure case where only the reached workers'
    /// messages are billed). Called by the cluster's fusion flusher
    /// with no router locks held; like [`Session::bill`], the two
    /// ledgers are locked one after the other, never nested.
    pub(super) fn bill_fused_submit(
        &self,
        aggregate: &Mutex<CommStats>,
        sent: u64,
        req_bytes: u64,
        seq: u64,
        format: WireFormat,
    ) {
        if sent == 0 {
            return;
        }
        {
            let mut st = self.stats.lock();
            st.requests_sent += sent;
            st.rounds += 1;
            st.bytes += req_bytes;
        }
        {
            let mut agg = aggregate.lock();
            agg.requests_sent += sent;
            agg.rounds += 1;
            agg.bytes += req_bytes;
        }
        crate::obs_inc!(CLUSTER_SUBMITS_TOTAL);
        crate::obs_hist!(SUBMIT_BYTES, req_bytes);
        obs_codec_bytes(format, req_bytes);
        crate::obs_trace!(
            "fused_submit",
            sid = self.sid,
            seq = seq,
            codec = format.label(),
            bytes = req_bytes,
            workers = sent
        );
    }
}

/// One tenant's handle on a shared [`Cluster`]: per-session
/// communication bill, per-session wire codec, and the full collective
/// API ([`Session::dist_matvec`], [`Session::dist_matmat`],
/// [`Session::local_top_eigvecs`], [`Session::local_top_k`],
/// [`Session::gram_average`], [`Session::oja_chain`]).
///
/// Create one with [`Cluster::session`]. Sessions are cheap (two mutexes
/// behind an `Arc`); single-query callers make one per run, services
/// make one per tenant/query. Dropping the session closes it: any
/// straggler reply still in flight for its sequence numbers is dropped
/// instead of billed.
pub struct Session<'c> {
    pub(super) cluster: &'c Cluster,
    pub(super) core: Arc<SessionCore>,
}

impl<'c> Session<'c> {
    pub(super) fn new(cluster: &'c Cluster) -> Session<'c> {
        Session {
            cluster,
            core: Arc::new(SessionCore {
                stats: Mutex::named(CommStats::default(), "session.stats"),
                codec: Mutex::named(
                    CodecLane { codec: WireCodec::default(), state: CodecState::default() },
                    "session.codec",
                ),
                sid: NEXT_SID.fetch_add(1, Ordering::Relaxed) + 1,
                label: Mutex::named(String::new(), "session.label"),
            }),
        }
    }

    /// The shared cluster this session runs on.
    pub fn cluster(&self) -> &'c Cluster {
        self.cluster
    }

    /// Number of machines `m`.
    pub fn m(&self) -> usize {
        self.cluster.m()
    }

    /// Per-machine sample size `n`.
    pub fn n(&self) -> usize {
        self.cluster.n()
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        self.cluster.d()
    }

    /// Number of live machines.
    pub fn live(&self) -> usize {
        self.cluster.live()
    }

    /// Machine 1's shard, visible to the leader for free (the leader *is*
    /// machine 1 in the paper's model).
    pub fn leader_shard(&self) -> &Shard {
        self.cluster.leader_shard()
    }

    /// This session's communication bill since creation or the last
    /// [`Session::reset_stats`]. Only traffic this session generated is
    /// in here — concurrent tenants bill separately.
    pub fn stats(&self) -> CommStats {
        self.core.stats.lock().clone()
    }

    /// Zero this session's bill. The cluster aggregate is monotonic and
    /// unaffected.
    pub fn reset_stats(&self) {
        *self.core.stats.lock() = CommStats::default();
    }

    /// This session's process-unique id — the `sid` field on every
    /// trace event it emits.
    pub fn sid(&self) -> u64 {
        self.core.sid
    }

    /// Tag this session with a tenant label for the trace timeline
    /// (`dspca trace-report` groups rounds by it). Pure observability:
    /// no effect on billing or scheduling.
    pub fn set_trace_label(&self, label: &str) {
        *self.core.label.lock() = label.to_string();
    }

    /// The wire codec installed on this session (default: lossless f64).
    pub fn codec(&self) -> WireCodec {
        self.core.codec.lock().codec
    }

    /// Install a wire codec **for this session only**. Every subsequent
    /// payload this session ships passes through it: lossy codecs both
    /// shrink the billed frames and degrade the delivered vectors,
    /// exactly as a real quantized wire would — without touching any
    /// concurrent tenant's traffic. Installing a codec resets the
    /// session's stream state (error-feedback residual, adaptive width):
    /// a new codec is a new stream.
    pub fn set_codec(&self, codec: WireCodec) {
        let mut lane = self.core.codec.lock();
        lane.codec = codec;
        lane.state = CodecState::for_codec(&codec);
    }

    /// Relative norm of the last error-feedback residual this session's
    /// leader→workers stream carried (0 for stateless codecs, and until
    /// the first stateful payload ships). The `final_residual` the
    /// quantized coordinator reports alongside `final_drift`.
    pub fn residual_norm(&self) -> f64 {
        self.core.codec.lock().state.last_residual_norm()
    }

    /// The adaptive controller's current bit-width, if this session's
    /// codec quantizes (`None` for plain f64/f32/bf16 codecs).
    pub fn active_bits(&self) -> Option<QuantBits> {
        self.core.codec.lock().state.active_bits()
    }

    /// (widenings, narrowings) the adaptive controller has performed on
    /// this session's outbound stream.
    pub fn codec_transitions(&self) -> (u64, u64) {
        self.core.codec.lock().state.transitions()
    }

    /// Close the session and return its final bill, **race-free**: after
    /// this returns, no straggler can be billed to this session anymore,
    /// and every straggler that *was* billed to it (by a concurrent
    /// tenant's drain, possibly after the algorithm's own stats
    /// snapshot) is included. This is what makes "Σ closed-session bills
    /// == aggregate window" exact for schedulers like `serve`: a plain
    /// drop + earlier `stats()` snapshot leaves a window in which a late
    /// reply lands on the aggregate but not on any report.
    pub fn close(self) -> CommStats {
        let Session { mut core, .. } = self;
        loop {
            // A straggler biller holds a transient strong ref (upgrade →
            // bill both ledgers → drop) under the wire lock, so this
            // loop is bounded by that critical section. Once `try_unwrap`
            // succeeds the strong count is zero: upgrades fail, billing
            // is impossible, and the stats we now own are final.
            match Arc::try_unwrap(core) {
                Ok(owned) => {
                    // `into_inner` recovers poison inside the shim
                    let stats = owned.stats.into_inner();
                    // the final, race-free bill is what the trace layer
                    // mirrors: emit it as the session's closing event so
                    // `dspca trace-report` can check Σ traced bytes
                    // against it
                    crate::obs_trace!(
                        "session_bill",
                        sid = owned.sid,
                        label = owned.label.into_inner(),
                        bytes = stats.bytes,
                        rounds = stats.rounds,
                        requests = stats.requests_sent,
                        responses = stats.responses_received
                    );
                    return stats;
                }
                Err(still_shared) => {
                    core = still_shared;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Apply one billing increment to both ledgers: this session's stats
    /// and the cluster aggregate. Keeping the two writes in one place is
    /// what makes "sum of session bills == aggregate" hold by
    /// construction.
    fn bill(&self, f: impl Fn(&mut CommStats)) {
        f(&mut self.core.stats.lock());
        f(&mut self.cluster.aggregate.lock());
    }

    /// **Submit phase** of a collective round: send `req` to every
    /// worker in `workers` under the cluster's send lock — held only
    /// while the requests go out — and return a [`Ticket`] for the
    /// replies. The round, its broadcast frame, and every request
    /// message are billed here, **as they happen** — to this session
    /// and the cluster aggregate — so a collective that later times out
    /// or fails still pays for the traffic it actually generated. The
    /// request payload passes through this session's [`WireCodec`] once
    /// (the §2.1 model bills a broadcast against the channel, not each
    /// recipient).
    ///
    /// If a send fails partway, the workers already reached may still
    /// reply; their provenance is recorded so those stragglers bill to
    /// this session at this round's codec width (or are dropped
    /// unbilled if the session closes first), and the error names the
    /// unreachable peer.
    ///
    /// Any number of tickets — from one session or many — may be in
    /// flight at once; replies are routed to the issuing ticket by the
    /// sequence number every worker echoes. Complete each ticket with
    /// [`Ticket::complete`]; a ticket dropped uncompleted retires onto
    /// the straggler path, never poisoning later collectives.
    pub fn submit(&self, workers: &[usize], req: &Request) -> Result<Ticket<'_, 'c>> {
        if workers.is_empty() {
            bail!("submit requires at least one worker");
        }
        // one request per distinct worker: a repeated id would fold two
        // replies into one reassembly slot, and an out-of-range id has
        // no peer — both are caller bugs surfaced as clean errors
        // before anything hits the wire
        let mut seen = vec![false; self.m()];
        for &w in workers {
            if w >= self.m() {
                bail!("submit: no such worker {w} (m = {})", self.m());
            }
            if std::mem::replace(&mut seen[w], true) {
                bail!("submit: worker {w} listed twice");
            }
        }
        let seq = self.cluster.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut req = req.clone();
        let cols = req.payload_cols();
        // The codec lane, in order: **adapt** the width from the
        // previous round's residual norm, **resolve** this round's wire
        // format, then **step** the stream — error-feedback add,
        // quantize in place, store the new residual. One short critical
        // section; the lane lock is released before any router or
        // transport lock is taken (DESIGN.md §11).
        let (codec, format, req_bytes) = {
            let mut lane = self.core.codec.lock();
            let codec = lane.codec;
            let (widened, narrowed) = lane.state.adapt(&codec);
            if widened {
                crate::obs_inc!(CODEC_WIDENINGS_TOTAL);
            }
            if narrowed {
                crate::obs_inc!(CODEC_NARROWINGS_TOTAL);
            }
            let format = codec.resolve(&lane.state);
            let track = codec.is_stateful();
            let bytes = req
                .payload_mut()
                .map_or(0, |p| lane.state.step(format, codec.feedback(), track, p, cols))
                as u64;
            (codec, format, bytes)
        };
        let desc = WireDesc { format, feedback: codec.feedback(), sid: self.core.sid };
        // open the routing slot before the first byte moves: a reply can
        // be routed by a concurrent driver the instant the send lands
        {
            let mut st = self.cluster.router.state.lock();
            prune_inflight(&mut st, seq);
            st.open.insert(
                seq,
                Slot {
                    format,
                    owner: Arc::downgrade(&self.core),
                    expected: workers.len(),
                    replies: Vec::with_capacity(workers.len()),
                    deadline: Instant::now() + self.cluster.timeout,
                },
            );
        }
        let mut sent = 0usize;
        let send_err = {
            let mut sender = self.cluster.sender.lock();
            let mut err = None;
            for &w in workers {
                // the transport moves the message (typed enum in-proc,
                // length-prefixed byte frame over TCP — encoded at this
                // round's resolved wire format); billing stays up here,
                // so the bill is backend-invariant
                if let Err(e) = sender.send(w, seq, desc, &req) {
                    err = Some(e);
                    break;
                }
                sent += 1;
                let first = sent == 1;
                self.bill(|st| {
                    st.requests_sent += 1;
                    if first {
                        // the round and its broadcast frame hit the wire
                        // with the first successful send, and are billed
                        // once regardless of fan-out; if no send
                        // succeeds, no traffic existed and nothing is
                        // billed
                        st.rounds += 1;
                        st.bytes += req_bytes;
                    }
                });
            }
            err
        };
        // observation only, outside the send lock: mirror exactly what
        // the loop above billed (round + broadcast frame iff the first
        // send landed), so the trace stays an identity over the bill
        let billed = if sent > 0 { req_bytes } else { 0 };
        crate::obs_inc!(CLUSTER_SUBMITS_TOTAL);
        if sent > 0 {
            crate::obs_hist!(SUBMIT_BYTES, billed);
            obs_codec_bytes(format, billed);
            if codec.is_stateful() {
                // stream health, refreshed per stateful round: what the
                // adaptive controller acted on, and what the round's
                // compression bought against a lossless f64 frame
                let rel = self.residual_norm();
                crate::obs_gauge!(CODEC_RESIDUAL_X1000, (rel * 1000.0) as u64);
                let words = req.payload().map_or(0, |p| p.len());
                if words > 0 && req_bytes > 0 {
                    let ratio = (8 * words) as f64 / req_bytes as f64;
                    crate::obs_gauge!(CODEC_COMPRESSION_X1000, (ratio * 1000.0) as u64);
                }
            }
        }
        crate::obs_trace!(
            "submit",
            sid = self.core.sid,
            seq = seq,
            codec = format.label(),
            bytes = billed,
            workers = sent
        );
        if let Some(e) = send_err {
            // only the workers actually reached owe replies; retire the
            // slot so their stragglers bill here (or nowhere, if we
            // reached nobody)
            let mut st = self.cluster.router.state.lock();
            if let Some(slot) = st.open.get_mut(&seq) {
                slot.expected = sent;
            }
            Cluster::retire_slot_locked(&mut st, seq);
            drop(st);
            self.cluster.router.cv.notify_all();
            return Err(e);
        }
        Ok(Ticket { session: self, seq, workers: workers.to_vec(), done: false })
    }

    /// Submit immediately followed by complete: the serial one-round
    /// collective every non-pipelined call site compiles to.
    fn exchange(&self, workers: &[usize], req: &Request) -> Result<Vec<Response>> {
        self.submit(workers, req)?.complete()
    }

    /// Fusable submit for matvec/matmat rounds (`data` row-major
    /// `d x k`; `vector` marks a matvec, whose reply comes back as a
    /// `Response::Vector`). With no fusion window configured this is a
    /// plain [`Session::submit`]. With one, the payload is transcoded
    /// once at this session's codec (exactly the frame a solo submit
    /// ships), the routing slot is opened *before* the member joins the
    /// pending batch — so a carrier reply can never race an absent
    /// slot — and the wire round happens at flush time. The returned
    /// ticket behaves exactly like an unfused one, and the bill is
    /// solo-identical by construction: outbound applied per member at
    /// flush ([`SessionCore::bill_fused_submit`]), inbound per split
    /// reply on arrival at this session's codec width.
    fn submit_fusable(
        &self,
        workers: &[usize],
        k: usize,
        data: Vec<f64>,
        vector: bool,
    ) -> Result<Ticket<'_, 'c>> {
        let d = self.d();
        let codec = self.codec();
        if !self.cluster.fusion_enabled() || !codec.fuses() {
            // A stateful codec (error-feedback, adaptive, top-s) never
            // enters the fusion window: a shared carrier would splice
            // foreign columns into this stream's residual arithmetic
            // and ship it under a codec that is not the member's. It
            // **displaces** instead — the pending batch (if any) is
            // flushed unfused — and the round ships solo through the
            // plain submit path, its bill and accumulator untouched by
            // concurrent fused tenants.
            if self.cluster.fusion_enabled() {
                self.cluster.displace_pending();
            }
            let req = if vector {
                Request::CovMatVec(data)
            } else {
                Request::CovMatMat { rows: d, cols: k, data }
            };
            return self.submit(workers, &req);
        }
        // `workers` is always the alive set here (distinct, in range),
        // so the duplicate/range validation in `submit` is not repeated
        let mut data = data;
        let req_bytes = codec.transcode(&mut data) as u64;
        let seq = self.cluster.seq.fetch_add(1, Ordering::Relaxed) + 1;
        {
            let mut st = self.cluster.router.state.lock();
            prune_inflight(&mut st, seq);
            st.open.insert(
                seq,
                Slot {
                    format: codec.default_format(),
                    owner: Arc::downgrade(&self.core),
                    expected: workers.len(),
                    replies: Vec::with_capacity(workers.len()),
                    deadline: Instant::now() + self.cluster.timeout,
                },
            );
        }
        self.cluster.enqueue_fused(
            codec,
            workers,
            FuseMember {
                seq,
                owner: Arc::downgrade(&self.core),
                cols: data,
                k,
                req_bytes,
                vector,
            },
        );
        Ok(Ticket { session: self, seq, workers: workers.to_vec(), done: false })
    }

    /// Distributed covariance matvec: `Xhat v = (1/m) sum_i Xhat_i v`.
    /// One communication round; the core primitive of the power method,
    /// Lanczos and the Shift-and-Invert solver (Algorithm 2, lines 2–6).
    pub fn dist_matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        self.dist_matvec_submit(v)?.complete()
    }

    /// Split-phase [`Session::dist_matvec`]: put the round on the wire
    /// and return immediately. Complete the returned ticket for the
    /// averaged result; until then the round is in flight and the
    /// leader is free — to compute, or to submit further independent
    /// rounds (pipelining). Billing is identical to the serial call.
    pub fn dist_matvec_submit(&self, v: &[f64]) -> Result<MatvecTicket<'_, 'c>> {
        let d = self.d();
        assert_eq!(v.len(), d);
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let inner = self.submit_fusable(&workers, 1, v.to_vec(), true)?;
        Ok(MatvecTicket { inner, d })
    }

    /// Distributed covariance **block** product:
    /// `Xhat V = (1/live) sum_i Xhat_i V` for a `d x k` block `V`.
    ///
    /// The core primitive of the top-`k` family (block power / orthogonal
    /// iteration, block Lanczos, batched deflation): **one round, one
    /// request/response message per live worker, `k` vectors of traffic
    /// each way** — where the column-wise loop it replaces paid `k`
    /// rounds and `k` message round-trips per worker. Numerically
    /// identical (up to summation order) to `k` [`Session::dist_matvec`]
    /// calls on the columns of `V`; billed as `k` matvec products.
    pub fn dist_matmat(&self, v: &Matrix) -> Result<Matrix> {
        self.dist_matmat_submit(v)?.complete()
    }

    /// Split-phase [`Session::dist_matmat`]: put the block round on the
    /// wire and return immediately — the pipelining hook the subspace
    /// hot loops use to overlap the in-flight round with leader-side QR
    /// of the previous block. Billing is identical to the serial call.
    pub fn dist_matmat_submit(&self, v: &Matrix) -> Result<MatmatTicket<'_, 'c>> {
        let d = self.d();
        assert_eq!(v.rows(), d, "dist_matmat: block must be d x k");
        let k = v.cols();
        assert!(k >= 1, "dist_matmat: empty block");
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let inner = self.submit_fusable(&workers, k, v.data().to_vec(), false)?;
        Ok(MatmatTicket { inner, d, k })
    }

    /// Gather every machine's local ERM solution (leading eigenvector of
    /// its `Xhat_i`). One round, `m` vectors to the leader. With
    /// `unbiased_signs`, each machine flips its eigenvector's sign by a
    /// private fair coin — the "unbiased ERM" premise of Theorem 3.
    pub fn local_top_eigvecs(&self, unbiased_signs: bool) -> Result<Vec<Vec<f64>>> {
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopEigvec { unbiased_signs })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            out.push(x);
        }
        self.bill(|st| st.vectors_gathered += workers.len() as u64);
        Ok(out)
    }

    /// Average of the local empirical covariances — the **centralized**
    /// baseline's input. One round but `m * d` vectors of traffic (the
    /// paper's round model only ships `R^d` vectors; this is the
    /// "ship-everything" reference point, not a round-efficient method).
    pub fn gram_average(&self) -> Result<Matrix> {
        let d = self.d();
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::Gram)?;
        let mut acc = Matrix::zeros(d, d);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            let m = Matrix::from_vec(rows, cols, data);
            acc.axpy_mat(1.0, &m);
        }
        acc.scale_mut(1.0 / workers.len() as f64);
        self.bill(|st| st.vectors_gathered += (workers.len() * d) as u64);
        Ok(acc)
    }

    /// Gather every machine's local top-`k` eigenbasis (`d x k` each).
    /// One round, `m * k` vectors of traffic.
    pub fn local_top_k(&self, k: usize) -> Result<Vec<Matrix>> {
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopK { k })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            out.push(Matrix::from_vec(rows, cols, data));
        }
        self.bill(|st| st.vectors_gathered += (workers.len() * k) as u64);
        Ok(out)
    }

    /// "Hot-potato" chain: pass the iterate machine-to-machine, each
    /// making a full Oja pass over its local samples. `m` rounds (one
    /// exchange per live machine — concurrent tenants may interleave
    /// between the hops, never inside one).
    pub fn oja_chain(&self, w0: &[f64], eta0: f64, t0: f64) -> Result<Vec<f64>> {
        assert_eq!(w0.len(), self.d());
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let mut w = w0.to_vec();
        let mut t_start = 0u64;
        for &i in &workers {
            let resps =
                self.exchange(&[i], &Request::OjaPass { w: w.clone(), eta0, t0, t_start })?;
            let Response::Vector(x) = &resps[0] else { bail!("unexpected response type") };
            w = x.clone();
            t_start += self.n() as u64;
            self.bill(|st| {
                st.vectors_broadcast += 1;
                st.vectors_gathered += 1;
            });
        }
        Ok(w)
    }
}

/// A submitted, in-flight collective round: the handle returned by
/// [`Session::submit`]. The requests are on the wire (and billed); the
/// replies accumulate in the reply router's slot for this ticket until
/// [`Ticket::complete`] collects them. Multiple tickets — from one
/// session or many — may be open at once; each is identified by the
/// cluster-unique sequence number its workers echo.
///
/// Dropping a ticket without completing it retires the round onto the
/// straggler path: replies still owed are drained by whoever runs the
/// router next and billed to this session on arrival (or dropped
/// unbilled once the session closes) — exactly like a timed-out round,
/// and never able to poison a later collective.
pub struct Ticket<'s, 'c> {
    session: &'s Session<'c>,
    seq: u64,
    /// Request order — replies are reassembled into this order.
    workers: Vec<usize>,
    done: bool,
}

impl Ticket<'_, '_> {
    /// The cluster-unique sequence number of this round.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The workers this round was sent to, in request order.
    pub fn workers(&self) -> &[usize] {
        &self.workers
    }

    /// **Complete phase**: park on the reply router until every owed
    /// reply has been routed to this ticket (driving the router while
    /// waiting — the completer that holds the reply stream delivers
    /// *everyone's* traffic, not just its own), then return the
    /// responses in request order. Each response was billed to the
    /// issuing session as it arrived, at this round's codec width.
    ///
    /// The full reply set is collected even when a worker reports an
    /// error — the round's traffic all really happened — and only then
    /// is the first worker error (in arrival order) surfaced. On
    /// timeout or a dead transport the ticket retires onto the
    /// straggler path and the same error the old drain loop produced is
    /// returned.
    pub fn complete(mut self) -> Result<Vec<Response>> {
        self.done = true;
        let workers = std::mem::take(&mut self.workers);
        let session = self.session;
        // a fused round may still be waiting in the fusion window: get
        // it onto the wire (waiting out the window remainder for more
        // members) and make sure its outbound bill has been applied
        session.cluster.ensure_flushed(self.seq, true);
        let replies = session.cluster.await_ticket(self.seq)?;
        crate::obs_inc!(CLUSTER_COMPLETES_TOTAL);
        crate::obs_trace!("complete", sid = session.core.sid, seq = self.seq);
        let mut by_worker: Vec<Option<Response>> = (0..session.m()).map(|_| None).collect();
        let mut first_err: Option<(usize, String)> = None;
        for (id, resp) in replies {
            if let Response::Err(e) = resp {
                if first_err.is_none() {
                    first_err = Some((id, e));
                }
                continue;
            }
            by_worker[id] = Some(resp);
        }
        if let Some((id, e)) = first_err {
            bail!("worker {id} failed: {e}");
        }
        Ok(workers.iter().map(|&w| by_worker[w].take().expect("missing response")).collect())
    }
}

impl Drop for Ticket<'_, '_> {
    fn drop(&mut self) {
        if !self.done {
            // an abandoned fused round still owes its wire traffic —
            // flush immediately (no window wait in a destructor) so the
            // submit half is billed exactly like a solo abandoned round
            self.session.cluster.ensure_flushed(self.seq, false);
            self.session.cluster.retire_ticket(self.seq);
        }
    }
}

/// An in-flight [`Session::dist_matvec`] round
/// ([`Session::dist_matvec_submit`]).
pub struct MatvecTicket<'s, 'c> {
    inner: Ticket<'s, 'c>,
    d: usize,
}

impl MatvecTicket<'_, '_> {
    /// Collect the replies and return the averaged matvec, billing the
    /// same tail counters the serial collective bills.
    pub fn complete(self) -> Result<Vec<f64>> {
        let MatvecTicket { inner, d } = self;
        let session = inner.session;
        let live = inner.workers.len();
        let resps = inner.complete()?;
        let mut acc = vec![0.0; d];
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            crate::linalg::vec_ops::axpy(&mut acc, 1.0, &x);
        }
        crate::linalg::vec_ops::scale(&mut acc, 1.0 / live as f64);
        session.bill(|st| {
            st.matvec_products += 1;
            st.vectors_broadcast += 1;
            st.vectors_gathered += live as u64;
        });
        Ok(acc)
    }
}

/// An in-flight [`Session::dist_matmat`] block round
/// ([`Session::dist_matmat_submit`]).
pub struct MatmatTicket<'s, 'c> {
    inner: Ticket<'s, 'c>,
    d: usize,
    k: usize,
}

impl MatmatTicket<'_, '_> {
    /// Width of the in-flight block (columns of the submitted basis).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Collect the replies and return the averaged block product,
    /// billing the same tail counters the serial collective bills.
    pub fn complete(self) -> Result<Matrix> {
        let MatmatTicket { inner, d, k } = self;
        let session = inner.session;
        let live = inner.workers.len();
        let resps = inner.complete()?;
        let mut acc = Matrix::zeros(d, k);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            if rows != d || cols != k {
                bail!("dist_matmat: worker returned {rows}x{cols}, expected {d}x{k}");
            }
            acc.axpy_mat(1.0, &Matrix::from_vec(rows, cols, data));
        }
        acc.scale_mut(1.0 / live as f64);
        session.bill(|st| {
            st.matvec_products += k as u64;
            st.vectors_broadcast += k as u64;
            st.vectors_gathered += (live * k) as u64;
        });
        Ok(acc)
    }
}
