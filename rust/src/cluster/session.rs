//! Per-tenant session: the billing and collective API of the cluster.
//!
//! A [`Session`] is one tenant's view of a shared [`Cluster`]: it owns
//! its own [`CommStats`] bill, its own [`WireCodec`] (a lossy tenant
//! cannot degrade a concurrent lossless tenant's traffic), and the
//! sequence numbers it draws from the cluster-wide namespace. Every
//! collective primitive lives here; the cluster itself only routes
//! messages, tracks worker liveness, and keeps the monotonic aggregate
//! bill ([`Cluster::aggregate_stats`]).
//!
//! **Concurrency model.** `Cluster` is `Sync`, so any number of leader
//! threads may hold sessions on one cluster. Wire access is serialized
//! at exchange granularity: one collective = one atomic
//! send-all/drain-all critical section under the cluster's wire lock,
//! so concurrent tenants interleave *between* rounds, never inside one.
//! Consequently every session's bill is identical to the bill the same
//! query would produce running alone — the multi-tenant accounting
//! invariant the propcheck properties in `tests/integration.rs` assert.
//!
//! **Billing.** Each increment is applied twice: to the session's own
//! stats and to the cluster aggregate, so the aggregate is always the
//! sum of everything ever billed to any session — and equals the sum
//! of the current session bills whenever none has been reset
//! (stragglers from a closed session are dropped unbilled on both
//! sides — see the exchange internals below).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::data::Shard;
use crate::linalg::Matrix;

use super::comm::CommStats;
use super::message::{Request, Response};
use super::wire::WireCodec;
use super::{prune_inflight, Cluster, Inflight};

/// The session state shared with the cluster's straggler-routing table:
/// inflight records hold a `Weak` to this, so a late reply can be billed
/// to the tenant that issued its sequence number — or dropped cleanly if
/// that tenant is gone.
pub(super) struct SessionCore {
    pub(super) stats: Mutex<CommStats>,
    pub(super) codec: Mutex<WireCodec>,
}

/// One tenant's handle on a shared [`Cluster`]: per-session
/// communication bill, per-session wire codec, and the full collective
/// API ([`Session::dist_matvec`], [`Session::dist_matmat`],
/// [`Session::local_top_eigvecs`], [`Session::local_top_k`],
/// [`Session::gram_average`], [`Session::oja_chain`]).
///
/// Create one with [`Cluster::session`]. Sessions are cheap (two mutexes
/// behind an `Arc`); single-query callers make one per run, services
/// make one per tenant/query. Dropping the session closes it: any
/// straggler reply still in flight for its sequence numbers is dropped
/// instead of billed.
pub struct Session<'c> {
    pub(super) cluster: &'c Cluster,
    pub(super) core: Arc<SessionCore>,
}

impl<'c> Session<'c> {
    pub(super) fn new(cluster: &'c Cluster) -> Session<'c> {
        Session {
            cluster,
            core: Arc::new(SessionCore {
                stats: Mutex::new(CommStats::default()),
                codec: Mutex::new(WireCodec::default()),
            }),
        }
    }

    /// The shared cluster this session runs on.
    pub fn cluster(&self) -> &'c Cluster {
        self.cluster
    }

    /// Number of machines `m`.
    pub fn m(&self) -> usize {
        self.cluster.m()
    }

    /// Per-machine sample size `n`.
    pub fn n(&self) -> usize {
        self.cluster.n()
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        self.cluster.d()
    }

    /// Number of live machines.
    pub fn live(&self) -> usize {
        self.cluster.live()
    }

    /// Machine 1's shard, visible to the leader for free (the leader *is*
    /// machine 1 in the paper's model).
    pub fn leader_shard(&self) -> &Shard {
        self.cluster.leader_shard()
    }

    /// This session's communication bill since creation or the last
    /// [`Session::reset_stats`]. Only traffic this session generated is
    /// in here — concurrent tenants bill separately.
    pub fn stats(&self) -> CommStats {
        self.core.stats.lock().unwrap().clone()
    }

    /// Zero this session's bill. The cluster aggregate is monotonic and
    /// unaffected.
    pub fn reset_stats(&self) {
        *self.core.stats.lock().unwrap() = CommStats::default();
    }

    /// The wire codec installed on this session (default: lossless f64).
    pub fn codec(&self) -> WireCodec {
        *self.core.codec.lock().unwrap()
    }

    /// Install a wire codec **for this session only**. Every subsequent
    /// payload this session ships passes through it: lossy codecs both
    /// shrink the billed frames and degrade the delivered vectors,
    /// exactly as a real quantized wire would — without touching any
    /// concurrent tenant's traffic.
    pub fn set_codec(&self, codec: WireCodec) {
        *self.core.codec.lock().unwrap() = codec;
    }

    /// Close the session and return its final bill, **race-free**: after
    /// this returns, no straggler can be billed to this session anymore,
    /// and every straggler that *was* billed to it (by a concurrent
    /// tenant's drain, possibly after the algorithm's own stats
    /// snapshot) is included. This is what makes "Σ closed-session bills
    /// == aggregate window" exact for schedulers like `serve`: a plain
    /// drop + earlier `stats()` snapshot leaves a window in which a late
    /// reply lands on the aggregate but not on any report.
    pub fn close(self) -> CommStats {
        let Session { mut core, .. } = self;
        loop {
            // A straggler biller holds a transient strong ref (upgrade →
            // bill both ledgers → drop) under the wire lock, so this
            // loop is bounded by that critical section. Once `try_unwrap`
            // succeeds the strong count is zero: upgrades fail, billing
            // is impossible, and the stats we now own are final.
            match Arc::try_unwrap(core) {
                Ok(owned) => {
                    return owned.stats.into_inner().unwrap_or_else(|p| p.into_inner());
                }
                Err(still_shared) => {
                    core = still_shared;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Apply one billing increment to both ledgers: this session's stats
    /// and the cluster aggregate. Keeping the two writes in one place is
    /// what makes "sum of session bills == aggregate" hold by
    /// construction.
    fn bill(&self, f: impl Fn(&mut CommStats)) {
        f(&mut self.core.stats.lock().unwrap());
        f(&mut self.cluster.aggregate.lock().unwrap());
    }

    /// Send `req` to a set of workers and collect their responses in
    /// worker order. One call is one synchronous round, executed as one
    /// critical section under the cluster's wire lock (concurrent
    /// sessions serialize at round granularity). The round, every
    /// request message, and every response message are billed **as they
    /// happen** — to this session and the cluster aggregate — so a
    /// timed-out or partially-failed collective still pays for the
    /// traffic it actually generated.
    ///
    /// Payloads pass through this session's [`WireCodec`] in both
    /// directions: the request payload is encoded once — the §2.1 model
    /// bills a broadcast against the channel, not per recipient — and
    /// each response payload on arrival, with `CommStats.bytes` advanced
    /// by the encoded frames' sizes and the decoded (possibly lossy)
    /// values delivered onward.
    ///
    /// On worker failure, the **full** response set is still drained
    /// before the error is reported: the response channel is shared by
    /// every session, so bailing early would leave the surviving
    /// workers' replies queued. Replies that *do* outlive their exchange
    /// (a worker stalls past the timeout and answers later) are caught
    /// by the sequence number every worker echoes: a stale reply is
    /// billed on arrival **to the session that issued that sequence
    /// number** — it really crossed the wire, at the codec width its own
    /// round shipped under (tracked per failed exchange in the wire
    /// state's inflight map) — whichever tenant happens to drain it. If
    /// the issuing session has since been closed (or the record aged
    /// out), the reply is dropped unbilled on both ledgers, keeping
    /// "sum of session bills == aggregate" exact.
    fn exchange(&self, workers: &[usize], req: &Request) -> Result<Vec<Response>> {
        let codec = self.codec();
        let seq = self.cluster.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut guard = self.cluster.wire.lock().unwrap();
        let wire = &mut *guard;
        let mut req = req.clone();
        let req_bytes = req.payload_mut().map_or(0, |p| codec.transcode(p)) as u64;
        let mut sent = 0usize;
        for &w in workers {
            // the transport moves the message (typed enum in-proc,
            // length-prefixed byte frame over TCP — encoded at this
            // session's wire precision); billing stays up here, so the
            // bill is backend-invariant
            if let Err(e) = wire.transport.send(w, seq, codec.precision(), &req) {
                if sent > 0 {
                    // the workers already reached may still reply; leave
                    // a record so their stragglers bill to this session
                    // at this width
                    prune_inflight(&mut wire.inflight, seq);
                    wire.inflight.insert(
                        seq,
                        Inflight { codec, outstanding: sent, owner: Arc::downgrade(&self.core) },
                    );
                }
                return Err(e);
            }
            sent += 1;
            let first = sent == 1;
            self.bill(|st| {
                st.requests_sent += 1;
                if first {
                    // the round and its broadcast frame hit the wire with
                    // the first successful send, and are billed once
                    // regardless of fan-out; if no send succeeds, no
                    // traffic existed and nothing is billed
                    st.rounds += 1;
                    st.bytes += req_bytes;
                }
            });
        }
        let mut responses: Vec<Option<Response>> = vec![None; self.cluster.m()];
        let mut first_err: Option<(usize, String)> = None;
        let mut got = 0usize;
        while got < workers.len() {
            let (id, rseq, mut resp) = match wire.transport.recv_timeout(self.cluster.timeout) {
                Ok(msg) => msg,
                Err(e) => {
                    prune_inflight(&mut wire.inflight, seq);
                    wire.inflight.insert(
                        seq,
                        Inflight {
                            codec,
                            outstanding: workers.len() - got,
                            owner: Arc::downgrade(&self.core),
                        },
                    );
                    bail!("waiting for worker responses: {e}");
                }
            };
            if rseq != seq {
                // straggler from an exchange that already failed —
                // possibly another session's. Bill it to the session
                // that issued `rseq`, at the width its own round shipped
                // under; if that session is closed or the record was
                // pruned, drop the reply unbilled.
                let mut record = None;
                if let Some(rec) = wire.inflight.get_mut(&rseq) {
                    rec.outstanding -= 1;
                    record = Some((rec.codec, rec.owner.clone(), rec.outstanding == 0));
                }
                if let Some((stale_codec, owner, emptied)) = record {
                    if emptied {
                        wire.inflight.remove(&rseq);
                    }
                    if let Some(owner) = owner.upgrade() {
                        let stale_bytes =
                            resp.payload().map_or(0, |p| stale_codec.frame_bytes(p.len())) as u64;
                        {
                            let mut st = owner.stats.lock().unwrap();
                            st.responses_received += 1;
                            st.bytes += stale_bytes;
                        }
                        let mut agg = self.cluster.aggregate.lock().unwrap();
                        agg.responses_received += 1;
                        agg.bytes += stale_bytes;
                    }
                }
                continue;
            }
            let resp_bytes = resp.payload_mut().map_or(0, |p| codec.transcode(p)) as u64;
            self.bill(|st| {
                st.responses_received += 1;
                st.bytes += resp_bytes;
            });
            got += 1;
            if let Response::Err(e) = resp {
                if first_err.is_none() {
                    first_err = Some((id, e));
                }
                continue;
            }
            responses[id] = Some(resp);
        }
        if let Some((id, e)) = first_err {
            bail!("worker {id} failed: {e}");
        }
        Ok(workers.iter().map(|&w| responses[w].take().expect("missing response")).collect())
    }

    /// Distributed covariance matvec: `Xhat v = (1/m) sum_i Xhat_i v`.
    /// One communication round; the core primitive of the power method,
    /// Lanczos and the Shift-and-Invert solver (Algorithm 2, lines 2–6).
    pub fn dist_matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        let d = self.d();
        assert_eq!(v.len(), d);
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::CovMatVec(v.to_vec()))?;
        let mut acc = vec![0.0; d];
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            crate::linalg::vec_ops::axpy(&mut acc, 1.0, &x);
        }
        crate::linalg::vec_ops::scale(&mut acc, 1.0 / workers.len() as f64);
        self.bill(|st| {
            st.matvec_products += 1;
            st.vectors_broadcast += 1;
            st.vectors_gathered += workers.len() as u64;
        });
        Ok(acc)
    }

    /// Distributed covariance **block** product:
    /// `Xhat V = (1/live) sum_i Xhat_i V` for a `d x k` block `V`.
    ///
    /// The core primitive of the top-`k` family (block power / orthogonal
    /// iteration, block Lanczos, batched deflation): **one round, one
    /// request/response message per live worker, `k` vectors of traffic
    /// each way** — where the column-wise loop it replaces paid `k`
    /// rounds and `k` message round-trips per worker. Numerically
    /// identical (up to summation order) to `k` [`Session::dist_matvec`]
    /// calls on the columns of `V`; billed as `k` matvec products.
    pub fn dist_matmat(&self, v: &Matrix) -> Result<Matrix> {
        let d = self.d();
        assert_eq!(v.rows(), d, "dist_matmat: block must be d x k");
        let k = v.cols();
        assert!(k >= 1, "dist_matmat: empty block");
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let req = Request::CovMatMat { rows: d, cols: k, data: v.data().to_vec() };
        let resps = self.exchange(&workers, &req)?;
        let mut acc = Matrix::zeros(d, k);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            if rows != d || cols != k {
                bail!("dist_matmat: worker returned {rows}x{cols}, expected {d}x{k}");
            }
            acc.axpy_mat(1.0, &Matrix::from_vec(rows, cols, data));
        }
        acc.scale_mut(1.0 / workers.len() as f64);
        self.bill(|st| {
            st.matvec_products += k as u64;
            st.vectors_broadcast += k as u64;
            st.vectors_gathered += (workers.len() * k) as u64;
        });
        Ok(acc)
    }

    /// Gather every machine's local ERM solution (leading eigenvector of
    /// its `Xhat_i`). One round, `m` vectors to the leader. With
    /// `unbiased_signs`, each machine flips its eigenvector's sign by a
    /// private fair coin — the "unbiased ERM" premise of Theorem 3.
    pub fn local_top_eigvecs(&self, unbiased_signs: bool) -> Result<Vec<Vec<f64>>> {
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopEigvec { unbiased_signs })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            out.push(x);
        }
        self.bill(|st| st.vectors_gathered += workers.len() as u64);
        Ok(out)
    }

    /// Average of the local empirical covariances — the **centralized**
    /// baseline's input. One round but `m * d` vectors of traffic (the
    /// paper's round model only ships `R^d` vectors; this is the
    /// "ship-everything" reference point, not a round-efficient method).
    pub fn gram_average(&self) -> Result<Matrix> {
        let d = self.d();
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::Gram)?;
        let mut acc = Matrix::zeros(d, d);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            let m = Matrix::from_vec(rows, cols, data);
            acc.axpy_mat(1.0, &m);
        }
        acc.scale_mut(1.0 / workers.len() as f64);
        self.bill(|st| st.vectors_gathered += (workers.len() * d) as u64);
        Ok(acc)
    }

    /// Gather every machine's local top-`k` eigenbasis (`d x k` each).
    /// One round, `m * k` vectors of traffic.
    pub fn local_top_k(&self, k: usize) -> Result<Vec<Matrix>> {
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopK { k })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            out.push(Matrix::from_vec(rows, cols, data));
        }
        self.bill(|st| st.vectors_gathered += (workers.len() * k) as u64);
        Ok(out)
    }

    /// "Hot-potato" chain: pass the iterate machine-to-machine, each
    /// making a full Oja pass over its local samples. `m` rounds (one
    /// exchange per live machine — concurrent tenants may interleave
    /// between the hops, never inside one).
    pub fn oja_chain(&self, w0: &[f64], eta0: f64, t0: f64) -> Result<Vec<f64>> {
        assert_eq!(w0.len(), self.d());
        let workers = self.cluster.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let mut w = w0.to_vec();
        let mut t_start = 0u64;
        for &i in &workers {
            let resps =
                self.exchange(&[i], &Request::OjaPass { w: w.clone(), eta0, t0, t_start })?;
            let Response::Vector(x) = &resps[0] else { bail!("unexpected response type") };
            w = x.clone();
            t_start += self.n() as u64;
            self.bill(|st| {
                st.vectors_broadcast += 1;
                st.vectors_gathered += 1;
            });
        }
        Ok(w)
    }
}
