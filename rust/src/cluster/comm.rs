//! Communication accounting — the paper's cost model made measurable.

/// Counters for all communication performed by a cluster since the last
/// reset. A *round* follows §2.1: the leader broadcasts at most one
/// `R^d` vector and every machine sends at most one vector back.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Synchronous communication rounds.
    pub rounds: u64,
    /// Distributed matrix-vector products with `Xhat` (the unit Thm 6
    /// counts). A `d x k` block product ([`dist_matmat`]) bills `k` — it
    /// is numerically `k` matvecs fused into one round.
    ///
    /// [`dist_matmat`]: crate::cluster::Cluster::dist_matmat
    pub matvec_products: u64,
    /// Vectors broadcast leader -> workers.
    pub vectors_broadcast: u64,
    /// Vectors gathered workers -> leader.
    pub vectors_gathered: u64,
    /// Request **messages** sent leader -> workers. One collective op
    /// costs exactly one request per live worker regardless of how many
    /// vectors the message carries — this is what distinguishes the block
    /// protocol (1 message of `k` vectors) from `k` column-wise calls
    /// (`k` messages).
    pub requests_sent: u64,
    /// Response **messages** received workers -> leader. Error replies
    /// count too: they crossed the wire whether or not the collective
    /// succeeded.
    pub responses_received: u64,
    /// Total payload bytes moved, billed from the wire codec's encoded
    /// frames ([`WireCodec`]): 8 bytes per f64 word under the default
    /// lossless codec, 4 under F32, 2 under Bf16. Broadcast frames are
    /// billed once regardless of fan-out.
    ///
    /// [`WireCodec`]: crate::cluster::WireCodec
    pub bytes: u64,
}

impl CommStats {
    /// Merge another stats block into this one (used when an algorithm
    /// combines phases measured separately).
    pub fn merge(&mut self, other: &CommStats) {
        self.rounds += other.rounds;
        self.matvec_products += other.matvec_products;
        self.vectors_broadcast += other.vectors_broadcast;
        self.vectors_gathered += other.vectors_gathered;
        self.requests_sent += other.requests_sent;
        self.responses_received += other.responses_received;
        self.bytes += other.bytes;
    }
}

impl std::fmt::Display for CommStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rounds={} matvecs={} bcast={} gathered={} reqs={} resps={} bytes={}",
            self.rounds,
            self.matvec_products,
            self.vectors_broadcast,
            self.vectors_gathered,
            self.requests_sent,
            self.responses_received,
            self.bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds() {
        let mut a = CommStats {
            rounds: 1,
            matvec_products: 2,
            vectors_broadcast: 3,
            vectors_gathered: 4,
            requests_sent: 5,
            responses_received: 6,
            bytes: 7,
        };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.rounds, 2);
        assert_eq!(a.requests_sent, 10);
        assert_eq!(a.responses_received, 12);
        assert_eq!(a.bytes, 14);
    }

    #[test]
    fn display_contains_fields() {
        let s = CommStats::default().to_string();
        assert!(s.contains("rounds=0"));
        assert!(s.contains("reqs=0"));
    }
}
