//! Communication accounting — the paper's cost model made measurable.

/// Counters for the communication performed by one tenant
/// ([`Session`](crate::cluster::Session)) since the last reset, or by the
/// whole cluster since construction
/// ([`Cluster::aggregate_stats`](crate::cluster::Cluster::aggregate_stats),
/// monotonic). A *round* follows §2.1: the leader broadcasts at most one
/// `R^d` vector and every machine sends at most one vector back.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Synchronous communication rounds.
    pub rounds: u64,
    /// Distributed matrix-vector products with `Xhat` (the unit Thm 6
    /// counts). A `d x k` block product ([`dist_matmat`]) bills `k` — it
    /// is numerically `k` matvecs fused into one round.
    ///
    /// [`dist_matmat`]: crate::cluster::Session::dist_matmat
    pub matvec_products: u64,
    /// Vectors broadcast leader -> workers.
    pub vectors_broadcast: u64,
    /// Vectors gathered workers -> leader.
    pub vectors_gathered: u64,
    /// Request **messages** sent leader -> workers. One collective op
    /// costs exactly one request per live worker regardless of how many
    /// vectors the message carries — this is what distinguishes the block
    /// protocol (1 message of `k` vectors) from `k` column-wise calls
    /// (`k` messages).
    pub requests_sent: u64,
    /// Response **messages** received workers -> leader. Error replies
    /// count too: they crossed the wire whether or not the collective
    /// succeeded.
    pub responses_received: u64,
    /// Total payload bytes moved, billed from the wire codec's encoded
    /// frames ([`WireCodec`]): 8 bytes per f64 word under the default
    /// lossless codec, 4 under F32, 2 under Bf16; the stateful family
    /// bills its materialized [`WireFormat`] frames — `4·cols + w` for
    /// q8, `4·cols + ⌈w/2⌉` for q4, and `8 + 4·kept + levels(kept)` for
    /// top-s sparse frames. Error feedback and the adaptive controller
    /// change *which* format a round resolves to, never how a format is
    /// priced, and an adaptive straggler is billed at the width its own
    /// round shipped. Broadcast frames are billed once regardless of
    /// fan-out.
    ///
    /// [`WireCodec`]: crate::cluster::WireCodec
    /// [`WireFormat`]: crate::cluster::WireFormat
    pub bytes: u64,
}

impl CommStats {
    /// Merge another stats block into this one (used when an algorithm
    /// combines phases measured separately, and to sum concurrent
    /// tenants' bills against the cluster aggregate).
    pub fn merge(&mut self, other: &CommStats) {
        self.rounds += other.rounds;
        self.matvec_products += other.matvec_products;
        self.vectors_broadcast += other.vectors_broadcast;
        self.vectors_gathered += other.vectors_gathered;
        self.requests_sent += other.requests_sent;
        self.responses_received += other.responses_received;
        self.bytes += other.bytes;
    }

    /// Field-wise difference against an earlier snapshot of the same
    /// monotonic counter set. This is how callers meter a window of the
    /// cluster's aggregate bill (snapshot before, subtract after) without
    /// a reset that would stomp concurrent tenants.
    pub fn delta_since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            rounds: self.rounds.saturating_sub(earlier.rounds),
            matvec_products: self.matvec_products.saturating_sub(earlier.matvec_products),
            vectors_broadcast: self.vectors_broadcast.saturating_sub(earlier.vectors_broadcast),
            vectors_gathered: self.vectors_gathered.saturating_sub(earlier.vectors_gathered),
            requests_sent: self.requests_sent.saturating_sub(earlier.requests_sent),
            responses_received: self.responses_received.saturating_sub(earlier.responses_received),
            bytes: self.bytes.saturating_sub(earlier.bytes),
        }
    }
}

impl std::fmt::Display for CommStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rounds={} matvecs={} bcast={} gathered={} reqs={} resps={} bytes={}",
            self.rounds,
            self.matvec_products,
            self.vectors_broadcast,
            self.vectors_gathered,
            self.requests_sent,
            self.responses_received,
            self.bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds() {
        let mut a = CommStats {
            rounds: 1,
            matvec_products: 2,
            vectors_broadcast: 3,
            vectors_gathered: 4,
            requests_sent: 5,
            responses_received: 6,
            bytes: 7,
        };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.rounds, 2);
        assert_eq!(a.requests_sent, 10);
        assert_eq!(a.responses_received, 12);
        assert_eq!(a.bytes, 14);
    }

    #[test]
    fn delta_since_inverts_merge() {
        let earlier = CommStats {
            rounds: 1,
            matvec_products: 2,
            vectors_broadcast: 3,
            vectors_gathered: 4,
            requests_sent: 5,
            responses_received: 6,
            bytes: 7,
        };
        let mut later = earlier.clone();
        let window = CommStats { rounds: 10, bytes: 100, ..Default::default() };
        later.merge(&window);
        assert_eq!(later.delta_since(&earlier), window);
        // saturates rather than underflowing on a mismatched snapshot
        assert_eq!(earlier.delta_since(&later).rounds, 0);
    }

    #[test]
    fn display_contains_fields() {
        let s = CommStats::default().to_string();
        assert!(s.contains("rounds=0"));
        assert!(s.contains("reqs=0"));
    }
}
