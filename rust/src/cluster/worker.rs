//! Worker thread: owns one shard and a compute oracle, answers leader
//! requests until shutdown.
//!
//! The compute oracle abstracts *how* the local numerical work is done:
//! [`NativeOracle`] computes in pure Rust; the PJRT oracle in
//! [`crate::runtime`] executes the AOT-compiled JAX/Pallas artifacts. The
//! oracle is constructed *inside* the worker thread from an [`OracleSpec`]
//! (PJRT clients are not `Send`).
//!
//! Workers see payloads exactly as the wire delivers them: the leader
//! passes every request through the issuing session's
//! [`WireCodec`](super::WireCodec) (encode→decode) before it reaches this
//! loop, so under a lossy codec the shard math runs on the degraded
//! vectors. Replies are compressed **worker-side** at the request's
//! [`WireDesc`](super::WireDesc): each worker keeps a
//! [`ReplyBank`](super::ReplyBank) — one error-feedback accumulator per
//! session id — and quantizes every reply payload through it before the
//! send, on every backend. No handshake ships this state; it is rebuilt
//! purely from the request envelopes the worker sees.

use std::sync::mpsc;
use std::sync::Arc;

use crate::data::Shard;
use crate::linalg::vec_ops;
use crate::rng::Pcg64;

use super::message::{Request, Response};
use super::wire::{ReplyBank, WireDesc};

/// Local compute engine interface. `&mut self` because engines may keep
/// caches (compiled executables, scratch buffers).
pub trait ComputeOracle {
    /// `Xhat_i v` for the local shard.
    fn cov_matvec(&mut self, shard: &Shard, v: &[f64]) -> anyhow::Result<Vec<f64>>;

    /// Block product `Xhat_i V` for a `d x k` basis `V` — the local half
    /// of the cluster's block protocol ([`crate::cluster::Session::dist_matmat`]).
    ///
    /// Default: loop [`ComputeOracle::cov_matvec`] column by column, so
    /// every oracle is block-capable. Oracles with a batched kernel
    /// (e.g. [`NativeOracle`]'s blocked shard-level `A^T (A V)`) override
    /// this to amortize the pass over the shard across all `k` columns.
    fn cov_matmat(
        &mut self,
        shard: &Shard,
        v: &crate::linalg::Matrix,
    ) -> anyhow::Result<crate::linalg::Matrix> {
        let d = shard.d();
        anyhow::ensure!(v.rows() == d, "cov_matmat: block must be {d} x k, got {} rows", v.rows());
        let k = v.cols();
        anyhow::ensure!(k >= 1, "cov_matmat: empty block");
        let mut out = crate::linalg::Matrix::zeros(d, k);
        for c in 0..k {
            let col = self.cov_matvec(shard, &v.col(c))?;
            out.set_col(c, &col);
        }
        Ok(out)
    }

    /// Leading eigenvector of the local empirical covariance (unit norm,
    /// deterministic sign).
    fn local_top_eigvec(&mut self, shard: &Shard) -> anyhow::Result<Vec<f64>>;

    /// Local empirical covariance matrix.
    fn gram(&mut self, shard: &Shard) -> anyhow::Result<crate::linalg::Matrix>;

    /// Top-`k` local eigenbasis (`d x k`). Default: eigendecompose the
    /// oracle's Gram output — works for both the native and PJRT oracles
    /// (the PJRT Gram comes from the AOT kernel; the small `d x d`
    /// eigensolve stays on the worker CPU either way).
    fn local_top_k(&mut self, shard: &Shard, k: usize) -> anyhow::Result<crate::linalg::Matrix> {
        let g = self.gram(shard)?;
        let d = g.rows();
        anyhow::ensure!(k >= 1 && k <= d, "local_top_k: bad rank {k} for d={d}");
        let eig = crate::linalg::eigen::SymEigen::new(&g);
        let mut w = crate::linalg::Matrix::zeros(d, k);
        for c in 0..k {
            w.set_col(c, &eig.eigvec(c));
        }
        Ok(w)
    }

    /// One sequential Oja pass over the shard's rows:
    /// `w <- normalize(w + eta_t * x_t (x_t^T w))`, `eta_t = eta0/(t0+t)`.
    fn oja_pass(
        &mut self,
        shard: &Shard,
        w: &[f64],
        eta0: f64,
        t0: f64,
        t_start: u64,
    ) -> anyhow::Result<Vec<f64>> {
        // default implementation shared by both oracles: the per-sample
        // update is O(d) and memory-bound; there is nothing for an
        // accelerator kernel to win here unless batched (see
        // python/compile/model.py:oja_pass for the batched variant).
        // Store-agnostic via row_dot/row_axpy: identical arithmetic to
        // the historical dense slice loop, and CSR shards stream their
        // non-zeros.
        let mut w = w.to_vec();
        let d = shard.d();
        assert_eq!(w.len(), d);
        for i in 0..shard.n() {
            let t = t_start + i as u64;
            let eta = eta0 / (t0 + t as f64);
            let xw = shard.row_dot(i, &w);
            shard.row_axpy(i, eta * xw, &mut w);
            vec_ops::normalize(&mut w);
        }
        Ok(w)
    }
}

/// Product horizon the native oracle assumes when consulting
/// [`Shard::prefer_gram`]: iterative coordinators (power, Lanczos, Oja
/// chains) issue at least this many matvec-equivalent products per run.
///
/// Deliberately a **fixed constant**, not a running counter: the
/// materialization decision must be a pure function of the shard shape so
/// worker numerics stay bit-identical across transport backends and
/// independent of request interleaving under concurrent multi-tenant
/// serve (round counts are convergence-dependent, so
/// interleaving-dependent last-bit drift would make bills
/// nondeterministic).
const GRAM_HORIZON: usize = 64;

/// Pure-Rust compute oracle.
#[derive(Default)]
pub struct NativeOracle {
    scratch: Vec<f64>,
}

impl NativeOracle {
    /// Materialize the shard Gram up front when the
    /// [`Shard::prefer_gram`] cost model says repeated products amortize
    /// the build (fixing the "stream O(nd) forever" regression — the
    /// model used to be computed and never consulted). No-op once cached.
    fn ensure_preferred_path(shard: &Shard) {
        if !shard.gram_ready() && shard.prefer_gram(GRAM_HORIZON) {
            let _ = shard.empirical_covariance();
        }
    }
}

impl ComputeOracle for NativeOracle {
    fn cov_matvec(&mut self, shard: &Shard, v: &[f64]) -> anyhow::Result<Vec<f64>> {
        Self::ensure_preferred_path(shard);
        let mut out = vec![0.0; shard.d()];
        shard.cov_matvec_into(v, &mut self.scratch, &mut out);
        Ok(out)
    }

    fn cov_matmat(
        &mut self,
        shard: &Shard,
        v: &crate::linalg::Matrix,
    ) -> anyhow::Result<crate::linalg::Matrix> {
        let d = shard.d();
        anyhow::ensure!(v.rows() == d, "cov_matmat: block must be {d} x k, got {} rows", v.rows());
        anyhow::ensure!(v.cols() >= 1, "cov_matmat: empty block");
        Self::ensure_preferred_path(shard);
        let mut out = crate::linalg::Matrix::zeros(d, v.cols());
        shard.cov_matmat_into(v, &mut self.scratch, &mut out);
        Ok(out)
    }

    fn local_top_eigvec(&mut self, shard: &Shard) -> anyhow::Result<Vec<f64>> {
        Ok(shard.local_top_eigvec())
    }

    fn gram(&mut self, shard: &Shard) -> anyhow::Result<crate::linalg::Matrix> {
        Ok(shard.empirical_covariance().clone())
    }
}

/// How each worker should build its compute oracle.
#[derive(Clone, Debug)]
pub enum OracleSpec {
    /// Pure Rust ([`NativeOracle`]).
    Native,
    /// PJRT-backed: load AOT HLO artifacts from this directory (see
    /// `python/compile/aot.py` and [`crate::runtime`]).
    Pjrt { artifact_dir: String },
}

impl OracleSpec {
    pub(crate) fn build(&self) -> anyhow::Result<Box<dyn ComputeOracle>> {
        match self {
            OracleSpec::Native => Ok(Box::new(NativeOracle::default())),
            OracleSpec::Pjrt { artifact_dir } => {
                Ok(Box::new(crate::runtime::PjrtOracle::new(artifact_dir)?))
            }
        }
    }
}

/// Per-worker seed stream: `next_u64()` once per worker, in worker
/// order, yields each worker's RNG seed. One derivation shared by every
/// transport backend — the in-proc spawner draws it locally, the TCP
/// leader draws the same values and ships them in the handshake — so
/// worker coin flips (and therefore estimates and bills) are
/// backend-invariant at a fixed cluster seed. Must stay the single
/// source of truth: a divergent copy would silently break the
/// invariance contract.
pub(crate) fn worker_seeder(seed: u64) -> Pcg64 {
    Pcg64::with_stream(seed, 0x3a1e)
}

/// The worker-side RNG (sign coins for unbiased ERM), built from the
/// seed [`worker_seeder`] dealt this worker.
pub(crate) fn worker_rng(id: usize, seed: u64) -> Pcg64 {
    Pcg64::with_stream(seed, 0x11c2 + id as u64)
}

/// Answer one leader request on the local shard: the worker-side
/// dispatch shared by every transport backend (the in-proc thread loop
/// below, and the TCP connection loop in `transport::tcp`). Returns
/// `None` for [`Request::Shutdown`]; compute failures come back as
/// [`Response::Err`] so they cross the wire instead of killing the
/// worker.
pub(crate) fn handle_request(
    oracle: &mut dyn ComputeOracle,
    shard: &Shard,
    rng: &mut Pcg64,
    req: Request,
) -> Option<Response> {
    let resp = match req {
        Request::Shutdown => return None,
        Request::CovMatVec(v) => match oracle.cov_matvec(shard, &v) {
            Ok(out) => Response::Vector(out),
            Err(e) => Response::Err(e.to_string()),
        },
        Request::CovMatMat { rows, cols, data } => {
            if data.len() != rows * cols {
                Response::Err(format!(
                    "cov_matmat: payload length {} != {rows}x{cols}",
                    data.len()
                ))
            } else {
                let v = crate::linalg::Matrix::from_vec(rows, cols, data);
                match oracle.cov_matmat(shard, &v) {
                    Ok(out) => Response::Mat {
                        rows: out.rows(),
                        cols: out.cols(),
                        data: out.data().to_vec(),
                    },
                    Err(e) => Response::Err(e.to_string()),
                }
            }
        }
        Request::LocalTopEigvec { unbiased_signs } => match oracle.local_top_eigvec(shard) {
            Ok(mut v) => {
                if unbiased_signs && rng.next_rademacher() < 0.0 {
                    for x in &mut v {
                        *x = -*x;
                    }
                }
                Response::Vector(v)
            }
            Err(e) => Response::Err(e.to_string()),
        },
        Request::Gram => match oracle.gram(shard) {
            Ok(g) => Response::Mat { rows: g.rows(), cols: g.cols(), data: g.data().to_vec() },
            Err(e) => Response::Err(e.to_string()),
        },
        Request::LocalTopK { k } => match oracle.local_top_k(shard, k) {
            Ok(w) => Response::Mat { rows: w.rows(), cols: w.cols(), data: w.data().to_vec() },
            Err(e) => Response::Err(e.to_string()),
        },
        Request::OjaPass { w, eta0, t0, t_start } => {
            match oracle.oja_pass(shard, &w, eta0, t0, t_start) {
                Ok(out) => Response::Vector(out),
                Err(e) => Response::Err(e.to_string()),
            }
        }
    };
    Some(resp)
}

/// Worker event loop (in-proc transport). The `u64` riding alongside
/// each request is the leader's exchange sequence number; it is echoed
/// verbatim on the reply so the leader can drop stragglers from
/// timed-out rounds.
pub(crate) fn worker_main(
    id: usize,
    shard: Arc<Shard>,
    spec: OracleSpec,
    seed: u64,
    rx: mpsc::Receiver<(u64, WireDesc, Request)>,
    tx: mpsc::Sender<crate::transport::ReplyFrame>,
) {
    let mut rng = worker_rng(id, seed);
    let mut bank = ReplyBank::new();
    let mut oracle: Box<dyn ComputeOracle> = match spec.build() {
        Ok(o) => o,
        Err(e) => {
            // Surface construction failure on the first request instead of
            // crashing the thread silently.
            while let Ok((seq, _desc, req)) = rx.recv() {
                if matches!(req, Request::Shutdown) {
                    return;
                }
                let _ = tx.send((id, seq, Response::Err(format!("oracle init failed: {e}"))));
            }
            return;
        }
    };
    while let Ok((seq, desc, req)) = rx.recv() {
        let Some(mut resp) = handle_request(oracle.as_mut(), &shard, &mut rng, req) else {
            break; // Shutdown
        };
        // worker-side reply compression at the round's format — the
        // same ReplyBank path the TCP worker loop runs, so reply
        // numerics and feedback streams are backend-invariant
        bank.compress(&desc, &mut resp);
        if tx.send((id, seq, resp)).is_err() {
            break; // leader gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Pcg64::new(seed);
        Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn native_oracle_matvec_matches_shard() {
        let s = shard(30, 5, 1);
        let mut o = NativeOracle::default();
        let v = vec![1.0, 0.5, -0.5, 2.0, 0.0];
        let got = o.cov_matvec(&s, &v).unwrap();
        let want = s.cov_matvec(&v);
        for i in 0..5 {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn oja_pass_keeps_unit_norm_and_improves() {
        // strongly anisotropic shard: rows mostly along e1
        let n = 500;
        let d = 4;
        let mut rng = Pcg64::new(2);
        let mut rows = Vec::with_capacity(n * d);
        for _ in 0..n {
            rows.push(2.0 * rng.next_gaussian());
            for _ in 1..d {
                rows.push(0.1 * rng.next_gaussian());
            }
        }
        let s = Shard::new(n, d, rows);
        let mut o = NativeOracle::default();
        let w0 = vec_ops::normalized(&[0.5, 0.5, 0.5, 0.5]);
        let w = o.oja_pass(&s, &w0, 1.0, 10.0, 0).unwrap();
        assert!((vec_ops::norm(&w) - 1.0).abs() < 1e-9);
        let e1 = [1.0, 0.0, 0.0, 0.0];
        assert!(
            vec_ops::alignment_error(&w, &e1) < vec_ops::alignment_error(&w0, &e1),
            "Oja pass should improve alignment"
        );
    }

    #[test]
    fn native_oracle_matmat_matches_columnwise_matvec() {
        let s = shard(40, 6, 11);
        let mut o = NativeOracle::default();
        let mut rng = Pcg64::new(12);
        let k = 3;
        let v = crate::linalg::Matrix::from_vec(
            6,
            k,
            (0..6 * k).map(|_| rng.next_gaussian()).collect(),
        );
        let got = o.cov_matmat(&s, &v).unwrap();
        assert_eq!(got.rows(), 6);
        assert_eq!(got.cols(), k);
        for c in 0..k {
            let want = o.cov_matvec(&s, &v.col(c)).unwrap();
            for i in 0..6 {
                assert!((got.get(i, c) - want[i]).abs() < 1e-12, "col {c} row {i}");
            }
        }
        // the default (loop) implementation must agree with the override
        struct LoopOracle(NativeOracle);
        impl ComputeOracle for LoopOracle {
            fn cov_matvec(&mut self, shard: &Shard, v: &[f64]) -> anyhow::Result<Vec<f64>> {
                self.0.cov_matvec(shard, v)
            }
            fn local_top_eigvec(&mut self, shard: &Shard) -> anyhow::Result<Vec<f64>> {
                self.0.local_top_eigvec(shard)
            }
            fn gram(&mut self, shard: &Shard) -> anyhow::Result<crate::linalg::Matrix> {
                self.0.gram(shard)
            }
        }
        let mut fallback = LoopOracle(NativeOracle::default());
        let via_loop = fallback.cov_matmat(&s, &v).unwrap();
        assert!(got.sub(&via_loop).max_abs() < 1e-12);
    }

    #[test]
    fn oracle_materializes_gram_when_cost_model_prefers_it() {
        // n=30, d=5: the gram build amortizes well inside GRAM_HORIZON
        let s = shard(30, 5, 21);
        assert!(s.prefer_gram(GRAM_HORIZON));
        assert!(!s.gram_ready());
        // streaming reference from an identical shard the oracle never saw
        // (clones reset the gram cache)
        let fresh = s.clone();
        let v = vec![0.3, -1.0, 0.25, 2.0, -0.5];
        let streamed = fresh.cov_matvec(&v);
        assert!(!fresh.gram_ready(), "reference must have streamed");
        let mut o = NativeOracle::default();
        let via_oracle = o.cov_matvec(&s, &v).unwrap();
        assert!(s.gram_ready(), "oracle must wire prefer_gram into the hot path");
        // regression (ISSUE 6): identical results on both paths
        for i in 0..5 {
            assert!(
                (via_oracle[i] - streamed[i]).abs() < 1e-12,
                "gram vs streaming mismatch at {i}"
            );
        }
    }

    #[test]
    fn oracle_keeps_streaming_when_gram_does_not_amortize() {
        // n=4, d=40: wide shard, gram build + d^2 products lose to
        // streaming within the horizon
        let s = shard(4, 40, 22);
        assert!(!s.prefer_gram(GRAM_HORIZON));
        let mut o = NativeOracle::default();
        let v = vec![0.1; 40];
        let _ = o.cov_matvec(&s, &v).unwrap();
        assert!(!s.gram_ready(), "oracle must not materialize an unprofitable gram");
    }

    #[test]
    fn oracle_serves_csr_shards() {
        // CSR shard through the full oracle surface the request loop uses
        let (n, d) = (20, 6);
        let mut rng = Pcg64::new(23);
        let mut dense = vec![0.0; n * d];
        let (mut indptr, mut indices, mut values) = (vec![0usize], Vec::new(), Vec::new());
        for r in 0..n {
            for c in 0..d {
                if c == r % d || rng.next_f64() < 0.4 {
                    let x = rng.next_gaussian();
                    dense[r * d + c] = x;
                    indices.push(c as u32);
                    values.push(x);
                }
            }
            indptr.push(values.len());
        }
        let csr = Shard::from_csr(n, d, indptr, indices, values);
        let dense = Shard::new(n, d, dense);
        let mut oc = NativeOracle::default();
        let mut od = NativeOracle::default();
        let v = vec![0.5, -0.5, 1.0, 0.0, 0.25, -1.0];
        let got = oc.cov_matvec(&csr, &v).unwrap();
        let want = od.cov_matvec(&dense, &v).unwrap();
        for i in 0..d {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
        let block = crate::linalg::Matrix::identity(d);
        let gm = oc.cov_matmat(&csr, &block).unwrap();
        let gw = od.cov_matmat(&dense, &block).unwrap();
        assert!(gm.sub(&gw).max_abs() < 1e-12);
        assert!(oc.gram(&csr).unwrap().sub(&od.gram(&dense).unwrap()).max_abs() < 1e-12);
        let e = oc.local_top_eigvec(&csr).unwrap();
        assert!((vec_ops::norm(&e) - 1.0).abs() < 1e-9);
        // oja default goes through row_dot/row_axpy on both stores
        let w0 = vec_ops::normalized(&[1.0; 6]);
        let wc = oc.oja_pass(&csr, &w0, 0.5, 10.0, 0).unwrap();
        let wd = od.oja_pass(&dense, &w0, 0.5, 10.0, 0).unwrap();
        for i in 0..d {
            assert!((wc[i] - wd[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn cov_matmat_rejects_bad_shapes() {
        let s = shard(10, 4, 13);
        let mut o = NativeOracle::default();
        let wrong = crate::linalg::Matrix::zeros(3, 2);
        assert!(o.cov_matmat(&s, &wrong).is_err());
    }

    #[test]
    fn gram_is_covariance() {
        let s = shard(10, 3, 3);
        let mut o = NativeOracle::default();
        let g = o.gram(&s).unwrap();
        assert!(g.sub(s.empirical_covariance()).max_abs() < 1e-15);
    }
}
