//! Simulated distributed cluster.
//!
//! The paper's model: `m` machines, machine 1 doubling as the leader.
//! Per round, the leader may broadcast one vector in `R^d` and every
//! machine may send one vector back. The **block protocol** generalizes
//! this to multi-vector rounds for the top-`k` family: a block round
//! broadcasts one message carrying `k` vectors and gathers one message of
//! `k` vectors per live machine — still exactly one synchronous exchange,
//! one request and one response per live worker, billed as `k` vectors of
//! traffic each way. We reproduce the model with one OS thread per
//! machine, each owning its shard (data never crosses thread boundaries
//! except through the typed message channel), and **exact communication
//! accounting** on every primitive (`live` = machines not killed).
//!
//! Every request/response payload passes through the cluster's
//! [`WireCodec`] (default: lossless f64), and `CommStats.bytes` is the
//! sum of the **encoded frames' sizes** — billed inside the exchange as
//! messages are actually sent and received (timeouts and error replies
//! included), never per-collective `8·d` arithmetic. Writing `B(w)` for
//! the codec's frame size on `w` payload words (`8w` under the default
//! F64 codec, `4w` under F32, `2w` under Bf16):
//!
//! | primitive | rounds | words leader→workers | words workers→leader | msgs (req / resp) | bytes |
//! |---|---|---|---|---|---|
//! | [`Cluster::dist_matvec`] | 1 | d | live·d | live / live | B(d)·(live+1) |
//! | [`Cluster::dist_matmat`] (`d×k`) | 1 | d·k | live·d·k | live / live | B(d·k)·(live+1) |
//! | [`Cluster::local_top_eigvecs`] | 1 | 0 | live·d | live / live | B(d)·live |
//! | [`Cluster::local_top_k`] (`k`) | 1 | 0 | live·d·k | live / live | B(d·k)·live |
//! | [`Cluster::oja_chain`] | live | live·d (handoffs) | live·d | live / live | 2·B(d)·live |
//! | [`Cluster::gram_average`] | 1 | 0 | live·d² | live / live | B(d²)·live |
//!
//! With the default lossless codec `B(w) = 8w` and the table reduces to
//! the original `8·d·…` accounting verbatim. A broadcast frame is billed
//! once regardless of fan-out (the §2.1 model charges the channel, not
//! each recipient); per-worker request/response *messages* are billed per
//! send/arrival. The codec-parameterized rows are the contract the
//! propcheck properties in `tests/integration.rs` assert for every
//! collective × every codec.
//!
//! The block-protocol rows remain the block contract: one `dist_matmat`
//! (and hence one block-power iteration at any `k`) costs **exactly one
//! round and one request/response message per live worker**, where the
//! column-wise loop it replaces paid `k` rounds and `k` messages per
//! worker.
//!
//! The leader *is* machine 1, so reading shard 1 (`leader_shard`) is free —
//! this matches the paper's preconditioner, built from machine 1's data
//! "without additional communication overhead" (§4.2).

mod comm;
mod message;
mod wire;
mod worker;

pub use comm::CommStats;
pub use message::{Request, Response};
pub use wire::{Frame, WireCodec, WirePrecision};
pub use worker::{ComputeOracle, NativeOracle, OracleSpec};

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::{Distribution, Shard};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Sequence number used for control messages (`Shutdown`) that are not
/// part of any exchange; real exchanges start at 1.
const CONTROL_SEQ: u64 = 0;

/// How many exchanges an in-flight straggler record survives. A reply
/// from a timed-out round either shows up within the next few rounds or
/// never will (its worker is wedged or dead); pruning at this horizon
/// keeps the record map bounded across long failure-heavy runs. A
/// straggler older than the horizon is still detected by its sequence
/// number — it just bills at the currently-installed codec width as a
/// best effort.
const INFLIGHT_RETENTION: u64 = 1024;

/// Handle to a running simulated cluster.
pub struct Cluster {
    m: usize,
    n: usize,
    d: usize,
    senders: Vec<mpsc::Sender<(u64, Request)>>,
    receiver: mpsc::Receiver<(usize, u64, Response)>,
    handles: Vec<Option<JoinHandle<()>>>,
    leader_shard: Arc<Shard>,
    stats: Mutex<CommStats>,
    dead: Mutex<HashSet<usize>>,
    /// Wire codec every request/response payload passes through; bytes
    /// are billed from its encoded frames. Interior-mutable so a
    /// coordinator can install a lossy codec for the duration of a run
    /// (see `coordinator::QuantizedPower`).
    codec: Mutex<WireCodec>,
    /// Exchange sequence counter. Workers echo the request's sequence
    /// number on their reply, so a straggler from a timed-out round is
    /// recognizable (and droppable) instead of being misattributed to a
    /// later collective on the shared response channel.
    seq: AtomicU64,
    /// Codec + outstanding-reply count for exchanges that failed before
    /// draining (timeout / dead send): lets a straggler reply be billed
    /// at the width its round actually shipped under — not whatever
    /// codec happens to be installed when it finally arrives — and then
    /// forgotten. Empty in every fully-drained (i.e. normal) history.
    inflight: Mutex<HashMap<u64, (WireCodec, usize)>>,
    /// Max wall time to wait for any single worker response.
    timeout: Duration,
}

impl Cluster {
    /// Generate a cluster of `m` machines with `n` i.i.d. samples each,
    /// using the pure-Rust compute oracle.
    pub fn generate(dist: &dyn Distribution, m: usize, n: usize, seed: u64) -> Result<Cluster> {
        Self::generate_with(dist, m, n, seed, OracleSpec::Native)
    }

    /// Generate with an explicit compute-oracle spec (e.g. PJRT-backed).
    pub fn generate_with(
        dist: &dyn Distribution,
        m: usize,
        n: usize,
        seed: u64,
        oracle: OracleSpec,
    ) -> Result<Cluster> {
        if m == 0 || n == 0 {
            bail!("cluster requires m >= 1, n >= 1");
        }
        let mut root = Pcg64::with_stream(seed, 0xdeca_f);
        let shards: Vec<Arc<Shard>> = (0..m)
            .map(|i| {
                let mut rng = root.fork(i as u64);
                Arc::new(dist.sample_shard(&mut rng, n))
            })
            .collect();
        Self::from_shards(shards, seed, oracle)
    }

    /// Build a cluster around pre-generated shards (all `n x d` equal
    /// shapes).
    pub fn from_shards(shards: Vec<Arc<Shard>>, seed: u64, oracle: OracleSpec) -> Result<Cluster> {
        if shards.is_empty() {
            bail!("no shards");
        }
        let (n, d) = (shards[0].n(), shards[0].d());
        for s in &shards {
            if s.n() != n || s.d() != d {
                bail!("ragged shards: expected {n}x{d}, got {}x{}", s.n(), s.d());
            }
        }
        let m = shards.len();
        let leader_shard = Arc::clone(&shards[0]);
        let (resp_tx, resp_rx) = mpsc::channel::<(usize, u64, Response)>();
        let mut senders = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        let mut seeder = Pcg64::with_stream(seed, 0x3a1e);
        for (i, shard) in shards.into_iter().enumerate() {
            let (req_tx, req_rx) = mpsc::channel::<(u64, Request)>();
            let tx = resp_tx.clone();
            let spec = oracle.clone();
            let wseed = seeder.next_u64();
            let handle = std::thread::Builder::new()
                .name(format!("dspca-worker-{i}"))
                .spawn(move || worker::worker_main(i, shard, spec, wseed, req_rx, tx))
                .context("spawning worker thread")?;
            senders.push(req_tx);
            handles.push(Some(handle));
        }
        Ok(Cluster {
            m,
            n,
            d,
            senders,
            receiver: resp_rx,
            handles,
            leader_shard,
            stats: Mutex::new(CommStats::default()),
            dead: Mutex::new(HashSet::new()),
            codec: Mutex::new(WireCodec::default()),
            seq: AtomicU64::new(CONTROL_SEQ),
            inflight: Mutex::new(HashMap::new()),
            timeout: Duration::from_secs(120),
        })
    }

    /// Number of machines `m`.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Per-machine sample size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Machine 1's shard, visible to the leader for free (the leader *is*
    /// machine 1 in the paper's model).
    pub fn leader_shard(&self) -> &Shard {
        &self.leader_shard
    }

    /// Communication statistics accumulated since the last reset.
    pub fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = CommStats::default();
    }

    /// The wire codec currently installed (default: lossless f64).
    pub fn codec(&self) -> WireCodec {
        *self.codec.lock().unwrap()
    }

    /// Install a wire codec. Every subsequent payload is shipped through
    /// it: lossy codecs both shrink the billed frames and degrade the
    /// delivered vectors, exactly as a real quantized wire would.
    pub fn set_codec(&self, codec: WireCodec) {
        *self.codec.lock().unwrap() = codec;
    }

    fn alive_workers(&self) -> Vec<usize> {
        let dead = self.dead.lock().unwrap();
        (0..self.m).filter(|i| !dead.contains(i)).collect()
    }

    /// Send `req` to a set of workers and collect their responses in
    /// worker order. One call is one synchronous round; the round, every
    /// request message, and every response message are billed **as they
    /// happen**, so a timed-out or partially-failed collective still
    /// pays for the traffic it actually generated (the seed billed
    /// messages only after the drain loop — nothing at all on the
    /// timeout/send-failure paths — and rounds/bytes only in the
    /// collectives' success paths, after any worker-error bail).
    ///
    /// Payloads pass through the installed [`WireCodec`] in both
    /// directions: the request payload is encoded once — the §2.1 model
    /// bills a broadcast against the channel, not per recipient — and
    /// each response payload on arrival, with `CommStats.bytes` advanced
    /// by the encoded frames' sizes and the decoded (possibly lossy)
    /// values delivered onward.
    ///
    /// On worker failure, the **full** response set is still drained
    /// before the error is reported: the response channel is shared by
    /// every collective, so bailing early would leave the surviving
    /// workers' replies queued. Replies that *do* outlive their exchange
    /// (a worker stalls past the timeout and answers later) are caught by
    /// the sequence number every worker echoes: a stale reply is billed
    /// on arrival — it really crossed the wire, at the codec width its
    /// own round shipped under (tracked per failed exchange in
    /// `inflight`) — and then dropped instead of being misattributed to
    /// the current collective.
    fn exchange(&self, workers: &[usize], req: &Request) -> Result<Vec<Response>> {
        let codec = self.codec();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut req = req.clone();
        let req_bytes = req.payload_mut().map_or(0, |p| codec.transcode(p)) as u64;
        let mut sent = 0usize;
        for &w in workers {
            if self.senders[w].send((seq, req.clone())).is_err() {
                if sent > 0 {
                    // the workers already reached may still reply; leave
                    // a record so their stragglers bill at this width
                    let mut infl = self.inflight.lock().unwrap();
                    infl.retain(|&s, _| s + INFLIGHT_RETENTION > seq);
                    infl.insert(seq, (codec, sent));
                }
                bail!("worker {w} channel closed");
            }
            sent += 1;
            let mut st = self.stats.lock().unwrap();
            st.requests_sent += 1;
            if sent == 1 {
                // the round and its broadcast frame hit the wire with the
                // first successful send, and are billed once regardless
                // of fan-out; if no send succeeds, no traffic existed and
                // nothing is billed
                st.rounds += 1;
                st.bytes += req_bytes;
            }
        }
        let mut responses: Vec<Option<Response>> = vec![None; self.m];
        let mut first_err: Option<(usize, String)> = None;
        let mut got = 0usize;
        while got < workers.len() {
            let (id, rseq, mut resp) = match self.receiver.recv_timeout(self.timeout) {
                Ok(msg) => msg,
                Err(_) => {
                    let mut infl = self.inflight.lock().unwrap();
                    infl.retain(|&s, _| s + INFLIGHT_RETENTION > seq);
                    infl.insert(seq, (codec, workers.len() - got));
                    bail!("timed out waiting for worker response");
                }
            };
            if rseq != seq {
                // straggler from a round that already failed: bill it at
                // the width its own round shipped under (it did cross
                // the wire), then drop it
                let stale_bytes = {
                    let mut infl = self.inflight.lock().unwrap();
                    let stale_codec = infl.get(&rseq).map_or(codec, |e| e.0);
                    if let Some(e) = infl.get_mut(&rseq) {
                        e.1 -= 1;
                        if e.1 == 0 {
                            infl.remove(&rseq);
                        }
                    }
                    resp.payload().map_or(0, |p| stale_codec.frame_bytes(p.len())) as u64
                };
                let mut st = self.stats.lock().unwrap();
                st.responses_received += 1;
                st.bytes += stale_bytes;
                continue;
            }
            let resp_bytes = resp.payload_mut().map_or(0, |p| codec.transcode(p)) as u64;
            {
                let mut st = self.stats.lock().unwrap();
                st.responses_received += 1;
                st.bytes += resp_bytes;
            }
            got += 1;
            if let Response::Err(e) = resp {
                if first_err.is_none() {
                    first_err = Some((id, e));
                }
                continue;
            }
            responses[id] = Some(resp);
        }
        if let Some((id, e)) = first_err {
            bail!("worker {id} failed: {e}");
        }
        Ok(workers.iter().map(|&w| responses[w].take().expect("missing response")).collect())
    }

    /// Distributed covariance matvec: `Xhat v = (1/m) sum_i Xhat_i v`.
    /// One communication round; the core primitive of the power method,
    /// Lanczos and the Shift-and-Invert solver (Algorithm 2, lines 2–6).
    pub fn dist_matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        assert_eq!(v.len(), self.d);
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::CovMatVec(v.to_vec()))?;
        let mut acc = vec![0.0; self.d];
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            crate::linalg::vec_ops::axpy(&mut acc, 1.0, &x);
        }
        crate::linalg::vec_ops::scale(&mut acc, 1.0 / workers.len() as f64);
        let mut st = self.stats.lock().unwrap();
        st.matvec_products += 1;
        st.vectors_broadcast += 1;
        st.vectors_gathered += workers.len() as u64;
        Ok(acc)
    }

    /// Distributed covariance **block** product:
    /// `Xhat V = (1/live) sum_i Xhat_i V` for a `d x k` block `V`.
    ///
    /// The core primitive of the top-`k` family (block power / orthogonal
    /// iteration, block Lanczos, batched deflation): **one round, one
    /// request/response message per live worker, `k` vectors of traffic
    /// each way** — where the column-wise loop it replaces paid `k`
    /// rounds and `k` message round-trips per worker. Numerically
    /// identical (up to summation order) to `k` [`Cluster::dist_matvec`]
    /// calls on the columns of `V`; billed as `k` matvec products.
    pub fn dist_matmat(&self, v: &Matrix) -> Result<Matrix> {
        assert_eq!(v.rows(), self.d, "dist_matmat: block must be d x k");
        let k = v.cols();
        assert!(k >= 1, "dist_matmat: empty block");
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let req = Request::CovMatMat { rows: self.d, cols: k, data: v.data().to_vec() };
        let resps = self.exchange(&workers, &req)?;
        let mut acc = Matrix::zeros(self.d, k);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            if rows != self.d || cols != k {
                bail!("dist_matmat: worker returned {rows}x{cols}, expected {}x{k}", self.d);
            }
            acc.axpy_mat(1.0, &Matrix::from_vec(rows, cols, data));
        }
        acc.scale_mut(1.0 / workers.len() as f64);
        let mut st = self.stats.lock().unwrap();
        st.matvec_products += k as u64;
        st.vectors_broadcast += k as u64;
        st.vectors_gathered += (workers.len() * k) as u64;
        Ok(acc)
    }

    /// Gather every machine's local ERM solution (leading eigenvector of
    /// its `Xhat_i`). One round, `m` vectors to the leader. With
    /// `unbiased_signs`, each machine flips its eigenvector's sign by a
    /// private fair coin — the "unbiased ERM" premise of Theorem 3.
    pub fn local_top_eigvecs(&self, unbiased_signs: bool) -> Result<Vec<Vec<f64>>> {
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopEigvec { unbiased_signs })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Vector(x) = r else { bail!("unexpected response type") };
            out.push(x);
        }
        let mut st = self.stats.lock().unwrap();
        st.vectors_gathered += workers.len() as u64;
        Ok(out)
    }

    /// Average of the local empirical covariances — the **centralized**
    /// baseline's input. One round but `m * d` vectors of traffic (the
    /// paper's round model only ships `R^d` vectors; this is the
    /// "ship-everything" reference point, not a round-efficient method).
    pub fn gram_average(&self) -> Result<Matrix> {
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::Gram)?;
        let mut acc = Matrix::zeros(self.d, self.d);
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            let m = Matrix::from_vec(rows, cols, data);
            acc.axpy_mat(1.0, &m);
        }
        acc.scale_mut(1.0 / workers.len() as f64);
        let mut st = self.stats.lock().unwrap();
        st.vectors_gathered += (workers.len() * self.d) as u64;
        Ok(acc)
    }

    /// Gather every machine's local top-`k` eigenbasis (`d x k` each).
    /// One round, `m * k` vectors of traffic.
    pub fn local_top_k(&self, k: usize) -> Result<Vec<Matrix>> {
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let resps = self.exchange(&workers, &Request::LocalTopK { k })?;
        let mut out = Vec::with_capacity(workers.len());
        for r in resps {
            let Response::Mat { rows, cols, data } = r else { bail!("unexpected response type") };
            out.push(Matrix::from_vec(rows, cols, data));
        }
        let mut st = self.stats.lock().unwrap();
        st.vectors_gathered += (workers.len() * k) as u64;
        Ok(out)
    }

    /// "Hot-potato" chain: pass the iterate machine-to-machine, each
    /// making a full Oja pass over its local samples. `m` rounds.
    pub fn oja_chain(&self, w0: &[f64], eta0: f64, t0: f64) -> Result<Vec<f64>> {
        assert_eq!(w0.len(), self.d);
        let workers = self.alive_workers();
        if workers.is_empty() {
            bail!("no live workers");
        }
        let mut w = w0.to_vec();
        let mut t_start = 0u64;
        for &i in &workers {
            let resps = self.exchange(
                &[i],
                &Request::OjaPass { w: w.clone(), eta0, t0, t_start },
            )?;
            let Response::Vector(x) = &resps[0] else { bail!("unexpected response type") };
            w = x.clone();
            t_start += self.n as u64;
            let mut st = self.stats.lock().unwrap();
            st.vectors_broadcast += 1;
            st.vectors_gathered += 1;
        }
        Ok(w)
    }

    /// Kill a worker (failure injection for tests). Subsequent collective
    /// ops exclude it; killing the leader's machine is not allowed.
    pub fn kill_worker(&self, i: usize) -> Result<()> {
        if i == 0 {
            bail!("machine 1 is the leader; cannot kill it");
        }
        if i >= self.m {
            bail!("no such worker {i}");
        }
        let mut dead = self.dead.lock().unwrap();
        if dead.insert(i) {
            // best effort: tell the thread to exit
            let _ = self.senders[i].send((CONTROL_SEQ, Request::Shutdown));
        }
        Ok(())
    }

    /// Number of live machines.
    pub fn live(&self) -> usize {
        self.alive_workers().len()
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for s in &self.senders {
            let _ = s.send((CONTROL_SEQ, Request::Shutdown));
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CovModel;
    use crate::linalg::vec_ops::{alignment_error, norm};

    fn small_cluster(m: usize, n: usize) -> (Cluster, Vec<f64>) {
        let dist = CovModel::paper_fig1(8, 3).gaussian();
        let v1 = dist.v1().to_vec();
        (Cluster::generate(&dist, m, n, 42).unwrap(), v1)
    }

    #[test]
    fn dist_matvec_matches_mean_of_local() {
        let (c, _) = small_cluster(4, 50);
        let v: Vec<f64> = (0..8).map(|i| (i as f64 + 1.0) / 8.0).collect();
        let got = c.dist_matvec(&v).unwrap();
        // reference: average the per-shard matvecs via a second cluster
        // primitive (gram_average)
        let g = c.gram_average().unwrap();
        let want = g.matvec(&v);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn stats_accounting() {
        let (c, _) = small_cluster(3, 20);
        let v = vec![1.0; 8];
        c.dist_matvec(&v).unwrap();
        c.dist_matvec(&v).unwrap();
        let st = c.stats();
        assert_eq!(st.rounds, 2);
        assert_eq!(st.matvec_products, 2);
        assert_eq!(st.vectors_broadcast, 2);
        assert_eq!(st.vectors_gathered, 6);
        c.reset_stats();
        assert_eq!(c.stats().rounds, 0);
    }

    #[test]
    fn local_eigvecs_count_and_norm() {
        let (c, v1) = small_cluster(5, 400);
        let vs = c.local_top_eigvecs(false).unwrap();
        assert_eq!(vs.len(), 5);
        for v in &vs {
            assert!((norm(v) - 1.0).abs() < 1e-10);
            // with n=400 each local ERM is already well aligned
            assert!(alignment_error(v, &v1) < 0.2);
        }
        assert_eq!(c.stats().rounds, 1);
    }

    #[test]
    fn unbiased_signs_flip_randomly() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        let c = Cluster::generate(&dist, 16, 100, 7).unwrap();
        let vs = c.local_top_eigvecs(true).unwrap();
        // sign wrt v1: with 16 unbiased machines, both signs should appear
        let signs: Vec<bool> = vs
            .iter()
            .map(|v| crate::linalg::vec_ops::dot(v, dist.v1()) >= 0.0)
            .collect();
        assert!(signs.iter().any(|&s| s));
        assert!(signs.iter().any(|&s| !s));
    }

    #[test]
    fn oja_chain_runs_m_rounds() {
        let (c, _) = small_cluster(4, 30);
        let mut w0 = vec![0.0; 8];
        w0[0] = 1.0;
        let w = c.oja_chain(&w0, 0.5, 10.0).unwrap();
        assert!((norm(&w) - 1.0).abs() < 1e-9);
        assert_eq!(c.stats().rounds, 4);
    }

    #[test]
    fn kill_worker_excludes_from_collectives() {
        let (c, _) = small_cluster(4, 20);
        c.kill_worker(2).unwrap();
        assert_eq!(c.live(), 3);
        let v = vec![1.0; 8];
        let out = c.dist_matvec(&v).unwrap();
        assert_eq!(out.len(), 8);
        let st = c.stats();
        assert_eq!(st.vectors_gathered, 3);
    }

    #[test]
    fn cannot_kill_leader() {
        let (c, _) = small_cluster(2, 10);
        assert!(c.kill_worker(0).is_err());
    }

    #[test]
    fn dist_matmat_matches_columnwise_matvec() {
        let (c, _) = small_cluster(4, 60);
        let k = 3;
        let mut v = Matrix::zeros(8, k);
        for col in 0..k {
            let x: Vec<f64> = (0..8).map(|i| ((i + col) as f64 * 0.37).sin()).collect();
            v.set_col(col, &x);
        }
        let blk = c.dist_matmat(&v).unwrap();
        assert_eq!(blk.rows(), 8);
        assert_eq!(blk.cols(), k);
        for col in 0..k {
            let want = c.dist_matvec(&v.col(col)).unwrap();
            for i in 0..8 {
                assert!((blk.get(i, col) - want[i]).abs() < 1e-12, "col {col} row {i}");
            }
        }
    }

    #[test]
    fn dist_matmat_accounting_matches_table() {
        let (c, _) = small_cluster(3, 20);
        let k = 5;
        let v = Matrix::from_vec(8, k, (0..8 * k).map(|i| i as f64 * 0.01).collect());
        c.dist_matmat(&v).unwrap();
        let st = c.stats();
        assert_eq!(st.rounds, 1);
        assert_eq!(st.matvec_products, k as u64);
        assert_eq!(st.vectors_broadcast, k as u64);
        assert_eq!(st.vectors_gathered, 3 * k as u64);
        assert_eq!(st.requests_sent, 3);
        assert_eq!(st.responses_received, 3);
        assert_eq!(st.bytes, (8 * 8 * k * 4) as u64);
    }

    #[test]
    fn columnwise_loop_costs_k_rounds_block_costs_one() {
        // the protocol contrast the block rewrite exists for
        let (c, _) = small_cluster(3, 20);
        let k = 4;
        let v = Matrix::from_vec(8, k, (0..8 * k).map(|i| (i as f64).cos()).collect());
        for col in 0..k {
            c.dist_matvec(&v.col(col)).unwrap();
        }
        let loop_stats = c.stats();
        assert_eq!(loop_stats.rounds, k as u64);
        assert_eq!(loop_stats.requests_sent, (3 * k) as u64);
        c.reset_stats();
        c.dist_matmat(&v).unwrap();
        let blk_stats = c.stats();
        assert_eq!(blk_stats.rounds, 1);
        assert_eq!(blk_stats.requests_sent, 3);
        // same vector traffic either way
        assert_eq!(blk_stats.vectors_gathered, loop_stats.vectors_gathered);
    }

    #[test]
    fn all_collectives_survive_one_dead_worker() {
        let (c, _) = small_cluster(4, 30);
        c.kill_worker(2).unwrap();
        assert_eq!(c.live(), 3);
        // gram_average
        c.reset_stats();
        let g = c.gram_average().unwrap();
        assert_eq!(g.rows(), 8);
        assert_eq!(c.stats().responses_received, 3);
        // local_top_k
        c.reset_stats();
        let locals = c.local_top_k(2).unwrap();
        assert_eq!(locals.len(), 3);
        assert_eq!(c.stats().vectors_gathered, 6);
        // oja_chain: live rounds, one handoff per live machine
        c.reset_stats();
        let mut w0 = vec![0.0; 8];
        w0[0] = 1.0;
        let w = c.oja_chain(&w0, 0.5, 10.0).unwrap();
        assert!((crate::linalg::vec_ops::norm(&w) - 1.0).abs() < 1e-9);
        assert_eq!(c.stats().rounds, 3);
        assert_eq!(c.stats().requests_sent, 3);
        // dist_matmat: averages over survivors only
        c.reset_stats();
        let v = Matrix::from_vec(8, 2, (0..16).map(|i| i as f64 * 0.1).collect());
        let blk = c.dist_matmat(&v).unwrap();
        assert_eq!(blk.cols(), 2);
        assert_eq!(c.stats().vectors_gathered, 6);
        assert_eq!(c.stats().requests_sent, 3);
        // block average equals the survivors' gram average applied to v
        let want = g.matmul(&v);
        assert!(blk.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn all_collectives_survive_two_dead_workers() {
        let (c, _) = small_cluster(5, 25);
        c.kill_worker(1).unwrap();
        c.kill_worker(4).unwrap();
        assert_eq!(c.live(), 3);
        let g = c.gram_average().unwrap();
        assert_eq!(g.cols(), 8);
        let locals = c.local_top_k(3).unwrap();
        assert_eq!(locals.len(), 3);
        let vs = c.local_top_eigvecs(false).unwrap();
        assert_eq!(vs.len(), 3);
        let mut w0 = vec![0.0; 8];
        w0[1] = 1.0;
        assert!(c.oja_chain(&w0, 0.5, 10.0).is_ok());
        let v = Matrix::from_vec(8, 2, vec![0.25; 16]);
        assert!(c.dist_matmat(&v).is_ok());
        // killing the same worker twice is a no-op, not an error
        c.kill_worker(1).unwrap();
        assert_eq!(c.live(), 3);
    }

    #[test]
    fn failed_collective_does_not_poison_the_next_one() {
        // every worker rejects local_top_k(k > d); the error must not
        // leave stale responses in the shared channel for the next
        // collective to misread
        let (c, _) = small_cluster(3, 20);
        assert!(c.local_top_k(99).is_err());
        let v = vec![1.0; 8];
        let a = c.dist_matvec(&v).unwrap();
        // and the result is the real matvec, not a stale frame
        let g = c.gram_average().unwrap();
        let want = g.matvec(&v);
        for i in 0..8 {
            assert!((a[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn failed_collective_still_bills_its_traffic() {
        // regression (ISSUE 2 satellite): the seed billed rounds and
        // bytes only on the collectives' success paths — an exchange
        // that drained worker errors billed its messages but never its
        // round, and a timed-out exchange billed nothing at all. The
        // load-bearing assertion here is rounds == 1; the message
        // counts pin the billed-as-they-happen behavior alongside it.
        let (c, _) = small_cluster(3, 20);
        c.reset_stats();
        assert!(c.local_top_k(99).is_err());
        let st = c.stats();
        assert_eq!(st.rounds, 1, "the round happened even though it failed");
        assert_eq!(st.requests_sent, 3, "three requests crossed the wire");
        assert_eq!(st.responses_received, 3, "three Err replies crossed the wire");
        assert_eq!(st.bytes, 0, "Err replies carry no f64 payload");
        assert_eq!(st.vectors_gathered, 0, "no vectors were delivered");
    }

    #[test]
    fn bytes_are_billed_from_the_codec_encoded_frames() {
        let (c, _) = small_cluster(3, 20);
        let v = vec![1.0; 8];
        for (prec, bpe) in
            [(WirePrecision::F64, 8u64), (WirePrecision::F32, 4), (WirePrecision::Bf16, 2)]
        {
            c.set_codec(WireCodec::new(prec));
            c.reset_stats();
            c.dist_matvec(&v).unwrap();
            // B(d)·(live+1) with d = 8, live = 3
            assert_eq!(c.stats().bytes, bpe * 8 * 4, "{prec:?}");
        }
        c.set_codec(WireCodec::default());
        assert_eq!(c.codec(), WireCodec::lossless());
    }

    #[test]
    fn straggler_reply_bills_at_its_own_rounds_width_and_is_dropped() {
        // drive the sequence-number path for real: pretend an exchange
        // (seq 1000) timed out under a bf16 codec with one reply still
        // in flight, then have worker 1 actually answer it — the way a
        // stalled worker eventually would. The next collective must
        // drain the straggler, bill it at *bf16* width (not the current
        // lossless codec's), and deliver an unpoisoned result.
        let (c, _) = small_cluster(2, 20);
        let v = vec![0.3; 8];
        let g = c.gram_average().unwrap();
        let want = g.matvec(&v);
        c.inflight
            .lock()
            .unwrap()
            .insert(1000, (WireCodec::new(WirePrecision::Bf16), 1));
        c.senders[1].send((1000, Request::CovMatVec(v.clone()))).unwrap();
        c.reset_stats();
        let got = c.dist_matvec(&v).unwrap();
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-10, "straggler poisoned the result");
        }
        let st = c.stats();
        assert_eq!(st.requests_sent, 2);
        assert_eq!(st.responses_received, 3, "the straggler is billed on arrival");
        // 8·d·(live+1) for the real round + 2·d for the bf16 straggler
        assert_eq!(st.bytes, (8 * 8 * 3 + 2 * 8) as u64);
        assert_eq!(st.vectors_gathered, 2, "only genuine replies are delivered");
        assert!(c.inflight.lock().unwrap().is_empty(), "straggler record is forgotten");
    }

    #[test]
    fn lossy_codec_actually_quantizes_the_wire() {
        let (c, _) = small_cluster(2, 30);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.731).sin() * 1.0001 + 0.1).collect();
        let exact = c.dist_matvec(&x).unwrap();
        c.set_codec(WireCodec::new(WirePrecision::Bf16));
        let coarse = c.dist_matvec(&x).unwrap();
        c.set_codec(WireCodec::default());
        let again = c.dist_matvec(&x).unwrap();
        assert_eq!(exact, again, "default codec must be bit-exact");
        let total: f64 = exact.iter().zip(&coarse).map(|(a, b)| (a - b).abs()).sum();
        assert!(total > 0.0, "bf16 codec must actually perturb the wire");
        for (a, b) in exact.iter().zip(&coarse) {
            // perturbation stays at the 2^-8 relative scale of the codec
            assert!((a - b).abs() <= 0.1 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn dist_matmat_single_column_agrees_with_matvec() {
        let (c, _) = small_cluster(2, 15);
        let x: Vec<f64> = (0..8).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        let mut v = Matrix::zeros(8, 1);
        v.set_col(0, &x);
        let blk = c.dist_matmat(&v).unwrap();
        let want = c.dist_matvec(&x).unwrap();
        for i in 0..8 {
            assert!((blk.get(i, 0) - want[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn leader_shard_is_machine_one() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        let c = Cluster::generate(&dist, 3, 25, 9).unwrap();
        assert_eq!(c.leader_shard().n(), 25);
        assert_eq!(c.leader_shard().d(), 4);
    }

    #[test]
    fn ragged_shards_rejected() {
        use crate::data::Shard;
        let a = Arc::new(Shard::new(2, 2, vec![1.0; 4]));
        let b = Arc::new(Shard::new(3, 2, vec![1.0; 6]));
        assert!(Cluster::from_shards(vec![a, b], 0, OracleSpec::Native).is_err());
    }

    #[test]
    fn generate_rejects_degenerate() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        assert!(Cluster::generate(&dist, 0, 5, 1).is_err());
        assert!(Cluster::generate(&dist, 5, 0, 1).is_err());
    }
}
