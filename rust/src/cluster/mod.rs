//! Simulated distributed cluster — now a **multi-tenant service**.
//!
//! The paper's model: `m` machines, machine 1 doubling as the leader.
//! Per round, the leader may broadcast one vector in `R^d` and every
//! machine may send one vector back. The **block protocol** generalizes
//! this to multi-vector rounds for the top-`k` family: a block round
//! broadcasts one message carrying `k` vectors and gathers one message of
//! `k` vectors per live machine — still exactly one synchronous exchange,
//! one request and one response per live worker, billed as `k` vectors of
//! traffic each way. We reproduce the model over a **pluggable
//! transport** ([`crate::transport`]): by default one OS thread per
//! machine, each owning its shard (data never crosses thread boundaries
//! except through the typed message channel); with
//! [`TransportSpec::Tcp`](crate::transport::TransportSpec) the same
//! cluster runs against `dspca worker --listen <addr>` processes over
//! real sockets, with identical bills. Either way: **exact
//! communication accounting** on every primitive (`live` = machines not
//! killed).
//!
//! **Tenancy & split-phase collectives.** [`Cluster`] is `Sync` and
//! holds no per-query state: the billing counters, the wire codec, and
//! the collective API all live on the per-tenant [`Session`]
//! ([`Cluster::session`]). A collective is **split-phase**: submit
//! ([`Session::submit`] → [`Ticket`]) sends every request under a
//! short-held send lock and bills the outbound traffic as it goes;
//! complete ([`Ticket::complete`]) parks on the reply **router**, which
//! drains the one shared reply stream and delivers every response by
//! its echoed sequence number to the issuing ticket's slot — billing
//! the issuing session on arrival. Nothing holds the wire across a
//! reply wait, so concurrent tenants' rounds — and one algorithm's
//! independent rounds — genuinely overlap on the wire, while each
//! session's bill stays exactly what the same query would pay running
//! alone. The cluster keeps one monotonic [`Cluster::aggregate_stats`]
//! ledger equal to the sum of all traffic its sessions ever billed. The
//! `serve` module schedules whole job queues over this substrate.
//!
//! **Round fusion (opt-in).** [`Cluster::enable_fusion`] opens a short
//! fusion window in the matvec/matmat submit path: compatible rounds —
//! same codec, same live-worker set — submitted by any sessions within
//! the window coalesce into one stacked `CovMatMat` *carrier* round.
//! The router splits the carrier's reply columns back into each
//! member's own slot, so `k` concurrent power-method tenants cost the
//! workers one block pass instead of `k` vector passes. Fusion changes
//! wall clock only, never bills: each member session is billed exactly
//! its solo traffic at its own codec width — outbound when the batch
//! flushes, inbound per split reply on arrival (`tests/fusion.rs` pins
//! the equality per codec × backend).
//!
//! Every request/response payload passes through the owning session's
//! [`WireCodec`] (default: lossless f64), and `CommStats.bytes` is the
//! sum of the **encoded frames' sizes** — billed inside the exchange as
//! messages are actually sent and received (timeouts and error replies
//! included), never per-collective `8·d` arithmetic. Writing `B(w)` for
//! the codec's frame size on `w` payload words (`8w` under the default
//! F64 codec, `4w` under F32, `2w` under Bf16):
//!
//! | primitive | rounds | words leader→workers | words workers→leader | msgs (req / resp) | bytes |
//! |---|---|---|---|---|---|
//! | [`Session::dist_matvec`] | 1 | d | live·d | live / live | B(d)·(live+1) |
//! | [`Session::dist_matmat`] (`d×k`) | 1 | d·k | live·d·k | live / live | B(d·k)·(live+1) |
//! | [`Session::local_top_eigvecs`] | 1 | 0 | live·d | live / live | B(d)·live |
//! | [`Session::local_top_k`] (`k`) | 1 | 0 | live·d·k | live / live | B(d·k)·live |
//! | [`Session::oja_chain`] | live | live·d (handoffs) | live·d | live / live | 2·B(d)·live |
//! | [`Session::gram_average`] | 1 | 0 | live·d² | live / live | B(d²)·live |
//!
//! With the default lossless codec `B(w) = 8w` and the table reduces to
//! the original `8·d·…` accounting verbatim. The stateful codec family
//! (ISSUE 10) extends `B(w)` beyond fixed widths — all still pure
//! functions of the payload shape, so bills stay backend- and
//! history-invariant even when the *values* on the wire depend on the
//! stream's residual: for a `w`-word, `c`-column payload, `q8` bills
//! `4c + w` (one f32 scale per column + one level byte per word), `q4`
//! bills `4c + ⌈w/2⌉` (packed nibbles), and `top-s` bills
//! `8 + 4·min(s,w) + levels(min(s,w))` (u64 kept-count envelope + u32
//! indices + levels at the active width). Error feedback and the
//! adaptive controller change which format a round *resolves to* —
//! recorded per round in the bill and trace — never how a resolved
//! format is priced. A broadcast frame is billed
//! once regardless of fan-out (the §2.1 model charges the channel, not
//! each recipient); per-worker request/response *messages* are billed per
//! send/arrival. The codec-parameterized rows are the contract the
//! propcheck properties in `tests/integration.rs` assert for every
//! collective × every codec — per session, and summed across concurrent
//! sessions against the aggregate.
//!
//! The block-protocol rows remain the block contract: one `dist_matmat`
//! (and hence one block-power iteration at any `k`) costs **exactly one
//! round and one request/response message per live worker**, where the
//! column-wise loop it replaces paid `k` rounds and `k` messages per
//! worker.
//!
//! The leader *is* machine 1, so reading shard 1 (`leader_shard`) is free —
//! this matches the paper's preconditioner, built from machine 1's data
//! "without additional communication overhead" (§4.2).

mod comm;
mod message;
mod session;
pub(crate) mod wire;
pub(crate) mod worker;

pub use comm::CommStats;
pub use message::{Request, Response};
pub use session::{MatmatTicket, MatvecTicket, Session, Ticket};
pub use wire::{
    decode_request, decode_response, encode_request, encode_response, CodecKind, CodecState,
    Frame, QuantBits, ReplyBank, WireCodec, WireDesc, WireFormat, WirePrecision, NARROW_BELOW,
    WIDEN_ABOVE,
};
pub use worker::{ComputeOracle, NativeOracle, OracleSpec};

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::{Distribution, Shard};
use crate::rng::Pcg64;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{mpsc, Condvar, Mutex};
use crate::transport::{
    recv_reply, InProcTransport, RecvError, ReplyFrame, TcpTransport, Transport, TransportSpec,
    CONTROL_SEQ,
};

use session::SessionCore;

/// Max wall time to wait for any single worker response (refreshed per
/// arrival — the per-exchange *compute* deadline; socket I/O deadlines
/// are the transport's `io_timeout`).
const EXCHANGE_TIMEOUT: Duration = Duration::from_secs(120);

/// How many exchanges an in-flight straggler record survives. A reply
/// from a timed-out round either shows up within the next few rounds or
/// never will (its worker is wedged or dead); pruning at this horizon
/// keeps the record map bounded across long failure-heavy runs. A
/// straggler older than the horizon is still detected by its sequence
/// number — but with its provenance gone it can no longer be attributed
/// to a tenant, so it is dropped unbilled (billing it to whichever
/// session happens to drain it would corrupt that tenant's bill).
const INFLIGHT_RETENTION: u64 = 1024;

/// The reply **router**: the single delivery path for every worker
/// response, on every backend. Replies arrive on one shared transport
/// stream; whichever completing thread currently holds [`Router::rx`]
/// (the *driver*) drains it and routes each reply by its echoed
/// sequence number — into the open ticket's parking slot (billing the
/// issuing session as the bytes arrive), onto the straggler path for a
/// retired ticket, or to the floor for an unattributable orphan. This
/// generalizes the old straggler-drain special case into *the* way
/// replies are delivered: tickets from any number of sessions can be in
/// flight at once, and nobody holds a lock across a network wait except
/// the driver, which works for everyone while it waits.
struct Router {
    state: Mutex<RouterState>,
    /// Notified whenever a reply is routed or a driver retires, so
    /// parked completers re-check their slots (and elect a new driver).
    cv: Condvar,
    /// The transport's shared reply stream. Held only by the current
    /// driver; never held while the router's `state` lock is held.
    rx: Mutex<mpsc::Receiver<ReplyFrame>>,
}

/// Routing tables: open tickets' parking slots plus retired exchanges'
/// straggler provenance.
struct RouterState {
    /// One slot per in-flight ticket, keyed by exchange sequence number.
    open: HashMap<u64, Slot>,
    /// Provenance for exchanges that retired before draining (timeout /
    /// dead send / dropped ticket): codec width the round shipped
    /// under, outstanding reply count, and a weak handle to the issuing
    /// session — so a straggler reply is billed to the tenant whose
    /// round it belongs to (not whichever tenant drains next), or
    /// dropped cleanly if that session has been closed. Empty in every
    /// fully-drained (i.e. normal) history.
    inflight: HashMap<u64, Inflight>,
    /// Carrier-round split tables, keyed by the carrier's sequence
    /// number: how a fused reply's columns map back onto member rounds.
    /// Pruned on the same retention horizon as `inflight`.
    fused: HashMap<u64, FusedRoute>,
}

/// How to split one fused carrier reply back into its member rounds'
/// responses. The carrier itself has no slot and no owner — only the
/// members do, so only the members are ever billed.
struct FusedRoute {
    d: usize,
    /// Total stacked columns the carrier shipped.
    cols: usize,
    /// Carrier replies still owed (successful carrier sends).
    outstanding: usize,
    members: Vec<FusedSlice>,
}

/// One member round's column range within a carrier reply.
struct FusedSlice {
    seq: u64,
    col0: usize,
    k: usize,
    /// Deliver a `Response::Vector` (matvec member) instead of a
    /// `Response::Mat` block.
    vector: bool,
}

/// Fusion-window configuration ([`Cluster::enable_fusion`]).
#[derive(Clone, Copy)]
struct FusionConfig {
    window: Duration,
    max_cols: usize,
}

/// One member of a pending fusion batch: a submitted matvec/matmat
/// round whose request has not hit the wire yet. Its routing slot is
/// already open (opened before registration, so a carrier reply can
/// never race an absent slot).
pub(super) struct FuseMember {
    pub(super) seq: u64,
    pub(super) owner: Weak<SessionCore>,
    /// Payload, row-major `d x k`, already transcoded at the member's
    /// codec — exactly the frame a solo submit would ship.
    pub(super) cols: Vec<f64>,
    pub(super) k: usize,
    /// The member's solo broadcast-frame bill, applied at flush time.
    pub(super) req_bytes: u64,
    /// The member was a matvec (reply as `Response::Vector`).
    pub(super) vector: bool,
}

/// At most one fusion batch accumulates at a time; an incompatible
/// submit displaces (flushes) the current batch and opens its own.
struct PendingFuse {
    codec: WireCodec,
    workers: Vec<usize>,
    d: usize,
    members: Vec<FuseMember>,
    total_cols: usize,
    opened: Instant,
}

/// State behind the `cluster.fuse` lock: the window configuration, the
/// pending batch, and the member seqs currently being flushed (a
/// completer must not collect its replies before its outbound bill has
/// been applied — `flushing` is what it waits out).
struct FusionState {
    config: Option<FusionConfig>,
    pending: Option<PendingFuse>,
    flushing: Vec<u64>,
}

/// One in-flight ticket's parking slot: where the router delivers (and
/// bills) this exchange's replies until the completer collects them.
struct Slot {
    /// Resolved wire format the round shipped under. Replies arrive
    /// already compressed by the worker's [`ReplyBank`]; the router
    /// bills them at this format's frame size — a pure function of the
    /// payload shape — and never touches the payload.
    format: WireFormat,
    /// The issuing session, for billing at routing time.
    owner: Weak<SessionCore>,
    /// Replies owed (sends that succeeded).
    expected: usize,
    /// Routed replies in arrival order, payloads already transcoded.
    replies: Vec<(usize, Response)>,
    /// Per-exchange compute deadline, refreshed on every arrival for
    /// this slot (mirrors the old one-recv-at-a-time timeout).
    deadline: Instant,
}

/// One retired exchange's straggler-routing record. A straggler is
/// billed at the **format its round shipped under** — resolved at
/// submit time and frozen here — not at whatever the issuing session's
/// codec has adapted to since.
struct Inflight {
    format: WireFormat,
    outstanding: usize,
    owner: Weak<SessionCore>,
}

/// Drop inflight records — and fused split tables — too old to
/// attribute (see [`INFLIGHT_RETENTION`]).
fn prune_inflight(st: &mut RouterState, seq: u64) {
    st.inflight.retain(|&s, _| s + INFLIGHT_RETENTION > seq);
    st.fused.retain(|&s, _| s + INFLIGHT_RETENTION > seq);
}

/// Handle to a running simulated cluster. `Sync`: share it across leader
/// threads and open one [`Session`] per tenant ([`Cluster::session`]) —
/// all billing, codec state and collectives live on the session.
pub struct Cluster {
    m: usize,
    n: usize,
    d: usize,
    leader_shard: Arc<Shard>,
    dead: Mutex<HashSet<usize>>,
    /// Monotonic cluster-wide bill: every session increment is applied
    /// here too, so this is the sum of all traffic ever billed to any
    /// session — equal to Σ current session bills as long as none has
    /// been reset ([`Session::reset_stats`] zeroes only the session's
    /// ledger). Meter a window with [`CommStats::delta_since`].
    aggregate: Mutex<CommStats>,
    /// Cluster-wide exchange sequence namespace. Workers echo the
    /// request's sequence number on their reply, so every reply — on
    /// time or straggling — is routable to the ticket (and session)
    /// that issued it, never misattributed to a later collective on the
    /// shared response stream.
    seq: AtomicU64,
    /// The **send lock**: the transport's send side. Held only while a
    /// submit's requests go out (microseconds), never across a reply
    /// wait — which is what lets concurrent tenants' rounds, and one
    /// algorithm's independent rounds, overlap on the wire.
    sender: Mutex<Box<dyn Transport>>,
    /// The reply router (see [`Router`]): owns the transport's reply
    /// stream and delivers every response to its ticket's slot.
    router: Router,
    /// The fusion window ([`Cluster::enable_fusion`]): configuration
    /// plus the pending batch. Leaf lock — never held while any router
    /// or transport lock is taken.
    fusion: Mutex<FusionState>,
    /// Wakes fusion-window waiters: batch flushed or displaced.
    fuse_cv: Condvar,
    /// Carrier rounds sent / member rounds fused into them, for
    /// observability (bills never change under fusion, so the bill
    /// cannot tell you whether fusion engaged — these counters can).
    fused_carriers: AtomicU64,
    fused_members: AtomicU64,
    /// Max wall time to wait for any single worker response.
    timeout: Duration,
}

impl Cluster {
    /// Generate a cluster of `m` machines with `n` i.i.d. samples each,
    /// using the pure-Rust compute oracle (in-proc transport).
    pub fn generate(dist: &dyn Distribution, m: usize, n: usize, seed: u64) -> Result<Cluster> {
        Self::generate_with(dist, m, n, seed, OracleSpec::Native)
    }

    /// Generate with an explicit compute-oracle spec (e.g. PJRT-backed),
    /// on the in-proc transport.
    pub fn generate_with(
        dist: &dyn Distribution,
        m: usize,
        n: usize,
        seed: u64,
        oracle: OracleSpec,
    ) -> Result<Cluster> {
        Self::generate_on(dist, m, n, seed, oracle, &TransportSpec::InProc)
    }

    /// Generate with an explicit transport backend: [`TransportSpec::InProc`]
    /// spawns one worker thread per machine; [`TransportSpec::Tcp`]
    /// connects to one `dspca worker --listen <addr>` peer per machine
    /// (`m` must equal the address count) and ships each its shard.
    /// Bills are backend-invariant: the same seed produces the same
    /// estimates and the same `CommStats` on every backend.
    pub fn generate_on(
        dist: &dyn Distribution,
        m: usize,
        n: usize,
        seed: u64,
        oracle: OracleSpec,
        transport: &TransportSpec,
    ) -> Result<Cluster> {
        if m == 0 || n == 0 {
            bail!("cluster requires m >= 1, n >= 1");
        }
        let mut root = Pcg64::with_stream(seed, 0xdeca_f);
        let shards: Vec<Arc<Shard>> = (0..m)
            .map(|i| {
                let mut rng = root.fork(i as u64);
                Arc::new(dist.sample_shard(&mut rng, n))
            })
            .collect();
        Self::from_shards_on(shards, seed, oracle, transport)
    }

    /// Build a cluster around pre-generated shards (all `n x d` equal
    /// shapes) on the in-proc transport.
    pub fn from_shards(shards: Vec<Arc<Shard>>, seed: u64, oracle: OracleSpec) -> Result<Cluster> {
        Self::from_shards_on(shards, seed, oracle, &TransportSpec::InProc)
    }

    /// Build a cluster around pre-generated shards on an explicit
    /// transport backend (see [`Cluster::generate_on`]).
    pub fn from_shards_on(
        shards: Vec<Arc<Shard>>,
        seed: u64,
        oracle: OracleSpec,
        transport: &TransportSpec,
    ) -> Result<Cluster> {
        if shards.is_empty() {
            bail!("no shards");
        }
        let (n, d) = (shards[0].n(), shards[0].d());
        for s in &shards {
            if s.n() != n || s.d() != d {
                bail!("ragged shards: expected {n}x{d}, got {}x{}", s.n(), s.d());
            }
        }
        let m = shards.len();
        let leader_shard = Arc::clone(&shards[0]);
        let mut transport: Box<dyn Transport> = match transport {
            TransportSpec::InProc => Box::new(InProcTransport::spawn(shards, &oracle, seed)?),
            TransportSpec::Tcp { workers, io_timeout } => Box::new(TcpTransport::connect(
                workers,
                shards,
                &oracle,
                seed,
                *io_timeout,
            )?),
        };
        // the router owns the reply stream from day one; the transport
        // behind the send lock only ever sends
        let reply_stream = transport.take_reply_stream();
        Ok(Cluster {
            m,
            n,
            d,
            leader_shard,
            dead: Mutex::named(HashSet::new(), "cluster.dead"),
            aggregate: Mutex::named(CommStats::default(), "cluster.aggregate"),
            seq: AtomicU64::new(CONTROL_SEQ),
            // the send lock and the reply stream are the two locks
            // legitimately held across transport I/O (DESIGN.md §11) —
            // `named_io` exempts them from the analyze build's
            // no-locks-across-I/O check
            sender: Mutex::named_io(transport, "cluster.sender"),
            router: Router {
                state: Mutex::named(
                    RouterState {
                        open: HashMap::new(),
                        inflight: HashMap::new(),
                        fused: HashMap::new(),
                    },
                    "router.state",
                ),
                cv: Condvar::new(),
                rx: Mutex::named_io(reply_stream, "router.rx"),
            },
            fusion: Mutex::named(
                FusionState { config: None, pending: None, flushing: Vec::new() },
                "cluster.fuse",
            ),
            fuse_cv: Condvar::new(),
            fused_carriers: AtomicU64::new(0),
            fused_members: AtomicU64::new(0),
            timeout: EXCHANGE_TIMEOUT,
        })
    }

    /// Which transport backend this cluster runs on ("inproc" / "tcp").
    pub fn transport_name(&self) -> &'static str {
        self.sender.lock().name()
    }

    /// Leader-side reply-plumbing threads the transport runs
    /// ([`Transport::reader_threads`](crate::transport::Transport::reader_threads)):
    /// the TCP reactor reports 1 at any peer count — the E12
    /// constant-thread-budget gate reads this.
    pub fn reader_threads(&self) -> usize {
        self.sender.lock().reader_threads()
    }

    /// Open a new tenant session: its own bill, its own codec, the full
    /// collective API. Cheap — single-query callers make one per run
    /// (`alg.run(&cluster.session())`), services one per tenant.
    pub fn session(&self) -> Session<'_> {
        Session::new(self)
    }

    /// Number of machines `m`.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Per-machine sample size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Machine 1's shard, visible to the leader for free (the leader *is*
    /// machine 1 in the paper's model).
    pub fn leader_shard(&self) -> &Shard {
        &self.leader_shard
    }

    /// The monotonic cluster-wide bill: the sum of every session's
    /// traffic since the cluster was built. Never reset (a reset would
    /// stomp concurrent tenants) — meter a window by snapshotting before
    /// and using [`CommStats::delta_since`] after.
    pub fn aggregate_stats(&self) -> CommStats {
        self.aggregate.lock().clone()
    }

    fn alive_workers(&self) -> Vec<usize> {
        let dead = self.dead.lock();
        (0..self.m).filter(|i| !dead.contains(i)).collect()
    }

    /// Kill a worker (failure injection for tests). Subsequent collective
    /// ops — from every session — exclude it; killing the leader's
    /// machine is not allowed.
    pub fn kill_worker(&self, i: usize) -> Result<()> {
        if i == 0 {
            bail!("machine 1 is the leader; cannot kill it");
        }
        if i >= self.m {
            bail!("no such worker {i}");
        }
        // record first, notify after: the dead-set guard must not be
        // held across the (potentially blocking) transport send
        let newly_dead = self.dead.lock().insert(i);
        if newly_dead {
            // best effort: tell the worker (thread or remote process'
            // connection handler) to exit
            let _ = self.sender.lock().send(
                i,
                CONTROL_SEQ,
                WireDesc::lossless(),
                &Request::Shutdown,
            );
        }
        Ok(())
    }

    /// Number of live machines.
    pub fn live(&self) -> usize {
        self.alive_workers().len()
    }

    // -----------------------------------------------------------------
    // Reply-router engine (see [`Router`]). The session layer opens
    // slots at submit time; these methods deliver and collect replies.
    // -----------------------------------------------------------------

    /// Deliver one reply to wherever its sequence number points: an open
    /// ticket's slot (bill the issuing session and the aggregate at the
    /// round's resolved format — the worker already compressed the
    /// payload, so billing is pure shape arithmetic — park the reply,
    /// refresh the slot deadline), a retired exchange's straggler record
    /// (bill the issuer at the format its round shipped under, or drop
    /// unbilled if that session closed), or — unknown seq, record aged
    /// out — the floor. Always notifies parked completers.
    fn route_reply(&self, id: usize, rseq: u64, resp: Response) {
        let mut st = self.router.state.lock();
        if st.fused.contains_key(&rseq) {
            self.route_carrier_locked(&mut st, id, rseq, resp);
        } else {
            self.deliver_locked(&mut st, id, rseq, resp);
        }
        drop(st);
        self.router.cv.notify_all();
    }

    /// Split one carrier reply into its member responses and deliver
    /// each through the ordinary per-seq path — so billing, straggling,
    /// aging and orphan handling are *identical* to unfused rounds by
    /// construction. A worker error (or a malformed carrier shape) is
    /// delivered to every member. Caller holds the router state lock.
    fn route_carrier_locked(&self, st: &mut RouterState, id: usize, rseq: u64, resp: Response) {
        let (parts, emptied) = {
            let Some(route) = st.fused.get_mut(&rseq) else { return };
            route.outstanding = route.outstanding.saturating_sub(1);
            let parts: Vec<(u64, Response)> = match &resp {
                Response::Mat { rows, cols, data }
                    if *rows == route.d && *cols == route.cols =>
                {
                    route
                        .members
                        .iter()
                        .map(|m| {
                            let mut block = Vec::with_capacity(route.d * m.k);
                            for r in 0..route.d {
                                let at = r * route.cols + m.col0;
                                block.extend_from_slice(&data[at..at + m.k]);
                            }
                            let part = if m.vector {
                                Response::Vector(block)
                            } else {
                                Response::Mat { rows: route.d, cols: m.k, data: block }
                            };
                            (m.seq, part)
                        })
                        .collect()
                }
                Response::Err(e) => route
                    .members
                    .iter()
                    .map(|m| (m.seq, Response::Err(e.clone())))
                    .collect(),
                _ => {
                    let msg = "fused carrier returned a malformed reply".to_string();
                    route
                        .members
                        .iter()
                        .map(|m| (m.seq, Response::Err(msg.clone())))
                        .collect()
                }
            };
            (parts, route.outstanding == 0)
        };
        if emptied {
            st.fused.remove(&rseq);
        }
        for (mseq, part) in parts {
            self.deliver_locked(st, id, mseq, part);
        }
    }

    /// Deliver one (possibly split-off) reply to wherever its sequence
    /// number points — an open slot, a straggler record, or the floor.
    /// Caller holds the router state lock and notifies the router
    /// condvar afterwards.
    fn deliver_locked(&self, st: &mut RouterState, id: usize, rseq: u64, resp: Response) {
        if let Some(slot) = st.open.get_mut(&rseq) {
            let resp_bytes = resp
                .payload()
                .map_or(0, |p| slot.format.frame_bytes(p.len(), resp.payload_cols()))
                as u64;
            if let Some(owner) = slot.owner.upgrade() {
                // billing lives in the session layer (lint rule
                // `commstats-mutation`): one helper bills the issuing
                // session and the aggregate together
                owner.bill_reply_arrival(&self.aggregate, resp_bytes, rseq, slot.format);
            }
            slot.replies.push((id, resp));
            slot.deadline = Instant::now() + self.timeout;
        } else {
            // straggler from a retired exchange — possibly another
            // session's. Bill it to the session that issued `rseq`; if
            // that session is closed or the record was pruned, drop the
            // reply unbilled.
            let mut record = None;
            if let Some(rec) = st.inflight.get_mut(&rseq) {
                rec.outstanding -= 1;
                record = Some((rec.format, rec.owner.clone(), rec.outstanding == 0));
            }
            if let Some((stale_format, owner, emptied)) = record {
                if emptied {
                    st.inflight.remove(&rseq);
                }
                if let Some(owner) = owner.upgrade() {
                    let stale_bytes = resp
                        .payload()
                        .map_or(0, |p| stale_format.frame_bytes(p.len(), resp.payload_cols()))
                        as u64;
                    crate::obs_inc!(CLUSTER_STRAGGLER_REPLIES_TOTAL);
                    owner.bill_reply_arrival(&self.aggregate, stale_bytes, rseq, stale_format);
                } else {
                    // issuer closed before its straggler landed
                    crate::obs_inc!(CLUSTER_ORPHAN_REPLIES_TOTAL);
                    crate::obs_trace!("orphan", seq = rseq, worker = id);
                }
            } else {
                // record aged out of the straggler table (or never
                // existed): nothing to bill, nobody to deliver to
                crate::obs_inc!(CLUSTER_ORPHAN_REPLIES_TOTAL);
                crate::obs_trace!("orphan", seq = rseq, worker = id);
            }
        }
    }

    /// Move an open slot to the straggler table (timeout, send failure,
    /// dropped ticket): replies still owed become an [`Inflight`] record
    /// so they are billed to this issuer — not misdelivered — when they
    /// eventually arrive. Caller holds the router state lock.
    fn retire_slot_locked(st: &mut RouterState, seq: u64) {
        if let Some(slot) = st.open.remove(&seq) {
            let outstanding = slot.expected - slot.replies.len();
            if outstanding > 0 {
                prune_inflight(st, seq);
                st.inflight
                    .insert(seq, Inflight { format: slot.format, outstanding, owner: slot.owner });
            }
        }
    }

    /// Retire a ticket's slot (used by `Ticket::drop` and the failure
    /// paths) and wake parked completers.
    pub(crate) fn retire_ticket(&self, seq: u64) {
        let mut st = self.router.state.lock();
        Self::retire_slot_locked(&mut st, seq);
        drop(st);
        self.router.cv.notify_all();
    }

    // -----------------------------------------------------------------
    // Round fusion (see the module doc and DESIGN.md §2). The session
    // layer registers member rounds; these methods batch, flush, and
    // split them. All state lives behind the leaf `cluster.fuse` lock,
    // never held while a router or transport lock is taken.
    // -----------------------------------------------------------------

    /// Enable cross-tenant round fusion: compatible matvec/matmat
    /// rounds — same codec, same live-worker set — submitted within
    /// `window` of each other coalesce into one stacked `CovMatMat`
    /// carrier of at most `max_cols` columns. Wall clock changes; bills
    /// do **not**: every member session is billed exactly what its
    /// round costs solo, at its own codec width. Off by default; cannot
    /// be disabled once enabled (calling again adjusts the knobs).
    ///
    /// Latency note: a fused round reaches the wire when the batch
    /// fills, when an incompatible round displaces it, or when a member
    /// completes/drops its ticket and waits out the remainder of the
    /// window — so a *lone* session completing immediately after submit
    /// pays up to `window` extra latency per round. Size the window for
    /// the concurrency you expect (hundreds of microseconds to a few
    /// milliseconds).
    pub fn enable_fusion(&self, window: Duration, max_cols: usize) -> Result<()> {
        if max_cols == 0 {
            bail!("fusion max_cols must be >= 1");
        }
        self.fusion.lock().config = Some(FusionConfig { window, max_cols });
        Ok(())
    }

    /// Whether a fusion window is currently configured.
    pub(super) fn fusion_enabled(&self) -> bool {
        self.fusion.lock().config.is_some()
    }

    /// (carrier rounds sent, member rounds fused into them). Bills are
    /// fusion-invariant by design, so they cannot tell you whether
    /// fusion engaged — these counters can (the E11 driver and the
    /// regression tests use them).
    pub fn fusion_counters(&self) -> (u64, u64) {
        (self.fused_carriers.load(Ordering::Relaxed), self.fused_members.load(Ordering::Relaxed))
    }

    /// Register a member round with the pending batch: join a
    /// compatible batch (flushing it once full), displace an
    /// incompatible one, or open a fresh batch. The member's routing
    /// slot is already open. Called by `Session` right after slot
    /// creation; holds only the fuse lock, then flushes outside it.
    pub(super) fn enqueue_fused(&self, codec: WireCodec, workers: &[usize], member: FuseMember) {
        let d = self.d;
        let k = member.k;
        let mut flush_now: Vec<PendingFuse> = Vec::new();
        {
            let mut fu = self.fusion.lock();
            let cfg = fu
                .config
                .unwrap_or(FusionConfig { window: Duration::from_micros(0), max_cols: 1 });
            let mut leftover = Some(member);
            let mut take_current = false;
            match &mut fu.pending {
                Some(p)
                    if p.codec == codec
                        && p.workers.as_slice() == workers
                        && p.d == d
                        && p.total_cols + k <= cfg.max_cols =>
                {
                    if let Some(m) = leftover.take() {
                        p.total_cols += m.k;
                        p.members.push(m);
                    }
                    take_current = p.total_cols >= cfg.max_cols;
                }
                Some(_) => {
                    // incompatible (codec/worker-set/width) submit
                    // displaces the pending batch onto the wire
                    crate::obs_inc!(FUSION_DISPLACEMENTS_TOTAL);
                    take_current = true;
                }
                None => {}
            }
            if take_current {
                if let Some(batch) = fu.pending.take() {
                    fu.flushing.extend(batch.members.iter().map(|m| m.seq));
                    flush_now.push(batch);
                }
            }
            if let Some(m) = leftover {
                let batch = PendingFuse {
                    codec,
                    workers: workers.to_vec(),
                    d,
                    total_cols: m.k,
                    members: vec![m],
                    opened: Instant::now(),
                };
                if batch.total_cols >= cfg.max_cols {
                    fu.flushing.extend(batch.members.iter().map(|m| m.seq));
                    flush_now.push(batch);
                } else {
                    fu.pending = Some(batch);
                }
            }
        }
        for batch in flush_now {
            self.flush_batch(batch);
        }
    }

    /// Displace (flush unfused) whatever batch is pending in the fusion
    /// window without joining it — the path a **stateful-codec** submit
    /// takes: its round must never share a carrier, but it must not
    /// leave earlier members parked for the window remainder either.
    /// Counted as a displacement, exactly like an incompatible member.
    pub(super) fn displace_pending(&self) {
        let batch = {
            let mut fu = self.fusion.lock();
            match fu.pending.take() {
                Some(batch) => {
                    crate::obs_inc!(FUSION_DISPLACEMENTS_TOTAL);
                    fu.flushing.extend(batch.members.iter().map(|m| m.seq));
                    Some(batch)
                }
                None => None,
            }
        };
        if let Some(batch) = batch {
            self.flush_batch(batch);
        }
    }

    /// Get ticket `seq`'s round onto the wire if it is still pending in
    /// the fusion window, and — for completers (`wait`) — block until
    /// its outbound bill has been applied, so `complete()` can never
    /// observe a round whose submit half is unbilled. No-op for
    /// non-fused tickets; cheap when fusion is disabled.
    pub(crate) fn ensure_flushed(&self, seq: u64, wait: bool) {
        let mut fu = self.fusion.lock();
        loop {
            let pending_deadline = match (&fu.config, &fu.pending) {
                (Some(cfg), Some(p)) if p.members.iter().any(|m| m.seq == seq) => {
                    Some(p.opened + cfg.window)
                }
                _ => None,
            };
            if let Some(deadline) = pending_deadline {
                let now = Instant::now();
                if !wait || now >= deadline {
                    if wait {
                        // a completer waited out the window remainder
                        crate::obs_inc!(FUSION_DEADLINE_FLUSHES_TOTAL);
                    }
                    if let Some(batch) = fu.pending.take() {
                        fu.flushing.extend(batch.members.iter().map(|m| m.seq));
                        drop(fu);
                        self.flush_batch(batch);
                        fu = self.fusion.lock();
                    }
                    continue;
                }
                // park for the window remainder: a joiner may still
                // fill the batch (its flush notifies us early)
                let (guard, _) = self.fuse_cv.wait_timeout(fu, deadline - now);
                fu = guard;
                continue;
            }
            if wait && fu.flushing.contains(&seq) {
                let (guard, _) = self.fuse_cv.wait_timeout(fu, Duration::from_millis(10));
                fu = guard;
                continue;
            }
            return;
        }
    }

    /// Put one fusion batch on the wire. A single-member batch ships
    /// the member's own request under its own sequence number —
    /// wire-identical to an unfused submit, no carrier. A multi-member
    /// batch interleaves the member columns into one row-major
    /// `d x K` carrier `CovMatMat`, registers the column split with the
    /// router *before* sending, then sends once per worker. Each member
    /// is billed its solo outbound (the billing body lives in
    /// `cluster/session.rs`); a partial send failure synthesizes a
    /// worker error into every member's slot for each unreached worker
    /// (unbilled — no bytes moved), so completers fail fast exactly
    /// like a solo submit error, while replies from reached workers
    /// still bill on arrival.
    fn flush_batch(&self, batch: PendingFuse) {
        let PendingFuse { codec, workers, d, members, total_cols, .. } = batch;
        let seqs: Vec<u64> = members.iter().map(|m| m.seq).collect();
        let (send_seq, req) = if members.len() == 1 {
            let m = &members[0];
            let req = if m.vector {
                Request::CovMatVec(m.cols.clone())
            } else {
                Request::CovMatMat { rows: d, cols: m.k, data: m.cols.clone() }
            };
            (m.seq, req)
        } else {
            let mut data = Vec::with_capacity(d * total_cols);
            for r in 0..d {
                for m in &members {
                    data.extend_from_slice(&m.cols[r * m.k..(r + 1) * m.k]);
                }
            }
            let carrier_seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
            let mut col0 = 0;
            let slices: Vec<FusedSlice> = members
                .iter()
                .map(|m| {
                    let s = FusedSlice { seq: m.seq, col0, k: m.k, vector: m.vector };
                    col0 += m.k;
                    s
                })
                .collect();
            {
                let mut st = self.router.state.lock();
                prune_inflight(&mut st, carrier_seq);
                st.fused.insert(
                    carrier_seq,
                    FusedRoute { d, cols: total_cols, outstanding: workers.len(), members: slices },
                );
            }
            self.fused_carriers.fetch_add(1, Ordering::Relaxed);
            self.fused_members.fetch_add(members.len() as u64, Ordering::Relaxed);
            crate::obs_inc!(FUSION_CARRIERS_TOTAL);
            crate::obs_add!(FUSION_MEMBERS_TOTAL, members.len() as u64);
            crate::obs_hist!(FUSION_BATCH_COLS, total_cols as u64);
            crate::obs_trace!(
                "fusion_flush",
                seq = carrier_seq,
                members = members.len(),
                cols = total_cols
            );
            (carrier_seq, Request::CovMatMat { rows: d, cols: total_cols, data })
        };
        // only `codec.fuses()` members ever reach a batch (stateless,
        // no feedback stream), so the carrier ships under the codec's
        // fixed default format with no stream key
        let desc = WireDesc { format: codec.default_format(), feedback: false, sid: 0 };
        let mut sent = 0usize;
        {
            let mut sender = self.sender.lock();
            for &w in &workers {
                if sender.send(w, send_seq, desc, &req).is_err() {
                    break;
                }
                sent += 1;
            }
        }
        for m in &members {
            if let Some(owner) = m.owner.upgrade() {
                owner.bill_fused_submit(
                    &self.aggregate,
                    sent as u64,
                    m.req_bytes,
                    m.seq,
                    codec.default_format(),
                );
            }
        }
        if sent < workers.len() {
            // the unreached tail owes no replies
            let mut st = self.router.state.lock();
            if members.len() > 1 {
                let missing = workers.len() - sent;
                let mut emptied = false;
                if let Some(route) = st.fused.get_mut(&send_seq) {
                    route.outstanding = route.outstanding.saturating_sub(missing);
                    emptied = route.outstanding == 0;
                }
                if emptied {
                    st.fused.remove(&send_seq);
                }
            }
            for &w in &workers[sent..] {
                for m in &members {
                    if let Some(slot) = st.open.get_mut(&m.seq) {
                        slot.replies.push((
                            w,
                            Response::Err(format!("fused send to worker {w} failed")),
                        ));
                    }
                }
            }
            drop(st);
            self.router.cv.notify_all();
        }
        let mut fu = self.fusion.lock();
        fu.flushing.retain(|s| !seqs.contains(s));
        drop(fu);
        self.fuse_cv.notify_all();
    }

    /// Block until ticket `seq`'s slot holds every owed reply, driving
    /// the router while waiting. Cooperative delivery: whichever
    /// completer acquires the reply stream becomes the *driver* and
    /// routes **every** arriving reply (its own and other tenants'); the
    /// rest park on the condvar until a route or a driver hand-off wakes
    /// them. On timeout/disconnect the slot is retired to the straggler
    /// table and the same error the old drain loop produced is returned.
    fn await_ticket(&self, seq: u64) -> Result<Vec<(usize, Response)>> {
        loop {
            let mut st = self.router.state.lock();
            loop {
                let slot = st.open.get(&seq).expect("await_ticket: no slot for ticket");
                if slot.replies.len() == slot.expected {
                    let slot = st.open.remove(&seq).expect("slot vanished");
                    drop(st);
                    // a parked completer may need to take over driving
                    self.router.cv.notify_all();
                    return Ok(slot.replies);
                }
                let now = Instant::now();
                let deadline = slot.deadline;
                // driver election: a try_lock cannot block, so taking
                // `rx` under `state` here does not order state before rx
                // (the shim records no incoming edge for try_lock) —
                // which is what lets the elected driver take rx → state
                // in the opposite order without a lockdep cycle. A
                // panicked driver's poison is recovered inside the shim.
                match self.router.rx.try_lock() {
                    Some(rx) => {
                        if now >= deadline {
                            // deadline passed with the stream idle: one
                            // non-blocking drain so replies that arrived
                            // while nobody was driving still land before
                            // we give up
                            drop(st);
                            let mut routed = false;
                            while let Ok((id, rseq, resp)) = rx.try_recv() {
                                routed = true;
                                self.route_reply(id, rseq, resp);
                            }
                            drop(rx);
                            if routed {
                                break; // re-check the slot
                            }
                            self.retire_ticket(seq);
                            bail!(
                                "waiting for worker responses: {}",
                                RecvError::TimedOut(self.timeout)
                            );
                        }
                        // we are the driver: wait for traffic on behalf
                        // of every open ticket, holding no state lock
                        drop(st);
                        match recv_reply(&rx, deadline - now) {
                            Ok((id, rseq, resp)) => {
                                // route while still holding the stream:
                                // once rx is released, everything
                                // received has been delivered, so a
                                // newly elected driver can trust the
                                // slot check it made before electing
                                // itself — releasing first would open a
                                // window where a completer blocks in
                                // recv on a quiesced stream while its
                                // own last reply is routed behind it
                                // (no condvar reaches a recv sleeper)
                                self.route_reply(id, rseq, resp);
                                drop(rx);
                            }
                            Err(RecvError::TimedOut(_)) => drop(rx),
                            Err(e @ RecvError::Disconnected(_)) => {
                                drop(rx);
                                self.retire_ticket(seq);
                                bail!("waiting for worker responses: {e}");
                            }
                        }
                        break; // re-enter with a fresh state lock
                    }
                    None => {
                        if now >= deadline {
                            // the active driver routed nothing for us in
                            // time — same timeout as if we drove
                            Self::retire_slot_locked(&mut st, seq);
                            drop(st);
                            self.router.cv.notify_all();
                            bail!(
                                "waiting for worker responses: {}",
                                RecvError::TimedOut(self.timeout)
                            );
                        }
                        // park until the driver routes something or
                        // retires; re-check the slot on every wake
                        let (guard, _) = self.router.cv.wait_timeout(st, deadline - now);
                        st = guard;
                    }
                }
            }
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        // idempotent on every backend: workers are told to stop, threads
        // and sockets are released; a second shutdown (e.g. the
        // transport's own Drop) is a no-op. `get_mut` recovers poison
        // inside the shim.
        self.sender.get_mut().shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CovModel;
    use crate::linalg::vec_ops::{alignment_error, norm};
    use crate::linalg::Matrix;

    fn small_cluster(m: usize, n: usize) -> (Cluster, Vec<f64>) {
        let dist = CovModel::paper_fig1(8, 3).gaussian();
        let v1 = dist.v1().to_vec();
        (Cluster::generate(&dist, m, n, 42).unwrap(), v1)
    }

    /// Route anything still sitting in the reply stream (tests only):
    /// per-worker reply order is FIFO on every backend, so after a
    /// collective completes, any straggler sent *before* it is already
    /// routed — this drain just makes that deterministic at the margin.
    fn drain_router(c: &Cluster) {
        loop {
            let rx = c.router.rx.lock();
            match rx.try_recv() {
                Ok((id, seq, resp)) => {
                    drop(rx);
                    c.route_reply(id, seq, resp);
                }
                Err(_) => break,
            }
        }
    }

    /// Assert the cluster is shareable across threads (the tentpole's
    /// compile-time requirement): `&Cluster` must cross thread
    /// boundaries, and sessions must be creatable per thread.
    #[test]
    fn cluster_is_sync_and_sessions_run_from_scoped_threads() {
        fn assert_sync<T: Sync>() {}
        assert_sync::<Cluster>();
        let (c, _) = small_cluster(3, 20);
        let v = vec![1.0; 8];
        let outs = std::thread::scope(|s| {
            let h1 = s.spawn(|| c.session().dist_matvec(&v).unwrap());
            let h2 = s.spawn(|| c.session().dist_matvec(&v).unwrap());
            (h1.join().unwrap(), h2.join().unwrap())
        });
        assert_eq!(outs.0, outs.1, "same query, same cluster, same answer");
    }

    #[test]
    fn dist_matvec_matches_mean_of_local() {
        let (c, _) = small_cluster(4, 50);
        let s = c.session();
        let v: Vec<f64> = (0..8).map(|i| (i as f64 + 1.0) / 8.0).collect();
        let got = s.dist_matvec(&v).unwrap();
        // reference: average the per-shard matvecs via a second cluster
        // primitive (gram_average)
        let g = s.gram_average().unwrap();
        let want = g.matvec(&v);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn stats_accounting() {
        let (c, _) = small_cluster(3, 20);
        let s = c.session();
        let v = vec![1.0; 8];
        s.dist_matvec(&v).unwrap();
        s.dist_matvec(&v).unwrap();
        let st = s.stats();
        assert_eq!(st.rounds, 2);
        assert_eq!(st.matvec_products, 2);
        assert_eq!(st.vectors_broadcast, 2);
        assert_eq!(st.vectors_gathered, 6);
        s.reset_stats();
        assert_eq!(s.stats().rounds, 0);
        // the aggregate is monotonic: a session reset does not touch it
        assert_eq!(c.aggregate_stats().rounds, 2);
    }

    #[test]
    fn sessions_bill_independently_and_sum_to_aggregate() {
        let (c, _) = small_cluster(3, 20);
        let a = c.session();
        let b = c.session();
        let v = vec![1.0; 8];
        a.dist_matvec(&v).unwrap();
        a.dist_matvec(&v).unwrap();
        b.gram_average().unwrap();
        assert_eq!(a.stats().rounds, 2, "tenant A pays only its own rounds");
        assert_eq!(b.stats().rounds, 1, "tenant B pays only its own round");
        assert_eq!(a.stats().vectors_gathered, 6);
        assert_eq!(b.stats().vectors_gathered, 3 * 8);
        let mut sum = a.stats();
        sum.merge(&b.stats());
        assert_eq!(sum, c.aggregate_stats());
    }

    #[test]
    fn per_session_codecs_do_not_interfere() {
        // a lossy tenant must not degrade a concurrent lossless tenant's
        // traffic — the codec is session state, not cluster state
        let (c, _) = small_cluster(2, 30);
        let lossless = c.session();
        let lossy = c.session();
        lossy.set_codec(WireCodec::new(WirePrecision::Bf16));
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.731).sin() * 1.0001 + 0.1).collect();
        let exact = lossless.dist_matvec(&x).unwrap();
        let coarse = lossy.dist_matvec(&x).unwrap();
        let again = lossless.dist_matvec(&x).unwrap();
        assert_eq!(exact, again, "lossless tenant must stay bit-exact");
        let total: f64 = exact.iter().zip(&coarse).map(|(a, b)| (a - b).abs()).sum();
        assert!(total > 0.0, "bf16 tenant must actually ship quantized frames");
        // and the bills reflect each tenant's own wire width
        assert_eq!(lossless.stats().bytes, 2 * 8 * 8 * 3, "two lossless rounds at 8B/entry");
        assert_eq!(lossy.stats().bytes, 2 * 8 * 3, "one bf16 round at 2B/entry");
    }

    #[test]
    fn local_eigvecs_count_and_norm() {
        let (c, v1) = small_cluster(5, 400);
        let s = c.session();
        let vs = s.local_top_eigvecs(false).unwrap();
        assert_eq!(vs.len(), 5);
        for v in &vs {
            assert!((norm(v) - 1.0).abs() < 1e-10);
            // with n=400 each local ERM is already well aligned
            assert!(alignment_error(v, &v1) < 0.2);
        }
        assert_eq!(s.stats().rounds, 1);
    }

    #[test]
    fn unbiased_signs_flip_randomly() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        let c = Cluster::generate(&dist, 16, 100, 7).unwrap();
        let vs = c.session().local_top_eigvecs(true).unwrap();
        // sign wrt v1: with 16 unbiased machines, both signs should appear
        let signs: Vec<bool> = vs
            .iter()
            .map(|v| crate::linalg::vec_ops::dot(v, dist.v1()) >= 0.0)
            .collect();
        assert!(signs.iter().any(|&s| s));
        assert!(signs.iter().any(|&s| !s));
    }

    #[test]
    fn oja_chain_runs_m_rounds() {
        let (c, _) = small_cluster(4, 30);
        let s = c.session();
        let mut w0 = vec![0.0; 8];
        w0[0] = 1.0;
        let w = s.oja_chain(&w0, 0.5, 10.0).unwrap();
        assert!((norm(&w) - 1.0).abs() < 1e-9);
        assert_eq!(s.stats().rounds, 4);
    }

    #[test]
    fn kill_worker_excludes_from_collectives() {
        let (c, _) = small_cluster(4, 20);
        c.kill_worker(2).unwrap();
        assert_eq!(c.live(), 3);
        let s = c.session();
        let v = vec![1.0; 8];
        let out = s.dist_matvec(&v).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(s.stats().vectors_gathered, 3);
    }

    #[test]
    fn cannot_kill_leader() {
        let (c, _) = small_cluster(2, 10);
        assert!(c.kill_worker(0).is_err());
    }

    #[test]
    fn dist_matmat_matches_columnwise_matvec() {
        let (c, _) = small_cluster(4, 60);
        let s = c.session();
        let k = 3;
        let mut v = Matrix::zeros(8, k);
        for col in 0..k {
            let x: Vec<f64> = (0..8).map(|i| ((i + col) as f64 * 0.37).sin()).collect();
            v.set_col(col, &x);
        }
        let blk = s.dist_matmat(&v).unwrap();
        assert_eq!(blk.rows(), 8);
        assert_eq!(blk.cols(), k);
        for col in 0..k {
            let want = s.dist_matvec(&v.col(col)).unwrap();
            for i in 0..8 {
                assert!((blk.get(i, col) - want[i]).abs() < 1e-12, "col {col} row {i}");
            }
        }
    }

    #[test]
    fn dist_matmat_accounting_matches_table() {
        let (c, _) = small_cluster(3, 20);
        let s = c.session();
        let k = 5;
        let v = Matrix::from_vec(8, k, (0..8 * k).map(|i| i as f64 * 0.01).collect());
        s.dist_matmat(&v).unwrap();
        let st = s.stats();
        assert_eq!(st.rounds, 1);
        assert_eq!(st.matvec_products, k as u64);
        assert_eq!(st.vectors_broadcast, k as u64);
        assert_eq!(st.vectors_gathered, 3 * k as u64);
        assert_eq!(st.requests_sent, 3);
        assert_eq!(st.responses_received, 3);
        assert_eq!(st.bytes, (8 * 8 * k * 4) as u64);
    }

    #[test]
    fn columnwise_loop_costs_k_rounds_block_costs_one() {
        // the protocol contrast the block rewrite exists for
        let (c, _) = small_cluster(3, 20);
        let k = 4;
        let v = Matrix::from_vec(8, k, (0..8 * k).map(|i| (i as f64).cos()).collect());
        let looped = c.session();
        for col in 0..k {
            looped.dist_matvec(&v.col(col)).unwrap();
        }
        let loop_stats = looped.stats();
        assert_eq!(loop_stats.rounds, k as u64);
        assert_eq!(loop_stats.requests_sent, (3 * k) as u64);
        let blocked = c.session();
        blocked.dist_matmat(&v).unwrap();
        let blk_stats = blocked.stats();
        assert_eq!(blk_stats.rounds, 1);
        assert_eq!(blk_stats.requests_sent, 3);
        // same vector traffic either way
        assert_eq!(blk_stats.vectors_gathered, loop_stats.vectors_gathered);
    }

    #[test]
    fn all_collectives_survive_one_dead_worker() {
        let (c, _) = small_cluster(4, 30);
        c.kill_worker(2).unwrap();
        assert_eq!(c.live(), 3);
        // gram_average
        let s = c.session();
        let g = s.gram_average().unwrap();
        assert_eq!(g.rows(), 8);
        assert_eq!(s.stats().responses_received, 3);
        // local_top_k
        let s = c.session();
        let locals = s.local_top_k(2).unwrap();
        assert_eq!(locals.len(), 3);
        assert_eq!(s.stats().vectors_gathered, 6);
        // oja_chain: live rounds, one handoff per live machine
        let s = c.session();
        let mut w0 = vec![0.0; 8];
        w0[0] = 1.0;
        let w = s.oja_chain(&w0, 0.5, 10.0).unwrap();
        assert!((crate::linalg::vec_ops::norm(&w) - 1.0).abs() < 1e-9);
        assert_eq!(s.stats().rounds, 3);
        assert_eq!(s.stats().requests_sent, 3);
        // dist_matmat: averages over survivors only
        let s = c.session();
        let v = Matrix::from_vec(8, 2, (0..16).map(|i| i as f64 * 0.1).collect());
        let blk = s.dist_matmat(&v).unwrap();
        assert_eq!(blk.cols(), 2);
        assert_eq!(s.stats().vectors_gathered, 6);
        assert_eq!(s.stats().requests_sent, 3);
        // block average equals the survivors' gram average applied to v
        let want = g.matmul(&v);
        assert!(blk.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn all_collectives_survive_two_dead_workers() {
        let (c, _) = small_cluster(5, 25);
        c.kill_worker(1).unwrap();
        c.kill_worker(4).unwrap();
        assert_eq!(c.live(), 3);
        let s = c.session();
        let g = s.gram_average().unwrap();
        assert_eq!(g.cols(), 8);
        let locals = s.local_top_k(3).unwrap();
        assert_eq!(locals.len(), 3);
        let vs = s.local_top_eigvecs(false).unwrap();
        assert_eq!(vs.len(), 3);
        let mut w0 = vec![0.0; 8];
        w0[1] = 1.0;
        assert!(s.oja_chain(&w0, 0.5, 10.0).is_ok());
        let v = Matrix::from_vec(8, 2, vec![0.25; 16]);
        assert!(s.dist_matmat(&v).is_ok());
        // killing the same worker twice is a no-op, not an error
        c.kill_worker(1).unwrap();
        assert_eq!(c.live(), 3);
    }

    #[test]
    fn failed_collective_does_not_poison_the_next_one() {
        // every worker rejects local_top_k(k > d); the error must not
        // leave stale responses in the shared channel for the next
        // collective — even one from a *different* session — to misread
        let (c, _) = small_cluster(3, 20);
        assert!(c.session().local_top_k(99).is_err());
        let s = c.session();
        let v = vec![1.0; 8];
        let a = s.dist_matvec(&v).unwrap();
        // and the result is the real matvec, not a stale frame
        let g = s.gram_average().unwrap();
        let want = g.matvec(&v);
        for i in 0..8 {
            assert!((a[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn failed_collective_still_bills_its_traffic() {
        // regression (ISSUE 2 satellite): the seed billed rounds and
        // bytes only on the collectives' success paths — an exchange
        // that drained worker errors billed its messages but never its
        // round, and a timed-out exchange billed nothing at all. The
        // load-bearing assertion here is rounds == 1; the message
        // counts pin the billed-as-they-happen behavior alongside it.
        let (c, _) = small_cluster(3, 20);
        let s = c.session();
        assert!(s.local_top_k(99).is_err());
        let st = s.stats();
        assert_eq!(st.rounds, 1, "the round happened even though it failed");
        assert_eq!(st.requests_sent, 3, "three requests crossed the wire");
        assert_eq!(st.responses_received, 3, "three Err replies crossed the wire");
        assert_eq!(st.bytes, 0, "Err replies carry no f64 payload");
        assert_eq!(st.vectors_gathered, 0, "no vectors were delivered");
    }

    #[test]
    fn bytes_are_billed_from_the_codec_encoded_frames() {
        let (c, _) = small_cluster(3, 20);
        let v = vec![1.0; 8];
        for (prec, bpe) in
            [(WirePrecision::F64, 8u64), (WirePrecision::F32, 4), (WirePrecision::Bf16, 2)]
        {
            let s = c.session();
            s.set_codec(WireCodec::new(prec));
            s.dist_matvec(&v).unwrap();
            // B(d)·(live+1) with d = 8, live = 3
            assert_eq!(s.stats().bytes, bpe * 8 * 4, "{prec:?}");
        }
        // a fresh session always starts lossless
        assert_eq!(c.session().codec(), WireCodec::lossless());
    }

    #[test]
    fn straggler_reply_bills_to_the_session_that_issued_it() {
        // regression (ISSUE 3 satellite): drive the sequence-number
        // path across tenants. Pretend tenant A's exchange (seq 1000)
        // timed out under a bf16 codec with one reply still in flight,
        // then have worker 1 actually answer it — the way a stalled
        // worker eventually would. Tenant B's next collective drains
        // the straggler; the bill must land on **A** (whose round it
        // was, at A's bf16 width), not on B, and B's result must be
        // unpoisoned.
        let (c, _) = small_cluster(2, 20);
        let issuer = c.session();
        let drainer = c.session();
        let v = vec![0.3; 8];
        let g = drainer.gram_average().unwrap();
        let want = g.matvec(&v);
        {
            let mut st = c.router.state.lock();
            st.inflight.insert(
                1000,
                Inflight {
                    format: WireFormat::Plain(WirePrecision::Bf16),
                    outstanding: 1,
                    owner: Arc::downgrade(&issuer.core),
                },
            );
        }
        // send outside the router-state guard: nothing holds a non-IO
        // lock across transport I/O (the analyze build enforces this)
        c.sender
            .lock()
            .send(1, 1000, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
            .unwrap();
        issuer.reset_stats();
        drainer.reset_stats();
        let got = drainer.dist_matvec(&v).unwrap();
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-10, "straggler poisoned the result");
        }
        // the drainer's complete() drives the router; the straggler may
        // interleave before or after its own replies, but always routes
        // to the issuer — drain any residue deterministically
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.requests_sent, 2);
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        // 8·d·(live+1) for the drainer's real round, nothing else
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        assert_eq!(db.vectors_gathered, 2, "only genuine replies are delivered");
        let ib = issuer.stats();
        assert_eq!(ib.responses_received, 1, "the straggler bills to its issuer on arrival");
        assert_eq!(ib.bytes, (2 * 8) as u64, "at the bf16 width its round shipped under");
        assert!(
            c.router.state.lock().inflight.is_empty(),
            "straggler record is forgotten"
        );
    }

    #[test]
    fn adaptive_straggler_bills_at_the_width_its_round_shipped() {
        // satellite: the `Inflight` record freezes the *resolved* format
        // at submit time, so a straggler from a round that shipped q4
        // bills q4 frame bytes even after the session's adaptive
        // controller (or a set_codec) has moved the stream to another
        // width — the bill reflects the bytes that actually crossed.
        let (c, _) = small_cluster(2, 20);
        let issuer = c.session();
        let drainer = c.session();
        let v = vec![0.3; 8];
        {
            let mut st = c.router.state.lock();
            st.inflight.insert(
                1000,
                Inflight {
                    format: WireFormat::Quant(QuantBits::Q4),
                    outstanding: 1,
                    owner: Arc::downgrade(&issuer.core),
                },
            );
        }
        // the issuer has since re-resolved to a wider codec than the
        // one round 1000 shipped under
        issuer.set_codec(WireCodec::quant(QuantBits::Q8).with_adaptive());
        c.sender
            .lock()
            .send(1, 1000, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
            .unwrap();
        issuer.reset_stats();
        drainer.reset_stats();
        drainer.dist_matvec(&v).unwrap();
        drain_router(&c);
        let ib = issuer.stats();
        assert_eq!(ib.responses_received, 1);
        // q4 frame of 8 words, one column: 4-byte scale + 4 nibble
        // bytes — not the 4 + 8 the session's current q8 would bill
        assert_eq!(ib.bytes, (4 + 4) as u64, "straggler billed at its round's frozen width");
        let db = drainer.stats();
        assert_eq!(db.bytes, (8 * 8 * 3) as u64, "drainer still bills lossless frames");
    }

    #[test]
    fn straggler_for_a_closed_session_is_dropped_unbilled() {
        // the second regression path: the issuing session is closed
        // before its straggler lands. The reply must be drained (so it
        // cannot poison anyone) but billed nowhere — neither to the
        // draining tenant nor to the aggregate, which stays equal to
        // the sum of live sessions' bills.
        let (c, _) = small_cluster(2, 20);
        let v = vec![0.3; 8];
        {
            let issuer = c.session();
            {
                let mut st = c.router.state.lock();
                st.inflight.insert(
                    2000,
                    Inflight {
                        codec: WireCodec::new(WirePrecision::Bf16),
                        outstanding: 1,
                        owner: Arc::downgrade(&issuer.core),
                    },
                );
            }
            c.sender
                .lock()
                .send(1, 2000, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
                .unwrap();
            // `issuer` drops here: the session is closed
        }
        let agg0 = c.aggregate_stats();
        let drainer = c.session();
        let got = drainer.dist_matvec(&v).unwrap();
        assert_eq!(got.len(), 8);
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        // aggregate window == drainer's bill: the orphan straggler was
        // dropped without billing anyone
        assert_eq!(c.aggregate_stats().delta_since(&agg0), db);
        assert!(
            c.router.state.lock().inflight.is_empty(),
            "orphan record is forgotten"
        );
    }

    // -----------------------------------------------------------------
    // Split-phase (ISSUE 5 tentpole): tickets, overlap, routing.
    // -----------------------------------------------------------------

    #[test]
    fn a_single_session_keeps_multiple_rounds_in_flight() {
        let (c, _) = small_cluster(3, 20);
        let s = c.session();
        let v = vec![1.0; 8];
        let t1 = s.dist_matvec_submit(&v).unwrap();
        let t2 = s.dist_matvec_submit(&v).unwrap();
        let t3 = s.dist_matvec_submit(&v).unwrap();
        // complete out of submission order: delivery is by echoed seq,
        // not by who drains first
        let r3 = t3.complete().unwrap();
        let r1 = t1.complete().unwrap();
        let r2 = t2.complete().unwrap();
        assert_eq!(r1, r2);
        assert_eq!(r2, r3);
        let st = s.stats();
        // the tentpole contract: overlap changes wall clock, not one
        // counter — three pipelined rounds bill like three serial ones
        let serial = c.session();
        for _ in 0..3 {
            serial.dist_matvec(&v).unwrap();
        }
        assert_eq!(st, serial.stats(), "pipelined bill != serial bill");
        assert_eq!(st.rounds, 3);
        assert_eq!(st.requests_sent, 9);
        assert_eq!(st.responses_received, 9);
        assert_eq!(st.bytes, 3 * 8 * 8 * 4, "3 rounds of B(d)·(live+1)");
    }

    #[test]
    fn interleaved_tenant_tickets_bill_like_solo_runs() {
        // two tenants with different codecs, rounds genuinely in flight
        // at once (submit/submit/complete/complete from one thread —
        // deterministic overlap, no scheduler luck needed)
        let (c, _) = small_cluster(2, 20);
        let v = vec![0.5; 8];
        let solo_lossless = {
            let s = c.session();
            s.dist_matvec(&v).unwrap();
            s.close()
        };
        let solo_bf16 = {
            let s = c.session();
            s.set_codec(WireCodec::new(WirePrecision::Bf16));
            s.dist_matvec(&v).unwrap();
            s.close()
        };
        let agg0 = c.aggregate_stats();
        let a = c.session();
        let b = c.session();
        b.set_codec(WireCodec::new(WirePrecision::Bf16));
        let ta = a.dist_matvec_submit(&v).unwrap();
        let tb = b.dist_matvec_submit(&v).unwrap();
        // B completes first: its driver routes A's replies into A's
        // slot along the way, billing A at A's codec width
        let _ = tb.complete().unwrap();
        let _ = ta.complete().unwrap();
        let (ba, bb) = (a.close(), b.close());
        assert_eq!(ba, solo_lossless, "tenant A's overlapped bill != its solo bill");
        assert_eq!(bb, solo_bf16, "tenant B's overlapped bill != its solo bill");
        let mut sum = ba;
        sum.merge(&bb);
        assert_eq!(c.aggregate_stats().delta_since(&agg0), sum);
    }

    #[test]
    fn dropping_an_uncompleted_ticket_retires_to_the_straggler_path() {
        let (c, _) = small_cluster(2, 20);
        let s = c.session();
        let v = vec![1.0; 8];
        {
            let _abandoned = s.dist_matvec_submit(&v).unwrap();
            // dropped here without complete()
        }
        // the round was billed at submit; its replies are drained by
        // whoever runs the router next and billed to the issuer
        let s2 = c.session();
        let out = s2.dist_matvec(&v).unwrap();
        assert_eq!(out.len(), 8);
        drain_router(&c);
        assert_eq!(s2.stats().responses_received, 2, "drainer pays only its own replies");
        let st = s.stats();
        assert_eq!(st.rounds, 1, "the abandoned round was still billed at submit");
        assert_eq!(st.requests_sent, 2);
        assert_eq!(st.responses_received, 2, "its replies bill to the issuer on arrival");
        assert!(c.router.state.lock().inflight.is_empty());
        assert!(c.router.state.lock().open.is_empty());
    }

    #[test]
    fn aged_out_inflight_record_drops_stragglers_unbilled_with_tickets_open() {
        // ISSUE 5 satellite: a straggler whose inflight record aged past
        // the retention horizon while *other tickets were open* is
        // drained-unbilled, and the aggregate identity stays exact.
        let (c, _) = small_cluster(2, 20);
        let v = vec![0.3; 8];
        let issuer = c.session();
        {
            let mut st = c.router.state.lock();
            st.inflight.insert(
                1,
                Inflight {
                    format: WireFormat::Plain(WirePrecision::Bf16),
                    outstanding: 1,
                    owner: Arc::downgrade(&issuer.core),
                },
            );
        }
        c.sender
            .lock()
            .send(1, 1, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
            .unwrap();
        // burn the sequence namespace past the retention horizon, so
        // the next submit prunes the record before its reply lands
        c.seq.fetch_add(INFLIGHT_RETENTION + 8, crate::sync::atomic::Ordering::Relaxed);
        let agg0 = c.aggregate_stats();
        let drainer = c.session();
        let ticket = drainer.dist_matvec_submit(&v).unwrap();
        assert!(
            !c.router.state.lock().inflight.contains_key(&1),
            "submit must prune records older than the horizon"
        );
        let got = ticket.complete().unwrap();
        assert_eq!(got.len(), 8);
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        assert_eq!(issuer.stats(), CommStats::default(), "aged straggler bills nobody");
        // aggregate window == the drainer's bill alone: exact identity
        assert_eq!(c.aggregate_stats().delta_since(&agg0), db);
        assert!(c.router.state.lock().inflight.is_empty());
    }

    #[test]
    fn session_close_returns_the_final_bill() {
        let (c, _) = small_cluster(2, 15);
        let s = c.session();
        let v = vec![1.0; 8];
        s.dist_matvec(&v).unwrap();
        let snapshot = s.stats();
        assert_eq!(s.close(), snapshot, "close() is the bill, race-free");
    }

    #[test]
    fn lossy_codec_actually_quantizes_the_wire() {
        let (c, _) = small_cluster(2, 30);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.731).sin() * 1.0001 + 0.1).collect();
        let s = c.session();
        let exact = s.dist_matvec(&x).unwrap();
        s.set_codec(WireCodec::new(WirePrecision::Bf16));
        let coarse = s.dist_matvec(&x).unwrap();
        s.set_codec(WireCodec::default());
        let again = s.dist_matvec(&x).unwrap();
        assert_eq!(exact, again, "default codec must be bit-exact");
        let total: f64 = exact.iter().zip(&coarse).map(|(a, b)| (a - b).abs()).sum();
        assert!(total > 0.0, "bf16 codec must actually perturb the wire");
        for (a, b) in exact.iter().zip(&coarse) {
            // perturbation stays at the 2^-8 relative scale of the codec
            assert!((a - b).abs() <= 0.1 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn dist_matmat_single_column_agrees_with_matvec() {
        let (c, _) = small_cluster(2, 15);
        let s = c.session();
        let x: Vec<f64> = (0..8).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        let mut v = Matrix::zeros(8, 1);
        v.set_col(0, &x);
        let blk = s.dist_matmat(&v).unwrap();
        let want = s.dist_matvec(&x).unwrap();
        for i in 0..8 {
            assert!((blk.get(i, 0) - want[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn leader_shard_is_machine_one() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        let c = Cluster::generate(&dist, 3, 25, 9).unwrap();
        assert_eq!(c.leader_shard().n(), 25);
        assert_eq!(c.leader_shard().d(), 4);
        // visible through the session view too
        assert_eq!(c.session().leader_shard().d(), 4);
    }

    #[test]
    fn ragged_shards_rejected() {
        use crate::data::Shard;
        let a = Arc::new(Shard::new(2, 2, vec![1.0; 4]));
        let b = Arc::new(Shard::new(3, 2, vec![1.0; 6]));
        assert!(Cluster::from_shards(vec![a, b], 0, OracleSpec::Native).is_err());
    }

    #[test]
    fn generate_rejects_degenerate() {
        let dist = CovModel::paper_fig1(4, 3).gaussian();
        assert!(Cluster::generate(&dist, 0, 5, 1).is_err());
        assert!(Cluster::generate(&dist, 5, 0, 1).is_err());
    }

    // -----------------------------------------------------------------
    // Transport-generic regressions (ISSUE 4 satellites): shutdown
    // idempotence / drop-order safety and the straggler contract on the
    // TCP backend, mirroring the in-proc tests above.
    // -----------------------------------------------------------------

    use crate::transport::LoopbackWorkers;

    fn tcp_cluster(m: usize, n: usize) -> (Cluster, LoopbackWorkers) {
        let dist = CovModel::paper_fig1(8, 3).gaussian();
        let workers = LoopbackWorkers::spawn(m, 1).unwrap();
        let c =
            Cluster::generate_on(&dist, m, n, 42, OracleSpec::Native, &workers.spec()).unwrap();
        (c, workers)
    }

    #[test]
    fn tcp_cluster_reports_its_backend_and_runs_collectives() {
        let (c, workers) = tcp_cluster(2, 20);
        assert_eq!(c.transport_name(), "tcp");
        let s = c.session();
        let ones = vec![1.0; 8];
        let got = s.dist_matvec(&ones).unwrap();
        assert_eq!(got.len(), 8);
        assert_eq!(s.stats().bytes, 8 * 8 * 3, "B(d)·(live+1) on TCP too");
        drop(s);
        drop(c);
        workers.join().unwrap();
    }

    #[test]
    fn inproc_shutdown_is_idempotent_and_later_traffic_fails_cleanly() {
        let (c, _) = small_cluster(2, 10);
        assert_eq!(c.transport_name(), "inproc");
        {
            let mut sender = c.sender.lock();
            sender.shutdown();
            sender.shutdown(); // double shutdown is a no-op
            let err = sender
                .send(1, 1, WireDesc::lossless(), &Request::CovMatVec(vec![1.0; 8]))
                .unwrap_err()
                .to_string();
            assert!(err.contains("worker 1"), "{err}");
        }
        // a collective after shutdown errors instead of hanging
        let ones = vec![1.0; 8];
        assert!(c.session().dist_matvec(&ones).is_err());
        // and dropping the cluster performs a third (no-op) shutdown
    }

    #[test]
    fn tcp_cluster_drop_mid_straggler_does_not_hang_or_double_close() {
        // regression (ISSUE 4 satellite): a TCP worker still owes a
        // reply when the cluster is dropped. Drop must complete —
        // Shutdown frames written best-effort, sockets closed once,
        // reader threads joined — and the worker side must come back to
        // a clean exit, not a wedged accept loop.
        let (c, workers) = tcp_cluster(2, 20);
        {
            // a request whose reply no ticket will ever collect
            c.sender
                .lock()
                .send(1, 999, WireDesc::lossless(), &Request::CovMatVec(vec![1.0; 8]))
                .unwrap();
        }
        drop(c); // must not hang; second shutdown inside transport Drop is a no-op
        workers.join().unwrap();
    }

    #[test]
    fn tcp_straggler_reply_bills_to_the_session_that_issued_it() {
        // the cross-tenant straggler contract, over real sockets: same
        // scenario as `straggler_reply_bills_to_the_session_that_issued_it`
        let (c, workers) = tcp_cluster(2, 20);
        let issuer = c.session();
        let drainer = c.session();
        let v = vec![0.3; 8];
        let g = drainer.gram_average().unwrap();
        let want = g.matvec(&v);
        {
            let mut st = c.router.state.lock();
            st.inflight.insert(
                1000,
                Inflight {
                    format: WireFormat::Plain(WirePrecision::Bf16),
                    outstanding: 1,
                    owner: Arc::downgrade(&issuer.core),
                },
            );
        }
        c.sender
            .lock()
            .send(1, 1000, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
            .unwrap();
        issuer.reset_stats();
        drainer.reset_stats();
        let got = drainer.dist_matvec(&v).unwrap();
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-10, "straggler poisoned the result");
        }
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        let ib = issuer.stats();
        assert_eq!(ib.responses_received, 1, "the straggler bills to its issuer on arrival");
        assert_eq!(ib.bytes, (2 * 8) as u64, "at the bf16 width its round shipped under");
        assert!(
            c.router.state.lock().inflight.is_empty(),
            "straggler record is forgotten"
        );
        drop(issuer);
        drop(drainer);
        drop(c);
        workers.join().unwrap();
    }

    #[test]
    fn tcp_straggler_for_a_closed_session_is_drained_and_billed_to_nobody() {
        // regression (ISSUE 4 satellite): a straggler reply arriving
        // over TCP after its issuing session closed is drained (cannot
        // poison anyone) and billed to nobody — neither the draining
        // tenant nor the aggregate — mirroring the in-proc test.
        let (c, workers) = tcp_cluster(2, 20);
        let v = vec![0.3; 8];
        {
            let issuer = c.session();
            {
                let mut st = c.router.state.lock();
                st.inflight.insert(
                    2000,
                    Inflight {
                        codec: WireCodec::new(WirePrecision::Bf16),
                        outstanding: 1,
                        owner: Arc::downgrade(&issuer.core),
                    },
                );
            }
            c.sender
                .lock()
                .send(1, 2000, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
                .unwrap();
            // `issuer` drops here: the session is closed
        }
        let agg0 = c.aggregate_stats();
        let drainer = c.session();
        let got = drainer.dist_matvec(&v).unwrap();
        assert_eq!(got.len(), 8);
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        assert_eq!(c.aggregate_stats().delta_since(&agg0), db);
        assert!(
            c.router.state.lock().inflight.is_empty(),
            "orphan record is forgotten"
        );
        drop(drainer);
        drop(c);
        workers.join().unwrap();
    }

    #[test]
    fn tcp_aged_out_inflight_record_drops_stragglers_unbilled_with_tickets_open() {
        // the retention-horizon aging contract over real sockets,
        // mirroring the in-proc test above
        let (c, workers) = tcp_cluster(2, 20);
        let v = vec![0.3; 8];
        let issuer = c.session();
        {
            let mut st = c.router.state.lock();
            st.inflight.insert(
                1,
                Inflight {
                    format: WireFormat::Plain(WirePrecision::Bf16),
                    outstanding: 1,
                    owner: Arc::downgrade(&issuer.core),
                },
            );
        }
        c.sender
            .lock()
            .send(1, 1, WireDesc::lossless(), &Request::CovMatVec(v.clone()))
            .unwrap();
        c.seq.fetch_add(INFLIGHT_RETENTION + 8, crate::sync::atomic::Ordering::Relaxed);
        let agg0 = c.aggregate_stats();
        let drainer = c.session();
        let ticket = drainer.dist_matvec_submit(&v).unwrap();
        assert!(!c.router.state.lock().inflight.contains_key(&1));
        let got = ticket.complete().unwrap();
        assert_eq!(got.len(), 8);
        drain_router(&c);
        let db = drainer.stats();
        assert_eq!(db.responses_received, 2, "drainer pays only its own replies");
        assert_eq!(db.bytes, (8 * 8 * 3) as u64);
        assert_eq!(issuer.stats(), CommStats::default(), "aged straggler bills nobody");
        assert_eq!(c.aggregate_stats().delta_since(&agg0), db);
        assert!(c.router.state.lock().inflight.is_empty());
        drop(issuer);
        drop(drainer);
        drop(c);
        workers.join().unwrap();
    }

    // -----------------------------------------------------------------
    // Round fusion (ISSUE 8 tentpole): batching, carrier splitting,
    // solo-identical billing. tests/fusion.rs drives the same contract
    // across codec × backend × tenant-thread count.
    // -----------------------------------------------------------------

    #[test]
    fn fused_matvec_results_and_bills_match_solo() {
        let (c, _) = small_cluster(3, 40);
        let va: Vec<f64> = (0..8).map(|i| (i as f64 * 0.37).sin()).collect();
        let vb: Vec<f64> = (0..8).map(|i| (i as f64 * 0.11).cos()).collect();
        let (ra, solo_a) = {
            let s = c.session();
            let r = s.dist_matvec(&va).unwrap();
            (r, s.close())
        };
        let (rb, solo_b) = {
            let s = c.session();
            let r = s.dist_matvec(&vb).unwrap();
            (r, s.close())
        };
        c.enable_fusion(Duration::from_millis(50), 2).unwrap();
        let agg0 = c.aggregate_stats();
        let a = c.session();
        let b = c.session();
        let ta = a.dist_matvec_submit(&va).unwrap();
        let tb = b.dist_matvec_submit(&vb).unwrap(); // fills the 2-col batch: flush
        let fa = ta.complete().unwrap();
        let fb = tb.complete().unwrap();
        for i in 0..8 {
            assert!((fa[i] - ra[i]).abs() < 1e-12, "member A row {i}");
            assert!((fb[i] - rb[i]).abs() < 1e-12, "member B row {i}");
        }
        let (ba, bb) = (a.close(), b.close());
        assert_eq!(ba, solo_a, "fused bill != solo bill (A)");
        assert_eq!(bb, solo_b, "fused bill != solo bill (B)");
        let mut sum = ba;
        sum.merge(&bb);
        assert_eq!(c.aggregate_stats().delta_since(&agg0), sum);
        assert_eq!(c.fusion_counters(), (1, 2), "one carrier, two members");
        assert!(c.router.state.lock().fused.is_empty(), "split table cleaned up");
    }

    #[test]
    fn fused_mixed_matvec_and_matmat_split_correctly() {
        let (c, _) = small_cluster(3, 30);
        let x: Vec<f64> = (0..8).map(|i| 1.0 / (i as f64 + 2.0)).collect();
        let v = Matrix::from_vec(8, 2, (0..16).map(|i| (i as f64 * 0.21).sin()).collect());
        let (rx, solo_x) = {
            let s = c.session();
            let r = s.dist_matvec(&x).unwrap();
            (r, s.close())
        };
        let (rv, solo_v) = {
            let s = c.session();
            let r = s.dist_matmat(&v).unwrap();
            (r, s.close())
        };
        c.enable_fusion(Duration::from_millis(50), 3).unwrap();
        let a = c.session();
        let b = c.session();
        let ta = a.dist_matvec_submit(&x).unwrap();
        let tb = b.dist_matmat_submit(&v).unwrap(); // 1 + 2 cols fills the batch
        let fa = ta.complete().unwrap();
        let fv = tb.complete().unwrap();
        for i in 0..8 {
            assert!((fa[i] - rx[i]).abs() < 1e-12, "matvec member row {i}");
            for j in 0..2 {
                assert!((fv.get(i, j) - rv.get(i, j)).abs() < 1e-12, "matmat member {i},{j}");
            }
        }
        assert_eq!(a.close(), solo_x, "matvec member bill != solo");
        assert_eq!(b.close(), solo_v, "matmat member bill != solo");
        assert_eq!(c.fusion_counters(), (1, 2));
    }

    #[test]
    fn mixed_codec_rounds_never_fuse() {
        let (c, _) = small_cluster(2, 20);
        c.enable_fusion(Duration::from_millis(5), 8).unwrap();
        let a = c.session();
        let b = c.session();
        b.set_codec(WireCodec::new(WirePrecision::Bf16));
        let v = vec![0.4; 8];
        let ta = a.dist_matvec_submit(&v).unwrap();
        // incompatible codec: B's submit displaces A's batch (flushed
        // unfused, no carrier) and opens its own
        let tb = b.dist_matvec_submit(&v).unwrap();
        ta.complete().unwrap();
        tb.complete().unwrap();
        assert_eq!(c.fusion_counters(), (0, 0), "mixed codecs must not share a carrier");
        assert_eq!(a.stats().bytes, 8 * 8 * 3, "lossless bill at 8B/entry");
        assert_eq!(b.stats().bytes, 2 * 8 * 3, "bf16 bill at 2B/entry");
    }

    #[test]
    fn stateful_codec_submits_displace_the_fusion_window() {
        // regression (ISSUE 10 satellite): a stateful-codec submit
        // entering a fusion window must displace the pending batch —
        // never fuse into it — and its own bill and accumulator stream
        // must be unaffected by the concurrent fused tenant.
        let (c, _) = small_cluster(2, 20);
        c.enable_fusion(Duration::from_millis(200), 8).unwrap();
        let fused = c.session();
        let lossy = c.session();
        lossy.set_codec(WireCodec::quant(QuantBits::Q4).with_feedback());
        let v: Vec<f64> = (0..8).map(|i| (i as f64 * 0.3).sin() + 0.05).collect();
        let ta = fused.dist_matvec_submit(&v).unwrap();
        // the stateful tenant never enters the window: A's pending
        // batch is flushed unfused and B's round ships solo
        let tb = lossy.dist_matvec_submit(&v).unwrap();
        ta.complete().unwrap();
        tb.complete().unwrap();
        assert_eq!(c.fusion_counters(), (0, 0), "stateful codecs must never share a carrier");
        // solo frame arithmetic, untouched by the fused neighbor:
        // Q4 on 8 words, 1 column = 4 (scale) + 4 (nibbles) per frame
        assert_eq!(lossy.stats().bytes, (4 + 4) * 3, "EF tenant bills its own sparse frames");
        assert_eq!(fused.stats().bytes, 8 * 8 * 3, "displaced tenant bills its solo frames");
        assert!(lossy.residual_norm() > 0.0, "the EF stream accumulated the Q4 drop");
        assert_eq!(fused.residual_norm(), 0.0, "stateless tenant keeps no stream");
    }

    #[test]
    fn quantized_and_sparse_codecs_bill_shape_only_frames() {
        // B(w) for the ISSUE 10 family is a pure function of shape: the
        // module-doc table rows, through a real collective
        let (c, _) = small_cluster(2, 20);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.731).sin() + 0.1).collect();
        for (codec, frame) in [
            (WireCodec::quant(QuantBits::Q8), 4 + 8u64),
            (WireCodec::quant(QuantBits::Q4), 4 + 4),
            (WireCodec::quant(QuantBits::Q8).with_feedback(), 4 + 8),
            (WireCodec::quant(QuantBits::Q4).with_feedback().with_adaptive(), 4 + 4),
            (WireCodec::top_s(3, QuantBits::Q8).with_feedback(), 8 + 4 * 3 + 3),
            (WireCodec::top_s(3, QuantBits::Q4).with_feedback(), 8 + 4 * 3 + 2),
        ] {
            let s = c.session();
            s.set_codec(codec);
            s.dist_matvec(&x).unwrap();
            // one broadcast frame + one reply frame per live worker
            assert_eq!(s.stats().bytes, frame * 3, "{}", codec.label());
        }
    }

    #[test]
    fn error_feedback_mean_tracks_the_lossless_result() {
        // the tentpole's point, at the collective level: averaging over
        // EF rounds telescopes the quantization error away, where plain
        // Q4 keeps paying it every round
        let (c, _) = small_cluster(2, 30);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.53).sin() * 0.8 + 0.1).collect();
        let exact = c.session().dist_matvec(&x).unwrap();
        let rounds = 32usize;
        let mean_err = |s: &Session<'_>| -> f64 {
            let mut mean = vec![0.0; 8];
            for _ in 0..rounds {
                let got = s.dist_matvec(&x).unwrap();
                for i in 0..8 {
                    mean[i] += got[i] / rounds as f64;
                }
            }
            exact.iter().zip(&mean).map(|(a, b)| (a - b).abs()).sum()
        };
        let plain = c.session();
        plain.set_codec(WireCodec::quant(QuantBits::Q4));
        let plain_err = mean_err(&plain);
        let ef = c.session();
        ef.set_codec(WireCodec::quant(QuantBits::Q4).with_feedback());
        let ef_err = mean_err(&ef);
        assert_eq!(plain.residual_norm(), 0.0, "stateless codec keeps no stream");
        assert!(ef.residual_norm() > 0.0, "EF stream carries the last drop");
        assert!(
            ef_err < plain_err,
            "error feedback must beat plain Q4 on the round average: {ef_err} vs {plain_err}"
        );
    }

    #[test]
    fn adaptive_codec_records_transitions_and_bills_the_resolved_width() {
        let (c, _) = small_cluster(2, 20);
        let s = c.session();
        s.set_codec(WireCodec::quant(QuantBits::Q8).with_adaptive());
        assert_eq!(s.active_bits(), Some(QuantBits::Q8));
        // a smooth payload quantizes well at Q8; once the controller
        // has one round of evidence it narrows to Q4
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.37).sin() + 2.0).collect();
        s.dist_matvec(&x).unwrap(); // ships q8: fresh stream, no evidence yet
        s.dist_matvec(&x).unwrap(); // adapt() sees a tiny residual: narrows, ships q4
        assert_eq!(s.active_bits(), Some(QuantBits::Q4));
        assert_eq!(s.codec_transitions(), (0, 1), "(widenings, narrowings)");
        // the bill records the width each round actually shipped under:
        // round 1 at q8 (4+8 per frame), round 2 at q4 (4+4)
        assert_eq!(s.stats().bytes, (4 + 8) * 3 + (4 + 4) * 3);
    }

    #[test]
    fn fused_round_with_dead_worker_degrades_like_unfused() {
        let (c, _) = small_cluster(4, 25);
        c.kill_worker(3).unwrap();
        let v = vec![0.7; 8];
        let solo = {
            let s = c.session();
            s.dist_matvec(&v).unwrap();
            s.close()
        };
        assert_eq!(solo.requests_sent, 3, "dead worker excluded from the solo round");
        c.enable_fusion(Duration::from_millis(50), 2).unwrap();
        let a = c.session();
        let b = c.session();
        let ta = a.dist_matvec_submit(&v).unwrap();
        let tb = b.dist_matvec_submit(&v).unwrap();
        let ra = ta.complete().unwrap();
        let rb = tb.complete().unwrap();
        assert_eq!(ra, rb, "identical inputs, identical split columns");
        assert_eq!(a.close(), solo, "fused member bill != unfused bill with a dead worker");
        assert_eq!(b.close(), solo);
        assert_eq!(c.fusion_counters(), (1, 2));
    }

    #[test]
    fn one_sessions_pipelined_rounds_fuse_and_bill_like_serial() {
        let (c, _) = small_cluster(3, 20);
        let v = vec![1.0; 8];
        let serial = {
            let s = c.session();
            for _ in 0..3 {
                s.dist_matvec(&v).unwrap();
            }
            s.close()
        };
        c.enable_fusion(Duration::from_millis(50), 3).unwrap();
        let s = c.session();
        let t1 = s.dist_matvec_submit(&v).unwrap();
        let t2 = s.dist_matvec_submit(&v).unwrap();
        let t3 = s.dist_matvec_submit(&v).unwrap(); // fills the batch
        let r3 = t3.complete().unwrap();
        let r1 = t1.complete().unwrap();
        let r2 = t2.complete().unwrap();
        assert_eq!(r1, r2);
        assert_eq!(r2, r3);
        assert_eq!(s.close(), serial, "fused pipelined bill != serial bill");
        assert_eq!(c.fusion_counters(), (1, 3));
    }

    #[test]
    fn lone_fused_round_flushes_at_the_window_deadline() {
        let (c, _) = small_cluster(2, 15);
        c.enable_fusion(Duration::from_millis(5), 8).unwrap();
        let s = c.session();
        let v = vec![0.9; 8];
        let t = s.dist_matvec_submit(&v).unwrap();
        // waits out the 5ms window, flushes unfused, collects
        let out = t.complete().unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(s.stats().rounds, 1);
        assert_eq!(c.fusion_counters(), (0, 0), "a lone member ships unfused");
    }

    #[test]
    fn dropping_a_pending_fused_ticket_flushes_and_bills_its_round() {
        let (c, _) = small_cluster(2, 15);
        c.enable_fusion(Duration::from_millis(200), 8).unwrap();
        let s = c.session();
        let v = vec![1.0; 8];
        {
            let _abandoned = s.dist_matvec_submit(&v).unwrap();
            // dropped while still pending in the fusion window
        }
        let s2 = c.session();
        s2.dist_matvec(&v).unwrap();
        drain_router(&c);
        let st = s.stats();
        assert_eq!(st.rounds, 1, "the abandoned fused round was still billed");
        assert_eq!(st.requests_sent, 2);
        assert_eq!(st.responses_received, 2, "its replies bill to the issuer");
        assert_eq!(c.fusion_counters(), (0, 0), "single-member flush ships unfused");
        assert!(c.router.state.lock().open.is_empty());
        assert!(c.router.state.lock().inflight.is_empty());
    }

    #[test]
    fn tcp_fused_rounds_bill_and_split_like_inproc() {
        let (c, workers) = tcp_cluster(3, 25);
        let v: Vec<f64> = (0..8).map(|i| (i as f64 * 0.53).sin()).collect();
        let (solo_out, solo) = {
            let s = c.session();
            let r = s.dist_matvec(&v).unwrap();
            (r, s.close())
        };
        c.enable_fusion(Duration::from_millis(50), 2).unwrap();
        let a = c.session();
        let b = c.session();
        let ta = a.dist_matvec_submit(&v).unwrap();
        let tb = b.dist_matvec_submit(&v).unwrap();
        let fa = ta.complete().unwrap();
        let fb = tb.complete().unwrap();
        for i in 0..8 {
            assert!((fa[i] - solo_out[i]).abs() < 1e-12, "row {i}");
        }
        assert_eq!(fa, fb);
        assert_eq!(a.close(), solo, "fused bill != solo bill over TCP");
        assert_eq!(b.close(), solo);
        assert_eq!(c.fusion_counters(), (1, 2));
        drop(c);
        workers.join().unwrap();
    }

    #[test]
    fn tcp_kill_worker_excludes_the_peer_and_collectives_continue() {
        let dist = CovModel::paper_fig1(8, 3).gaussian();
        let workers = LoopbackWorkers::spawn(3, 1).unwrap();
        let c =
            Cluster::generate_on(&dist, 3, 20, 42, OracleSpec::Native, &workers.spec()).unwrap();
        c.kill_worker(2).unwrap();
        c.kill_worker(2).unwrap(); // idempotent on the TCP backend too
        assert_eq!(c.live(), 2);
        let s = c.session();
        let ones = vec![1.0; 8];
        let out = s.dist_matvec(&ones).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(s.stats().vectors_gathered, 2);
        drop(s);
        drop(c);
        workers.join().unwrap();
    }
}
