//! Typed messages between the leader and workers. Everything that crosses
//! this boundary is what the paper would put on the wire; the accounting
//! in [`crate::cluster::Cluster`] is driven by these exchanges.

/// Leader -> worker requests.
#[derive(Clone, Debug)]
pub enum Request {
    /// Compute `Xhat_i v` on the local shard.
    CovMatVec(Vec<f64>),
    /// Compute the block product `Xhat_i V` for a `d x k` basis `V`
    /// (row-major `rows x cols` payload). One message carrying `k`
    /// vectors — the wire format of the block protocol, replacing `k`
    /// [`Request::CovMatVec`] round-trips with a single exchange.
    CovMatMat { rows: usize, cols: usize, data: Vec<f64> },
    /// Return the leading eigenvector of the local empirical covariance.
    /// With `unbiased_signs` the worker randomizes the sign with a private
    /// fair coin (Theorem 3's unbiased-ERM premise).
    LocalTopEigvec { unbiased_signs: bool },
    /// Return the local empirical covariance matrix (d x d).
    Gram,
    /// Return the top-`k` local eigenbasis (d x k, orthonormal columns).
    LocalTopK { k: usize },
    /// One full Oja/SGD pass over the local samples starting from `w`,
    /// with step size `eta_t = eta0 / (t0 + t)` at global sample count
    /// `t = t_start + local_index`.
    OjaPass { w: Vec<f64>, eta0: f64, t0: f64, t_start: u64 },
    /// Terminate the worker loop.
    Shutdown,
}

/// Worker -> leader responses.
#[derive(Clone, Debug)]
pub enum Response {
    Vector(Vec<f64>),
    Mat { rows: usize, cols: usize, data: Vec<f64> },
    Err(String),
}
