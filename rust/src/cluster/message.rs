//! Typed messages between the leader and workers. Everything that crosses
//! this boundary is what the paper would put on the wire; the accounting
//! in [`crate::cluster::Session`] is driven by these exchanges, and each
//! message's f64 payload ([`Request::payload_mut`],
//! [`Response::payload_mut`]) is what the issuing session's
//! [`WireCodec`](crate::cluster::WireCodec) encodes and bills.

/// Leader -> worker requests.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Compute `Xhat_i v` on the local shard.
    CovMatVec(Vec<f64>),
    /// Compute the block product `Xhat_i V` for a `d x k` basis `V`
    /// (row-major `rows x cols` payload). One message carrying `k`
    /// vectors — the wire format of the block protocol, replacing `k`
    /// [`Request::CovMatVec`] round-trips with a single exchange.
    CovMatMat { rows: usize, cols: usize, data: Vec<f64> },
    /// Return the leading eigenvector of the local empirical covariance.
    /// With `unbiased_signs` the worker randomizes the sign with a private
    /// fair coin (Theorem 3's unbiased-ERM premise).
    LocalTopEigvec { unbiased_signs: bool },
    /// Return the local empirical covariance matrix (d x d).
    Gram,
    /// Return the top-`k` local eigenbasis (d x k, orthonormal columns).
    LocalTopK { k: usize },
    /// One full Oja/SGD pass over the local samples starting from `w`,
    /// with step size `eta_t = eta0 / (t0 + t)` at global sample count
    /// `t = t_start + local_index`.
    OjaPass { w: Vec<f64>, eta0: f64, t0: f64, t_start: u64 },
    /// Terminate the worker loop.
    Shutdown,
}

impl Request {
    /// The f64 payload words this request puts on the wire, if any.
    /// Scalar hyperparameters and shape headers ride the message envelope
    /// and are not billed — consistent with the paper's cost model, which
    /// counts `R^d` vector traffic.
    pub fn payload(&self) -> Option<&[f64]> {
        match self {
            Request::CovMatVec(v) => Some(v),
            Request::CovMatMat { data, .. } => Some(data),
            Request::OjaPass { w, .. } => Some(w),
            Request::LocalTopEigvec { .. }
            | Request::Gram
            | Request::LocalTopK { .. }
            | Request::Shutdown => None,
        }
    }

    /// Mutable payload view — the hook the session's wire codec passes
    /// every outgoing request through (encode→decode + billing).
    pub fn payload_mut(&mut self) -> Option<&mut [f64]> {
        match self {
            Request::CovMatVec(v) => Some(v),
            Request::CovMatMat { data, .. } => Some(data),
            Request::OjaPass { w, .. } => Some(w),
            Request::LocalTopEigvec { .. }
            | Request::Gram
            | Request::LocalTopK { .. }
            | Request::Shutdown => None,
        }
    }

    /// Row-major column count of the payload, for scale-per-column wire
    /// codecs (1 for vector payloads and payload-free variants).
    pub fn payload_cols(&self) -> usize {
        match self {
            Request::CovMatMat { cols, .. } => (*cols).max(1),
            _ => 1,
        }
    }
}

/// Worker -> leader responses.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Vector(Vec<f64>),
    Mat { rows: usize, cols: usize, data: Vec<f64> },
    Err(String),
}

impl Response {
    /// The f64 payload words this response puts on the wire, if any
    /// (error replies carry only their message — no vector payload).
    pub fn payload(&self) -> Option<&[f64]> {
        match self {
            Response::Vector(v) => Some(v),
            Response::Mat { data, .. } => Some(data),
            Response::Err(_) => None,
        }
    }

    /// Mutable payload view — the hook the session's wire codec passes
    /// every incoming response through (encode→decode + billing).
    pub fn payload_mut(&mut self) -> Option<&mut [f64]> {
        match self {
            Response::Vector(v) => Some(v),
            Response::Mat { data, .. } => Some(data),
            Response::Err(_) => None,
        }
    }

    /// Row-major column count of the payload, for scale-per-column wire
    /// codecs (1 for vector payloads and error replies).
    pub fn payload_cols(&self) -> usize {
        match self {
            Response::Mat { cols, .. } => (*cols).max(1),
            _ => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_payloads() {
        assert_eq!(Request::CovMatVec(vec![1.0, 2.0]).payload().unwrap().len(), 2);
        assert_eq!(
            Request::CovMatMat { rows: 2, cols: 3, data: vec![0.0; 6] }.payload().unwrap().len(),
            6
        );
        assert_eq!(
            Request::OjaPass { w: vec![0.5; 4], eta0: 1.0, t0: 1.0, t_start: 0 }
                .payload()
                .unwrap()
                .len(),
            4
        );
        assert!(Request::Gram.payload().is_none());
        assert!(Request::LocalTopK { k: 2 }.payload().is_none());
        assert!(Request::LocalTopEigvec { unbiased_signs: true }.payload().is_none());
        assert!(Request::Shutdown.payload().is_none());
    }

    #[test]
    fn response_payloads() {
        assert_eq!(Response::Vector(vec![1.0; 3]).payload().unwrap().len(), 3);
        assert_eq!(Response::Mat { rows: 2, cols: 2, data: vec![0.0; 4] }.payload().unwrap().len(), 4);
        assert!(Response::Err("boom".into()).payload().is_none());
        let mut r = Response::Vector(vec![1.0; 3]);
        r.payload_mut().unwrap()[0] = 7.0;
        assert_eq!(r.payload().unwrap()[0], 7.0);
    }
}
