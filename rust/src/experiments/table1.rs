//! E3 — Table 1: estimation error and communication rounds for every
//! method, on one fixed workload.
//!
//! The paper's Table 1 is analytic; this driver regenerates its *shape*
//! empirically: measured error (vs the population `v_1`), measured error
//! ratio against the centralized ERM, and measured rounds / distributed
//! matvecs.

use anyhow::Result;

use crate::cluster::OracleSpec;
use crate::coordinator::{
    Algorithm, CentralizedErm, DistributedLanczos, DistributedPower, HotPotatoOja, NaiveAverage,
    ProjectionAverage, ShiftInvert, SignFixedAverage, SniConfig,
};
use crate::data::CovModel;
use crate::util::csv::CsvTable;

use super::mean_error;

#[derive(Clone, Debug)]
pub struct Table1Config {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub runs: usize,
    pub seed: u64,
    pub oracle: OracleSpec,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            d: 300,
            m: 25,
            n: 400,
            runs: super::runs_from_env(12),
            seed: 0x7ab1e,
            oracle: OracleSpec::Native,
        }
    }
}

/// One Table-1 row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    pub mean_error: f64,
    pub sem: f64,
    pub ratio_vs_centralized: f64,
    pub rounds: f64,
    pub matvecs: f64,
}

pub fn run(cfg: &Table1Config) -> Result<(Vec<Table1Row>, CsvTable)> {
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x7a).gaussian();
    let algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(DistributedPower::default()),
        Box::new(DistributedLanczos::default()),
        Box::new(HotPotatoOja::default()),
        Box::new(NaiveAverage),
        Box::new(SignFixedAverage),
        Box::new(ProjectionAverage),
        Box::new(ShiftInvert::new(SniConfig { eps: 1e-8, ..Default::default() })),
    ];
    let mut rows = Vec::new();
    let mut centralized_mean = None;
    for alg in &algs {
        let (summary, rounds, matvecs) =
            mean_error(&dist, alg.as_ref(), cfg.m, cfg.n, cfg.runs, cfg.seed, &cfg.oracle)?;
        if alg.name() == "centralized_erm" {
            centralized_mean = Some(summary.mean);
        }
        let base = centralized_mean.unwrap_or(summary.mean);
        rows.push(Table1Row {
            method: alg.name().to_string(),
            mean_error: summary.mean,
            sem: summary.sem,
            ratio_vs_centralized: summary.mean / base.max(1e-300),
            rounds,
            matvecs,
        });
        crate::info!(
            "table1: {:<22} err={:.3e} rounds={:>8.1} matvecs={:>8.1}",
            alg.name(),
            summary.mean,
            rounds,
            matvecs
        );
    }
    let mut table =
        CsvTable::new(&["method", "mean_error", "sem", "ratio_vs_centralized", "rounds", "matvecs"]);
    for r in &rows {
        table.push_row(vec![
            r.method.clone(),
            format!("{:.6e}", r.mean_error),
            format!("{:.3e}", r.sem),
            format!("{:.3}", r.ratio_vs_centralized),
            format!("{:.1}", r.rounds),
            format!("{:.1}", r.matvecs),
        ]);
    }
    Ok((rows, table))
}

/// Pretty-print rows as a terminal table (the Table-1 lookalike).
pub fn render_rows(rows: &[Table1Row], eps_erm: f64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<22} {:>12} {:>10} {:>9} {:>9}",
        "method", "err(1-(w.v1)^2)", "vs cERM", "rounds", "matvecs"
    );
    let _ = writeln!(out, "{}", "-".repeat(68));
    for r in rows {
        let _ = writeln!(
            out,
            "{:<22} {:>12.3e} {:>10.2} {:>9.1} {:>9.1}",
            r.method, r.mean_error, r.ratio_vs_centralized, r.rounds, r.matvecs
        );
    }
    let _ = writeln!(out, "(Lemma 1 eps_ERM bound at p=1/4: {eps_erm:.3e})");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_small_run_has_expected_shape() {
        let cfg = Table1Config { d: 12, m: 4, n: 150, runs: 3, seed: 3, oracle: OracleSpec::Native };
        let (rows, table) = run(&cfg).unwrap();
        assert_eq!(rows.len(), 8);
        assert_eq!(table.n_rows(), 8);
        // iterative exact methods track the centralized ERM closely
        let by_name = |n: &str| rows.iter().find(|r| r.method.contains(n)).unwrap();
        assert!(by_name("lanczos").ratio_vs_centralized < 1.5);
        assert!(by_name("shift_invert").ratio_vs_centralized < 1.5);
        // one-shot methods cost exactly one round
        assert_eq!(by_name("sign_fixed").rounds, 1.0);
        assert_eq!(by_name("naive").rounds, 1.0);
        // hot-potato costs m rounds
        assert_eq!(by_name("oja").rounds, 4.0);
        let rendered = render_rows(&rows, 1e-3);
        assert!(rendered.contains("shift_invert_pcg"));
    }

    /// Tiny-size smoke: all 8 method rows present, every field finite,
    /// and the CSV is schema-complete (6 columns per row).
    #[test]
    fn table1_smoke_rows_finite_and_schema_complete() {
        let cfg = Table1Config { d: 8, m: 3, n: 80, runs: 2, seed: 9, oracle: OracleSpec::Native };
        let (rows, table) = run(&cfg).unwrap();
        assert_eq!(rows.len(), 8);
        assert_eq!(table.n_rows(), 8);
        for r in &rows {
            assert!(!r.method.is_empty());
            assert!(r.mean_error.is_finite(), "{}", r.method);
            assert!((0.0..=1.0).contains(&r.mean_error), "{}", r.method);
            assert!(r.sem.is_finite() && r.sem >= 0.0, "{}", r.method);
            assert!(r.ratio_vs_centralized.is_finite() && r.ratio_vs_centralized >= 0.0);
            assert!(r.rounds.is_finite() && r.rounds >= 0.0);
            assert!(r.matvecs.is_finite() && r.matvecs >= 0.0);
        }
        for line in table.render().lines().skip(1) {
            assert_eq!(line.split(',').count(), 6, "schema-complete row: {line}");
        }
    }
}
