//! E11 — multi-tenant serving: throughput, latency and **per-query
//! bill** vs the number of concurrent tenant threads, for a
//! heterogeneous job mix on the Figure-1 workload (experiment index in
//! DESIGN.md §4).
//!
//! This is the axis the session layer opens: one shared cluster
//! answering many queries at once. The driver submits the same FIFO job
//! mix at each tenant count and records batch wallclock, throughput,
//! latency, and the mean per-query rounds/bytes — which must **not**
//! move with concurrency (each session's bill is its solo bill; the
//! scheduler verifies Σ job bills == cluster aggregate on every call).

use std::sync::Barrier;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::{Cluster, CommStats, OracleSpec, WirePrecision};
use crate::coordinator::{
    DistributedLanczos, DistributedPower, ProjectionAverage, QuantizedPower, SignFixedAverage,
};
use crate::data::{CovModel, Distribution};
use crate::linalg::vec_ops::normalize;
use crate::serve::{serve, Job, QosClass};
use crate::transport::TransportSpec;
use crate::util::csv::CsvTable;
use crate::util::stats::Summary;

/// `Some(ratio)` iff the wall-clock stress gates are armed
/// (`DSPCA_STRESS=1` — the release-mode CI concurrency job). Loaded
/// debug CI runners and arbitrary dev laptops measure the ratio but do
/// not gate on it; bill-equality checks stay unconditional everywhere.
pub fn stress_gate(ratio: f64) -> Option<f64> {
    if std::env::var("DSPCA_STRESS").as_deref() == Ok("1") {
        Some(ratio)
    } else {
        None
    }
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    /// Jobs per batch (the mix in [`job_mix`], cycled).
    pub jobs: usize,
    /// Tenant counts to sweep.
    pub tenants_list: Vec<usize>,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// Message substrate (per-job bills are backend-invariant).
    pub transport: TransportSpec,
    /// The split-phase acceptance gate: with `Some(r)`, and both a
    /// 1-tenant and a 4-tenant point in the sweep, `ensure!` that the
    /// 4-tenant batch wallclock is at most `r ×` the 1-tenant wallclock
    /// (rounds overlapping on the wire is exactly what buys this).
    /// `None` skips the gate (tiny smoke configs, hosts without
    /// parallelism). The default arms it only under `DSPCA_STRESS=1`
    /// ([`stress_gate`]), so loaded CI runners can't flake it; the
    /// bill-accounting `ensure!`s run unconditionally.
    pub assert_overlap: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            d: 60,
            m: 8,
            n: 400,
            jobs: 12,
            tenants_list: vec![1, 2, 4, 8],
            seed: 0x5e7e,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            assert_overlap: stress_gate(0.7),
        }
    }
}

/// The heterogeneous job mix: iterative lossless, iterative lossy
/// (bf16 and f32 wire codecs — exercising per-session codecs under
/// concurrency), and one-round estimators, cycled to `jobs` entries.
/// QoS classes rotate `i % 3` (standard / interactive / batch) —
/// independent of the algorithm rotation so every class appears from 3
/// jobs up, making the per-class latency columns of [`run`] total.
pub fn job_mix(jobs: usize) -> Vec<Job> {
    (0..jobs)
        .map(|i| {
            let job = match i % 6 {
                0 => Job::new(format!("power-{i}"), Box::new(DistributedPower::default())),
                1 => Job::new(
                    format!("quantized-bf16-{i}"),
                    Box::new(QuantizedPower::new(WirePrecision::Bf16)),
                ),
                2 => Job::new(format!("sign-fixed-{i}"), Box::new(SignFixedAverage)),
                3 => Job::new(
                    format!("quantized-f32-{i}"),
                    Box::new(QuantizedPower::new(WirePrecision::F32)),
                ),
                4 => Job::new(format!("projection-{i}"), Box::new(ProjectionAverage)),
                _ => Job::new(format!("lanczos-{i}"), Box::new(DistributedLanczos::default())),
            };
            match i % 3 {
                0 => job,
                1 => job.with_qos(QosClass::Interactive),
                _ => job.with_qos(QosClass::Batch),
            }
        })
        .collect()
}

/// Run the sweep; returns a CSV with one row per tenant count:
/// `tenants, jobs, wall_s, speedup_vs_1, throughput_jps, lat_mean_s,
/// lat_p50_s, lat_p95_s`, then `p50/p95` per QoS class
/// (interactive/standard/batch — the scheduler's fairness claims,
/// observable per class; 0.0 when no job of a class ran), then
/// `rounds_mean, bytes_mean, err_mean`. `speedup_vs_1` is the overlap
/// column the split-phase wire opened: 1-tenant batch wallclock over
/// this row's wallclock (NaN when the sweep has no 1-tenant point).
/// With [`ServeConfig::assert_overlap`] set, the 4-tenant point must
/// beat the configured ratio or the driver errors.
pub fn run(cfg: &ServeConfig) -> Result<CsvTable> {
    anyhow::ensure!(cfg.jobs >= 1, "serve sweep needs at least one job per batch");
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x5e).gaussian();
    let mut table = CsvTable::new(&[
        "tenants",
        "jobs",
        "wall_s",
        "speedup_vs_1",
        "throughput_jps",
        "lat_mean_s",
        "lat_p50_s",
        "lat_p95_s",
        "p50_interactive_s",
        "p95_interactive_s",
        "p50_standard_s",
        "p95_standard_s",
        "p50_batch_s",
        "p95_batch_s",
        "rounds_mean",
        "bytes_mean",
        "err_mean",
    ]);
    // two passes: measure every tenant count first, then emit rows — so
    // speedup_vs_1 is filled for every row whenever the sweep has a
    // 1-tenant point, regardless of where in the list it appears
    let mut measured: Vec<(usize, f64, Vec<f64>)> = Vec::new();
    for &tenants in &cfg.tenants_list {
        anyhow::ensure!(tenants >= 1, "tenants must be >= 1");
        // fresh cluster per point, same seed: identical data, so the
        // per-query bills are comparable across tenant counts
        let cluster = Cluster::generate_on(
            &dist,
            cfg.m,
            cfg.n,
            cfg.seed,
            cfg.oracle.clone(),
            &cfg.transport,
        )?;
        let report = serve(&cluster, job_mix(cfg.jobs), tenants)?;
        anyhow::ensure!(
            report.accounting_exact,
            "serve accounting violated on an exclusive cluster: \
             sum of job bills ({}) != aggregate ({})",
            report.bills_sum,
            report.aggregate
        );
        let k = report.jobs.len().max(1) as f64;
        let latencies: Vec<f64> =
            report.jobs.iter().map(|j| j.latency.as_secs_f64()).collect();
        let lat = Summary::of(&latencies);
        // per-class p50/p95 (satellite: fairness observable per QoS
        // class); a class with no jobs reports 0.0, keeping rows finite
        let class_lat: Vec<(f64, f64)> = QosClass::ALL
            .iter()
            .map(|&q| {
                report
                    .latency_summary(Some(q))
                    .map_or((0.0, 0.0), |s| (s.median, s.p95))
            })
            .collect();
        let rounds_mean =
            report.jobs.iter().map(|j| j.comm.rounds as f64).sum::<f64>() / k;
        let bytes_mean = report.jobs.iter().map(|j| j.comm.bytes as f64).sum::<f64>() / k;
        let errs: Vec<f64> = report
            .jobs
            .iter()
            .filter_map(|j| j.w.as_ref())
            .map(|w| crate::linalg::vec_ops::alignment_error(w, dist.v1()))
            .collect();
        let err_mean = if errs.is_empty() {
            f64::NAN
        } else {
            errs.iter().sum::<f64>() / errs.len() as f64
        };
        let wall_s = report.wall.as_secs_f64();
        crate::info!(
            "serve tenants={tenants}: {:.1} jobs/s wall={wall_s:.3}s \
             lat_mean={:.3}s rounds/query={rounds_mean:.1} bytes/query={bytes_mean:.0}",
            report.throughput,
            lat.mean
        );
        measured.push((
            tenants,
            wall_s,
            vec![
                tenants as f64,
                report.jobs.len() as f64,
                wall_s,
                f64::NAN, // speedup_vs_1, filled below
                report.throughput,
                lat.mean,
                lat.median,
                lat.p95,
                class_lat[0].0,
                class_lat[0].1,
                class_lat[1].0,
                class_lat[1].1,
                class_lat[2].0,
                class_lat[2].1,
                rounds_mean,
                bytes_mean,
                err_mean,
            ],
        ));
    }
    let wall_at =
        |t: usize| measured.iter().find(|(x, _, _)| *x == t).map(|&(_, w, _)| w);
    let wall_1 = wall_at(1);
    let wall_4 = wall_at(4);
    for (_, wall_s, mut row) in measured {
        row[3] = wall_1.map_or(f64::NAN, |w1| w1 / wall_s.max(1e-12));
        table.push_nums(&row);
    }
    // the split-phase acceptance gate (E11): overlapped tenant rounds
    // must buy real wallclock at 4 tenants vs 1
    if let Some(ratio) = cfg.assert_overlap {
        if let (Some(w1), Some(w4)) = (wall_1, wall_4) {
            anyhow::ensure!(
                w4 <= ratio * w1,
                "overlap win missing: 4-tenant batch took {w4:.3}s, \
                 expected <= {ratio} x the 1-tenant {w1:.3}s \
                 (tenant rounds are not overlapping on the wire)"
            );
        }
    }
    Ok(table)
}

/// Config for the E11 **round-fusion acceptance gate**
/// ([`run_fusion`]): many power-method tenants iterating concurrently
/// on one in-proc cluster, unfused vs fused.
#[derive(Clone, Debug)]
pub struct FusionSweepConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    /// Concurrent power-method tenants (the acceptance run uses 8).
    pub tenants: usize,
    /// Power iterations per tenant (every iteration is one matvec
    /// round; tenants sync per iteration so each round's batch fills).
    pub iters: usize,
    /// Fusion window handed to `Cluster::enable_fusion` for the fused
    /// phase; `max_cols` is the tenant count. Deliberately generous:
    /// tenants sync per iteration, so every batch *fills* (and flushes
    /// inside the last joiner's submit) — the window is only the
    /// timeout bound, and a tight one would let a scheduling hiccup
    /// flush a partial batch and flake the counter `ensure!`.
    pub window: Duration,
    pub seed: u64,
    /// With `Some(r)`, `ensure!` fused wall clock ≤ `r ×` the
    /// unfused-overlapped wall clock. Armed at 0.6 only under
    /// `DSPCA_STRESS=1` by default ([`stress_gate`]); bill equality,
    /// the aggregate identity and the fusion-engagement counters are
    /// `ensure!`d unconditionally.
    pub assert_speedup: Option<f64>,
}

impl Default for FusionSweepConfig {
    fn default() -> Self {
        FusionSweepConfig {
            d: 64,
            m: 4,
            n: 1500,
            tenants: 8,
            iters: 24,
            window: Duration::from_millis(500),
            seed: 0xf05e,
            assert_speedup: stress_gate(0.6),
        }
    }
}

/// E11 fusion gate: run `tenants` concurrent fixed-iteration power
/// methods twice on one in-proc cluster — unfused-overlapped, then
/// with round fusion on — and `ensure!` that (a) every tenant's bill
/// equals the solo bill in **both** phases, (b) Σ bills == the
/// aggregate ledger window per phase, (c) fusion actually engaged
/// (every fused iteration formed exactly one `tenants`-column
/// carrier), and (d) under [`FusionSweepConfig::assert_speedup`], the
/// fused phase beat the configured wall-clock ratio. Returns a CSV
/// with one row per phase:
/// `fused, tenants, iters, wall_s, speedup_vs_unfused, carriers,
/// members`.
pub fn run_fusion(cfg: &FusionSweepConfig) -> Result<CsvTable> {
    anyhow::ensure!(cfg.tenants >= 2, "the fusion gate needs at least two tenants");
    anyhow::ensure!(cfg.iters >= 1, "the fusion gate needs at least one iteration");
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0xf5).gaussian();
    let cluster = Cluster::generate(&dist, cfg.m, cfg.n, cfg.seed)?;
    let d = cfg.d;
    let start = |tenant: usize| -> Vec<f64> {
        let mut v: Vec<f64> =
            (0..d).map(|j| ((tenant * 37 + j + 1) as f64 * 0.61).sin()).collect();
        normalize(&mut v);
        v
    };
    let power = |v0: Vec<f64>| -> Result<CommStats> {
        let s = cluster.session();
        s.set_trace_label("solo-reference");
        let mut v = v0;
        for _ in 0..cfg.iters {
            v = s.dist_matvec(&v)?;
            normalize(&mut v);
        }
        Ok(s.close())
    };
    // solo reference bill on the quiesced cluster: every tenant's
    // workload has the same shape, so one solo run prices them all
    let solo = power(start(0))?;
    let phase = |label: &str| -> Result<(f64, Vec<CommStats>)> {
        let agg0 = cluster.aggregate_stats();
        let barrier = Barrier::new(cfg.tenants);
        let t0 = Instant::now();
        let bills: Vec<CommStats> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.tenants)
                .map(|i| {
                    let (cluster, barrier, start) = (&cluster, &barrier, &start);
                    scope.spawn(move || -> Result<CommStats> {
                        let s = cluster.session();
                        // observability only: names this tenant's round
                        // timeline in the trace
                        s.set_trace_label(&format!("tenant-{i}"));
                        let mut v = start(i);
                        for _ in 0..cfg.iters {
                            // per-iteration sync keeps every fused
                            // batch full (and is phase-invariant, so
                            // the unfused baseline pays it too)
                            barrier.wait();
                            v = s.dist_matvec(&v)?;
                            normalize(&mut v);
                        }
                        Ok(s.close())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| anyhow::anyhow!("tenant thread panicked"))?)
                .collect::<Result<Vec<_>>>()
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let mut sum = CommStats::default();
        for (i, b) in bills.iter().enumerate() {
            anyhow::ensure!(
                *b == solo,
                "{label}: tenant {i}'s bill diverged from its solo bill \
                 ({b:?} vs {solo:?})"
            );
            sum.merge(b);
        }
        anyhow::ensure!(
            cluster.aggregate_stats().delta_since(&agg0) == sum,
            "{label}: sum of tenant bills != aggregate ledger window"
        );
        Ok((wall, bills))
    };

    let (unfused_wall, _) = phase("unfused-overlapped")?;
    let counters0 = cluster.fusion_counters();
    anyhow::ensure!(counters0 == (0, 0), "fusion engaged before it was enabled");
    cluster.enable_fusion(cfg.window, cfg.tenants)?;
    let (fused_wall, _) = phase("fused")?;
    let (carriers, members) = cluster.fusion_counters();
    anyhow::ensure!(
        carriers == cfg.iters as u64 && members == (cfg.iters * cfg.tenants) as u64,
        "fusion under-engaged: {carriers} carriers / {members} members, \
         expected every iteration to form one {}-column carrier ({} / {})",
        cfg.tenants,
        cfg.iters,
        cfg.iters * cfg.tenants
    );
    let speedup = unfused_wall / fused_wall.max(1e-12);
    crate::info!(
        "fusion gate: {} tenants x {} iters — unfused {unfused_wall:.3}s, \
         fused {fused_wall:.3}s ({speedup:.2}x), {carriers} carriers / {members} members",
        cfg.tenants,
        cfg.iters
    );
    if let Some(ratio) = cfg.assert_speedup {
        anyhow::ensure!(
            fused_wall <= ratio * unfused_wall,
            "fusion win missing: fused batch took {fused_wall:.3}s, \
             expected <= {ratio} x the unfused {unfused_wall:.3}s"
        );
    }
    let mut table = CsvTable::new(&[
        "fused",
        "tenants",
        "iters",
        "wall_s",
        "speedup_vs_unfused",
        "carriers",
        "members",
    ]);
    table.push_nums(&[
        0.0,
        cfg.tenants as f64,
        cfg.iters as f64,
        unfused_wall,
        1.0,
        0.0,
        0.0,
    ]);
    table.push_nums(&[
        1.0,
        cfg.tenants as f64,
        cfg.iters as f64,
        fused_wall,
        speedup,
        carriers as f64,
        members as f64,
    ]);
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_rows(table: &CsvTable) -> Vec<Vec<f64>> {
        table
            .render()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            d: 8,
            m: 3,
            n: 60,
            jobs: 5,
            tenants_list: vec![1, 2],
            seed: 5,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            // tiny workloads on an arbitrary CI host: measure the
            // overlap, don't gate on it (the release-mode stress suite
            // gates at real size)
            assert_overlap: None,
        }
    }

    /// Tiny-size smoke: one schema-complete, finite row per tenant count.
    #[test]
    fn serve_smoke_rows_finite_and_schema_complete() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.len(), 17, "schema-complete row");
            for cell in row {
                assert!(cell.is_finite(), "non-finite cell {cell}");
            }
            assert_eq!(row[1], 5.0, "all jobs completed");
            assert!(row[3] > 0.0, "positive speedup column");
            assert!(row[4] > 0.0, "positive throughput");
            // 5 jobs rotate i % 3, so every QoS class ran: per-class
            // p50/p95 must be populated, not the empty-class 0.0
            for c in 8..14 {
                assert!(row[c] > 0.0, "per-class latency column {c} empty");
            }
            assert!((0.0..=1.0).contains(&row[16]), "error in range");
        }
        assert_eq!(rows[0][0], 1.0);
        assert_eq!(rows[1][0], 2.0);
        assert_eq!(rows[0][3], 1.0, "1-tenant row's speedup is exactly 1");
    }

    /// Tiny-size fusion gate: the bill-equality, aggregate-identity
    /// and counter `ensure!`s inside [`run_fusion`] all run
    /// unconditionally — this smoke proves them and the two-row schema
    /// at toy size (the wall-clock ratio stays un-gated here; the
    /// release-mode stress suite arms it at real size).
    #[test]
    fn fusion_gate_smoke_bills_counters_and_schema() {
        let cfg = FusionSweepConfig {
            d: 6,
            m: 2,
            n: 40,
            tenants: 2,
            iters: 2,
            window: Duration::from_millis(100),
            seed: 11,
            assert_speedup: None,
        };
        let table = run_fusion(&cfg).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 2, "one row per phase");
        for row in &rows {
            assert_eq!(row.len(), 7, "schema-complete row");
            assert!(row[3] > 0.0, "positive wall clock");
        }
        assert_eq!((rows[0][0], rows[1][0]), (0.0, 1.0), "unfused then fused");
        assert_eq!(rows[1][5], 2.0, "one carrier per fused iteration");
        assert_eq!(rows[1][6], 4.0, "every tenant joined every carrier");
    }

    /// The session-layer signature: the mean per-query bill must not
    /// move with concurrency (identical cluster data at every tenant
    /// count, bills independent of scheduling). The *error* column is
    /// deliberately not compared: the sign-randomized estimators draw
    /// worker coins in request-arrival order, which concurrency may
    /// permute — the bills cannot change, the coin flips can.
    #[test]
    fn per_query_bill_is_invariant_in_tenant_count() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows[0][14], rows[1][14], "rounds/query moved with tenant count");
        assert_eq!(rows[0][15], rows[1][15], "bytes/query moved with tenant count");
    }
}
