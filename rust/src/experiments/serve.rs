//! E11 — multi-tenant serving: throughput, latency and **per-query
//! bill** vs the number of concurrent tenant threads, for a
//! heterogeneous job mix on the Figure-1 workload (experiment index in
//! DESIGN.md §4).
//!
//! This is the axis the session layer opens: one shared cluster
//! answering many queries at once. The driver submits the same FIFO job
//! mix at each tenant count and records batch wallclock, throughput,
//! latency, and the mean per-query rounds/bytes — which must **not**
//! move with concurrency (each session's bill is its solo bill; the
//! scheduler verifies Σ job bills == cluster aggregate on every call).

use anyhow::Result;

use crate::cluster::{Cluster, OracleSpec, WirePrecision};
use crate::coordinator::{
    DistributedLanczos, DistributedPower, ProjectionAverage, QuantizedPower, SignFixedAverage,
};
use crate::data::{CovModel, Distribution};
use crate::serve::{serve, Job};
use crate::transport::TransportSpec;
use crate::util::csv::CsvTable;
use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    /// Jobs per batch (the mix in [`job_mix`], cycled).
    pub jobs: usize,
    /// Tenant counts to sweep.
    pub tenants_list: Vec<usize>,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// Message substrate (per-job bills are backend-invariant).
    pub transport: TransportSpec,
    /// The split-phase acceptance gate: with `Some(r)`, and both a
    /// 1-tenant and a 4-tenant point in the sweep, `ensure!` that the
    /// 4-tenant batch wallclock is at most `r ×` the 1-tenant wallclock
    /// (rounds overlapping on the wire is exactly what buys this).
    /// `None` skips the gate (tiny smoke configs, hosts without
    /// parallelism).
    pub assert_overlap: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            d: 60,
            m: 8,
            n: 400,
            jobs: 12,
            tenants_list: vec![1, 2, 4, 8],
            seed: 0x5e7e,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            assert_overlap: Some(0.7),
        }
    }
}

/// The heterogeneous job mix: iterative lossless, iterative lossy
/// (bf16 and f32 wire codecs — exercising per-session codecs under
/// concurrency), and one-round estimators, cycled to `jobs` entries.
pub fn job_mix(jobs: usize) -> Vec<Job> {
    (0..jobs)
        .map(|i| match i % 6 {
            0 => Job::new(format!("power-{i}"), Box::new(DistributedPower::default())),
            1 => Job::new(
                format!("quantized-bf16-{i}"),
                Box::new(QuantizedPower::new(WirePrecision::Bf16)),
            ),
            2 => Job::new(format!("sign-fixed-{i}"), Box::new(SignFixedAverage)),
            3 => Job::new(
                format!("quantized-f32-{i}"),
                Box::new(QuantizedPower::new(WirePrecision::F32)),
            ),
            4 => Job::new(format!("projection-{i}"), Box::new(ProjectionAverage)),
            _ => Job::new(format!("lanczos-{i}"), Box::new(DistributedLanczos::default())),
        })
        .collect()
}

/// Run the sweep; returns a CSV with one row per tenant count:
/// `tenants, jobs, wall_s, speedup_vs_1, throughput_jps, lat_mean_s,
/// lat_p95_s, rounds_mean, bytes_mean, err_mean`. `speedup_vs_1` is the
/// overlap column the split-phase wire opened: 1-tenant batch wallclock
/// over this row's wallclock (NaN when the sweep has no 1-tenant
/// point). With [`ServeConfig::assert_overlap`] set, the 4-tenant
/// point must beat the configured ratio or the driver errors.
pub fn run(cfg: &ServeConfig) -> Result<CsvTable> {
    anyhow::ensure!(cfg.jobs >= 1, "serve sweep needs at least one job per batch");
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x5e).gaussian();
    let mut table = CsvTable::new(&[
        "tenants",
        "jobs",
        "wall_s",
        "speedup_vs_1",
        "throughput_jps",
        "lat_mean_s",
        "lat_p95_s",
        "rounds_mean",
        "bytes_mean",
        "err_mean",
    ]);
    // two passes: measure every tenant count first, then emit rows — so
    // speedup_vs_1 is filled for every row whenever the sweep has a
    // 1-tenant point, regardless of where in the list it appears
    let mut measured: Vec<(usize, f64, Vec<f64>)> = Vec::new();
    for &tenants in &cfg.tenants_list {
        anyhow::ensure!(tenants >= 1, "tenants must be >= 1");
        // fresh cluster per point, same seed: identical data, so the
        // per-query bills are comparable across tenant counts
        let cluster = Cluster::generate_on(
            &dist,
            cfg.m,
            cfg.n,
            cfg.seed,
            cfg.oracle.clone(),
            &cfg.transport,
        )?;
        let report = serve(&cluster, job_mix(cfg.jobs), tenants)?;
        anyhow::ensure!(
            report.accounting_exact,
            "serve accounting violated on an exclusive cluster: \
             sum of job bills ({}) != aggregate ({})",
            report.bills_sum,
            report.aggregate
        );
        let k = report.jobs.len().max(1) as f64;
        let latencies: Vec<f64> =
            report.jobs.iter().map(|j| j.latency.as_secs_f64()).collect();
        let lat = Summary::of(&latencies);
        let rounds_mean =
            report.jobs.iter().map(|j| j.comm.rounds as f64).sum::<f64>() / k;
        let bytes_mean = report.jobs.iter().map(|j| j.comm.bytes as f64).sum::<f64>() / k;
        let errs: Vec<f64> = report
            .jobs
            .iter()
            .filter_map(|j| j.w.as_ref())
            .map(|w| crate::linalg::vec_ops::alignment_error(w, dist.v1()))
            .collect();
        let err_mean = if errs.is_empty() {
            f64::NAN
        } else {
            errs.iter().sum::<f64>() / errs.len() as f64
        };
        let wall_s = report.wall.as_secs_f64();
        crate::info!(
            "serve tenants={tenants}: {:.1} jobs/s wall={wall_s:.3}s \
             lat_mean={:.3}s rounds/query={rounds_mean:.1} bytes/query={bytes_mean:.0}",
            report.throughput,
            lat.mean
        );
        measured.push((
            tenants,
            wall_s,
            vec![
                tenants as f64,
                report.jobs.len() as f64,
                wall_s,
                f64::NAN, // speedup_vs_1, filled below
                report.throughput,
                lat.mean,
                lat.p95,
                rounds_mean,
                bytes_mean,
                err_mean,
            ],
        ));
    }
    let wall_at =
        |t: usize| measured.iter().find(|(x, _, _)| *x == t).map(|&(_, w, _)| w);
    let wall_1 = wall_at(1);
    let wall_4 = wall_at(4);
    for (_, wall_s, mut row) in measured {
        row[3] = wall_1.map_or(f64::NAN, |w1| w1 / wall_s.max(1e-12));
        table.push_nums(&row);
    }
    // the split-phase acceptance gate (E11): overlapped tenant rounds
    // must buy real wallclock at 4 tenants vs 1
    if let Some(ratio) = cfg.assert_overlap {
        if let (Some(w1), Some(w4)) = (wall_1, wall_4) {
            anyhow::ensure!(
                w4 <= ratio * w1,
                "overlap win missing: 4-tenant batch took {w4:.3}s, \
                 expected <= {ratio} x the 1-tenant {w1:.3}s \
                 (tenant rounds are not overlapping on the wire)"
            );
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_rows(table: &CsvTable) -> Vec<Vec<f64>> {
        table
            .render()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            d: 8,
            m: 3,
            n: 60,
            jobs: 5,
            tenants_list: vec![1, 2],
            seed: 5,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            // tiny workloads on an arbitrary CI host: measure the
            // overlap, don't gate on it (the release-mode stress suite
            // gates at real size)
            assert_overlap: None,
        }
    }

    /// Tiny-size smoke: one schema-complete, finite row per tenant count.
    #[test]
    fn serve_smoke_rows_finite_and_schema_complete() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.len(), 10, "schema-complete row");
            for cell in row {
                assert!(cell.is_finite(), "non-finite cell {cell}");
            }
            assert_eq!(row[1], 5.0, "all jobs completed");
            assert!(row[3] > 0.0, "positive speedup column");
            assert!(row[4] > 0.0, "positive throughput");
            assert!((0.0..=1.0).contains(&row[9]), "error in range");
        }
        assert_eq!(rows[0][0], 1.0);
        assert_eq!(rows[1][0], 2.0);
        assert_eq!(rows[0][3], 1.0, "1-tenant row's speedup is exactly 1");
    }

    /// The session-layer signature: the mean per-query bill must not
    /// move with concurrency (identical cluster data at every tenant
    /// count, bills independent of scheduling). The *error* column is
    /// deliberately not compared: the sign-randomized estimators draw
    /// worker coins in request-arrival order, which concurrency may
    /// permute — the bills cannot change, the coin flips can.
    #[test]
    fn per_query_bill_is_invariant_in_tenant_count() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows[0][7], rows[1][7], "rounds/query moved with tenant count");
        assert_eq!(rows[0][8], rows[1][8], "bytes/query moved with tenant count");
    }
}
