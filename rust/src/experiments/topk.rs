//! E9 — top-`k` subspace scaling: estimation error and communication
//! rounds vs the subspace rank `k`, for the whole top-`k` family on the
//! block protocol (experiment index in DESIGN.md §4).
//!
//! Mirrors Figure 1's layout — one row per sweep point, per-estimator
//! mean/sem columns, terminal log-log plot — with `k` on the x-axis
//! instead of `n`. The round columns make the block protocol's payoff
//! measurable: the iterative estimators' rounds stay flat in `k`
//! (one `dist_matmat` per iteration) where the seed's column-wise loop
//! scaled linearly.

use anyhow::Result;

use crate::cluster::{Cluster, OracleSpec, Session};
use crate::coordinator::subspace::{
    top_k_basis, CentralizedSubspace, DeflatedShiftInvert, DistributedOrthoIteration,
    SubspaceEstimate, SubspaceProjectionAverage,
};
use crate::coordinator::BlockLanczos;
use crate::data::{CovModel, Distribution, SparseDiag};
use crate::util::csv::CsvTable;
use crate::util::plot::{loglog, Series};
use crate::util::stats::Summary;

/// The estimator columns of the top-`k` sweep, in plot order.
pub const ESTIMATORS: [&str; 5] =
    ["centralized", "ortho_iter", "block_lanczos", "projection_avg", "deflated_sni"];

#[derive(Clone, Debug)]
pub struct TopkConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub k_list: Vec<usize>,
    pub runs: usize,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// `Some(rho)` swaps the gaussian §5 model for the sparse
    /// axis-aligned [`SparseDiag`] at keep probability `rho` — shards
    /// become CSR and the whole sweep runs on the streaming sparse
    /// kernels (CLI `--density`).
    pub density: Option<f64>,
}

impl Default for TopkConfig {
    fn default() -> Self {
        TopkConfig {
            d: 60,
            m: 8,
            n: 400,
            k_list: vec![1, 2, 4, 8],
            runs: super::runs_from_env(8),
            seed: 0x707b,
            oracle: OracleSpec::Native,
            density: None,
        }
    }
}

fn run_estimator(idx: usize, k: usize, session: &Session<'_>) -> Result<SubspaceEstimate> {
    match idx {
        0 => CentralizedSubspace { k }.run_mat(session),
        1 => DistributedOrthoIteration::new(k).run_mat(session),
        2 => BlockLanczos::new(k).run_mat(session),
        3 => SubspaceProjectionAverage { k }.run_mat(session),
        4 => DeflatedShiftInvert::new(k).run_mat(session),
        _ => unreachable!("unknown estimator index {idx}"),
    }
}

/// Run the sweep; returns a CSV with columns
/// `k, <estimator err means...>, <estimator err sems...>,
/// <estimator mean rounds...>`.
pub fn run(cfg: &TopkConfig) -> Result<CsvTable> {
    let (model, dist): (CovModel, Box<dyn Distribution>) = match cfg.density {
        Some(rho) => {
            let sparse = SparseDiag::paper_fig1(cfg.d, rho);
            (sparse.model(), Box::new(sparse))
        }
        None => {
            let model = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x70);
            (model.clone(), Box::new(model.gaussian()))
        }
    };
    let mut header = vec!["k".to_string()];
    header.extend(ESTIMATORS.iter().map(|e| format!("{e}_err")));
    header.extend(ESTIMATORS.iter().map(|e| format!("{e}_sem")));
    header.extend(ESTIMATORS.iter().map(|e| format!("{e}_rounds")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = CsvTable::new(&header_refs);

    let mut series: Vec<Series> = ESTIMATORS
        .iter()
        .zip(['C', 'o', 'L', 'p', 's'])
        .map(|(name, glyph)| Series::new(name, glyph))
        .collect();

    for &k in &cfg.k_list {
        anyhow::ensure!(k >= 1 && k <= cfg.d, "k={k} out of range for d={}", cfg.d);
        let v = top_k_basis(&model, k);
        let mut errors: Vec<Vec<f64>> = vec![Vec::with_capacity(cfg.runs); ESTIMATORS.len()];
        let mut rounds = vec![0.0f64; ESTIMATORS.len()];
        for r in 0..cfg.runs {
            // one cluster per run, shared by all estimators (paired
            // comparison, same as the Figure-1 driver)
            let cluster = Cluster::generate_with(
                dist.as_ref(),
                cfg.m,
                cfg.n,
                cfg.seed ^ ((r as u64) << 20) ^ ((k as u64) << 44),
                cfg.oracle.clone(),
            )?;
            for (idx, errs) in errors.iter_mut().enumerate() {
                let est = run_estimator(idx, k, &cluster.session())?;
                errs.push(est.error(&v));
                rounds[idx] += est.comm.rounds as f64;
            }
        }
        let mut row = vec![k as f64];
        let mut sems = Vec::new();
        let mut round_cells = Vec::new();
        for (idx, errs) in errors.iter().enumerate() {
            let summary = Summary::of(errs);
            row.push(summary.mean);
            sems.push(summary.sem);
            round_cells.push(rounds[idx] / cfg.runs as f64);
            series[idx].push(k as f64, summary.mean);
        }
        row.extend(sems);
        row.extend(round_cells);
        table.push_nums(&row);
        crate::info!(
            "topk k={k}: cen={:.2e} ortho={:.2e} blanczos={:.2e} proj={:.2e} dsni={:.2e}",
            row[1],
            row[2],
            row[3],
            row[4],
            row[5]
        );
    }
    println!(
        "{}",
        loglog(
            &series,
            72,
            20,
            &format!("Top-k subspace: error vs k (m={}, n={}, d={})", cfg.m, cfg.n, cfg.d)
        )
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_rows(table: &CsvTable) -> Vec<Vec<f64>> {
        table
            .render()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    /// Tiny-size smoke: every row is schema-complete and every cell is a
    /// finite number.
    #[test]
    fn topk_smoke_rows_finite_and_schema_complete() {
        let cfg = TopkConfig {
            d: 10,
            m: 3,
            n: 80,
            k_list: vec![1, 2],
            runs: 2,
            seed: 3,
            oracle: OracleSpec::Native,
            density: None,
        };
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 2);
        let want_cols = 1 + 3 * ESTIMATORS.len();
        for row in &rows {
            assert_eq!(row.len(), want_cols, "schema-complete row");
            for cell in row {
                assert!(cell.is_finite(), "non-finite cell {cell}");
            }
        }
        assert_eq!(rows[0][0], 1.0);
        assert_eq!(rows[1][0], 2.0);
    }

    /// The sparse workload (ISSUE 6): the same sweep on CSR shards from
    /// [`SparseDiag`] stays schema-complete with finite errors, i.e. the
    /// whole estimator family runs on the streaming sparse kernels.
    #[test]
    fn topk_sparse_smoke_runs_on_csr_shards() {
        let cfg = TopkConfig {
            d: 10,
            m: 3,
            n: 80,
            k_list: vec![2],
            runs: 2,
            seed: 7,
            oracle: OracleSpec::Native,
            density: Some(0.4),
        };
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 1);
        for row in &rows {
            assert_eq!(row.len(), 1 + 3 * ESTIMATORS.len());
            for cell in row {
                assert!(cell.is_finite(), "non-finite cell {cell}");
            }
        }
        // the centralized estimator should still recover the top-2
        // subspace of diag(sigma) decently at these sizes
        assert!(rows[0][1] < 0.9, "centralized error {} on sparse data", rows[0][1]);
    }

    /// The block protocol's signature: iterative estimators' round counts
    /// must not scale with k (one block round per iteration).
    #[test]
    fn topk_rounds_do_not_scale_with_k_for_block_methods() {
        let cfg = TopkConfig {
            d: 16,
            m: 4,
            n: 150,
            k_list: vec![2, 8],
            runs: 2,
            seed: 5,
            oracle: OracleSpec::Native,
            density: None,
        };
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        // ortho_iter mean-rounds column = 1 + len + len + 1 (k, errs, sems, then rounds)
        let ortho_rounds_col = 1 + 2 * ESTIMATORS.len() + 1;
        let (r_k2, r_k8) = (rows[0][ortho_rounds_col], rows[1][ortho_rounds_col]);
        // column-wise would pay exactly 4x more rounds at k=8 than k=2;
        // the block protocol keeps the per-iteration cost flat, so the
        // totals stay within iteration-count noise of each other
        assert!(
            r_k8 < 2.0 * r_k2.max(1.0),
            "ortho-iteration rounds scaled with k: k=2 -> {r_k2}, k=8 -> {r_k8}"
        );
    }
}
