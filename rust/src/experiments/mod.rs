//! Experiment drivers — one per table/figure in the paper's evaluation
//! (experiment index in DESIGN.md §4).
//!
//! Each driver is a plain function from a config struct to a
//! [`crate::util::csv::CsvTable`] (plus stdout reporting), shared between
//! the `examples/` binaries, the `cargo bench` targets and the `dspca`
//! launcher.

pub mod figure1;
pub mod lower_bounds;
pub mod scaling;
pub mod serve;
pub mod table1;
pub mod topk;
pub mod transport;
pub mod wire;

use anyhow::Result;

use crate::cluster::{Cluster, OracleSpec};
use crate::coordinator::Algorithm;
use crate::data::Distribution;
use crate::util::stats::Summary;

/// Mean estimation error of `alg` over `runs` independent clusters.
/// Returns (error summary, mean rounds, mean distributed matvecs).
pub fn mean_error(
    dist: &dyn Distribution,
    alg: &dyn Algorithm,
    m: usize,
    n: usize,
    runs: usize,
    seed: u64,
    oracle: &OracleSpec,
) -> Result<(Summary, f64, f64)> {
    let mut errors = Vec::with_capacity(runs);
    let mut rounds = 0.0;
    let mut matvecs = 0.0;
    for r in 0..runs {
        let cluster = Cluster::generate_with(dist, m, n, seed ^ (r as u64) << 20, oracle.clone())?;
        let est = alg.run(&cluster.session())?;
        errors.push(est.error(dist.v1()));
        rounds += est.comm.rounds as f64;
        matvecs += est.comm.matvec_products as f64;
    }
    Ok((Summary::of(&errors), rounds / runs as f64, matvecs / runs as f64))
}

/// Number of experiment repetitions: `DSPCA_RUNS` env override, else the
/// given default (the paper uses 400; the default examples use fewer to
/// stay interactive).
pub fn runs_from_env(default: usize) -> usize {
    std::env::var("DSPCA_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SignFixedAverage;
    use crate::data::CovModel;

    #[test]
    fn mean_error_aggregates() {
        let dist = CovModel::paper_fig1(6, 3).gaussian();
        let (summary, rounds, matvecs) =
            mean_error(&dist, &SignFixedAverage, 3, 50, 4, 1, &OracleSpec::Native).unwrap();
        assert_eq!(summary.n, 4);
        assert!(summary.mean > 0.0);
        assert_eq!(rounds, 1.0);
        assert_eq!(matvecs, 0.0);
    }

    #[test]
    fn runs_from_env_default() {
        std::env::remove_var("DSPCA_RUNS");
        assert_eq!(runs_from_env(7), 7);
    }
}
