//! E12 — transport ablation: per-round latency and bytes for the same
//! collective workload on the in-proc backend vs real TCP loopback
//! sockets, across dimension `d` and wire-codec width (experiment index
//! in DESIGN.md §4).
//!
//! This is the axis the transport subsystem opens: the §2.1 round model
//! executed over an actual network path. The driver times
//! `dist_matvec` rounds on both backends at each `(d, codec)` point and
//! **asserts the bills are backend-invariant** — identical rounds,
//! messages, and bytes on in-proc and TCP, because billing happens in
//! the session layer from the codec-encoded frames that are exactly the
//! payload bytes the TCP backend ships. What *does* move is latency:
//! the `round_us_mean` column is the price of frame
//! encode/decode + syscalls + loopback delivery, the real-deployment
//! overhead the in-proc simulation hides.
//!
//! Since the split-phase refactor the driver also times the same round
//! count issued through a **pipelined window** of in-flight tickets
//! ([`Session::dist_matvec_submit`](crate::cluster::Session)), and
//! asserts (a) the pipelined session's bill is *identical* to the
//! serialized session's — overlap changes when bytes move, never what
//! they cost — and (b) on TCP loopback, where each serialized round
//! pays real syscall + delivery latency, the pipelined rounds are
//! strictly faster per round. That pair is the tentpole's payoff —
//! same bills, better wall clock — measured on a real network path.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, CommStats, OracleSpec, WireCodec, WirePrecision};
use crate::data::{CovModel, Distribution, SparseDiag};
use crate::transport::{LoopbackWorkers, TransportSpec};
use crate::util::csv::CsvTable;
use crate::util::stats::Summary;

/// The backends of the sweep, in column order.
pub const BACKENDS: [&str; 2] = ["inproc", "tcp"];

/// The codec widths of the sweep (full-width and the narrowest).
pub const CODECS: [WirePrecision; 2] = [WirePrecision::F64, WirePrecision::Bf16];

#[derive(Clone, Debug)]
pub struct TransportConfig {
    /// Dimensions to sweep (one frame width per `d`).
    pub d_list: Vec<usize>,
    pub m: usize,
    pub n: usize,
    /// Timed collective rounds per `(backend, d, codec)` cell.
    pub rounds: usize,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// Worker-side socket I/O deadline for the loopback workers
    /// (`--io-timeout-secs`; also rides the generated
    /// [`TransportSpec::Tcp`]).
    pub io_timeout: std::time::Duration,
    /// Split-phase acceptance gate: `ensure!` that pipelined rounds
    /// beat serialized rounds on the TCP backend. Off for tiny smoke
    /// configs where a four-round sample is all noise.
    pub assert_pipeline_win: bool,
    /// `Some(rho)` runs the sweep on CSR shards from [`SparseDiag`]
    /// (CLI `--density`) — exercising the sparse branch of the TCP
    /// `Init` handshake plus the streaming kernels, with the same
    /// backend-invariant bills.
    pub density: Option<f64>,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            d_list: vec![16, 64, 256],
            m: 4,
            n: 200,
            rounds: super::runs_from_env(32),
            seed: 0x7ca9,
            oracle: OracleSpec::Native,
            io_timeout: crate::transport::DEFAULT_IO_TIMEOUT,
            assert_pipeline_win: true,
            density: None,
        }
    }
}

/// Run the sweep; returns a CSV with one row per
/// `(backend, d, codec)`: `backend, d, bytes_per_entry, rounds,
/// round_us_mean, round_us_p95, pipe_depth, pipe_us_mean, pipe_speedup,
/// bytes_per_round, total_bytes`. Errors if any bill differs between
/// backends, if a pipelined session's bill differs from the serialized
/// session's, or (with [`TransportConfig::assert_pipeline_win`]) if
/// pipelined rounds fail to beat serialized rounds on TCP.
pub fn run(cfg: &TransportConfig) -> Result<CsvTable> {
    ensure!(cfg.rounds >= 1, "transport sweep needs at least one timed round");
    let mut table = CsvTable::new(&[
        "backend",
        "d",
        "bytes_per_entry",
        "rounds",
        "round_us_mean",
        "round_us_p95",
        "pipe_depth",
        "pipe_us_mean",
        "pipe_speedup",
        "bytes_per_round",
        "total_bytes",
    ]);
    let pipe_depth = cfg.rounds.min(8).max(2);
    for &d in &cfg.d_list {
        let dist: Box<dyn Distribution> = match cfg.density {
            Some(rho) => Box::new(SparseDiag::paper_fig1(d, rho)),
            None => Box::new(CovModel::paper_fig1(d, cfg.seed ^ 0x12).gaussian()),
        };
        let mut rng = crate::rng::Pcg64::new(cfg.seed ^ d as u64);
        let v = rng.gaussian_vec(d);
        // per backend: one bill per codec, compared cell-by-cell below
        let mut bills: Vec<Vec<CommStats>> = Vec::with_capacity(BACKENDS.len());
        for backend in BACKENDS {
            // fresh loopback workers per cluster: each serves exactly
            // one leader connection, so their threads are joinable
            let loopback = if backend == "tcp" {
                Some(LoopbackWorkers::spawn_with(cfg.m, 1, cfg.io_timeout)?)
            } else {
                None
            };
            let spec = loopback.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
            let cluster = Cluster::generate_on(
                dist.as_ref(),
                cfg.m,
                cfg.n,
                cfg.seed,
                cfg.oracle.clone(),
                &spec,
            )?;
            let mut backend_bills = Vec::with_capacity(CODECS.len());
            for prec in CODECS {
                // serialized: complete every round before the next submit
                let session = cluster.session();
                session.set_codec(WireCodec::new(prec));
                session.dist_matvec(&v)?; // warm (connection, caches)
                session.reset_stats();
                let mut lat_us = Vec::with_capacity(cfg.rounds);
                for _ in 0..cfg.rounds {
                    let t = Instant::now();
                    session.dist_matvec(&v)?;
                    lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                }
                let bill = session.close();
                let lat = Summary::of(&lat_us);

                // pipelined: the same round count with up to
                // `pipe_depth` tickets in flight — split-phase overlap
                // hides per-round delivery latency behind the window
                let piped = cluster.session();
                piped.set_codec(WireCodec::new(prec));
                piped.dist_matvec(&v)?; // warm
                piped.reset_stats();
                let t0 = Instant::now();
                let mut window = VecDeque::with_capacity(pipe_depth);
                for _ in 0..cfg.rounds {
                    window.push_back(piped.dist_matvec_submit(&v)?);
                    if window.len() >= pipe_depth {
                        window.pop_front().expect("non-empty window").complete()?;
                    }
                }
                while let Some(ticket) = window.pop_front() {
                    ticket.complete()?;
                }
                drop(window);
                let pipe_us = t0.elapsed().as_secs_f64() * 1e6 / cfg.rounds as f64;
                let pipe_bill = piped.close();
                // the tentpole contract, half one: overlap must not
                // change a single counter
                ensure!(
                    pipe_bill == bill,
                    "pipelined bill diverged from serialized at \
                     {backend} d={d} {}: {pipe_bill} vs {bill}",
                    prec.label()
                );
                let speedup = lat.mean / pipe_us.max(1e-9);
                table.push_row(vec![
                    backend.to_string(),
                    d.to_string(),
                    prec.bytes_per_entry().to_string(),
                    bill.rounds.to_string(),
                    format!("{:.3}", lat.mean),
                    format!("{:.3}", lat.p95),
                    pipe_depth.to_string(),
                    format!("{pipe_us:.3}"),
                    format!("{speedup:.3}"),
                    (bill.bytes / bill.rounds.max(1)).to_string(),
                    bill.bytes.to_string(),
                ]);
                crate::info!(
                    "transport {backend} d={d} {}: {:.1}us/round serialized, \
                     {pipe_us:.1}us/round pipelined (x{speedup:.2}), {} B/round",
                    prec.label(),
                    lat.mean,
                    bill.bytes / bill.rounds.max(1)
                );
                // the tentpole contract, half two: on a real network
                // path, keeping the wire busy must buy wall clock
                if cfg.assert_pipeline_win && backend == "tcp" {
                    ensure!(
                        pipe_us < lat.mean,
                        "pipelined rounds did not beat serialized rounds on TCP at \
                         d={d} {}: {pipe_us:.1}us/round vs {:.1}us/round",
                        prec.label(),
                        lat.mean
                    );
                }
                backend_bills.push(bill);
            }
            bills.push(backend_bills);
            drop(cluster);
            if let Some(w) = loopback {
                w.join()?;
            }
        }
        // THE invariant this driver exists for: the bill is a property
        // of the protocol, not the substrate
        ensure!(
            bills[0] == bills[1],
            "transport backends disagree on the bill at d={d}: inproc={:?} tcp={:?}",
            bills[0],
            bills[1]
        );
    }
    Ok(table)
}

/// Config for the E12 **reactor acceptance gate** ([`run_reactor`]):
/// one leader collecting from many TCP peers, with the leader-side
/// reply plumbing capped at a single reactor thread.
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Loopback TCP peers (the acceptance run uses 64).
    pub peers: usize,
    pub d: usize,
    /// Samples per peer shard (total `n = peers * n_per_peer`).
    pub n_per_peer: usize,
    /// Normalized power-iteration rounds driven through the reactor.
    pub rounds: usize,
    pub seed: u64,
    pub io_timeout: std::time::Duration,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            peers: 64,
            d: 16,
            n_per_peer: 3,
            rounds: 8,
            seed: 0xeac7,
            io_timeout: crate::transport::DEFAULT_IO_TIMEOUT,
        }
    }
}

/// E12 reactor gate: run the same normalized power iteration on the
/// in-proc backend and on `peers` loopback TCP sockets, and `ensure!`
/// that (a) the TCP leader's reply plumbing is **at most one reader
/// thread** ([`Cluster::reader_threads`] — before the reactor this was
/// one blocking thread per peer, 64 here) and (b) the two backends'
/// bills are bit-identical. Both checks are unconditional: they are
/// structural, not wall-clock, so no host can flake them. Returns a
/// CSV with one row per backend: `backend, peers, rounds,
/// reader_threads, wall_s, total_bytes`.
pub fn run_reactor(cfg: &ReactorConfig) -> Result<CsvTable> {
    ensure!(cfg.peers >= 2, "the reactor gate needs at least two peers");
    ensure!(cfg.rounds >= 1 && cfg.n_per_peer >= 1, "empty reactor workload");
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0xea).gaussian();
    let n = cfg.peers * cfg.n_per_peer;
    let mut rng = crate::rng::Pcg64::new(cfg.seed ^ 0x1);
    let v0 = rng.gaussian_vec(cfg.d);
    let mut table = CsvTable::new(&[
        "backend",
        "peers",
        "rounds",
        "reader_threads",
        "wall_s",
        "total_bytes",
    ]);
    let mut bills: Vec<CommStats> = Vec::with_capacity(BACKENDS.len());
    for backend in BACKENDS {
        let loopback = if backend == "tcp" {
            Some(LoopbackWorkers::spawn_with(cfg.peers, 1, cfg.io_timeout)?)
        } else {
            None
        };
        let spec = loopback.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
        let cluster = Cluster::generate_on(
            &dist,
            cfg.peers,
            n,
            cfg.seed,
            OracleSpec::Native,
            &spec,
        )?;
        // the gate, half one: leader-side reply plumbing is one reactor
        // thread regardless of peer count (in-proc reports 0 — its
        // threads are the simulated machines, not reply plumbing)
        let readers = cluster.reader_threads();
        ensure!(
            readers <= 1,
            "leader reply plumbing did not stay constant: {readers} reader \
             threads for {} {backend} peers",
            cfg.peers
        );
        let session = cluster.session();
        let t0 = Instant::now();
        let mut v = v0.clone();
        for _ in 0..cfg.rounds {
            v = session.dist_matvec(&v)?;
            crate::linalg::vec_ops::normalize(&mut v);
        }
        let wall = t0.elapsed().as_secs_f64();
        let bill = session.close();
        table.push_row(vec![
            backend.to_string(),
            cfg.peers.to_string(),
            bill.rounds.to_string(),
            readers.to_string(),
            format!("{wall:.6}"),
            bill.bytes.to_string(),
        ]);
        crate::info!(
            "reactor {backend} peers={}: {} rounds in {wall:.3}s with \
             {readers} reader threads, {} B total",
            cfg.peers,
            bill.rounds,
            bill.bytes
        );
        bills.push(bill);
        drop(cluster);
        if let Some(workers) = loopback {
            workers.join()?;
        }
    }
    // the gate, half two: the reactor moved the reply path off
    // per-peer threads without touching a single counter
    ensure!(
        bills[0] == bills[1],
        "reactor bills diverged from in-proc at {} peers: inproc={:?} tcp={:?}",
        cfg.peers,
        bills[0],
        bills[1]
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TransportConfig {
        TransportConfig {
            d_list: vec![6],
            m: 2,
            n: 30,
            rounds: 4,
            seed: 5,
            oracle: OracleSpec::Native,
            io_timeout: crate::transport::DEFAULT_IO_TIMEOUT,
            // 4 rounds of microsecond noise prove nothing about overlap;
            // the release-mode stress suite gates the win at real size
            assert_pipeline_win: false,
            density: None,
        }
    }

    /// Tiny-size smoke: one schema-complete row per (backend, d, codec),
    /// with the backend-invariance and pipelined-bill assertions inside
    /// `run` exercised.
    #[test]
    fn transport_smoke_rows_schema_complete_and_bills_invariant() {
        let table = run(&tiny_cfg()).unwrap();
        let rendered = table.render();
        let rows: Vec<Vec<&str>> =
            rendered.lines().skip(1).map(|l| l.split(',').collect()).collect();
        assert_eq!(rows.len(), BACKENDS.len() * CODECS.len());
        for row in &rows {
            assert_eq!(row.len(), 11, "schema-complete row");
            assert!(row[0] == "inproc" || row[0] == "tcp");
            for cell in &row[1..] {
                let x: f64 = cell.parse().unwrap();
                assert!(x.is_finite());
            }
        }
        // per-round bytes follow the codec width on both backends:
        // B(d)·(live+1) with live = m
        let per_round = |r: &Vec<&str>| r[9].parse::<u64>().unwrap();
        let f64_rows: Vec<&Vec<&str>> = rows.iter().filter(|r| r[2] == "8").collect();
        let bf16_rows: Vec<&Vec<&str>> = rows.iter().filter(|r| r[2] == "2").collect();
        for (a, b) in f64_rows.into_iter().zip(bf16_rows) {
            assert_eq!(per_round(a), 8 * 6 * 3, "f64 row");
            assert_eq!(per_round(b), 2 * 6 * 3, "bf16 row");
            assert_eq!(per_round(a), 4 * per_round(b));
        }
    }

    /// Tiny-size reactor gate: the reader-thread cap and bill-identity
    /// `ensure!`s inside [`run_reactor`] are unconditional, so this
    /// smoke proves them at 8 peers; the stress suite runs the 64-peer
    /// acceptance size.
    #[test]
    fn reactor_gate_smoke_caps_reader_threads_and_matches_bills() {
        let cfg = ReactorConfig { peers: 8, rounds: 3, seed: 7, ..Default::default() };
        let table = run_reactor(&cfg).unwrap();
        let rendered = table.render();
        let rows: Vec<Vec<&str>> =
            rendered.lines().skip(1).map(|l| l.split(',').collect()).collect();
        assert_eq!(rows.len(), 2, "one row per backend");
        for row in &rows {
            assert_eq!(row.len(), 6, "schema-complete row");
        }
        assert_eq!((rows[0][0], rows[1][0]), ("inproc", "tcp"));
        assert_eq!(rows[0][3], "0", "in-proc worker threads are machines, not readers");
        assert_eq!(rows[1][3], "1", "tcp reply plumbing is exactly the reactor");
        assert_eq!(rows[0][5], rows[1][5], "total bytes backend-invariant");
    }

    /// Sparse workload across a real socket (ISSUE 6): CSR shards take
    /// the sparse branch of the TCP `Init` handshake, and the in-run
    /// `ensure!`s prove the bills stay identical to in-proc — storage
    /// format and transport both invisible to the §2.1 accounting.
    #[test]
    fn transport_sparse_smoke_ships_csr_over_tcp_with_invariant_bills() {
        let cfg = TransportConfig { density: Some(0.4), ..tiny_cfg() };
        let table = run(&cfg).unwrap();
        let rendered = table.render();
        let rows: Vec<Vec<&str>> =
            rendered.lines().skip(1).map(|l| l.split(',').collect()).collect();
        assert_eq!(rows.len(), BACKENDS.len() * CODECS.len());
        for row in &rows {
            assert_eq!(row.len(), 11, "schema-complete row");
        }
    }
}
