//! E6 — Theorem 6 scaling: Shift-and-Invert distributed-matvec count vs
//! per-machine sample size `n` (expected `~n^{-1/4}` once preconditioning
//! binds) and vs `m`, compared against distributed Lanczos (whose count
//! is `n`-independent).

use anyhow::Result;

use crate::cluster::{Cluster, OracleSpec};
use crate::coordinator::{Algorithm, DistributedLanczos, ShiftInvert, SniConfig};
use crate::data::{CovModel, Distribution};
use crate::util::csv::CsvTable;

#[derive(Clone, Debug)]
pub struct ScalingConfig {
    pub d: usize,
    pub m: usize,
    pub n_list: Vec<usize>,
    pub m_list: Vec<usize>,
    pub n_for_m_sweep: usize,
    pub runs: usize,
    pub seed: u64,
    pub eps: f64,
    /// Use the spread (linear-decay) spectrum where CG cannot cheat via
    /// eigenvalue clustering (see EXPERIMENTS.md E7).
    pub spread_spectrum: bool,
    pub delta: f64,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        ScalingConfig {
            d: 120,
            m: 8,
            n_list: vec![250, 500, 1000, 2000, 4000],
            m_list: vec![2, 4, 8, 16, 32],
            n_for_m_sweep: 1000,
            runs: super::runs_from_env(5),
            seed: 0x5ca1e,
            eps: 1e-6,
            spread_spectrum: true,
            delta: 0.1,
        }
    }
}

fn make_dist(cfg: &ScalingConfig) -> impl Distribution {
    let mut sigma = vec![1.0, 1.0 - cfg.delta];
    for j in 2..cfg.d {
        if cfg.spread_spectrum {
            sigma.push((1.0 - cfg.delta) * (1.0 - (j as f64 - 1.0) / cfg.d as f64));
        } else {
            let prev = sigma[j - 1];
            sigma.push(0.9 * prev);
        }
    }
    CovModel::with_spectrum(sigma, cfg.seed ^ 0xdd).gaussian()
}

fn avg_matvecs(
    dist: &dyn Distribution,
    alg: &dyn Algorithm,
    m: usize,
    n: usize,
    runs: usize,
    seed: u64,
) -> Result<f64> {
    let mut total = 0.0;
    for r in 0..runs {
        let c = Cluster::generate_with(dist, m, n, seed ^ (r as u64) << 18, OracleSpec::Native)?;
        total += alg.run(&c.session())?.comm.matvec_products as f64;
    }
    Ok(total / runs as f64)
}

/// Sweep over `n` at fixed `m`: columns `n, sni_matvecs, lanczos_matvecs`.
pub fn run_n_sweep(cfg: &ScalingConfig) -> Result<CsvTable> {
    let dist = make_dist(cfg);
    let sni = ShiftInvert::new(SniConfig { eps: cfg.eps, ..Default::default() });
    let lan = DistributedLanczos { tol: cfg.eps * 1e-2, ..Default::default() };
    let mut table = CsvTable::new(&["n", "sni_matvecs", "lanczos_matvecs"]);
    for &n in &cfg.n_list {
        let s = avg_matvecs(&dist, &sni, cfg.m, n, cfg.runs, cfg.seed)?;
        let l = avg_matvecs(&dist, &lan, cfg.m, n, cfg.runs, cfg.seed)?;
        table.push_nums(&[n as f64, s, l]);
        crate::info!("scaling n={n}: sni={s:.1} lanczos={l:.1}");
    }
    Ok(table)
}

/// Sweep over `m` at fixed `n`: columns `m, sni_matvecs, lanczos_matvecs,
/// oja_rounds(=m)`.
pub fn run_m_sweep(cfg: &ScalingConfig) -> Result<CsvTable> {
    let dist = make_dist(cfg);
    let sni = ShiftInvert::new(SniConfig { eps: cfg.eps, ..Default::default() });
    let lan = DistributedLanczos { tol: cfg.eps * 1e-2, ..Default::default() };
    let mut table = CsvTable::new(&["m", "sni_matvecs", "lanczos_matvecs", "oja_rounds"]);
    for &m in &cfg.m_list {
        let s = avg_matvecs(&dist, &sni, m, cfg.n_for_m_sweep, cfg.runs, cfg.seed)?;
        let l = avg_matvecs(&dist, &lan, m, cfg.n_for_m_sweep, cfg.runs, cfg.seed)?;
        table.push_nums(&[m as f64, s, l, m as f64]);
        crate::info!("scaling m={m}: sni={s:.1} lanczos={l:.1}");
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_sweep_shows_sni_decreasing() {
        let cfg = ScalingConfig {
            d: 40,
            m: 4,
            n_list: vec![250, 4000],
            runs: 2,
            ..Default::default()
        };
        let table = run_n_sweep(&cfg).unwrap();
        let lines: Vec<Vec<f64>> = table
            .render()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect();
        // S&I matvecs should not increase with 16x more data per machine
        assert!(
            lines[1][1] <= lines[0][1] * 1.3,
            "sni matvecs grew with n: {} -> {}",
            lines[0][1],
            lines[1][1]
        );
    }

    #[test]
    fn m_sweep_runs() {
        let cfg = ScalingConfig {
            d: 24,
            m_list: vec![2, 8],
            n_for_m_sweep: 400,
            runs: 2,
            ..Default::default()
        };
        let table = run_m_sweep(&cfg).unwrap();
        assert_eq!(table.n_rows(), 2);
    }

    /// Tiny-size smoke for both sweeps: schema-complete rows, every cell
    /// finite and positive (matvec counts are at least 1).
    #[test]
    fn scaling_smoke_rows_finite_and_schema_complete() {
        let cfg = ScalingConfig {
            d: 16,
            m: 3,
            n_list: vec![200, 400],
            m_list: vec![2, 4],
            n_for_m_sweep: 200,
            runs: 2,
            ..Default::default()
        };
        let tn = run_n_sweep(&cfg).unwrap();
        let tm = run_m_sweep(&cfg).unwrap();
        for (table, cols) in [(&tn, 3usize), (&tm, 4usize)] {
            let rendered = table.render();
            let mut lines = rendered.lines();
            assert_eq!(lines.next().unwrap().split(',').count(), cols);
            let mut n_rows = 0;
            for line in lines {
                let cells: Vec<f64> = line.split(',').map(|c| c.parse().unwrap()).collect();
                assert_eq!(cells.len(), cols, "schema-complete row: {line}");
                for cell in &cells {
                    assert!(cell.is_finite() && *cell > 0.0, "bad cell {cell} in {line}");
                }
                n_rows += 1;
            }
            assert_eq!(n_rows, 2);
        }
    }
}
