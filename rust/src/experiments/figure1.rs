//! E1/E2 — Figure 1: estimation error vs per-machine sample size `n`,
//! for the five §5 estimators, under the gaussian (left pane) and
//! scaled-uniform (right pane) distributions.
//!
//! Paper parameters: `d = 300`, `m = 25`, `delta = 0.2`, 400 runs,
//! `n` sweep. All are configurable (`DSPCA_RUNS`, CLI flags) because the
//! full-size figure takes a while on one box.

use anyhow::Result;

use crate::cluster::OracleSpec;
use crate::coordinator::{
    Algorithm, CentralizedErm, NaiveAverage, ProjectionAverage, SignFixedAverage, SingleMachineErm,
};
use crate::data::{CovModel, Distribution};
use crate::transport::TransportSpec;
use crate::util::csv::CsvTable;
use crate::util::plot::{loglog, Series};



/// Which §5 data distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig1Dist {
    Gaussian,
    ScaledUniform,
}

#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub d: usize,
    pub m: usize,
    pub n_list: Vec<usize>,
    pub runs: usize,
    pub seed: u64,
    pub dist: Fig1Dist,
    pub oracle: OracleSpec,
    /// Message substrate: in-proc threads (default) or TCP workers
    /// (`--transport tcp --workers a:p,...`). The sweep's estimates and
    /// bills are backend-invariant; with TCP, every run's cluster
    /// reconnects to the same worker set.
    pub transport: TransportSpec,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            d: 300,
            m: 25,
            n_list: vec![25, 50, 100, 200, 400, 800],
            runs: super::runs_from_env(40),
            seed: 0xf1f1,
            dist: Fig1Dist::Gaussian,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        }
    }
}

/// The five estimator columns of Figure 1, in plot order.
pub const ESTIMATORS: [&str; 5] =
    ["centralized", "single_machine", "naive_avg", "sign_fixed_avg", "projection_avg"];

/// Run the sweep; returns a CSV with columns `n, <estimator means...>,
/// <estimator sems...>`.
pub fn run(cfg: &Fig1Config) -> Result<CsvTable> {
    let model = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0xbeef);
    let dist: Box<dyn Distribution> = match cfg.dist {
        Fig1Dist::Gaussian => Box::new(model.gaussian()),
        Fig1Dist::ScaledUniform => Box::new(model.scaled_uniform()),
    };
    let algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(SingleMachineErm),
        Box::new(NaiveAverage),
        Box::new(SignFixedAverage),
        Box::new(ProjectionAverage),
    ];
    let mut header = vec!["n".to_string()];
    header.extend(ESTIMATORS.iter().map(|e| format!("{e}_mean")));
    header.extend(ESTIMATORS.iter().map(|e| format!("{e}_sem")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = CsvTable::new(&header_refs);

    let mut series: Vec<Series> = ESTIMATORS
        .iter()
        .zip(['C', '1', 'x', 's', 'p'])
        .map(|(name, glyph)| Series::new(name, glyph))
        .collect();

    for &n in &cfg.n_list {
        // one cluster per run, shared by all five estimators (paired
        // comparisons, exactly like the paper's per-dataset plots, and 5x
        // less data generation)
        let mut errors: Vec<Vec<f64>> = vec![Vec::with_capacity(cfg.runs); algs.len()];
        for r in 0..cfg.runs {
            let cluster = crate::cluster::Cluster::generate_on(
                dist.as_ref(),
                cfg.m,
                n,
                cfg.seed ^ (r as u64) << 20,
                cfg.oracle.clone(),
                &cfg.transport,
            )?;
            for (k, alg) in algs.iter().enumerate() {
                errors[k].push(alg.run(&cluster.session())?.error(dist.v1()));
            }
        }
        let mut row = vec![n as f64];
        let mut sems = Vec::new();
        for (k, errs) in errors.iter().enumerate() {
            let summary = crate::util::stats::Summary::of(errs);
            row.push(summary.mean);
            sems.push(summary.sem);
            series[k].push(n as f64, summary.mean);
        }
        row.extend(sems);
        table.push_nums(&row);
        crate::info!(
            "figure1[{:?}] n={n}: cen={:.2e} single={:.2e} naive={:.2e} signfix={:.2e} proj={:.2e}",
            cfg.dist,
            row[1],
            row[2],
            row[3],
            row[4],
            row[5]
        );
    }
    println!(
        "{}",
        loglog(&series, 72, 20, &format!("Figure 1 ({:?}): error vs n (m={}, d={})", cfg.dist, cfg.m, cfg.d))
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small end-to-end Figure-1 run asserting the paper's qualitative
    /// ordering: centralized < {sign-fixed, projection} < naive for the
    /// larger n.
    #[test]
    fn figure1_ordering_holds_small() {
        let cfg = Fig1Config {
            d: 20,
            m: 8,
            n_list: vec![60, 240],
            runs: 12,
            seed: 7,
            dist: Fig1Dist::Gaussian,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        };
        let table = run(&cfg).unwrap();
        assert_eq!(table.n_rows(), 2);
        let rendered = table.render();
        let last = rendered.lines().last().unwrap();
        let cells: Vec<f64> = last.split(',').map(|c| c.parse().unwrap()).collect();
        let (cen, _single, naive, signfix, proj) = (cells[1], cells[2], cells[3], cells[4], cells[5]);
        assert!(cen < naive, "centralized {cen:.2e} < naive {naive:.2e}");
        assert!(signfix < naive, "sign-fixed {signfix:.2e} < naive {naive:.2e}");
        assert!(proj < naive, "projection {proj:.2e} < naive {naive:.2e}");
    }

    /// Tiny-size smoke: every row is schema-complete (1 + 5 means + 5
    /// sems columns) and every cell parses to a finite number.
    #[test]
    fn figure1_smoke_rows_finite_and_schema_complete() {
        let cfg = Fig1Config {
            d: 8,
            m: 3,
            n_list: vec![30, 60],
            runs: 2,
            seed: 11,
            dist: Fig1Dist::Gaussian,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        };
        let table = run(&cfg).unwrap();
        assert_eq!(table.n_rows(), 2);
        let rendered = table.render();
        let mut lines = rendered.lines();
        let header = lines.next().unwrap();
        assert_eq!(header.split(',').count(), 1 + 2 * ESTIMATORS.len());
        for line in lines {
            let cells: Vec<f64> = line.split(',').map(|c| c.parse().unwrap()).collect();
            assert_eq!(cells.len(), 1 + 2 * ESTIMATORS.len(), "schema-complete row");
            for cell in &cells {
                assert!(cell.is_finite(), "non-finite cell {cell} in {line}");
            }
            // errors live in [0, 1], sems are non-negative
            for err in &cells[1..=ESTIMATORS.len()] {
                assert!((0.0..=1.0).contains(err), "error {err} out of range");
            }
            for sem in &cells[ESTIMATORS.len() + 1..] {
                assert!(*sem >= 0.0);
            }
        }
    }

    #[test]
    fn scaled_uniform_variant_runs() {
        let cfg = Fig1Config {
            d: 10,
            m: 4,
            n_list: vec![50],
            runs: 4,
            seed: 9,
            dist: Fig1Dist::ScaledUniform,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        };
        let table = run(&cfg).unwrap();
        assert_eq!(table.n_rows(), 1);
    }
}
