//! E4/E5 — empirical verification of the lower bounds.
//!
//! - Theorem 3: on the appendix-A.1 construction, naive averaging of
//!   unbiased local eigenvectors does not improve with `m` (the paper's
//!   Omega(1/n) lower bound; empirically the curve is even *flat* in `n`
//!   because sign-cancellation events dominate).
//! - Theorem 5 (Lemma 9): on the asymmetric-`xi` construction, even
//!   sign-fixed averaging keeps a bias term `Theta(1/(delta^4 n^2))`;
//!   the measured log-log slope in `n` should approach `-2` once the
//!   bias dominates the `1/(delta^2 m n)` variance term (large `m`).

use anyhow::Result;

use crate::cluster::OracleSpec;
use crate::coordinator::{NaiveAverage, SignFixedAverage};
use crate::data::{Thm3Dist, Thm5Dist};
use crate::util::csv::CsvTable;
use crate::util::stats::loglog_slope;

use super::mean_error;

#[derive(Clone, Debug)]
pub struct LowerBoundConfig {
    pub n_list: Vec<usize>,
    pub m_list: Vec<usize>,
    pub runs: usize,
    pub seed: u64,
    /// Eigengap for the Thm-5 construction.
    pub delta: f64,
}

impl Default for LowerBoundConfig {
    fn default() -> Self {
        LowerBoundConfig {
            // n >> 1/delta^2 (Taylor regime of Lemma 9) and a large final
            // m so the Thm-5 bias dominates the variance floor
            n_list: vec![90, 270, 810],
            m_list: vec![4, 32, 256],
            runs: super::runs_from_env(60),
            seed: 0x10b0,
            delta: 0.4,
        }
    }
}

/// Theorem-3 sweep: rows `n, err(m) for each m`, plus fitted slopes.
pub fn run_thm3(cfg: &LowerBoundConfig) -> Result<(CsvTable, Vec<f64>)> {
    let dist = Thm3Dist;
    let mut header = vec!["n".to_string()];
    header.extend(cfg.m_list.iter().map(|m| format!("naive_err_m{m}")));
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = CsvTable::new(&refs);
    let mut per_m_errors: Vec<Vec<f64>> = vec![Vec::new(); cfg.m_list.len()];
    for &n in &cfg.n_list {
        let mut row = vec![n as f64];
        for (k, &m) in cfg.m_list.iter().enumerate() {
            let (summary, _, _) =
                mean_error(&dist, &NaiveAverage, m, n, cfg.runs, cfg.seed, &OracleSpec::Native)?;
            row.push(summary.mean);
            per_m_errors[k].push(summary.mean);
        }
        table.push_nums(&row);
    }
    let ns: Vec<f64> = cfg.n_list.iter().map(|&n| n as f64).collect();
    let slopes: Vec<f64> = per_m_errors.iter().map(|errs| loglog_slope(&ns, errs)).collect();
    Ok((table, slopes))
}

/// Theorem-5 sweep: sign-fixed averaging on the asymmetric construction.
/// Returns the table and the fitted slope in `n` for the largest `m`.
pub fn run_thm5(cfg: &LowerBoundConfig) -> Result<(CsvTable, f64)> {
    let dist = Thm5Dist::new(cfg.delta);
    let m = *cfg.m_list.last().expect("need at least one m");
    let mut table = CsvTable::new(&["n", "sign_fixed_err"]);
    let mut errs = Vec::new();
    for &n in &cfg.n_list {
        let (summary, _, _) =
            mean_error(&dist, &SignFixedAverage, m, n, cfg.runs, cfg.seed ^ 0x5, &OracleSpec::Native)?;
        table.push_nums(&[n as f64, summary.mean]);
        errs.push(summary.mean);
    }
    let ns: Vec<f64> = cfg.n_list.iter().map(|&n| n as f64).collect();
    Ok((table, loglog_slope(&ns, &errs)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thm3_naive_error_flat_in_n_and_m() {
        // Theorem 3 is the *lower* bound Omega(1/n); empirically the
        // failure is even starker: the error is dominated by
        // sign-cancellation events (the m Rademacher signs nearly summing
        // to zero), which are n-independent. So the measured curve is
        // essentially FLAT in n — it certainly does not improve like the
        // centralized 1/(mn).
        let cfg = LowerBoundConfig {
            n_list: vec![20, 80, 320],
            m_list: vec![4, 32],
            runs: 60,
            seed: 5,
            delta: 0.5,
        };
        let (table, slopes) = run_thm3(&cfg).unwrap();
        assert_eq!(table.n_rows(), 3);
        for (k, s) in slopes.iter().enumerate() {
            assert!(
                (-0.8..=0.3).contains(s),
                "m index {k}: slope {s} — should be far from the centralized -1"
            );
        }
        // error at fixed n should NOT drop ~8x when m grows 8x:
        let rendered = table.render();
        let mid: Vec<f64> = rendered
            .lines()
            .nth(2)
            .unwrap()
            .split(',')
            .map(|c| c.parse().unwrap())
            .collect();
        let ratio = mid[1] / mid[2];
        assert!(ratio < 4.0, "naive error improved {ratio}x with 8x machines");
    }

    #[test]
    fn thm5_bias_slope_steeper_than_variance() {
        // In the Taylor regime (n >> 1/delta^2) the 1/(delta^4 n^2) bias
        // dominates at large m: slope well below the -1 variance-only law.
        let cfg = LowerBoundConfig {
            n_list: vec![270, 810],
            m_list: vec![256],
            runs: 80,
            seed: 11,
            delta: 0.4,
        };
        let (_, slope) = run_thm5(&cfg).unwrap();
        assert!(slope < -1.25, "Thm5 slope {slope} should reflect the n^-2 bias term");
    }

    #[test]
    fn thm5_asymmetry_is_what_creates_the_bias() {
        // Same pipeline on the symmetric Lemma-8 construction
        // (E[xi^3] = 0): no bias term, so at large m the error is far
        // below the asymmetric construction's.
        use crate::data::Lemma8Dist;
        // large m shrinks the shared 1/(delta^2 mn) variance floor so the
        // asymmetric bias stands out
        let (m, n, runs, delta) = (512, 270, 60, 0.4);
        let asym = Thm5Dist::new(delta);
        let sym = Lemma8Dist::new(delta);
        let (e_asym, _, _) =
            mean_error(&asym, &SignFixedAverage, m, n, runs, 21, &OracleSpec::Native).unwrap();
        let (e_sym, _, _) =
            mean_error(&sym, &SignFixedAverage, m, n, runs, 22, &OracleSpec::Native).unwrap();
        assert!(
            e_asym.mean > 3.0 * e_sym.mean,
            "asymmetric bias should dominate: asym {:.3e} vs sym {:.3e}",
            e_asym.mean,
            e_sym.mean
        );
    }
}
