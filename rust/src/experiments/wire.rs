//! E10 — wire-codec ablation: estimation error vs **actual** bytes per
//! round for the distributed power method under the full codec family —
//! plain F64/F32/Bf16, low-bit quantizers (q8/q4) with and without
//! error feedback, top-s sparsification, and the adaptive bit-width
//! controller — on the Figure-1 workload (experiment index in
//! DESIGN.md §4).
//!
//! This is the bytes-vs-error axis the wire layer opens: every number in
//! the `bytes_per_round` column is read back from `CommStats` — which
//! bills the codec's encoded frames — not estimated from `8·d`
//! arithmetic, so the CSV is an end-to-end check that the bill and the
//! wire agree. One row per codec, from 8 bytes/entry down to the
//! nibble-packed and sparse frames. The headline row pair is
//! `f64` vs `q4+ef`: error feedback lets the 4-bit stream track the
//! lossless error trajectory at ≥4× fewer billed bytes per round
//! (hard-gated under `DSPCA_STRESS=1`).

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, OracleSpec, QuantBits, WireCodec, WirePrecision};
use crate::coordinator::{Algorithm, QuantizedPower};
use crate::data::{CovModel, Distribution};
use crate::transport::TransportSpec;
use crate::util::csv::CsvTable;
use crate::util::plot::{loglog, Series};
use crate::util::stats::Summary;

/// The codec sweep, in decreasing wire width: the three plain widths,
/// the fixed quantizers with and without error feedback, the top-s
/// sparsifier (s = max(d/8, 1) kept coordinates, q8 values, feedback —
/// top-s without feedback diverges and is not worth a row), and the
/// adaptive q8↔q4 ladder.
pub fn codecs(d: usize) -> Vec<WireCodec> {
    let s = (d / 8).max(1) as u32;
    vec![
        WireCodec::lossless(),
        WireCodec::new(WirePrecision::F32),
        WireCodec::new(WirePrecision::Bf16),
        WireCodec::quant(QuantBits::Q8),
        WireCodec::quant(QuantBits::Q8).with_feedback(),
        WireCodec::quant(QuantBits::Q4),
        WireCodec::quant(QuantBits::Q4).with_feedback(),
        WireCodec::top_s(s, QuantBits::Q8).with_feedback(),
        WireCodec::quant(QuantBits::Q8).with_adaptive(),
    ]
}

#[derive(Clone, Debug)]
pub struct WireConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub runs: usize,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// Message substrate (bills and estimates are backend-invariant).
    pub transport: TransportSpec,
    /// `Some(codec)` restricts the sweep to a single codec row (the
    /// `--codec`/`--feedback`/`--adaptive` CLI path); `None` runs the
    /// whole family.
    pub codec: Option<WireCodec>,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            d: 60,
            m: 8,
            n: 400,
            runs: super::runs_from_env(8),
            seed: 0x317e,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            codec: None,
        }
    }
}

/// Run the sweep; returns a CSV with one row per codec:
/// `codec, bytes_per_round, err_mean, err_sem, drift_mean,
/// residual_mean, rounds_mean, total_bytes_mean`.
pub fn run(cfg: &WireConfig) -> Result<CsvTable> {
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x3f).gaussian();
    let mut table = CsvTable::new(&[
        "codec",
        "bytes_per_round",
        "err_mean",
        "err_sem",
        "drift_mean",
        "residual_mean",
        "rounds_mean",
        "total_bytes_mean",
    ]);
    let mut series = Series::new("power", 'q');
    let sweep = match cfg.codec {
        Some(c) => vec![c],
        None => codecs(cfg.d),
    };
    let n_codecs = sweep.len();
    let mut errors: Vec<Vec<f64>> = vec![Vec::with_capacity(cfg.runs); n_codecs];
    let mut drift = vec![0.0f64; n_codecs];
    let mut residual = vec![0.0f64; n_codecs];
    let mut rounds = vec![0.0f64; n_codecs];
    let mut bytes = vec![0.0f64; n_codecs];
    let mut bpr = vec![0.0f64; n_codecs];
    for r in 0..cfg.runs {
        // one cluster per run, shared by all codecs (paired comparison,
        // same as the Figure-1 and top-k drivers — each session carries
        // its own codec and feedback stream, so runs cannot interfere)
        let cluster = Cluster::generate_on(
            &dist,
            cfg.m,
            cfg.n,
            cfg.seed ^ ((r as u64) << 20),
            cfg.oracle.clone(),
            &cfg.transport,
        )?;
        for (i, &codec) in sweep.iter().enumerate() {
            let est = QuantizedPower::with_codec(codec).run(&cluster.session())?;
            errors[i].push(est.error(dist.v1()));
            drift[i] += est.info["final_drift"];
            residual[i] += est.info["residual_feedback_norm"];
            rounds[i] += est.comm.rounds as f64;
            bytes[i] += est.comm.bytes as f64;
            bpr[i] += est.info["wire_bytes_per_round"];
        }
    }
    let k = cfg.runs as f64;
    let mut per_round = vec![0.0f64; n_codecs];
    let mut err_mean = vec![0.0f64; n_codecs];
    for (i, codec) in sweep.iter().enumerate() {
        let summary = Summary::of(&errors[i]);
        per_round[i] = bpr[i] / k;
        err_mean[i] = summary.mean;
        series.push(per_round[i], summary.mean);
        table.push_row(vec![
            codec.label(),
            format!("{:.12e}", per_round[i]),
            format!("{:.12e}", summary.mean),
            format!("{:.12e}", summary.sem),
            format!("{:.12e}", drift[i] / k),
            format!("{:.12e}", residual[i] / k),
            format!("{:.12e}", rounds[i] / k),
            format!("{:.12e}", bytes[i] / k),
        ]);
        crate::info!(
            "wire {}: bytes/round={:.0} err={:.2e} drift_floor={:.2e} residual={:.2e}",
            codec.label(),
            per_round[i],
            summary.mean,
            drift[i] / k,
            residual[i] / k
        );
    }
    // the E10 acceptance gates, armed for the release-mode CI stress
    // job: q4+ef must track the lossless error trajectory at a ≥4×
    // per-round byte discount — both read back from the bill
    if cfg.codec.is_none() && std::env::var("DSPCA_STRESS").as_deref() == Ok("1") {
        let idx = |label: &str| {
            sweep
                .iter()
                .position(|c| c.label() == label)
                .unwrap_or_else(|| panic!("codec {label} missing from sweep"))
        };
        let (f64_i, q4ef_i) = (idx("f64"), idx("q4+ef"));
        ensure!(
            per_round[f64_i] >= 4.0 * per_round[q4ef_i],
            "q4+ef byte discount below 4x: f64 {} vs q4+ef {}",
            per_round[f64_i],
            per_round[q4ef_i]
        );
        ensure!(
            err_mean[q4ef_i] <= 3.0 * err_mean[f64_i],
            "q4+ef error off the f64 trajectory: {} vs {}",
            err_mean[q4ef_i],
            err_mean[f64_i]
        );
    }
    println!(
        "{}",
        loglog(
            &[series],
            72,
            18,
            &format!("Wire codecs: error vs bytes/round (m={}, n={}, d={})", cfg.m, cfg.n, cfg.d)
        )
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Rows as (codec label, numeric cells).
    fn parse_rows(table: &CsvTable) -> Vec<(String, Vec<f64>)> {
        table
            .render()
            .lines()
            .skip(1)
            .map(|l| {
                let mut cells = l.split(',');
                let label = cells.next().unwrap().to_string();
                (label, cells.map(|c| c.parse().unwrap()).collect())
            })
            .collect()
    }

    fn tiny_cfg() -> WireConfig {
        WireConfig {
            d: 8,
            m: 3,
            n: 60,
            runs: 2,
            seed: 5,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
            codec: None,
        }
    }

    /// Tiny-size smoke: one schema-complete, finite row per codec, in
    /// sweep order, with the whole family present.
    #[test]
    fn wire_smoke_rows_finite_and_schema_complete() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), codecs(8).len());
        for (label, nums) in &rows {
            assert_eq!(nums.len(), 7, "schema-complete row for {label}");
            for cell in nums {
                assert!(cell.is_finite(), "{label}: non-finite cell {cell}");
            }
        }
        let labels: Vec<&str> = rows.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(
            labels,
            vec!["f64", "f32", "bf16", "q8", "q8+ef", "q4", "q4+ef", "top1-q8+ef", "q8+ad"]
        );
        // feedback rows surface a positive stream residual; stateless
        // rows a zero one
        for (label, nums) in &rows {
            if label.ends_with("+ef") {
                assert!(nums[4] > 0.0, "{label}: feedback row must report its residual");
            }
            if ["f64", "f32", "bf16"].contains(&label.as_str()) {
                assert_eq!(nums[4], 0.0, "{label}: stateless row keeps no stream");
            }
        }
    }

    /// The honest-bytes signature: bytes per round are the codec's
    /// materialized frame sizes times (live+1) — read back from the
    /// bill, not computed from width arithmetic.
    #[test]
    fn wire_bytes_per_round_match_the_materialized_frames() {
        let cfg = tiny_cfg();
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        let fanout = (cfg.m + 1) as f64;
        let at = |label: &str| {
            rows.iter().find(|(l, _)| l == label).unwrap_or_else(|| panic!("row {label}")).1[0]
        };
        assert_eq!(at("f64"), 8.0 * cfg.d as f64 * fanout);
        assert_eq!(at("f32") * 2.0, at("f64"), "f32 ships exactly half the bytes");
        assert_eq!(at("bf16") * 4.0, at("f64"), "bf16 ships exactly a quarter");
        // q8: 4-byte scale + d level bytes; q4: scale + ⌈d/2⌉ nibbles —
        // feedback changes the stream, never the frame shape
        assert_eq!(at("q8"), (4 + cfg.d) as f64 * fanout);
        assert_eq!(at("q8+ef"), at("q8"));
        assert_eq!(at("q4"), (4 + cfg.d.div_ceil(2)) as f64 * fanout);
        assert_eq!(at("q4+ef"), at("q4"));
        // top-1 at q8 values: 8-byte header + 4-byte index + 1 level
        assert_eq!(at("top1-q8+ef"), (8 + 4 + 1) as f64 * fanout);
        // total bytes are per-round bytes times rounds for every fixed-
        // width codec; the adaptive row mixes widths so it is exempt
        for (label, nums) in &rows {
            if label != "q8+ad" {
                assert_eq!(nums[6], nums[0] * nums[5], "{label}: total = per-round × rounds");
            }
        }
    }

    /// The `--codec` CLI path: a `Some(codec)` config produces exactly
    /// one row, labeled with the full codec (flags included).
    #[test]
    fn wire_single_codec_override_produces_one_labeled_row() {
        let cfg = WireConfig {
            codec: Some(WireCodec::quant(QuantBits::Q4).with_feedback()),
            ..tiny_cfg()
        };
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, "q4+ef");
    }

    /// The adaptive controller actually moves: on the settling Fig-1
    /// iterate it narrows q8→q4, so its mean bytes/round land strictly
    /// between the two fixed widths.
    #[test]
    fn wire_adaptive_row_lands_between_the_fixed_widths() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        let at = |label: &str| {
            rows.iter().find(|(l, _)| l == label).unwrap_or_else(|| panic!("row {label}")).1[0]
        };
        let ad = at("q8+ad");
        assert!(
            ad > at("q4") && ad < at("q8"),
            "adaptive bytes/round {ad} not between q4 {} and q8 {}",
            at("q4"),
            at("q8")
        );
    }
}
