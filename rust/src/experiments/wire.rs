//! E10 — wire-codec ablation: estimation error vs **actual** bytes per
//! round for the distributed power method under the F64/F32/Bf16 wire
//! codecs, on the Figure-1 workload (experiment index in DESIGN.md §4).
//!
//! This is the bytes-vs-error axis the wire layer opens: every number in
//! the `bytes_per_round` column is read back from `CommStats` — which
//! bills the codec's encoded frames — not estimated from `8·d`
//! arithmetic, so the CSV is an end-to-end check that the bill and the
//! wire agree. One row per codec, sweeping the frame width down from
//! 8 bytes/entry to 2.

use anyhow::Result;

use crate::cluster::{Cluster, OracleSpec, WirePrecision};
use crate::coordinator::{Algorithm, QuantizedPower};
use crate::data::{CovModel, Distribution};
use crate::transport::TransportSpec;
use crate::util::csv::CsvTable;
use crate::util::plot::{loglog, Series};
use crate::util::stats::Summary;

/// The codecs of the sweep, in decreasing wire width.
pub const PRECISIONS: [WirePrecision; 3] =
    [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16];

#[derive(Clone, Debug)]
pub struct WireConfig {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub runs: usize,
    pub seed: u64,
    pub oracle: OracleSpec,
    /// Message substrate (bills and estimates are backend-invariant).
    pub transport: TransportSpec,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            d: 60,
            m: 8,
            n: 400,
            runs: super::runs_from_env(8),
            seed: 0x317e,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        }
    }
}

/// Run the sweep; returns a CSV with one row per codec:
/// `bytes_per_entry, bytes_per_round, err_mean, err_sem, drift_mean,
/// rounds_mean, total_bytes_mean`.
pub fn run(cfg: &WireConfig) -> Result<CsvTable> {
    let dist = CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x3f).gaussian();
    let mut table = CsvTable::new(&[
        "bytes_per_entry",
        "bytes_per_round",
        "err_mean",
        "err_sem",
        "drift_mean",
        "rounds_mean",
        "total_bytes_mean",
    ]);
    let mut series = Series::new("power", 'q');
    let n_prec = PRECISIONS.len();
    let mut errors: Vec<Vec<f64>> = vec![Vec::with_capacity(cfg.runs); n_prec];
    let mut drift = vec![0.0f64; n_prec];
    let mut rounds = vec![0.0f64; n_prec];
    let mut bytes = vec![0.0f64; n_prec];
    let mut bpr = vec![0.0f64; n_prec];
    for r in 0..cfg.runs {
        // one cluster per run, shared by all codecs (paired comparison,
        // same as the Figure-1 and top-k drivers — QuantizedPower
        // installs and restores the codec around each run)
        let cluster = Cluster::generate_on(
            &dist,
            cfg.m,
            cfg.n,
            cfg.seed ^ ((r as u64) << 20),
            cfg.oracle.clone(),
            &cfg.transport,
        )?;
        for (i, &prec) in PRECISIONS.iter().enumerate() {
            let est = QuantizedPower::new(prec).run(&cluster.session())?;
            errors[i].push(est.error(dist.v1()));
            drift[i] += est.info["final_drift"];
            rounds[i] += est.comm.rounds as f64;
            bytes[i] += est.comm.bytes as f64;
            bpr[i] += est.info["wire_bytes_per_round"];
        }
    }
    let k = cfg.runs as f64;
    for (i, &prec) in PRECISIONS.iter().enumerate() {
        let summary = Summary::of(&errors[i]);
        let per_round = bpr[i] / k;
        series.push(per_round, summary.mean);
        table.push_nums(&[
            prec.bytes_per_entry() as f64,
            per_round,
            summary.mean,
            summary.sem,
            drift[i] / k,
            rounds[i] / k,
            bytes[i] / k,
        ]);
        crate::info!(
            "wire {}: bytes/round={per_round:.0} err={:.2e} drift_floor={:.2e}",
            prec.label(),
            summary.mean,
            drift[i] / k
        );
    }
    println!(
        "{}",
        loglog(
            &[series],
            72,
            18,
            &format!("Wire codecs: error vs bytes/round (m={}, n={}, d={})", cfg.m, cfg.n, cfg.d)
        )
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_rows(table: &CsvTable) -> Vec<Vec<f64>> {
        table
            .render()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    fn tiny_cfg() -> WireConfig {
        WireConfig {
            d: 8,
            m: 3,
            n: 60,
            runs: 2,
            seed: 5,
            oracle: OracleSpec::Native,
            transport: TransportSpec::InProc,
        }
    }

    /// Tiny-size smoke: one schema-complete, finite row per codec.
    #[test]
    fn wire_smoke_rows_finite_and_schema_complete() {
        let table = run(&tiny_cfg()).unwrap();
        let rows = parse_rows(&table);
        assert_eq!(rows.len(), PRECISIONS.len());
        for row in &rows {
            assert_eq!(row.len(), 7, "schema-complete row");
            for cell in row {
                assert!(cell.is_finite(), "non-finite cell {cell}");
            }
        }
        let widths: Vec<f64> = rows.iter().map(|r| r[0]).collect();
        assert_eq!(widths, vec![8.0, 4.0, 2.0]);
    }

    /// The honest-bytes signature: bytes per round scale exactly with
    /// the codec's frame width — B(d)·(live+1) read back from the bill.
    #[test]
    fn wire_bytes_per_round_scale_exactly_with_codec_width() {
        let cfg = tiny_cfg();
        let table = run(&cfg).unwrap();
        let rows = parse_rows(&table);
        let per_round_f64 = (8 * cfg.d * (cfg.m + 1)) as f64;
        assert_eq!(rows[0][1], per_round_f64);
        assert_eq!(rows[1][1] * 2.0, per_round_f64, "f32 ships exactly half the bytes");
        assert_eq!(rows[2][1] * 4.0, per_round_f64, "bf16 ships exactly a quarter");
        // and total bytes are per-round bytes times rounds, exactly
        for row in &rows {
            assert_eq!(row[6], row[1] * row[5], "total = per-round × rounds");
        }
    }
}
