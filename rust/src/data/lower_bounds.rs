//! The appendix's lower-bound constructions.
//!
//! - [`Thm3Dist`] (Appendix A.1): `x = e_1 + (eps_1, eps_2)`,
//!   `eps_i ~ U{-1,+1}` over `R^2`. Population covariance `diag(2, 1)`,
//!   gap `delta = 1`, `v_1 = e_1`. Naive averaging of *unbiased* local
//!   eigenvectors stays at `Omega(1/n)` error for every `m`.
//! - [`Lemma8Dist`]: `x = sqrt(1+delta) e_1 + sigma e_2`,
//!   `sigma ~ U{-1,+1}` — the variance part `Omega(1/(delta^2 m n))` of
//!   the Thm 5 lower bound.
//! - [`Thm5Dist`] (Lemma 9): `x = sqrt(1+delta) e_1 + xi e_2` with the
//!   *asymmetric* `xi` (`sqrt 2` w.p. 1/3, `-1/sqrt 2` w.p. 2/3,
//!   `E[xi^3] = 1/sqrt 2 != 0`) — the bias part
//!   `Omega(1/(delta^4 n^2))` that sign-fixed averaging cannot beat.

use crate::rng::Pcg64;

use super::Distribution;

const E1: [f64; 2] = [1.0, 0.0];

/// Theorem 3 construction (naive-averaging failure).
#[derive(Clone, Debug, Default)]
pub struct Thm3Dist;

impl Distribution for Thm3Dist {
    fn dim(&self) -> usize {
        2
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        out[0] = 1.0 + rng.next_rademacher();
        out[1] = rng.next_rademacher();
    }

    fn v1(&self) -> &[f64] {
        &E1
    }

    fn eigengap(&self) -> f64 {
        1.0
    }

    fn lambda1(&self) -> f64 {
        2.0
    }

    fn norm_bound_sq(&self) -> f64 {
        5.0
    }
}

/// Lemma 8 construction: symmetric second coordinate, tunable gap.
#[derive(Clone, Debug)]
pub struct Lemma8Dist {
    delta: f64,
}

impl Lemma8Dist {
    pub fn new(delta: f64) -> Self {
        assert!((0.0..=1.0).contains(&delta) && delta > 0.0);
        Lemma8Dist { delta }
    }
}

impl Distribution for Lemma8Dist {
    fn dim(&self) -> usize {
        2
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        out[0] = (1.0 + self.delta).sqrt();
        out[1] = rng.next_rademacher();
    }

    fn v1(&self) -> &[f64] {
        &E1
    }

    fn eigengap(&self) -> f64 {
        self.delta
    }

    fn lambda1(&self) -> f64 {
        1.0 + self.delta
    }

    fn norm_bound_sq(&self) -> f64 {
        2.0 + self.delta
    }
}

/// Lemma 9 construction (Theorem 5): asymmetric third moment.
#[derive(Clone, Debug)]
pub struct Thm5Dist {
    delta: f64,
}

impl Thm5Dist {
    pub fn new(delta: f64) -> Self {
        assert!((0.0..=1.0).contains(&delta) && delta > 0.0);
        Thm5Dist { delta }
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }
}

impl Distribution for Thm5Dist {
    fn dim(&self) -> usize {
        2
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        out[0] = (1.0 + self.delta).sqrt();
        out[1] = rng.next_asymmetric_xi();
    }

    fn v1(&self) -> &[f64] {
        &E1
    }

    fn eigengap(&self) -> f64 {
        self.delta
    }

    fn lambda1(&self) -> f64 {
        1.0 + self.delta
    }

    fn norm_bound_sq(&self) -> f64 {
        // (1+delta) + xi^2 <= 1 + delta + 2
        3.0 + self.delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn empirical_cov(dist: &dyn Distribution, n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let shard = dist.sample_shard(&mut rng, n);
        shard.empirical_covariance().clone()
    }

    #[test]
    fn thm3_population_covariance() {
        let c = empirical_cov(&Thm3Dist, 400_000, 1);
        assert!((c.get(0, 0) - 2.0).abs() < 0.02);
        assert!((c.get(1, 1) - 1.0).abs() < 0.02);
        assert!(c.get(0, 1).abs() < 0.02);
    }

    #[test]
    fn thm5_population_covariance() {
        let d = Thm5Dist::new(0.3);
        let c = empirical_cov(&d, 400_000, 2);
        assert!((c.get(0, 0) - 1.3).abs() < 0.02);
        assert!((c.get(1, 1) - 1.0).abs() < 0.02);
        assert!(c.get(0, 1).abs() < 0.02);
    }

    #[test]
    fn lemma8_covariance_structure() {
        let d = Lemma8Dist::new(0.5);
        let c = empirical_cov(&d, 200_000, 3);
        assert!((c.get(0, 0) - 1.5).abs() < 0.02);
        assert!((c.get(1, 1) - 1.0).abs() < 0.02);
    }

    #[test]
    fn empirical_structure_matches_proof() {
        // The Thm 3 proof: Xhat = [[2, y_n], [y_n, 1]] in expectation
        // structure — diag entries are exactly 2 and 1 + o(1) since
        // (1+eps)^2 averages to 2 and eps^2 = 1 deterministically.
        let mut rng = Pcg64::new(4);
        let shard = Thm3Dist.sample_shard(&mut rng, 1000);
        let c = shard.empirical_covariance();
        // (1,1) entry is exactly 1: eps_2^2 = 1 always
        assert!((c.get(1, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn norm_bounds_hold() {
        let dists: Vec<Box<dyn Distribution>> = vec![
            Box::new(Thm3Dist),
            Box::new(Lemma8Dist::new(0.4)),
            Box::new(Thm5Dist::new(0.4)),
        ];
        let mut rng = Pcg64::new(5);
        let mut buf = [0.0; 2];
        for d in &dists {
            let b = d.norm_bound_sq();
            for _ in 0..5000 {
                d.sample_into(&mut rng, &mut buf);
                let nsq = buf[0] * buf[0] + buf[1] * buf[1];
                assert!(nsq <= b + 1e-12);
            }
        }
    }

    #[test]
    #[should_panic]
    fn zero_delta_rejected() {
        let _ = Thm5Dist::new(0.0);
    }
}
