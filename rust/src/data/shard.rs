//! One machine's local sample and its empirical-covariance kernels.
//!
//! A shard is the `n x d` row-major sample matrix `A`. The empirical
//! covariance is `Xhat = A^T A / n`; the two operations the paper's
//! communication model exposes are
//!
//! - `cov_matvec(v) = Xhat v = A^T (A v) / n` — computed *without*
//!   forming `Xhat` (O(nd) per product), and
//! - the local leading eigenvector (the machine's ERM solution).
//!
//! The Gram matrix is cached after first use (the one-shot estimators and
//! local eigensolves need it; the iterative algorithms never form it when
//! `n` is small relative to `d` — see [`Shard::prefer_gram`]).

use std::sync::OnceLock;

use crate::linalg::eigen::SymEigen;
use crate::linalg::Matrix;

/// Sign convention shared with [`SymEigen::leading`]: entry of largest
/// magnitude made positive.
fn canonical_sign(mut v: Vec<f64>) -> Vec<f64> {
    let mut imax = 0;
    for (i, x) in v.iter().enumerate() {
        if x.abs() > v[imax].abs() {
            imax = i;
        }
    }
    if v[imax] < 0.0 {
        for x in &mut v {
            *x = -*x;
        }
    }
    v
}

/// An `n x d` local dataset (row-major).
#[derive(Debug)]
pub struct Shard {
    rows: Matrix,
    gram: OnceLock<Matrix>,
}

impl Clone for Shard {
    fn clone(&self) -> Self {
        Shard { rows: self.rows.clone(), gram: OnceLock::new() }
    }
}

impl Shard {
    pub fn new(n: usize, d: usize, data: Vec<f64>) -> Shard {
        assert!(n > 0 && d > 0, "empty shard");
        Shard { rows: Matrix::from_vec(n, d, data), gram: OnceLock::new() }
    }

    pub fn from_matrix(rows: Matrix) -> Shard {
        Shard { rows, gram: OnceLock::new() }
    }

    /// Number of local samples `n`.
    pub fn n(&self) -> usize {
        self.rows.rows()
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        self.rows.cols()
    }

    /// Sample `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        self.rows.row(i)
    }

    /// The raw sample matrix.
    pub fn matrix(&self) -> &Matrix {
        &self.rows
    }

    /// Empirical covariance `Xhat_i = A^T A / n` (cached).
    pub fn empirical_covariance(&self) -> &Matrix {
        self.gram.get_or_init(|| {
            let mut g = self.rows.syrk_t();
            g.scale_mut(1.0 / self.n() as f64);
            g
        })
    }

    /// Whether the cached-Gram path is cheaper for repeated matvecs:
    /// forming `Xhat` costs `O(n d^2)` once and `O(d^2)` per product vs
    /// `O(n d)` per product streaming.
    pub fn prefer_gram(&self, expected_products: usize) -> bool {
        let (n, d) = (self.n() as f64, self.d() as f64);
        let stream = expected_products as f64 * 2.0 * n * d;
        let gram = n * d * d / 2.0 + expected_products as f64 * d * d;
        gram < stream
    }

    /// `Xhat v` streaming the rows: `A^T (A v) / n`, never forming `Xhat`.
    /// Allocation-free given a caller scratch buffer of length `n`.
    pub fn cov_matvec_into(&self, v: &[f64], scratch_n: &mut Vec<f64>, out: &mut [f64]) {
        let n = self.n();
        scratch_n.resize(n, 0.0);
        if let Some(g) = self.gram.get() {
            // Gram already materialized: O(d^2) product is cheaper.
            g.matvec_into(v, out);
            return;
        }
        self.rows.matvec_into(v, scratch_n);
        self.rows.matvec_t_into(scratch_n, out);
        let inv = 1.0 / n as f64;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    /// Convenience allocating form of [`Shard::cov_matvec_into`].
    pub fn cov_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut scratch = Vec::new();
        let mut out = vec![0.0; self.d()];
        self.cov_matvec_into(v, &mut scratch, &mut out);
        out
    }

    /// Blocked shard-level block product `Xhat V = A^T (A V) / n` for a
    /// `d x k` basis `V`, never forming `Xhat`. Both stages stream the
    /// rows of `A` once with a contiguous `k`-wide multiply-accumulate
    /// inner loop, so the whole block costs one pass over the shard per
    /// stage instead of `k` separate streaming matvecs — this is the
    /// worker-side kernel behind the cluster's one-round block protocol.
    /// Allocation-free given a caller scratch buffer (`n * k` doubles).
    pub fn cov_matmat_into(&self, v: &Matrix, scratch_nk: &mut Vec<f64>, out: &mut Matrix) {
        let (n, d) = (self.n(), self.d());
        assert_eq!(v.rows(), d, "cov_matmat: block must be d x k");
        let k = v.cols();
        assert_eq!(out.rows(), d, "cov_matmat: output must be d x k");
        assert_eq!(out.cols(), k, "cov_matmat: output must be d x k");
        if let Some(g) = self.gram.get() {
            // Gram already materialized: O(d^2 k) product is cheaper —
            // written straight into `out`, keeping the call allocation-free.
            out.data_mut().iter_mut().for_each(|x| *x = 0.0);
            for i in 0..d {
                let grow = g.row(i);
                let orow = &mut out.data_mut()[i * k..(i + 1) * k];
                for (c, &gv) in grow.iter().enumerate() {
                    if gv == 0.0 {
                        continue;
                    }
                    let vrow = v.row(c);
                    for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                        *o += gv * vv;
                    }
                }
            }
            return;
        }
        // stage 1: Y = A V (n x k), streaming A row by row
        scratch_nk.clear();
        scratch_nk.resize(n * k, 0.0);
        for r in 0..n {
            let arow = self.rows.row(r);
            let yrow = &mut scratch_nk[r * k..(r + 1) * k];
            for (c, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let vrow = v.row(c);
                for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
                    *y += a * vv;
                }
            }
        }
        // stage 2: out = A^T Y / n, streaming A again (axpy per row)
        out.data_mut().iter_mut().for_each(|x| *x = 0.0);
        for r in 0..n {
            let arow = self.rows.row(r);
            let yrow = &scratch_nk[r * k..(r + 1) * k];
            for (c, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data_mut()[c * k..(c + 1) * k];
                for (o, &y) in orow.iter_mut().zip(yrow.iter()) {
                    *o += a * y;
                }
            }
        }
        out.scale_mut(1.0 / n as f64);
    }

    /// Convenience allocating form of [`Shard::cov_matmat_into`].
    pub fn cov_matmat(&self, v: &Matrix) -> Matrix {
        let mut scratch = Vec::new();
        let mut out = Matrix::zeros(self.d(), v.cols());
        self.cov_matmat_into(v, &mut scratch, &mut out);
        out
    }

    /// Local ERM: eigendecomposition of the empirical covariance.
    pub fn local_eigen(&self) -> SymEigen {
        SymEigen::new(self.empirical_covariance())
    }

    /// Local leading eigenvector (deterministic sign; see
    /// [`SymEigen::leading`]).
    ///
    /// Perf (EXPERIMENTS.md §Perf): the one-shot estimators only need the
    /// *leading* pair, so this avoids the full `O(d^3)` eigensolve —
    /// analytic for `d = 2` (the lower-bound constructions), power
    /// iteration with a residual stop otherwise, falling back to the full
    /// solver only when the local gap is too small for power iteration to
    /// certify convergence.
    pub fn local_top_eigvec(&self) -> Vec<f64> {
        let g = self.empirical_covariance();
        let d = self.d();
        if d == 2 {
            let v = crate::linalg::eigen2x2::leading_eigvec_2x2(g.get(0, 0), g.get(0, 1), g.get(1, 1));
            return canonical_sign(vec![v[0], v[1]]);
        }
        // power iteration with Rayleigh-residual certification
        let mut w: Vec<f64> = (0..d).map(|i| 1.0 + (i as f64 * 0.7).sin() * 0.1).collect();
        crate::linalg::vec_ops::normalize(&mut w);
        let mut gw = vec![0.0; d];
        let max_iters = 40 * d.max(64);
        for it in 0..max_iters {
            g.matvec_into(&w, &mut gw);
            let rho = crate::linalg::vec_ops::dot(&w, &gw);
            // residual ||Gw - rho w||
            let mut res_sq = 0.0;
            for i in 0..d {
                let r = gw[i] - rho * w[i];
                res_sq += r * r;
            }
            let norm_gw = crate::linalg::vec_ops::normalize(&mut gw);
            if norm_gw == 0.0 {
                break; // zero matrix: any unit vector is fine
            }
            std::mem::swap(&mut w, &mut gw);
            if res_sq.sqrt() <= 1e-13 * rho.abs().max(1e-300) {
                return canonical_sign(w);
            }
            // plateau without certification (tiny gap): give up early and
            // use the exact solver rather than burning iterations
            if it == max_iters - 1 {
                break;
            }
        }
        self.local_eigen().leading()
    }

    /// Largest squared row norm — the empirical `b`.
    pub fn max_row_norm_sq(&self) -> f64 {
        (0..self.n())
            .map(|i| crate::linalg::vec_ops::dot(self.row(i), self.row(i)))
            .fold(0.0, f64::max)
    }

    /// Rescale all samples by `s` (used to normalize to `b = 1` for the
    /// Shift-and-Invert algorithm, which the paper assumes w.l.o.g.).
    pub fn rescaled(&self, s: f64) -> Shard {
        Shard::from_matrix(self.rows.scale(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{alignment_error, dot};
    use crate::rng::Pcg64;

    fn random_shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Pcg64::new(seed);
        Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn empirical_covariance_is_gram_over_n() {
        let s = random_shard(50, 7, 1);
        let g = s.empirical_covariance();
        // check one entry by hand
        let mut acc = 0.0;
        for i in 0..50 {
            acc += s.row(i)[2] * s.row(i)[4];
        }
        assert!((g.get(2, 4) - acc / 50.0).abs() < 1e-12);
    }

    #[test]
    fn cov_matvec_matches_explicit_gram() {
        let s = random_shard(40, 9, 2);
        let mut rng = Pcg64::new(3);
        let v = rng.gaussian_vec(9);
        let got = s.cov_matvec(&v);
        let want = s.empirical_covariance().matvec(&v);
        for i in 0..9 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn cov_matmat_matches_columnwise_matvec() {
        let s = random_shard(35, 7, 21);
        let mut rng = Pcg64::new(22);
        let k = 4;
        let v = crate::linalg::Matrix::from_vec(
            7,
            k,
            (0..7 * k).map(|_| rng.next_gaussian()).collect(),
        );
        let got = s.cov_matmat(&v);
        for c in 0..k {
            let want = s.cov_matvec(&v.col(c));
            for i in 0..7 {
                assert!((got.get(i, c) - want[i]).abs() < 1e-12, "col {c} row {i}");
            }
        }
    }

    #[test]
    fn cov_matmat_uses_cached_gram_consistently() {
        let s = random_shard(25, 5, 23);
        let cells: Vec<f64> = (0..10).map(|i| i as f64 * 0.3 - 1.0).collect();
        let v = crate::linalg::Matrix::from_vec(5, 2, cells);
        let before = s.cov_matmat(&v); // streaming path
        let _ = s.empirical_covariance(); // materialize the Gram
        let after = s.cov_matmat(&v); // gram path
        assert!(before.sub(&after).max_abs() < 1e-12);
    }

    #[test]
    fn cov_matmat_scratch_reuse_is_clean() {
        // reusing a dirty scratch buffer must not contaminate results
        let s = random_shard(20, 4, 24);
        let v = crate::linalg::Matrix::identity(4);
        let mut scratch = vec![999.0; 7]; // wrong size AND dirty
        let mut out = crate::linalg::Matrix::zeros(4, 4);
        s.cov_matmat_into(&v, &mut scratch, &mut out);
        assert!(out.sub(s.empirical_covariance()).max_abs() < 1e-12);
        // second call with the now-larger scratch
        let mut out2 = crate::linalg::Matrix::zeros(4, 4);
        s.cov_matmat_into(&v, &mut scratch, &mut out2);
        assert!(out2.sub(&out).max_abs() < 1e-15);
    }

    #[test]
    fn cov_matvec_uses_cached_gram_consistently() {
        let s = random_shard(30, 5, 4);
        let v = vec![1.0, -1.0, 0.5, 0.0, 2.0];
        let before = s.cov_matvec(&v); // streaming path
        let _ = s.empirical_covariance(); // materialize
        let after = s.cov_matvec(&v); // gram path
        for i in 0..5 {
            assert!((before[i] - after[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn local_top_eigvec_solves_erm() {
        let s = random_shard(200, 6, 5);
        let v = s.local_top_eigvec();
        let g = s.empirical_covariance();
        // Rayleigh quotient of v equals lambda_1
        let rq = dot(&v, &g.matvec(&v));
        let eig = s.local_eigen();
        assert!((rq - eig.lambda1()).abs() < 1e-9);
        assert!(alignment_error(&v, &eig.eigvec(0)) < 1e-16);
    }

    #[test]
    fn rescaled_scales_covariance_quadratically() {
        let s = random_shard(20, 4, 6);
        let s2 = s.rescaled(0.5);
        let g1 = s.empirical_covariance();
        let g2 = s2.empirical_covariance();
        assert!(g2.sub(&g1.scale(0.25)).max_abs() < 1e-12);
    }

    #[test]
    fn prefer_gram_crossover() {
        let s = random_shard(100, 10, 7);
        assert!(!s.prefer_gram(1)); // one product: streaming wins
        assert!(s.prefer_gram(1000)); // many products: gram wins
    }

    #[test]
    fn max_row_norm_sq_is_max() {
        let s = Shard::new(2, 2, vec![3.0, 4.0, 1.0, 0.0]);
        assert!((s.max_row_norm_sq() - 25.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn empty_shard_panics() {
        let _ = Shard::new(0, 3, vec![]);
    }
}
