//! One machine's local sample and its empirical-covariance kernels.
//!
//! A shard is the `n x d` sample matrix `A`, stored **dense** (row-major)
//! or **CSR sparse** (row pointers + column indices + values). The
//! empirical covariance is `Xhat = A^T A / n`; the two operations the
//! paper's communication model exposes are
//!
//! - `cov_matvec(v) = Xhat v = A^T (A v) / n` — computed *without*
//!   forming `Xhat` (O(nd) dense / O(nnz) sparse per product), and
//! - the local leading eigenvector (the machine's ERM solution).
//!
//! The Gram matrix is cached after first use (the one-shot estimators and
//! local eigensolves need it; the iterative algorithms only form it when
//! the [`Shard::prefer_gram`] cost model says repeated products amortize
//! the build — the oracle layer consults it, see
//! [`crate::cluster::NativeOracle`]).
//!
//! ## Threading and determinism
//!
//! `cov_matvec_into` / `cov_matmat_into` honor the process-global thread
//! budget ([`crate::linalg::compute_threads`], default 1); the
//! `*_into_threads` variants take the count explicitly (tests use these so
//! `cargo test` never mutates process globals). At `threads == 1` the
//! kernels are the exact scalar loops this repo has always had —
//! bit-identical to every prior release. At `threads > 1` rows are split
//! into contiguous panels, each thread accumulates a private `d x k`
//! partial, and partials are reduced **in panel order** — deterministic at
//! a fixed thread count, within ~1e-12 elementwise of the scalar result
//! across thread counts (floating-point reassociation only). Communication
//! bills never depend on the thread count: kernels change wall clock, not
//! rounds/messages/bytes.
//!
//! ## f32-accumulate fast path
//!
//! [`Shard::cov_matmat_f32`] is an explicit opt-in kernel that streams the
//! same fused product with `f32` accumulators. Per-entry absolute error vs
//! the f64 kernel is bounded by `gamma * (|A|^T (|A| |V|))_{ij} / n` with
//! `gamma = (2(n + d) + 8) * 2^-24` (standard dot-product forward error;
//! checked by the kernel-equivalence suite). It never consults the cached
//! Gram and is never used implicitly.

use std::fmt;
use std::sync::OnceLock;

use anyhow::{ensure, Result};

use crate::linalg::eigen::SymEigen;
use crate::linalg::threads::row_panels;
use crate::linalg::{vec_ops, Matrix};

/// Sign convention shared with [`SymEigen::leading`]: entry of largest
/// magnitude made positive.
fn canonical_sign(mut v: Vec<f64>) -> Vec<f64> {
    let mut imax = 0;
    for (i, x) in v.iter().enumerate() {
        if x.abs() > v[imax].abs() {
            imax = i;
        }
    }
    if v[imax] < 0.0 {
        for x in &mut v {
            *x = -*x;
        }
    }
    v
}

/// CSR storage: row `r` holds `indices[indptr[r]..indptr[r+1]]` (strictly
/// ascending column ids) with matching `values`.
#[derive(Clone)]
struct CsrData {
    n: usize,
    d: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrData {
    #[inline(always)]
    fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }
}

enum Store {
    Dense(Matrix),
    Csr(CsrData),
}

/// An `n x d` local dataset, dense or CSR sparse.
pub struct Shard {
    store: Store,
    gram: OnceLock<Matrix>,
}

impl fmt::Debug for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.store {
            Store::Dense(m) => write!(f, "Shard(dense {}x{})", m.rows(), m.cols()),
            Store::Csr(c) => write!(f, "Shard(csr {}x{}, nnz={})", c.n, c.d, c.values.len()),
        }
    }
}

impl Clone for Shard {
    fn clone(&self) -> Self {
        let store = match &self.store {
            Store::Dense(m) => Store::Dense(m.clone()),
            Store::Csr(c) => Store::Csr(c.clone()),
        };
        Shard { store, gram: OnceLock::new() }
    }
}

impl Shard {
    pub fn new(n: usize, d: usize, data: Vec<f64>) -> Shard {
        assert!(n > 0 && d > 0, "empty shard");
        Shard { store: Store::Dense(Matrix::from_vec(n, d, data)), gram: OnceLock::new() }
    }

    pub fn from_matrix(rows: Matrix) -> Shard {
        assert!(rows.rows() > 0 && rows.cols() > 0, "empty shard");
        Shard { store: Store::Dense(rows), gram: OnceLock::new() }
    }

    /// CSR constructor. Panics on malformed input (programmer error); the
    /// wire decoder uses [`Shard::try_from_csr`] instead.
    pub fn from_csr(
        n: usize,
        d: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Shard {
        Shard::try_from_csr(n, d, indptr, indices, values).expect("malformed CSR shard")
    }

    /// Validating CSR constructor: `indptr` must be a monotone `n + 1`
    /// prefix-sum ending at `nnz`, per-row column indices strictly
    /// ascending and `< d`.
    pub fn try_from_csr(
        n: usize,
        d: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Shard> {
        ensure!(n > 0 && d > 0, "empty shard");
        ensure!(indptr.len() == n + 1, "csr: indptr must have n+1 entries");
        ensure!(indptr[0] == 0, "csr: indptr must start at 0");
        ensure!(indices.len() == values.len(), "csr: indices/values length mismatch");
        ensure!(indptr[n] == values.len(), "csr: indptr must end at nnz");
        for r in 0..n {
            ensure!(indptr[r] <= indptr[r + 1], "csr: indptr must be monotone");
            let row = &indices[indptr[r]..indptr[r + 1]];
            for (i, &c) in row.iter().enumerate() {
                ensure!((c as usize) < d, "csr: column index {c} out of range (d={d})");
                ensure!(i == 0 || row[i - 1] < c, "csr: row {r} columns must be ascending");
            }
        }
        Ok(Shard {
            store: Store::Csr(CsrData { n, d, indptr, indices, values }),
            gram: OnceLock::new(),
        })
    }

    /// Number of local samples `n`.
    pub fn n(&self) -> usize {
        match &self.store {
            Store::Dense(m) => m.rows(),
            Store::Csr(c) => c.n,
        }
    }

    /// Dimension `d`.
    pub fn d(&self) -> usize {
        match &self.store {
            Store::Dense(m) => m.cols(),
            Store::Csr(c) => c.d,
        }
    }

    /// Stored non-zeros (`n * d` for dense).
    pub fn nnz(&self) -> usize {
        match &self.store {
            Store::Dense(m) => m.rows() * m.cols(),
            Store::Csr(c) => c.values.len(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self.store, Store::Csr(_))
    }

    /// Dense row-major view, if this shard is dense.
    pub fn try_dense(&self) -> Option<&Matrix> {
        match &self.store {
            Store::Dense(m) => Some(m),
            Store::Csr(_) => None,
        }
    }

    /// CSR view `(indptr, indices, values)`, if this shard is sparse.
    pub fn csr_parts(&self) -> Option<(&[usize], &[u32], &[f64])> {
        match &self.store {
            Store::Dense(_) => None,
            Store::Csr(c) => Some((&c.indptr, &c.indices, &c.values)),
        }
    }

    /// Sample `i` as a slice. Dense shards only — sparse callers use
    /// [`Shard::row_dot`] / [`Shard::row_axpy`].
    pub fn row(&self, i: usize) -> &[f64] {
        self.try_dense()
            .expect("Shard::row: sparse shard has no dense rows; use row_dot/row_axpy")
            .row(i)
    }

    /// The raw sample matrix. Dense shards only.
    pub fn matrix(&self) -> &Matrix {
        self.try_dense()
            .expect("Shard::matrix: sparse shard has no dense matrix; use csr_parts()")
    }

    /// `x_i . w` for sample `i` — works on both stores.
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        match &self.store {
            Store::Dense(m) => vec_ops::dot(m.row(i), w),
            Store::Csr(c) => {
                let (idx, vals) = c.row(i);
                let mut acc = 0.0;
                for (&col, &a) in idx.iter().zip(vals.iter()) {
                    acc += a * w[col as usize];
                }
                acc
            }
        }
    }

    /// `out += s * x_i` for sample `i` — works on both stores.
    pub fn row_axpy(&self, i: usize, s: f64, out: &mut [f64]) {
        match &self.store {
            Store::Dense(m) => vec_ops::axpy(out, s, m.row(i)),
            Store::Csr(c) => {
                let (idx, vals) = c.row(i);
                for (&col, &a) in idx.iter().zip(vals.iter()) {
                    out[col as usize] += s * a;
                }
            }
        }
    }

    /// `target += x_i x_i^T` for sample `i` — works on both stores.
    /// `target` must be `d x d`.
    pub fn add_row_outer(&self, i: usize, target: &mut Matrix) {
        let d = self.d();
        assert!(target.rows() == d && target.cols() == d, "add_row_outer: target must be d x d");
        match &self.store {
            Store::Dense(m) => {
                let x = m.row(i);
                for (ci, &a) in x.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let trow = target.row_mut(ci);
                    for (t, &b) in trow.iter_mut().zip(x.iter()) {
                        *t += a * b;
                    }
                }
            }
            Store::Csr(c) => {
                let (idx, vals) = c.row(i);
                for (&ci, &a) in idx.iter().zip(vals.iter()) {
                    let trow = target.row_mut(ci as usize);
                    for (&cj, &b) in idx.iter().zip(vals.iter()) {
                        trow[cj as usize] += a * b;
                    }
                }
            }
        }
    }

    /// Whether the Gram has already been materialized (cached).
    pub fn gram_ready(&self) -> bool {
        self.gram.get().is_some()
    }

    /// Empirical covariance `Xhat_i = A^T A / n` (cached).
    pub fn empirical_covariance(&self) -> &Matrix {
        self.gram.get_or_init(|| {
            let n = self.n();
            let mut g = match &self.store {
                Store::Dense(m) => m.syrk_t(),
                Store::Csr(c) => {
                    let d = c.d;
                    let mut g = Matrix::zeros(d, d);
                    for r in 0..c.n {
                        let (idx, vals) = c.row(r);
                        // ascending indices: inner j >= i stays in the
                        // upper triangle, mirrored below
                        for (ii, (&ci, &a)) in idx.iter().zip(vals.iter()).enumerate() {
                            let grow = g.row_mut(ci as usize);
                            for (&cj, &b) in idx[ii..].iter().zip(vals[ii..].iter()) {
                                grow[cj as usize] += a * b;
                            }
                        }
                    }
                    for i in 0..d {
                        for j in (i + 1)..d {
                            let v = g.get(i, j);
                            g.set(j, i, v);
                        }
                    }
                    g
                }
            };
            g.scale_mut(1.0 / n as f64);
            g
        })
    }

    /// Whether the cached-Gram path is cheaper for `expected_products`
    /// repeated matvecs: forming `Xhat` costs the one-time build (dense
    /// `n d^2 / 2`, CSR `sum_r nnz_r^2 / 2`) plus `O(d^2)` per product,
    /// vs `O(nd)` (dense) / `O(nnz)` (sparse) per streamed product.
    pub fn prefer_gram(&self, expected_products: usize) -> bool {
        let d = self.d() as f64;
        let p = expected_products as f64;
        let (build, stream_per) = match &self.store {
            Store::Dense(m) => {
                let n = m.rows() as f64;
                (n * d * d / 2.0, 2.0 * n * d)
            }
            Store::Csr(c) => {
                let mut build = 0.0;
                for r in 0..c.n {
                    let len = (c.indptr[r + 1] - c.indptr[r]) as f64;
                    build += len * len;
                }
                (build / 2.0, 2.0 * c.values.len() as f64)
            }
        };
        build + p * d * d < p * stream_per
    }

    /// `Xhat v` without forming `Xhat`: dense shards stream
    /// `A^T (A v) / n`, CSR shards stream the non-zeros once. Uses the
    /// cached Gram when already materialized (`O(d^2)` is then cheaper).
    /// Allocation-free given a caller scratch buffer; the scratch is only
    /// touched on the dense single-threaded streaming path.
    pub fn cov_matvec_into(&self, v: &[f64], scratch_n: &mut Vec<f64>, out: &mut [f64]) {
        self.cov_matvec_into_threads(v, scratch_n, out, crate::linalg::compute_threads());
    }

    /// [`Shard::cov_matvec_into`] with an explicit thread count.
    /// `threads == 1` is the exact scalar kernel (bit-identical to the
    /// historical implementation); `threads > 1` fuses both stages over
    /// row panels with per-thread `d`-vector partials reduced in panel
    /// order.
    pub fn cov_matvec_into_threads(
        &self,
        v: &[f64],
        scratch_n: &mut Vec<f64>,
        out: &mut [f64],
        threads: usize,
    ) {
        let (n, d) = (self.n(), self.d());
        assert_eq!(v.len(), d, "cov_matvec: dim mismatch");
        assert_eq!(out.len(), d, "cov_matvec: output dim mismatch");
        if let Some(g) = self.gram.get() {
            // Gram already materialized: O(d^2) product is cheaper. The
            // scratch buffer is deliberately untouched on this path.
            g.matvec_into(v, out);
            return;
        }
        let inv = 1.0 / n as f64;
        match (&self.store, threads <= 1 || n == 1) {
            (Store::Dense(rows), true) => {
                scratch_n.resize(n, 0.0);
                rows.matvec_into(v, scratch_n);
                rows.matvec_t_into(scratch_n, out);
                for o in out.iter_mut() {
                    *o *= inv;
                }
            }
            (Store::Dense(rows), false) => {
                let partials = map_panels(n, threads, |r0, r1| {
                    let mut partial = vec![0.0; d];
                    for r in r0..r1 {
                        let arow = rows.row(r);
                        let y = vec_ops::dot(arow, v);
                        if y != 0.0 {
                            vec_ops::axpy(&mut partial, y, arow);
                        }
                    }
                    partial
                });
                reduce_partials(&partials, out, inv);
            }
            (Store::Csr(c), true) => {
                out.iter_mut().for_each(|x| *x = 0.0);
                for r in 0..n {
                    let (idx, vals) = c.row(r);
                    let mut y = 0.0;
                    for (&col, &a) in idx.iter().zip(vals.iter()) {
                        y += a * v[col as usize];
                    }
                    if y != 0.0 {
                        for (&col, &a) in idx.iter().zip(vals.iter()) {
                            out[col as usize] += y * a;
                        }
                    }
                }
                for o in out.iter_mut() {
                    *o *= inv;
                }
            }
            (Store::Csr(c), false) => {
                let partials = map_panels(n, threads, |r0, r1| {
                    let mut partial = vec![0.0; d];
                    for r in r0..r1 {
                        let (idx, vals) = c.row(r);
                        let mut y = 0.0;
                        for (&col, &a) in idx.iter().zip(vals.iter()) {
                            y += a * v[col as usize];
                        }
                        if y != 0.0 {
                            for (&col, &a) in idx.iter().zip(vals.iter()) {
                                partial[col as usize] += y * a;
                            }
                        }
                    }
                    partial
                });
                reduce_partials(&partials, out, inv);
            }
        }
    }

    /// Convenience allocating form of [`Shard::cov_matvec_into`].
    pub fn cov_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut scratch = Vec::new();
        let mut out = vec![0.0; self.d()];
        self.cov_matvec_into(v, &mut scratch, &mut out);
        out
    }

    /// Blocked shard-level block product `Xhat V = A^T (A V) / n` for a
    /// `d x k` basis `V`, never forming `Xhat`. The single-threaded dense
    /// kernel streams the rows of `A` once per stage with a contiguous
    /// `k`-wide multiply-accumulate inner loop; the threaded kernel fuses
    /// both stages over row panels (per-thread `d x k` partials, reduced
    /// in panel order); CSR shards stream non-zeros. This is the
    /// worker-side kernel behind the cluster's one-round block protocol.
    /// Allocation-free given a caller scratch buffer (`n * k` doubles;
    /// only touched on the dense single-threaded path).
    pub fn cov_matmat_into(&self, v: &Matrix, scratch_nk: &mut Vec<f64>, out: &mut Matrix) {
        self.cov_matmat_into_threads(v, scratch_nk, out, crate::linalg::compute_threads());
    }

    /// [`Shard::cov_matmat_into`] with an explicit thread count.
    /// `threads == 1` is the exact scalar kernel (bit-identical to the
    /// historical implementation).
    pub fn cov_matmat_into_threads(
        &self,
        v: &Matrix,
        scratch_nk: &mut Vec<f64>,
        out: &mut Matrix,
        threads: usize,
    ) {
        let (n, d) = (self.n(), self.d());
        assert_eq!(v.rows(), d, "cov_matmat: block must be d x k");
        let k = v.cols();
        assert_eq!(out.rows(), d, "cov_matmat: output must be d x k");
        assert_eq!(out.cols(), k, "cov_matmat: output must be d x k");
        if let Some(g) = self.gram.get() {
            // Gram already materialized: O(d^2 k) product is cheaper —
            // written straight into `out`, keeping the call allocation-free.
            out.data_mut().iter_mut().for_each(|x| *x = 0.0);
            for i in 0..d {
                let grow = g.row(i);
                let orow = &mut out.data_mut()[i * k..(i + 1) * k];
                for (c, &gv) in grow.iter().enumerate() {
                    if gv == 0.0 {
                        continue;
                    }
                    let vrow = v.row(c);
                    for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                        *o += gv * vv;
                    }
                }
            }
            return;
        }
        let inv = 1.0 / n as f64;
        match (&self.store, threads <= 1 || n == 1) {
            (Store::Dense(rows), true) => {
                // stage 1: Y = A V (n x k), streaming A row by row
                scratch_nk.clear();
                scratch_nk.resize(n * k, 0.0);
                for r in 0..n {
                    let arow = rows.row(r);
                    let yrow = &mut scratch_nk[r * k..(r + 1) * k];
                    for (c, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let vrow = v.row(c);
                        for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
                            *y += a * vv;
                        }
                    }
                }
                // stage 2: out = A^T Y / n, streaming A again (axpy per row)
                out.data_mut().iter_mut().for_each(|x| *x = 0.0);
                for r in 0..n {
                    let arow = rows.row(r);
                    let yrow = &scratch_nk[r * k..(r + 1) * k];
                    for (c, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let orow = &mut out.data_mut()[c * k..(c + 1) * k];
                        for (o, &y) in orow.iter_mut().zip(yrow.iter()) {
                            *o += a * y;
                        }
                    }
                }
                out.scale_mut(inv);
            }
            (Store::Dense(rows), false) => {
                let partials = map_panels(n, threads, |r0, r1| {
                    let mut partial = vec![0.0; d * k];
                    let mut yrow = vec![0.0; k];
                    for r in r0..r1 {
                        let arow = rows.row(r);
                        yrow.iter_mut().for_each(|y| *y = 0.0);
                        for (c, &a) in arow.iter().enumerate() {
                            if a == 0.0 {
                                continue;
                            }
                            let vrow = v.row(c);
                            for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
                                *y += a * vv;
                            }
                        }
                        for (c, &a) in arow.iter().enumerate() {
                            if a == 0.0 {
                                continue;
                            }
                            let prow = &mut partial[c * k..(c + 1) * k];
                            for (p, &y) in prow.iter_mut().zip(yrow.iter()) {
                                *p += a * y;
                            }
                        }
                    }
                    partial
                });
                reduce_partials(&partials, out.data_mut(), inv);
            }
            (Store::Csr(c), true) => {
                out.data_mut().iter_mut().for_each(|x| *x = 0.0);
                let mut yrow = vec![0.0; k];
                for r in 0..n {
                    let (idx, vals) = c.row(r);
                    stream_csr_row_matmat(idx, vals, v, &mut yrow, out.data_mut(), k);
                }
                out.scale_mut(inv);
            }
            (Store::Csr(c), false) => {
                let partials = map_panels(n, threads, |r0, r1| {
                    let mut partial = vec![0.0; d * k];
                    let mut yrow = vec![0.0; k];
                    for r in r0..r1 {
                        let (idx, vals) = c.row(r);
                        stream_csr_row_matmat(idx, vals, v, &mut yrow, &mut partial, k);
                    }
                    partial
                });
                reduce_partials(&partials, out.data_mut(), inv);
            }
        }
    }

    /// Convenience allocating form of [`Shard::cov_matmat_into`].
    pub fn cov_matmat(&self, v: &Matrix) -> Matrix {
        let mut scratch = Vec::new();
        let mut out = Matrix::zeros(self.d(), v.cols());
        self.cov_matmat_into(v, &mut scratch, &mut out);
        out
    }

    /// Explicit opt-in f32-accumulate block product: the fused streaming
    /// kernel with `f32` accumulators (inputs cast once). Per-entry
    /// absolute error vs [`Shard::cov_matmat`] is bounded by
    /// `gamma * (|A|^T (|A| |V|))_{ij} / n` with
    /// `gamma = (2(n + d) + 8) * 2^-24` — see the module docs. Never uses
    /// the cached Gram; never used implicitly by the oracle layer.
    pub fn cov_matmat_f32(&self, v: &Matrix) -> Matrix {
        let (n, d) = (self.n(), self.d());
        assert_eq!(v.rows(), d, "cov_matmat_f32: block must be d x k");
        let k = v.cols();
        let vf: Vec<f32> = v.data().iter().map(|&x| x as f32).collect();
        let mut acc = vec![0.0f32; d * k];
        let mut yrow = vec![0.0f32; k];
        for r in 0..n {
            yrow.iter_mut().for_each(|y| *y = 0.0);
            match &self.store {
                Store::Dense(m) => {
                    let arow = m.row(r);
                    for (c, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let a32 = a as f32;
                        let vrow = &vf[c * k..(c + 1) * k];
                        for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
                            *y += a32 * vv;
                        }
                    }
                    for (c, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let a32 = a as f32;
                        let prow = &mut acc[c * k..(c + 1) * k];
                        for (p, &y) in prow.iter_mut().zip(yrow.iter()) {
                            *p += a32 * y;
                        }
                    }
                }
                Store::Csr(c) => {
                    let (idx, vals) = c.row(r);
                    for (&col, &a) in idx.iter().zip(vals.iter()) {
                        let a32 = a as f32;
                        let vrow = &vf[col as usize * k..(col as usize + 1) * k];
                        for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
                            *y += a32 * vv;
                        }
                    }
                    for (&col, &a) in idx.iter().zip(vals.iter()) {
                        let a32 = a as f32;
                        let prow = &mut acc[col as usize * k..(col as usize + 1) * k];
                        for (p, &y) in prow.iter_mut().zip(yrow.iter()) {
                            *p += a32 * y;
                        }
                    }
                }
            }
        }
        let inv = 1.0 / n as f64;
        Matrix::from_vec(d, k, acc.iter().map(|&x| x as f64 * inv).collect())
    }

    /// Local ERM: eigendecomposition of the empirical covariance.
    pub fn local_eigen(&self) -> SymEigen {
        SymEigen::new(self.empirical_covariance())
    }

    /// Local leading eigenvector (deterministic sign; see
    /// [`SymEigen::leading`]).
    ///
    /// Perf (EXPERIMENTS.md §Perf): the one-shot estimators only need the
    /// *leading* pair, so this avoids the full `O(d^3)` eigensolve —
    /// analytic for `d = 2` (the lower-bound constructions), power
    /// iteration with a residual stop otherwise, falling back to the full
    /// solver only when the local gap is too small for power iteration to
    /// certify convergence.
    pub fn local_top_eigvec(&self) -> Vec<f64> {
        let g = self.empirical_covariance();
        let d = self.d();
        if d == 2 {
            let v = crate::linalg::eigen2x2::leading_eigvec_2x2(g.get(0, 0), g.get(0, 1), g.get(1, 1));
            return canonical_sign(vec![v[0], v[1]]);
        }
        // power iteration with Rayleigh-residual certification
        let mut w: Vec<f64> = (0..d).map(|i| 1.0 + (i as f64 * 0.7).sin() * 0.1).collect();
        crate::linalg::vec_ops::normalize(&mut w);
        let mut gw = vec![0.0; d];
        let max_iters = 40 * d.max(64);
        for it in 0..max_iters {
            g.matvec_into(&w, &mut gw);
            let rho = crate::linalg::vec_ops::dot(&w, &gw);
            // residual ||Gw - rho w||
            let mut res_sq = 0.0;
            for i in 0..d {
                let r = gw[i] - rho * w[i];
                res_sq += r * r;
            }
            let norm_gw = crate::linalg::vec_ops::normalize(&mut gw);
            if norm_gw == 0.0 {
                break; // zero matrix: any unit vector is fine
            }
            std::mem::swap(&mut w, &mut gw);
            if res_sq.sqrt() <= 1e-13 * rho.abs().max(1e-300) {
                return canonical_sign(w);
            }
            // plateau without certification (tiny gap): give up early and
            // use the exact solver rather than burning iterations
            if it == max_iters - 1 {
                break;
            }
        }
        self.local_eigen().leading()
    }

    /// Largest squared row norm — the empirical `b`.
    pub fn max_row_norm_sq(&self) -> f64 {
        match &self.store {
            Store::Dense(m) => (0..m.rows())
                .map(|i| vec_ops::dot(m.row(i), m.row(i)))
                .fold(0.0, f64::max),
            Store::Csr(c) => (0..c.n)
                .map(|r| {
                    let (_, vals) = c.row(r);
                    vals.iter().map(|a| a * a).sum::<f64>()
                })
                .fold(0.0, f64::max),
        }
    }

    /// Rescale all samples by `s` (used to normalize to `b = 1` for the
    /// Shift-and-Invert algorithm, which the paper assumes w.l.o.g.).
    /// Preserves the storage format.
    pub fn rescaled(&self, s: f64) -> Shard {
        match &self.store {
            Store::Dense(m) => Shard::from_matrix(m.scale(s)),
            Store::Csr(c) => {
                let mut scaled = c.clone();
                for v in &mut scaled.values {
                    *v *= s;
                }
                Shard { store: Store::Csr(scaled), gram: OnceLock::new() }
            }
        }
    }
}

/// Run `work(r0, r1)` over contiguous row panels on `threads` scoped
/// threads; returns the per-panel results **in panel order**.
fn map_panels<T, F>(total_rows: usize, threads: usize, work: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let panels = row_panels(total_rows, threads);
    if panels.len() == 1 {
        return vec![work(0, total_rows)];
    }
    let work = &work;
    std::thread::scope(|s| {
        let handles: Vec<_> =
            panels.iter().map(|&(r0, r1)| s.spawn(move || work(r0, r1))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard kernel panel thread panicked"))
            .collect()
    })
}

/// Sum per-panel partials into `out` (zeroed first) in panel order, then
/// scale by `inv` — the deterministic reduction shared by the threaded
/// kernels.
fn reduce_partials(partials: &[Vec<f64>], out: &mut [f64], inv: f64) {
    out.iter_mut().for_each(|x| *x = 0.0);
    for partial in partials {
        for (o, &p) in out.iter_mut().zip(partial.iter()) {
            *o += p;
        }
    }
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// One CSR row of the fused block kernel: `yrow = x_r^T V`, then
/// `acc += x_r yrow` (rank-1 update on the touched coordinates only).
#[inline(always)]
fn stream_csr_row_matmat(
    idx: &[u32],
    vals: &[f64],
    v: &Matrix,
    yrow: &mut [f64],
    acc: &mut [f64],
    k: usize,
) {
    yrow.iter_mut().for_each(|y| *y = 0.0);
    for (&col, &a) in idx.iter().zip(vals.iter()) {
        let vrow = v.row(col as usize);
        for (y, &vv) in yrow.iter_mut().zip(vrow.iter()) {
            *y += a * vv;
        }
    }
    for (&col, &a) in idx.iter().zip(vals.iter()) {
        let arow = &mut acc[col as usize * k..(col as usize + 1) * k];
        for (o, &y) in arow.iter_mut().zip(yrow.iter()) {
            *o += a * y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{alignment_error, dot};
    use crate::rng::Pcg64;

    fn random_shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Pcg64::new(seed);
        Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect())
    }

    /// A CSR shard plus the equivalent dense shard, ~`density` fill.
    fn random_csr_pair(n: usize, d: usize, density: f64, seed: u64) -> (Shard, Shard) {
        let mut rng = Pcg64::new(seed);
        let mut dense = vec![0.0; n * d];
        let (mut indptr, mut indices, mut values) = (vec![0usize], Vec::new(), Vec::new());
        for r in 0..n {
            for c in 0..d {
                // guarantee at least one entry on the diagonal band so no
                // row is empty-by-chance in tiny tests
                if rng.next_f64() < density || c == r % d {
                    let x = rng.next_gaussian();
                    dense[r * d + c] = x;
                    indices.push(c as u32);
                    values.push(x);
                }
            }
            indptr.push(values.len());
        }
        (Shard::new(n, d, dense), Shard::from_csr(n, d, indptr, indices, values))
    }

    #[test]
    fn empirical_covariance_is_gram_over_n() {
        let s = random_shard(50, 7, 1);
        let g = s.empirical_covariance();
        // check one entry by hand
        let mut acc = 0.0;
        for i in 0..50 {
            acc += s.row(i)[2] * s.row(i)[4];
        }
        assert!((g.get(2, 4) - acc / 50.0).abs() < 1e-12);
    }

    #[test]
    fn cov_matvec_matches_explicit_gram() {
        let s = random_shard(40, 9, 2);
        let mut rng = Pcg64::new(3);
        let v = rng.gaussian_vec(9);
        let got = s.cov_matvec(&v);
        let want = s.empirical_covariance().matvec(&v);
        for i in 0..9 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn cov_matmat_matches_columnwise_matvec() {
        let s = random_shard(35, 7, 21);
        let mut rng = Pcg64::new(22);
        let k = 4;
        let v = crate::linalg::Matrix::from_vec(
            7,
            k,
            (0..7 * k).map(|_| rng.next_gaussian()).collect(),
        );
        let got = s.cov_matmat(&v);
        for c in 0..k {
            let want = s.cov_matvec(&v.col(c));
            for i in 0..7 {
                assert!((got.get(i, c) - want[i]).abs() < 1e-12, "col {c} row {i}");
            }
        }
    }

    #[test]
    fn cov_matmat_uses_cached_gram_consistently() {
        let s = random_shard(25, 5, 23);
        let cells: Vec<f64> = (0..10).map(|i| i as f64 * 0.3 - 1.0).collect();
        let v = crate::linalg::Matrix::from_vec(5, 2, cells);
        let before = s.cov_matmat(&v); // streaming path
        let _ = s.empirical_covariance(); // materialize the Gram
        let after = s.cov_matmat(&v); // gram path
        assert!(before.sub(&after).max_abs() < 1e-12);
    }

    #[test]
    fn cov_matmat_scratch_reuse_is_clean() {
        // reusing a dirty scratch buffer must not contaminate results
        let s = random_shard(20, 4, 24);
        let v = crate::linalg::Matrix::identity(4);
        let mut scratch = vec![999.0; 7]; // wrong size AND dirty
        let mut out = crate::linalg::Matrix::zeros(4, 4);
        s.cov_matmat_into(&v, &mut scratch, &mut out);
        assert!(out.sub(s.empirical_covariance()).max_abs() < 1e-12);
        // second call with the now-larger scratch
        let mut out2 = crate::linalg::Matrix::zeros(4, 4);
        s.cov_matmat_into(&v, &mut scratch, &mut out2);
        assert!(out2.sub(&out).max_abs() < 1e-15);
    }

    #[test]
    fn cov_matvec_uses_cached_gram_consistently() {
        let s = random_shard(30, 5, 4);
        let v = vec![1.0, -1.0, 0.5, 0.0, 2.0];
        let before = s.cov_matvec(&v); // streaming path
        let _ = s.empirical_covariance(); // materialize
        let after = s.cov_matvec(&v); // gram path
        for i in 0..5 {
            assert!((before[i] - after[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn gram_path_matvec_leaves_scratch_untouched() {
        // regression (ISSUE 6): the n-length scratch used to be resized
        // *before* the cached-Gram check — a wasted alloc/touch per call
        let s = random_shard(30, 5, 40);
        let _ = s.empirical_covariance(); // materialize
        let v = vec![1.0; 5];
        let mut scratch: Vec<f64> = Vec::new();
        let mut out = vec![0.0; 5];
        s.cov_matvec_into(&v, &mut scratch, &mut out);
        assert!(scratch.is_empty(), "gram path must not touch the scratch buffer");
    }

    #[test]
    fn threaded_cov_kernels_match_scalar() {
        let s = random_shard(67, 9, 41);
        let mut rng = Pcg64::new(42);
        let v = rng.gaussian_vec(9);
        let block =
            crate::linalg::Matrix::from_vec(9, 3, (0..27).map(|_| rng.next_gaussian()).collect());
        let mut scratch = Vec::new();
        let mut want_v = vec![0.0; 9];
        s.cov_matvec_into_threads(&v, &mut scratch, &mut want_v, 1);
        let mut want_m = crate::linalg::Matrix::zeros(9, 3);
        s.cov_matmat_into_threads(&block, &mut scratch, &mut want_m, 1);
        for t in [2, 4, 8] {
            let mut got_v = vec![0.0; 9];
            s.cov_matvec_into_threads(&v, &mut scratch, &mut got_v, t);
            for i in 0..9 {
                assert!((got_v[i] - want_v[i]).abs() < 1e-12, "matvec t={t} i={i}");
            }
            let mut got_m = crate::linalg::Matrix::zeros(9, 3);
            s.cov_matmat_into_threads(&block, &mut scratch, &mut got_m, t);
            assert!(got_m.sub(&want_m).max_abs() < 1e-12, "matmat t={t}");
        }
    }

    #[test]
    fn csr_shard_matches_dense_on_core_kernels() {
        let (dense, csr) = random_csr_pair(30, 8, 0.3, 43);
        assert!(csr.is_sparse() && !dense.is_sparse());
        assert_eq!(csr.n(), 30);
        assert_eq!(csr.d(), 8);
        assert!(csr.nnz() < dense.nnz());
        let mut rng = Pcg64::new(44);
        let v = rng.gaussian_vec(8);
        let got = csr.cov_matvec(&v);
        let want = dense.cov_matvec(&v);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
        assert!(
            csr.empirical_covariance().sub(dense.empirical_covariance()).max_abs() < 1e-12
        );
        assert!((csr.max_row_norm_sq() - dense.max_row_norm_sq()).abs() < 1e-12);
        for i in [0usize, 7, 29] {
            assert!((csr.row_dot(i, &v) - dense.row_dot(i, &v)).abs() < 1e-12);
            let mut a = vec![1.0; 8];
            let mut b = vec![1.0; 8];
            csr.row_axpy(i, 0.5, &mut a);
            dense.row_axpy(i, 0.5, &mut b);
            for j in 0..8 {
                assert!((a[j] - b[j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csr_rescaled_scales_covariance_quadratically() {
        let (_, csr) = random_csr_pair(20, 6, 0.4, 45);
        let csr2 = csr.rescaled(0.5);
        assert!(csr2.is_sparse());
        let g1 = csr.empirical_covariance();
        let g2 = csr2.empirical_covariance();
        assert!(g2.sub(&g1.scale(0.25)).max_abs() < 1e-12);
    }

    #[test]
    fn add_row_outer_matches_gram_accumulation() {
        let (dense, csr) = random_csr_pair(10, 5, 0.5, 46);
        for shard in [&dense, &csr] {
            let mut acc = crate::linalg::Matrix::zeros(5, 5);
            for i in 0..10 {
                shard.add_row_outer(i, &mut acc);
            }
            acc.scale_mut(1.0 / 10.0);
            assert!(acc.sub(shard.empirical_covariance()).max_abs() < 1e-12);
        }
    }

    #[test]
    fn try_from_csr_rejects_malformed_input() {
        // bad indptr tail
        assert!(Shard::try_from_csr(2, 3, vec![0, 1, 3], vec![0, 1], vec![1.0, 2.0]).is_err());
        // out-of-range column
        assert!(Shard::try_from_csr(1, 3, vec![0, 1], vec![3], vec![1.0]).is_err());
        // non-ascending columns within a row
        assert!(
            Shard::try_from_csr(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 2.0]).is_err()
        );
        // non-monotone indptr
        assert!(
            Shard::try_from_csr(2, 3, vec![0, 2, 1], vec![0, 1, 2], vec![1.0; 3]).is_err()
        );
        // valid
        assert!(Shard::try_from_csr(2, 3, vec![0, 1, 2], vec![2, 0], vec![1.0, 2.0]).is_ok());
    }

    #[test]
    fn cov_matmat_f32_within_documented_bound() {
        let s = random_shard(60, 7, 47);
        let mut rng = Pcg64::new(48);
        let v = crate::linalg::Matrix::from_vec(
            7,
            3,
            (0..21).map(|_| rng.next_gaussian()).collect(),
        );
        let exact = s.cov_matmat(&v);
        let fast = s.cov_matmat_f32(&v);
        // bound: gamma * |A|^T (|A| |V|) / n, via the same kernel on abs values
        let abs_shard =
            Shard::new(60, 7, s.matrix().data().iter().map(|x| x.abs()).collect());
        let abs_v =
            crate::linalg::Matrix::from_vec(7, 3, v.data().iter().map(|x| x.abs()).collect());
        let bound = abs_shard.cov_matmat(&abs_v);
        let gamma = (2.0 * (60.0 + 7.0) + 8.0) * 2f64.powi(-24);
        for i in 0..7 {
            for c in 0..3 {
                let err = (fast.get(i, c) - exact.get(i, c)).abs();
                assert!(
                    err <= gamma * bound.get(i, c) + 1e-12,
                    "f32 error {err:.3e} exceeds bound at ({i},{c})"
                );
            }
        }
    }

    #[test]
    fn local_top_eigvec_solves_erm() {
        let s = random_shard(200, 6, 5);
        let v = s.local_top_eigvec();
        let g = s.empirical_covariance();
        // Rayleigh quotient of v equals lambda_1
        let rq = dot(&v, &g.matvec(&v));
        let eig = s.local_eigen();
        assert!((rq - eig.lambda1()).abs() < 1e-9);
        assert!(alignment_error(&v, &eig.eigvec(0)) < 1e-16);
    }

    #[test]
    fn rescaled_scales_covariance_quadratically() {
        let s = random_shard(20, 4, 6);
        let s2 = s.rescaled(0.5);
        let g1 = s.empirical_covariance();
        let g2 = s2.empirical_covariance();
        assert!(g2.sub(&g1.scale(0.25)).max_abs() < 1e-12);
    }

    #[test]
    fn prefer_gram_crossover() {
        let s = random_shard(100, 10, 7);
        assert!(!s.prefer_gram(1)); // one product: streaming wins
        assert!(s.prefer_gram(1000)); // many products: gram wins
    }

    #[test]
    fn prefer_gram_sparse_accounts_for_nnz() {
        // very sparse wide shard: streaming O(nnz) beats the dense d^2
        // gram product even for many repeated matvecs
        let (_, csr) = random_csr_pair(50, 40, 0.05, 49);
        assert!(!csr.prefer_gram(1));
        assert!(!csr.prefer_gram(100_000));
        // dense-ish sparse storage on a small d behaves like dense
        let (_, csr2) = random_csr_pair(200, 6, 0.9, 50);
        assert!(csr2.prefer_gram(1000));
    }

    #[test]
    fn max_row_norm_sq_is_max() {
        let s = Shard::new(2, 2, vec![3.0, 4.0, 1.0, 0.0]);
        assert!((s.max_row_norm_sq() - 25.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn empty_shard_panics() {
        let _ = Shard::new(0, 3, vec![]);
    }

    #[test]
    #[should_panic]
    fn sparse_shard_dense_row_access_panics() {
        let s = Shard::from_csr(1, 2, vec![0, 1], vec![0], vec![1.0]);
        let _ = s.row(0);
    }
}
