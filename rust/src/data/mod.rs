//! Data layer: the paper's synthetic distributions and per-machine shards.
//!
//! - [`Distribution`] — trait for i.i.d. samplers with known population
//!   covariance structure (`v1`, eigengap `delta`, norm bound `b`).
//! - [`CovModel`] — the §5 experimental covariance model
//!   `X = U Sigma U^T` with `Sigma = diag(1, 0.8, 0.8*0.9, ...)`, plus its
//!   gaussian and scaled-uniform samplers (left/right panes of Figure 1).
//! - [`SparseDiag`] — axis-aligned sparse sampler (coordinates kept with
//!   probability `density`) whose shards are CSR; the workload the sparse
//!   shard kernels target.
//! - [`Thm3Dist`] / [`Thm5Dist`] — the lower-bound constructions from the
//!   appendix (naive-averaging failure; sign-fixing bias).
//! - [`Shard`] — one machine's `n x d` sample with its empirical
//!   covariance kernels (the objects the cluster workers own).

mod cov_model;
mod lower_bounds;
mod shard;
mod sparse;

pub use cov_model::{fig1_spectrum, CovModel, GaussianCov, ScaledUniformCov};
pub use lower_bounds::{Lemma8Dist, Thm3Dist, Thm5Dist};
pub use shard::Shard;
pub use sparse::SparseDiag;

use crate::rng::Pcg64;

/// An i.i.d. data distribution with known population spectral facts.
///
/// Implementations must be `Send + Sync`: shard generation fans out across
/// worker threads.
pub trait Distribution: Send + Sync {
    /// Ambient dimension `d`.
    fn dim(&self) -> usize;

    /// Draw one sample into `out` (`out.len() == dim()`).
    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]);

    /// Leading population eigenvector `v_1` (unit norm).
    fn v1(&self) -> &[f64];

    /// Population eigengap `delta = lambda_1 - lambda_2 > 0`.
    fn eigengap(&self) -> f64;

    /// Leading population eigenvalue `lambda_1`.
    fn lambda1(&self) -> f64;

    /// Norm bound `b` with `||x||^2 <= b` (up to negligible tail for the
    /// gaussian case, which the paper's experiments also use).
    fn norm_bound_sq(&self) -> f64;

    /// Draw a full `n x d` shard.
    fn sample_shard(&self, rng: &mut Pcg64, n: usize) -> Shard {
        let d = self.dim();
        let mut rows = vec![0.0; n * d];
        for i in 0..n {
            self.sample_into(rng, &mut rows[i * d..(i + 1) * d]);
        }
        Shard::new(n, d, rows)
    }

    /// The centralized-ERM risk bound of Lemma 1:
    /// `eps_ERM(p) = 32 b^2 ln(d/p) / (m n delta^2)`.
    fn eps_erm(&self, m: usize, n: usize, p: f64) -> f64 {
        let b = self.norm_bound_sq();
        32.0 * b * b * (self.dim() as f64 / p).ln()
            / (m as f64 * n as f64 * self.eigengap() * self.eigengap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::norm;

    #[test]
    fn sample_shard_shapes() {
        let dist = CovModel::paper_fig1(16, 3).gaussian();
        let mut rng = Pcg64::new(1);
        let shard = dist.sample_shard(&mut rng, 10);
        assert_eq!(shard.n(), 10);
        assert_eq!(shard.d(), 16);
        for i in 0..10 {
            assert!(norm(shard.row(i)) > 0.0);
        }
    }

    #[test]
    fn eps_erm_scales_inverse_mn() {
        let dist = CovModel::paper_fig1(8, 3).gaussian();
        let e1 = dist.eps_erm(5, 100, 0.25);
        let e2 = dist.eps_erm(10, 100, 0.25);
        let e3 = dist.eps_erm(5, 200, 0.25);
        assert!((e1 / e2 - 2.0).abs() < 1e-12);
        assert!((e1 / e3 - 2.0).abs() < 1e-12);
    }
}
