//! The paper's §5 experimental covariance model and its two samplers.
//!
//! > "we used the covariance matrix `X = U Sigma U^T` with `U` a random
//! > `d x d` orthonormal matrix and `Sigma` diagonal satisfying
//! > `Sigma(1,1) = 1, Sigma(2,2) = 0.8, for j >= 3:
//! > Sigma(j,j) = 0.9 * Sigma(j-1,j-1)`, i.e. `delta = 0.2`."
//!
//! Dataset 1 samples `N(0, X)`; dataset 2 samples
//! `x = sqrt(3/2) X^{1/2} y` with `y ~ U[-1,1]^d` (which also has
//! covariance exactly `X`, since `Var(U[-1,1]) = 1/3`).

use crate::linalg::qr::qr_thin;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::Distribution;

/// The §5 spectrum `diag(1, 0.8, 0.9 * prev, ...)` in dimension `d`
/// (`delta = 0.2`), shared by [`CovModel::paper_fig1`] and the sparse
/// generator ([`SparseDiag::paper_fig1`](super::SparseDiag::paper_fig1)).
pub fn fig1_spectrum(d: usize) -> Vec<f64> {
    assert!(d >= 2);
    let mut sigma = Vec::with_capacity(d);
    sigma.push(1.0);
    sigma.push(0.8);
    for j in 2..d {
        sigma.push(0.9 * sigma[j - 1]);
    }
    sigma
}

/// The population covariance model `X = U Sigma U^T`.
#[derive(Clone, Debug)]
pub struct CovModel {
    /// Orthonormal basis (columns are the population eigenvectors).
    u: Matrix,
    /// Spectrum, descending.
    sigma: Vec<f64>,
    /// `U diag(sqrt(sigma))` — the factor used to color samples.
    factor: Matrix,
    /// `v1` cached as a column.
    v1: Vec<f64>,
}

impl CovModel {
    /// The exact §5 model in dimension `d` with a Haar-random `U` drawn
    /// from `seed`.
    pub fn paper_fig1(d: usize, seed: u64) -> CovModel {
        Self::with_spectrum(fig1_spectrum(d), seed)
    }

    /// Arbitrary descending spectrum with a Haar-random basis.
    pub fn with_spectrum(sigma: Vec<f64>, seed: u64) -> CovModel {
        let d = sigma.len();
        for w in sigma.windows(2) {
            assert!(w[0] >= w[1], "spectrum must be descending");
        }
        assert!(sigma[d - 1] >= 0.0, "spectrum must be PSD");
        let mut rng = Pcg64::with_stream(seed, 0xc0f_fee);
        let g = Matrix::from_vec(d, d, (0..d * d).map(|_| rng.next_gaussian()).collect());
        let (u, _) = qr_thin(&g);
        Self::with_basis(u, sigma)
    }

    /// Explicit basis + spectrum (basis columns must be orthonormal).
    pub fn with_basis(u: Matrix, sigma: Vec<f64>) -> CovModel {
        let d = sigma.len();
        assert_eq!(u.rows(), d);
        assert_eq!(u.cols(), d);
        let mut factor = u.clone();
        for c in 0..d {
            let s = sigma[c].max(0.0).sqrt();
            for r in 0..d {
                factor.set(r, c, factor.get(r, c) * s);
            }
        }
        let v1 = u.col(0);
        CovModel { u, sigma, factor, v1 }
    }

    /// Identity-basis model (useful in tests: `v1 = e1`).
    pub fn axis_aligned(sigma: Vec<f64>) -> CovModel {
        let d = sigma.len();
        Self::with_basis(Matrix::identity(d), sigma)
    }

    pub fn dim(&self) -> usize {
        self.sigma.len()
    }

    pub fn spectrum(&self) -> &[f64] {
        &self.sigma
    }

    pub fn basis(&self) -> &Matrix {
        &self.u
    }

    pub fn v1(&self) -> &[f64] {
        &self.v1
    }

    pub fn eigengap(&self) -> f64 {
        self.sigma[0] - self.sigma[1]
    }

    /// Dense population covariance `U Sigma U^T` (tests / diagnostics).
    pub fn covariance(&self) -> Matrix {
        let ut = self.u.transpose();
        let mut su = ut.clone();
        for r in 0..self.dim() {
            let s = self.sigma[r];
            for c in 0..self.dim() {
                su.set(r, c, su.get(r, c) * s);
            }
        }
        self.u.matmul(&su)
    }

    /// Gaussian sampler `N(0, X)` (Figure 1, left pane).
    pub fn gaussian(self) -> GaussianCov {
        GaussianCov::new(self)
    }

    /// Scaled-uniform sampler `sqrt(3/2) X^{1/2} y, y ~ U[-1,1]^d`
    /// (Figure 1, right pane).
    pub fn scaled_uniform(self) -> ScaledUniformCov {
        ScaledUniformCov::new(self)
    }
}

/// `x = U sqrt(Sigma) z`, `z ~ N(0, I)` — covariance exactly `X`.
pub struct GaussianCov {
    model: CovModel,
    norm_bound_sq: f64,
}

impl GaussianCov {
    pub fn new(model: CovModel) -> Self {
        // E||x||^2 = tr(X) = sum sigma; the "effective" b the bounds use.
        // The gaussian is unbounded; the paper's own experiments use it
        // anyway. We report b as a high-probability envelope: 4 * tr(X).
        let tr: f64 = model.sigma.iter().sum();
        GaussianCov { model, norm_bound_sq: 4.0 * tr }
    }

    pub fn model(&self) -> &CovModel {
        &self.model
    }
}

impl Distribution for GaussianCov {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        let d = self.model.dim();
        debug_assert_eq!(out.len(), d);
        let z = rng.gaussian_vec(d);
        self.model.factor.matvec_into(&z, out);
    }

    /// Batched sampling: `A = Z F^T` with one blocked GEMM instead of `n`
    /// per-sample matvecs (~2.5x on the Figure-1 shapes; §Perf).
    fn sample_shard(&self, rng: &mut Pcg64, n: usize) -> crate::data::Shard {
        let d = self.model.dim();
        let z = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect());
        crate::data::Shard::from_matrix(z.matmul(&self.model.factor.transpose()))
    }

    fn v1(&self) -> &[f64] {
        self.model.v1()
    }

    fn eigengap(&self) -> f64 {
        self.model.eigengap()
    }

    fn lambda1(&self) -> f64 {
        self.model.sigma[0]
    }

    fn norm_bound_sq(&self) -> f64 {
        self.norm_bound_sq
    }
}

/// `x = sqrt(3/2) X^{1/2} y`, `y ~ U[-1,1]^d`.
///
/// `X^{1/2} = U sqrt(Sigma) U^T`; since `Cov(y) = (1/3) I`, we have
/// `Cov(x) = (3/2)(1/3) X^{1/2} X^{1/2} * 2 = X`... more precisely
/// `Cov(x) = (3/2) X^{1/2} (1/3 I) X^{1/2} ... ` — the paper's constant:
/// `E[x x^T] = (3/2) * (1/3) * X = X/2`? No: `sqrt(3/2)^2 * 1/3 = 1/2`.
/// The paper scales by `sqrt(3/2)` against `Var(U[-1,1]) = 1/3`, giving
/// covariance `X/2`... Both panes only need covariance *proportional* to
/// `X` (same eigenvectors, gap scaled); we keep the paper's constant and
/// report the scaled gap.
pub struct ScaledUniformCov {
    model: CovModel,
    sqrt_x: Matrix,
    /// Covariance scale factor: `(3/2) * Var(U[-1,1]) = 1/2`.
    cov_scale: f64,
    norm_bound_sq: f64,
}

impl ScaledUniformCov {
    pub fn new(model: CovModel) -> Self {
        let d = model.dim();
        // X^{1/2} = U diag(sqrt(sigma)) U^T = factor * U^T
        let sqrt_x = model.factor.matmul(&model.u.transpose());
        // ||x||^2 <= (3/2) * lambda_1(X) * ||y||^2 <= (3/2) * sigma_1 * d
        let norm_bound_sq = 1.5 * model.sigma[0] * d as f64;
        ScaledUniformCov { model, sqrt_x, cov_scale: 0.5, norm_bound_sq }
    }

    pub fn model(&self) -> &CovModel {
        &self.model
    }
}

impl Distribution for ScaledUniformCov {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        let d = self.model.dim();
        debug_assert_eq!(out.len(), d);
        let scale = (1.5f64).sqrt();
        let y: Vec<f64> = (0..d).map(|_| scale * rng.next_sym_uniform()).collect();
        self.sqrt_x.matvec_into(&y, out);
    }

    /// Batched sampling, as in [`GaussianCov::sample_shard`]. `X^{1/2}` is
    /// symmetric so no transpose is needed.
    fn sample_shard(&self, rng: &mut Pcg64, n: usize) -> crate::data::Shard {
        let d = self.model.dim();
        let scale = (1.5f64).sqrt();
        let y = Matrix::from_vec(n, d, (0..n * d).map(|_| scale * rng.next_sym_uniform()).collect());
        crate::data::Shard::from_matrix(y.matmul(&self.sqrt_x))
    }

    fn v1(&self) -> &[f64] {
        self.model.v1()
    }

    fn eigengap(&self) -> f64 {
        self.cov_scale * self.model.eigengap()
    }

    fn lambda1(&self) -> f64 {
        self.cov_scale * self.model.sigma[0]
    }

    fn norm_bound_sq(&self) -> f64 {
        self.norm_bound_sq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{alignment_error, dot, norm};

    #[test]
    fn paper_fig1_spectrum() {
        let m = CovModel::paper_fig1(5, 1);
        let s = m.spectrum();
        assert_eq!(s[0], 1.0);
        assert_eq!(s[1], 0.8);
        assert!((s[2] - 0.72).abs() < 1e-15);
        assert!((s[3] - 0.648).abs() < 1e-15);
        assert!((m.eigengap() - 0.2).abs() < 1e-15);
    }

    #[test]
    fn covariance_leading_eigvec_is_v1() {
        let m = CovModel::paper_fig1(12, 5);
        let x = m.covariance();
        let v = crate::linalg::eigen::leading_eigvec(&x);
        assert!(alignment_error(&v, m.v1()) < 1e-18);
    }

    #[test]
    fn basis_is_orthonormal() {
        let m = CovModel::paper_fig1(20, 9);
        let defect = crate::linalg::qr::orthonormality_defect(m.basis());
        assert!(defect < 1e-11);
    }

    #[test]
    fn gaussian_empirical_covariance_converges() {
        let d = 6;
        let model = CovModel::paper_fig1(d, 11);
        let pop = model.covariance();
        let dist = model.gaussian();
        let mut rng = Pcg64::new(3);
        let n = 60_000;
        let shard = dist.sample_shard(&mut rng, n);
        let emp = shard.empirical_covariance();
        let err = emp.sub(&pop).max_abs();
        assert!(err < 0.03, "empirical covariance error {err}");
    }

    #[test]
    fn scaled_uniform_covariance_proportional_to_x() {
        let d = 5;
        let model = CovModel::paper_fig1(d, 13);
        let pop = model.covariance();
        let dist = model.scaled_uniform();
        let mut rng = Pcg64::new(7);
        let n = 120_000;
        let shard = dist.sample_shard(&mut rng, n);
        let emp = shard.empirical_covariance();
        // Cov = 0.5 * X for the paper's sqrt(3/2) scaling
        let err = emp.sub(&pop.scale(0.5)).max_abs();
        assert!(err < 0.02, "scaled uniform covariance error {err}");
    }

    #[test]
    fn scaled_uniform_norm_bound_holds() {
        let model = CovModel::paper_fig1(8, 17);
        let dist = model.scaled_uniform();
        let b = dist.norm_bound_sq();
        let mut rng = Pcg64::new(9);
        let mut buf = vec![0.0; 8];
        for _ in 0..2000 {
            dist.sample_into(&mut rng, &mut buf);
            let nsq = dot(&buf, &buf);
            assert!(nsq <= b + 1e-12, "||x||^2 = {nsq} > b = {b}");
        }
    }

    #[test]
    fn axis_aligned_v1_is_e1() {
        let m = CovModel::axis_aligned(vec![2.0, 1.0, 0.5]);
        assert_eq!(m.v1(), &[1.0, 0.0, 0.0]);
        assert_eq!(m.eigengap(), 1.0);
    }

    #[test]
    fn gaussian_mean_zero() {
        let model = CovModel::paper_fig1(4, 19).gaussian();
        let mut rng = Pcg64::new(11);
        let mut acc = vec![0.0; 4];
        let n = 40_000;
        let mut buf = vec![0.0; 4];
        for _ in 0..n {
            model.sample_into(&mut rng, &mut buf);
            for (a, b) in acc.iter_mut().zip(&buf) {
                *a += b;
            }
        }
        for a in &acc {
            assert!((a / n as f64).abs() < 0.02);
        }
    }

    #[test]
    fn v1_unit_norm() {
        let m = CovModel::paper_fig1(30, 23);
        assert!((norm(m.v1()) - 1.0).abs() < 1e-12);
    }
}
