//! Sparse synthetic distribution (ISSUE 6): the workload the CSR shard
//! kernels exist for.
//!
//! [`SparseDiag`] draws rows whose coordinates are independently zero
//! with probability `1 - density`; a kept coordinate `j` is gaussian
//! with variance `sigma_j / density`, so the population covariance is
//! exactly `diag(sigma)` — axis-aligned, `v1 = e1`, eigengap
//! `sigma_1 - sigma_2` — and every estimator/baseline that consumes a
//! [`Distribution`] runs unchanged on sparse data.
//!
//! **Dense/CSR equivalence is bit-exact by construction**: the
//! [`Distribution::sample_shard`] override emits a CSR shard but
//! consumes the RNG in *exactly* the per-coordinate order
//! [`SparseDiag::sample_into`] does (one uniform inclusion coin per
//! coordinate, one gaussian per kept coordinate), so a CSR shard and
//! the dense shard built row-by-row from the same seed hold the same
//! values bit for bit. The experiments lean on this: E9/E12 sparse
//! runs are the dense runs with a different storage format, and the
//! bills must not move.

use crate::rng::Pcg64;

use super::cov_model::fig1_spectrum;
use super::{CovModel, Distribution, Shard};

/// Axis-aligned sparse distribution with covariance `diag(sigma)`.
pub struct SparseDiag {
    /// Spectrum, descending (`= the population eigenvalues`).
    sigma: Vec<f64>,
    /// Per-coordinate keep probability in `(0, 1]`.
    density: f64,
    /// `e1` — the leading population eigenvector.
    v1: Vec<f64>,
    /// `sqrt(sigma_j / density)` — the kept-coordinate scale that makes
    /// `E[x_j^2] = sigma_j` exactly.
    scale: Vec<f64>,
    norm_bound_sq: f64,
}

impl SparseDiag {
    /// Sparse distribution with the given descending spectrum and
    /// per-coordinate keep probability `density` in `(0, 1]`.
    pub fn new(sigma: Vec<f64>, density: f64) -> SparseDiag {
        let d = sigma.len();
        assert!(d >= 2, "need d >= 2 for an eigengap");
        for w in sigma.windows(2) {
            assert!(w[0] >= w[1], "spectrum must be descending");
        }
        assert!(sigma[d - 1] >= 0.0, "spectrum must be PSD");
        assert!(
            density > 0.0 && density <= 1.0,
            "density must be in (0, 1], got {density}"
        );
        let mut v1 = vec![0.0; d];
        v1[0] = 1.0;
        let scale: Vec<f64> = sigma.iter().map(|s| (s / density).sqrt()).collect();
        // E||x||^2 = tr(Sigma) but each kept coordinate is inflated by
        // 1/density, so the high-probability envelope scales with it
        // (same 4x slack convention as the gaussian sampler's).
        let tr: f64 = sigma.iter().sum();
        SparseDiag { sigma, density, v1, scale, norm_bound_sq: 4.0 * tr / density }
    }

    /// The §5 spectrum ([`fig1_spectrum`]) at keep probability
    /// `density` — the sparse twin of [`CovModel::paper_fig1`], minus
    /// the Haar rotation (a rotated sparse vector is dense).
    pub fn paper_fig1(d: usize, density: f64) -> SparseDiag {
        SparseDiag::new(fig1_spectrum(d), density)
    }

    /// Keep probability.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// The population model (`axis_aligned`, so `top_k_basis`-style
    /// reference subspaces work unchanged on sparse runs).
    pub fn model(&self) -> CovModel {
        CovModel::axis_aligned(self.sigma.clone())
    }
}

impl Distribution for SparseDiag {
    fn dim(&self) -> usize {
        self.sigma.len()
    }

    fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.sigma.len());
        for (j, o) in out.iter_mut().enumerate() {
            // one inclusion coin per coordinate, one gaussian per kept
            // coordinate — the exact consumption order sample_shard
            // mirrors, which is what makes dense == CSR bit-exact
            if rng.next_f64() < self.density {
                *o = self.scale[j] * rng.next_gaussian();
            } else {
                *o = 0.0;
            }
        }
    }

    /// CSR-emitting override: same draws as [`SparseDiag::sample_into`]
    /// row by row, stored sparse.
    fn sample_shard(&self, rng: &mut Pcg64, n: usize) -> Shard {
        let d = self.dim();
        let expected = ((n * d) as f64 * self.density) as usize + 8;
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0);
        let mut indices: Vec<u32> = Vec::with_capacity(expected);
        let mut values: Vec<f64> = Vec::with_capacity(expected);
        for _ in 0..n {
            for j in 0..d {
                if rng.next_f64() < self.density {
                    indices.push(j as u32);
                    values.push(self.scale[j] * rng.next_gaussian());
                }
            }
            indptr.push(values.len());
        }
        Shard::from_csr(n, d, indptr, indices, values)
    }

    fn v1(&self) -> &[f64] {
        &self.v1
    }

    fn eigengap(&self) -> f64 {
        self.sigma[0] - self.sigma[1]
    }

    fn lambda1(&self) -> f64 {
        self.sigma[0]
    }

    fn norm_bound_sq(&self) -> f64 {
        self.norm_bound_sq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_shard_matches_dense_rows_bit_for_bit() {
        let dist = SparseDiag::paper_fig1(9, 0.3);
        let n = 40;
        let sparse = dist.sample_shard(&mut Pcg64::new(71), n);
        assert!(sparse.is_sparse());
        // dense twin from the same seed via the per-row sampler
        let mut rng = Pcg64::new(71);
        let mut row = vec![0.0; 9];
        for i in 0..n {
            dist.sample_into(&mut rng, &mut row);
            for (j, want) in row.iter().enumerate() {
                let got = sparse.csr_parts().map(|(ip, ix, vals)| {
                    let (lo, hi) = (ip[i], ip[i + 1]);
                    ix[lo..hi]
                        .iter()
                        .position(|&c| c as usize == j)
                        .map_or(0.0, |p| vals[lo + p])
                });
                assert_eq!(got.unwrap().to_bits(), want.to_bits(), "row {i} col {j}");
            }
        }
    }

    #[test]
    fn empirical_covariance_converges_to_diag_sigma() {
        let dist = SparseDiag::paper_fig1(6, 0.3);
        let shard = dist.sample_shard(&mut Pcg64::new(5), 60_000);
        let emp = shard.empirical_covariance();
        for r in 0..6 {
            for c in 0..6 {
                let want = if r == c { dist.sigma[r] } else { 0.0 };
                let got = emp.get(r, c);
                assert!((got - want).abs() < 0.1, "cov[{r}][{c}] = {got}, want {want}");
            }
        }
    }

    #[test]
    fn nnz_tracks_density() {
        let dist = SparseDiag::paper_fig1(20, 0.1);
        let shard = dist.sample_shard(&mut Pcg64::new(13), 2_000);
        let frac = shard.nnz() as f64 / (2_000.0 * 20.0);
        assert!((frac - 0.1).abs() < 0.02, "nnz fraction {frac} far from density 0.1");
    }

    #[test]
    fn population_facts_are_axis_aligned() {
        let dist = SparseDiag::paper_fig1(8, 0.5);
        assert_eq!(dist.dim(), 8);
        assert_eq!(dist.v1()[0], 1.0);
        assert!(dist.v1()[1..].iter().all(|&x| x == 0.0));
        assert!((dist.eigengap() - 0.2).abs() < 1e-15);
        assert_eq!(dist.lambda1(), 1.0);
        assert_eq!(dist.model().spectrum(), CovModel::paper_fig1(8, 3).spectrum());
        assert!(dist.norm_bound_sq() > 0.0);
    }

    #[test]
    fn full_density_rows_are_fully_dense() {
        let dist = SparseDiag::new(vec![2.0, 1.0, 0.5], 1.0);
        let shard = dist.sample_shard(&mut Pcg64::new(3), 25);
        assert_eq!(shard.nnz(), 25 * 3, "density 1.0 keeps every coordinate");
    }

    #[test]
    #[should_panic(expected = "density must be in (0, 1]")]
    fn zero_density_is_rejected() {
        let _ = SparseDiag::new(vec![1.0, 0.5], 0.0);
    }
}
