//! Quantized-communication ablation.
//!
//! The paper's §1 contrasts its round-based cost model with the
//! bit-complexity line of work ([15, 5]) and argues vector-valued rounds
//! sidestep bit accounting. This module quantifies the other direction:
//! if each broadcast/gathered vector is rounded to fewer bits per entry,
//! how much estimation error does that inject into the distributed power
//! method, and how many bytes does a round actually need?
//!
//! Findings (test-asserted): f32 mantissas (24 bits) leave the Figure-1
//! workload's error indistinguishable from f64 down to `~1e-14` iterate
//! drift, i.e. the paper's rounds could ship half the bytes for free;
//! bf16-style 8-bit mantissas put a `~1e-4`-scale floor on the iterate,
//! visible once the statistical error drops below it. (8 mantissa bits keep relative error under 2^-8.)

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Cluster;
use crate::linalg::vec_ops::{alignment_error, normalize};
use crate::rng::Pcg64;

use super::{instrumented, Algorithm, Estimate};

/// Per-entry precision of every vector that crosses the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WirePrecision {
    /// Full f64 (the baseline model of the paper).
    F64,
    /// Round-trip every entry through f32.
    F32,
    /// Keep 8 mantissa bits (bfloat16-style dynamic range).
    Bf16,
}

impl WirePrecision {
    /// Apply the precision loss to a vector (in place).
    pub fn quantize(&self, v: &mut [f64]) {
        match self {
            WirePrecision::F64 => {}
            WirePrecision::F32 => {
                for x in v.iter_mut() {
                    *x = *x as f32 as f64;
                }
            }
            WirePrecision::Bf16 => {
                for x in v.iter_mut() {
                    // zero the low 48 bits of the mantissa: 1 sign + 11
                    // exponent + ~4 explicit mantissa bits survive beyond
                    // the implicit one — a deliberately crude 8-bit-class
                    // wire format
                    let bits = x.to_bits() & 0xFFFF_F000_0000_0000;
                    *x = f64::from_bits(bits);
                }
            }
        }
    }

    /// Bytes per entry on the wire.
    pub fn bytes_per_entry(&self) -> usize {
        match self {
            WirePrecision::F64 => 8,
            WirePrecision::F32 => 4,
            WirePrecision::Bf16 => 2,
        }
    }
}

/// Distributed power method with wire quantization of the broadcast
/// iterate (models compressing the leader->workers direction; the
/// workers' replies are averaged at the leader in full precision, as a
/// real allreduce would accumulate in f32/f64 regardless).
#[derive(Clone, Debug)]
pub struct QuantizedPower {
    pub precision: WirePrecision,
    pub max_iters: usize,
    pub tol: f64,
    pub seed: u64,
}

impl QuantizedPower {
    pub fn new(precision: WirePrecision) -> Self {
        QuantizedPower { precision, max_iters: 2_000, tol: 1e-18, seed: 0x9d }
    }
}

impl Algorithm for QuantizedPower {
    fn name(&self) -> &'static str {
        match self.precision {
            WirePrecision::F64 => "power_wire_f64",
            WirePrecision::F32 => "power_wire_f32",
            WirePrecision::Bf16 => "power_wire_bf16",
        }
    }

    fn run(&self, cluster: &Cluster) -> Result<Estimate> {
        instrumented(cluster, || {
            let d = cluster.d();
            let mut rng = Pcg64::new(self.seed);
            let mut w = rng.gaussian_vec(d);
            normalize(&mut w);
            let mut iters = 0usize;
            let mut floor_hit = 0.0f64;
            for _ in 0..self.max_iters {
                let mut wire = w.clone();
                self.precision.quantize(&mut wire);
                let mut next = cluster.dist_matvec(&wire)?;
                normalize(&mut next);
                iters += 1;
                let drift = alignment_error(&next, &w);
                w = next;
                if drift <= self.tol {
                    break;
                }
                floor_hit = drift;
            }
            let mut info = BTreeMap::new();
            info.insert("iters".into(), iters as f64);
            info.insert("final_drift".into(), floor_hit);
            info.insert(
                "wire_bytes_per_round".into(),
                (self.precision.bytes_per_entry() * d) as f64,
            );
            Ok((w, info))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::CentralizedErm;
    use super::*;
    use crate::coordinator::Algorithm;

    #[test]
    fn quantize_roundtrips() {
        let mut v = vec![1.0, -0.3333333333333333, 1e-8, 12345.6789];
        let orig = v.clone();
        WirePrecision::F64.quantize(&mut v);
        assert_eq!(v, orig);
        WirePrecision::F32.quantize(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() <= 1e-7 * b.abs().max(1e-30));
        }
        WirePrecision::Bf16.quantize(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            // 8 explicit mantissa bits -> relative error <= 2^-8
            assert!((a - b).abs() <= 4e-3 * b.abs().max(1e-30), "{a} vs {b}");
        }
    }

    #[test]
    fn f32_wire_is_free_at_statistical_scale() {
        let (c, dist) = fig1_cluster(4, 200, 12, 101);
        use crate::data::Distribution;
        let full = QuantizedPower::new(WirePrecision::F64).run(&c).unwrap();
        let half = QuantizedPower::new(WirePrecision::F32).run(&c).unwrap();
        let e_full = full.error(dist.v1());
        let e_half = half.error(dist.v1());
        // statistical error dominates quantization by orders of magnitude
        assert!(
            (e_full - e_half).abs() <= 1e-6 * e_full.max(1e-12),
            "f32 wire changed the answer: {e_full:.6e} vs {e_half:.6e}"
        );
        assert_eq!(half.info["wire_bytes_per_round"], 4.0 * 12.0);
    }

    #[test]
    fn bf16_wire_puts_a_floor_on_the_iterate() {
        let (c, _) = fig1_cluster(4, 400, 12, 103);
        let cen = CentralizedErm.run(&c).unwrap();
        let full = QuantizedPower::new(WirePrecision::F64).run(&c).unwrap();
        let crude = QuantizedPower::new(WirePrecision::Bf16).run(&c).unwrap();
        let e_full = crate::linalg::vec_ops::alignment_error(&full.w, &cen.w);
        let e_crude = crate::linalg::vec_ops::alignment_error(&crude.w, &cen.w);
        // full precision nails vhat1; crude wire cannot get below its floor
        assert!(e_full < 1e-12);
        assert!(e_crude > e_full, "bf16 floor should be visible: {e_crude:.3e}");
        // ...but the floor is still far below the statistical error scale
        assert!(e_crude < 1e-3, "bf16 floor unexpectedly large: {e_crude:.3e}");
    }

    #[test]
    fn quantized_name_and_accounting() {
        let (c, _) = fig1_cluster(3, 60, 6, 105);
        let est = QuantizedPower::new(WirePrecision::Bf16).run(&c).unwrap();
        assert_eq!(
            QuantizedPower::new(WirePrecision::Bf16).name(),
            "power_wire_bf16"
        );
        assert_eq!(est.comm.rounds, est.comm.matvec_products);
    }
}
