//! Quantized-communication ablation.
//!
//! The paper's §1 contrasts its round-based cost model with the
//! bit-complexity line of work ([15, 5]) and argues vector-valued rounds
//! sidestep bit accounting. This module quantifies the other direction:
//! if every vector that crosses the network is shipped through a lossy
//! wire codec, how much estimation error does that inject into the
//! distributed power method, and how many bytes does a round actually
//! need?
//!
//! Since the wire layer landed, quantization lives in the **wire**
//! ([`WireCodec`], owned per tenant by the [`Session`]): [`QuantizedPower`]
//! is a thin coordinator that installs the requested codec on its own
//! session for the duration of the run and drives the plain distributed
//! power method — a concurrent lossless tenant's traffic is untouched. Both directions pass through the
//! codec (the pre-wire-layer version hand-quantized only the broadcast
//! while the cluster billed full f64 — its `wire_bytes_per_round` could
//! never agree with `CommStats.bytes`; now the info value is read back
//! from the bill itself).
//!
//! Findings (test-asserted): f32 frames (24-bit mantissa) leave the
//! Figure-1 workload's error indistinguishable from f64 at statistical
//! scale, i.e. the paper's rounds could ship half the bytes for free;
//! bf16 frames (8-bit exponent, 7 explicit mantissa bits,
//! round-to-nearest-even via f32 — relative error <= 2^-8 + 2^-24) put
//! a small floor on the iterate, visible once the statistical error
//! drops below it. The stateful family goes further: 4-bit frames with
//! error feedback track the f64 trajectory at a fraction of the bytes,
//! and the run's `info` surfaces the leader-side residual norm
//! (`residual_feedback_norm`) and adaptive transitions so the
//! compression error is observable next to `final_drift`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::{CodecKind, Session, WireCodec};
use crate::linalg::vec_ops::{alignment_error, normalize};
use crate::rng::Pcg64;

use super::{instrumented, Algorithm, Estimate};

pub use crate::cluster::WirePrecision;

/// Distributed power method run entirely through a lossy wire codec:
/// broadcasts *and* gathered replies are shipped as encoded frames, and
/// the byte bill is whatever the codec actually put on the wire. Takes
/// any [`WireCodec`] — including the stateful error-feedback /
/// sparsifying / adaptive family — and surfaces the leader-side
/// residual trajectory in `info` alongside `final_drift`.
#[derive(Clone, Debug)]
pub struct QuantizedPower {
    pub codec: WireCodec,
    pub max_iters: usize,
    pub tol: f64,
    pub seed: u64,
}

impl QuantizedPower {
    /// Back-compat constructor for the stateless fixed-width family.
    pub fn new(precision: WirePrecision) -> Self {
        Self::with_codec(WireCodec::new(precision))
    }

    /// Run the power loop through an arbitrary wire codec (quantized,
    /// sparsified, error-feedback, adaptive — anything the session's
    /// wire layer speaks).
    pub fn with_codec(codec: WireCodec) -> Self {
        QuantizedPower { codec, max_iters: 2_000, tol: 1e-18, seed: 0x9d }
    }

    fn power_loop(&self, session: &Session<'_>) -> Result<(Vec<f64>, BTreeMap<String, f64>)> {
        let d = session.d();
        let mut rng = Pcg64::new(self.seed);
        let mut w = rng.gaussian_vec(d);
        normalize(&mut w);
        let mut iters = 0usize;
        // the last measured iterate drift, reported unconditionally —
        // including when the very first iteration already meets `tol`
        // (the pre-fix code skipped the update on the break path and
        // reported final_drift = 0.0 for a first-iteration break)
        let mut last_drift = 0.0f64;
        for _ in 0..self.max_iters {
            let mut next = session.dist_matvec(&w)?;
            normalize(&mut next);
            iters += 1;
            last_drift = alignment_error(&next, &w);
            w = next;
            if last_drift <= self.tol {
                break;
            }
        }
        let st = session.stats();
        let mut info = BTreeMap::new();
        info.insert("iters".into(), iters as f64);
        info.insert("final_drift".into(), last_drift);
        // read back from the bill, not re-derived: every round of this
        // loop is one dist_matvec, so the per-round cost is uniform and
        // this value cannot contradict `CommStats`
        info.insert(
            "wire_bytes_per_round".into(),
            if st.rounds > 0 { st.bytes as f64 / st.rounds as f64 } else { 0.0 },
        );
        // the leader-side stream state, read while the codec is still
        // installed (set_codec resets the stream): the last relative
        // error-feedback residual norm — 0.0 for stateless codecs, the
        // per-round compression error otherwise — plus the adaptive
        // controller's transition counts
        info.insert("residual_feedback_norm".into(), session.residual_norm());
        let (wid, nar) = session.codec_transitions();
        info.insert("codec_widenings".into(), wid as f64);
        info.insert("codec_narrowings".into(), nar as f64);
        Ok((w, info))
    }
}

impl Algorithm for QuantizedPower {
    fn name(&self) -> &'static str {
        // coarse, flag-blind names: job registries key on the codec
        // family; the exact label (with +ef/+ad) lives in the obs trace
        match self.codec.kind() {
            CodecKind::Stateless(WirePrecision::F64) => "power_wire_f64",
            CodecKind::Stateless(WirePrecision::F32) => "power_wire_f32",
            CodecKind::Stateless(WirePrecision::Bf16) => "power_wire_bf16",
            CodecKind::Quant(crate::cluster::QuantBits::Q8) => "power_wire_q8",
            CodecKind::Quant(crate::cluster::QuantBits::Q4) => "power_wire_q4",
            CodecKind::TopS { .. } => "power_wire_tops",
        }
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            // install the lossy codec on THIS session for the duration
            // of the run — concurrent tenants' wires are untouched —
            // and restore whatever was there before, even on error
            let prev = session.codec();
            session.set_codec(self.codec);
            let out = self.power_loop(session);
            session.set_codec(prev);
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::CentralizedErm;
    use super::*;
    use crate::coordinator::Algorithm;

    #[test]
    fn f32_wire_is_free_at_statistical_scale() {
        let (c, dist) = fig1_cluster(4, 200, 12, 101);
        use crate::data::Distribution;
        let full = QuantizedPower::new(WirePrecision::F64).run(&c.session()).unwrap();
        let half = QuantizedPower::new(WirePrecision::F32).run(&c.session()).unwrap();
        let e_full = full.error(dist.v1());
        let e_half = half.error(dist.v1());
        // statistical error dominates quantization by orders of magnitude
        // (both directions now ship f32, hence the 1e-4 rather than the
        // broadcast-only version's 1e-6)
        assert!(
            (e_full - e_half).abs() <= 1e-4 * e_full.max(1e-12),
            "f32 wire changed the answer: {e_full:.6e} vs {e_half:.6e}"
        );
        // the info value is the bill itself: B(d)·(live+1) per round
        assert_eq!(half.info["wire_bytes_per_round"], (4 * 12 * 5) as f64);
        assert_eq!(
            half.info["wire_bytes_per_round"] * half.comm.rounds as f64,
            half.comm.bytes as f64,
            "info must agree with CommStats"
        );
    }

    #[test]
    fn bf16_wire_puts_a_floor_on_the_iterate() {
        let (c, _) = fig1_cluster(4, 400, 12, 103);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let full = QuantizedPower::new(WirePrecision::F64).run(&c.session()).unwrap();
        let crude = QuantizedPower::new(WirePrecision::Bf16).run(&c.session()).unwrap();
        let e_full = crate::linalg::vec_ops::alignment_error(&full.w, &cen.w);
        let e_crude = crate::linalg::vec_ops::alignment_error(&crude.w, &cen.w);
        // full precision nails vhat1; crude wire cannot get below its floor
        assert!(e_full < 1e-12);
        assert!(e_crude > e_full, "bf16 floor should be visible: {e_crude:.3e}");
        // ...but the floor is still far below the statistical error scale
        assert!(e_crude < 1e-3, "bf16 floor unexpectedly large: {e_crude:.3e}");
    }

    #[test]
    fn quantized_name_and_accounting() {
        let (c, _) = fig1_cluster(3, 60, 6, 105);
        let est = QuantizedPower::new(WirePrecision::Bf16).run(&c.session()).unwrap();
        assert_eq!(QuantizedPower::new(WirePrecision::Bf16).name(), "power_wire_bf16");
        assert_eq!(est.comm.rounds, est.comm.matvec_products);
        // bf16 frames: B(d)·(live+1) = 2·6·4 bytes per round, exactly
        assert_eq!(est.comm.bytes, est.comm.rounds * (2 * 6 * 4) as u64);
    }

    #[test]
    fn q4_error_feedback_matches_f64_at_a_fraction_of_the_bytes() {
        use crate::cluster::QuantBits;
        use crate::data::Distribution;
        let (c, dist) = fig1_cluster(4, 200, 12, 101);
        let full = QuantizedPower::new(WirePrecision::F64).run(&c.session()).unwrap();
        let alg = QuantizedPower::with_codec(WireCodec::quant(QuantBits::Q4).with_feedback());
        assert_eq!(alg.name(), "power_wire_q4");
        let ef = alg.run(&c.session()).unwrap();
        // 4-bit frames: (4·1 scale + ⌈12/2⌉ nibble) bytes × (4 live + 1
        // broadcast) — read back from the bill
        assert_eq!(ef.info["wire_bytes_per_round"], (10 * 5) as f64);
        // the headline: ≥4× fewer billed bytes per round than f64...
        assert!(
            full.info["wire_bytes_per_round"] >= 4.0 * ef.info["wire_bytes_per_round"],
            "{} vs {}",
            full.info["wire_bytes_per_round"],
            ef.info["wire_bytes_per_round"]
        );
        // ...with the iterate still tracking the principal direction
        let e_full = full.error(dist.v1());
        let e_ef = ef.error(dist.v1());
        assert!(e_full < 0.5);
        assert!(e_ef < 0.5, "q4+ef power lost the principal direction: {e_ef:.3e}");
        // the leader-side stream state is surfaced next to final_drift:
        // a lossy feedback stream has a positive, sub-unit residual norm
        let rel = ef.info["residual_feedback_norm"];
        assert!(rel > 0.0 && rel < 1.0, "residual norm {rel}");
        // a non-adaptive codec never transitions
        assert_eq!(ef.info["codec_widenings"], 0.0);
        assert_eq!(ef.info["codec_narrowings"], 0.0);
        // and the stateless runs report a zero residual
        assert_eq!(full.info["residual_feedback_norm"], 0.0);
    }

    #[test]
    fn adaptive_codec_narrows_once_the_iterate_settles() {
        use crate::cluster::QuantBits;
        let (c, _) = fig1_cluster(3, 150, 8, 113);
        let alg = QuantizedPower::with_codec(WireCodec::quant(QuantBits::Q8).with_adaptive());
        let est = alg.run(&c.session()).unwrap();
        // q8's relative residual (≈step/2 against the payload rms) sits
        // well under the narrow threshold, so the controller steps down
        // to q4 once it has one round of evidence
        assert!(
            est.info["codec_narrowings"] >= 1.0,
            "adaptive controller never narrowed: {:?}",
            est.info
        );
        assert!(est.info["residual_feedback_norm"] > 0.0);
    }

    #[test]
    fn final_drift_reported_on_first_iteration_break() {
        // regression (ISSUE 2 satellite): with tol = 1.0 every run breaks
        // on its first iteration; the seed reported final_drift = 0.0 on
        // that path because the update was skipped before `break`
        let (c, _) = fig1_cluster(3, 50, 8, 107);
        let alg = QuantizedPower {
            codec: WireCodec::lossless(),
            max_iters: 500,
            tol: 1.0,
            seed: 0x9d,
        };
        let est = alg.run(&c.session()).unwrap();
        assert_eq!(est.info["iters"], 1.0);
        let drift = est.info["final_drift"];
        assert!(
            drift > 0.0 && drift <= 1.0,
            "first-iteration break must report the measured drift, got {drift}"
        );
    }

    #[test]
    fn codec_is_restored_after_the_run() {
        let (c, dist) = fig1_cluster(3, 150, 8, 109);
        use crate::data::Distribution;
        let s = c.session();
        assert_eq!(s.codec(), WireCodec::lossless());
        let _ = QuantizedPower::new(WirePrecision::Bf16).run(&s).unwrap();
        assert_eq!(s.codec(), WireCodec::lossless(), "lossy codec must not leak");
        // and a subsequent full-precision algorithm on the same session
        // is unaffected
        let cen = CentralizedErm.run(&s).unwrap();
        assert!(cen.error(dist.v1()) < 0.5);
        assert_eq!(cen.comm.bytes, (8 * 8 * 8 * 3) as u64, "gram ships full f64 again");
    }
}
