//! The paper's algorithms, written against the per-tenant [`Session`]
//! view of the cluster ([`crate::cluster::Cluster::session`]): every
//! estimator runs on its own bill and its own wire codec, so any number
//! of them can execute concurrently on one shared cluster (see the
//! `serve` scheduler) without corrupting each other's accounting.
//!
//! | type | paper reference | rounds |
//! |---|---|---|
//! | [`CentralizedErm`] | Lemma 1 baseline | 1 (heavy: ships d×d) |
//! | [`NaiveAverage`] | Theorem 3 (negative result) | 1 |
//! | [`SignFixedAverage`] | Theorem 4 | 1 |
//! | [`ProjectionAverage`] | §5 heuristic | 1 |
//! | [`DistributedPower`] | §2.2.2 | `O((λ1/δ) log(d/ε))` |
//! | [`DistributedLanczos`] | §2.2.2 | `O(sqrt(λ1/δ) log(d/ε))` |
//! | [`HotPotatoOja`] | §2.2.2 ("hot-potato" SGD) | `m` |
//! | [`ShiftInvert`] | Algorithm 1 + 2, Theorem 6 | `~O(sqrt(1/(δ sqrt n)))` matvecs |
//! | [`QuantizedPower`] | §1 bit-complexity contrast (wire-codec ablation) | as power, lossy [`WireCodec`](crate::cluster::WireCodec) |
//!
//! The top-`k` family (Theorem 7's metric) rides the cluster's **block
//! protocol** — every iterative step below is one multi-vector round
//! ([`crate::cluster::Session::dist_matmat`]), not `k` scalar rounds:
//!
//! | type | analog of | block rounds |
//! |---|---|---|
//! | [`CentralizedSubspace`] | [`CentralizedErm`] | 1 (heavy: ships d×d) |
//! | [`DistributedOrthoIteration`] | [`DistributedPower`] | `O((λk/δk) log(d/ε))`, 1 round/iter |
//! | [`BlockLanczos`] | [`DistributedLanczos`] | `O(sqrt(λk/δk) log(d/ε))`, 1 round/block |
//! | [`SubspaceProjectionAverage`] | [`ProjectionAverage`] | 1 |
//! | [`DeflatedShiftInvert`] | [`ShiftInvert`] | component-0 solve + 1 round/block iter |

mod erm;
mod lanczos;
mod oja;
mod one_shot;
mod power;
pub mod precond;
pub mod quantized;
mod shift_invert;
pub mod solvers;
pub mod subspace;

pub use erm::{CentralizedErm, SingleMachineErm};
pub use lanczos::{BlockLanczos, DistributedLanczos};
pub use oja::HotPotatoOja;
pub use one_shot::{NaiveAverage, ProjectionAverage, SignFixedAverage};
pub use power::DistributedPower;
pub use quantized::{QuantizedPower, WirePrecision};
pub use shift_invert::{MuStrategy, ShiftInvert, SniConfig, SniSolver};
pub use subspace::{
    CentralizedSubspace, DeflatedShiftInvert, DistributedOrthoIteration, SubspaceEstimate,
    SubspaceProjectionAverage,
};

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::{CommStats, Session};
use crate::linalg::vec_ops;

/// Output of one algorithm run: the unit-norm estimate of `v_1` plus the
/// communication bill and wallclock.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Unit-norm estimate of the leading eigenvector.
    pub w: Vec<f64>,
    /// Communication performed during the run.
    pub comm: CommStats,
    /// Leader-side wallclock.
    pub wall: Duration,
    /// Algorithm-specific diagnostics (inner iteration counts, shifts, …).
    pub info: BTreeMap<String, f64>,
}

impl Estimate {
    /// The paper's risk: `1 - (w^T v1)^2`.
    pub fn error(&self, v1: &[f64]) -> f64 {
        vec_ops::alignment_error(&self.w, v1)
    }
}

/// A distributed PCA algorithm. `run` executes against one tenant
/// session — resetting that session's communication counters first —
/// and returns the estimate with the session's bill attached. Pass a
/// fresh `cluster.session()` per query; concurrent runs on separate
/// sessions of one cluster bill independently.
pub trait Algorithm {
    /// Short identifier used in reports (`"sign_fixed_avg"`, …).
    fn name(&self) -> &'static str;

    /// Execute on a tenant session of a cluster.
    fn run(&self, session: &Session<'_>) -> Result<Estimate>;
}

/// Helper for implementations: time `f`, snapshot the session's comm
/// stats around it.
pub(crate) fn instrumented(
    session: &Session<'_>,
    f: impl FnOnce() -> Result<(Vec<f64>, BTreeMap<String, f64>)>,
) -> Result<Estimate> {
    session.reset_stats();
    let t0 = Instant::now();
    let (mut w, info) = f()?;
    let wall = t0.elapsed();
    vec_ops::normalize(&mut w);
    Ok(Estimate { w, comm: session.stats(), wall, info })
}

/// Matrix-valued variant for the subspace estimators.
pub(crate) fn instrumented_mat(
    session: &Session<'_>,
    k: usize,
    f: impl FnOnce() -> Result<(crate::linalg::Matrix, BTreeMap<String, f64>)>,
) -> Result<subspace::SubspaceEstimate> {
    session.reset_stats();
    let t0 = Instant::now();
    let (w, info) = f()?;
    let wall = t0.elapsed();
    debug_assert_eq!(w.cols(), k);
    Ok(subspace::SubspaceEstimate { w, comm: session.stats(), wall, info })
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::cluster::Cluster;
    use crate::data::{CovModel, Distribution, GaussianCov};

    /// Small axis-aligned gaussian cluster: `v1 = e_1`, gap 0.5.
    pub fn test_cluster(m: usize, n: usize, d: usize, seed: u64) -> (Cluster, GaussianCov) {
        let mut sigma = vec![0.0; d];
        sigma[0] = 1.0;
        for j in 1..d {
            sigma[j] = 0.5 * (0.9f64).powi(j as i32 - 1);
        }
        let dist = CovModel::axis_aligned(sigma).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        (c, dist)
    }

    /// The paper's Figure-1 model at reduced dimension.
    pub fn fig1_cluster(m: usize, n: usize, d: usize, seed: u64) -> (Cluster, GaussianCov) {
        let dist = CovModel::paper_fig1(d, seed ^ 0xabc).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        (c, dist)
    }

    /// Exact pooled empirical covariance for cross-checks (regenerates the
    /// same shards the cluster saw).
    pub fn pooled_cov(dist: &dyn Distribution, m: usize, n: usize, seed: u64) -> crate::linalg::Matrix {
        let mut root = crate::rng::Pcg64::with_stream(seed, 0xdeca_f);
        let mut acc = crate::linalg::Matrix::zeros(dist.dim(), dist.dim());
        for i in 0..m {
            let mut rng = root.fork(i as u64);
            let shard = dist.sample_shard(&mut rng, n);
            acc.axpy_mat(1.0, shard.empirical_covariance());
        }
        acc.scale_mut(1.0 / m as f64);
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn estimate_error_uses_alignment() {
        let e = Estimate {
            w: vec![1.0, 0.0],
            comm: CommStats::default(),
            wall: Duration::ZERO,
            info: BTreeMap::new(),
        };
        assert!(e.error(&[1.0, 0.0]) < 1e-15);
        assert!((e.error(&[0.0, 1.0]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn instrumented_resets_and_snapshots() {
        let (c, _) = test_cluster(3, 20, 4, 1);
        let s = c.session();
        let v = vec![1.0, 0.0, 0.0, 0.0];
        s.dist_matvec(&v).unwrap(); // pollute counters
        let est = instrumented(&s, || {
            s.dist_matvec(&v)?;
            Ok((v.clone(), BTreeMap::new()))
        })
        .unwrap();
        assert_eq!(est.comm.rounds, 1, "stats must be reset before the run");
        assert!((vec_ops::norm(&est.w) - 1.0).abs() < 1e-12);
    }
}
