//! Distributed Lanczos (§2.2.2) — scalar and block variants.
//!
//! [`DistributedLanczos`] builds a Krylov basis of the pooled covariance
//! with one [`Session::dist_matvec`] round per basis vector, with full
//! re-orthogonalization at the leader (local, free). The Ritz vector of
//! the tridiagonal projection converges in
//! `O(sqrt(lambda_1/delta) ln(d/p eps))` rounds — quadratically fewer
//! than the power method, the baseline the S&I algorithm is benchmarked
//! against in Table 1.
//!
//! [`BlockLanczos`] is the top-`k` member of the family, built on the
//! cluster's block protocol: each block expansion is **one**
//! [`Session::dist_matmat`] round moving a `d x k` block, producing the
//! block-tridiagonal projection whose top-`k` Ritz vectors estimate the
//! pooled top-`k` subspace — the Krylov counterpart of
//! [`crate::coordinator::DistributedOrthoIteration`], converging in
//! quadratically fewer block rounds on slowly decaying spectra.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cluster::Session;
use crate::linalg::eigen::SymEigen;
use crate::linalg::qr::qr_thin;
use crate::linalg::vec_ops::{axpy, dot, normalize};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::subspace::SubspaceEstimate;
use super::{instrumented, instrumented_mat, Algorithm, Estimate};

/// Distributed Lanczos iterations.
#[derive(Clone, Debug)]
pub struct DistributedLanczos {
    /// Max Krylov dimension (each step = 1 round). Also capped at `d`.
    pub max_iters: usize,
    /// Stop when the Ritz-pair residual estimate
    /// `beta_k * |last component of Ritz vector|` drops below `tol`.
    pub tol: f64,
    /// Seed for the random start vector.
    pub seed: u64,
}

impl Default for DistributedLanczos {
    fn default() -> Self {
        DistributedLanczos { max_iters: 400, tol: 1e-14, seed: 0x1a }
    }
}

impl Algorithm for DistributedLanczos {
    fn name(&self) -> &'static str {
        "distributed_lanczos"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let d = session.d();
            let kmax = self.max_iters.min(d);
            let mut rng = Pcg64::new(self.seed);
            let mut q = rng.gaussian_vec(d);
            normalize(&mut q);

            let mut basis: Vec<Vec<f64>> = vec![q.clone()];
            let mut alphas: Vec<f64> = Vec::new();
            let mut betas: Vec<f64> = Vec::new();
            let mut iters = 0usize;

            for k in 0..kmax {
                let mut v = session.dist_matvec(&basis[k])?;
                iters += 1;
                let alpha = dot(&basis[k], &v);
                alphas.push(alpha);
                // v <- v - alpha q_k - beta_{k-1} q_{k-1}
                axpy(&mut v, -alpha, &basis[k]);
                if k > 0 {
                    let beta_prev = betas[k - 1];
                    axpy(&mut v, -beta_prev, &basis[k - 1]);
                }
                // full re-orthogonalization (twice for stability)
                for _pass in 0..2 {
                    for b in &basis {
                        let c = dot(b, &v);
                        axpy(&mut v, -c, b);
                    }
                }
                let beta = normalize(&mut v);
                // convergence check on the current Ritz pair
                let (theta, y) = top_ritz(&alphas, &betas);
                let resid = beta * y.last().copied().unwrap_or(1.0).abs();
                if beta <= 1e-14 || resid <= self.tol * theta.abs().max(1e-30) || k + 1 == kmax {
                    let w = ritz_vector(&basis, &y);
                    let mut info = BTreeMap::new();
                    info.insert("iters".into(), iters as f64);
                    info.insert("ritz_value".into(), theta);
                    info.insert("ritz_residual".into(), resid);
                    return Ok((w, info));
                }
                betas.push(beta);
                basis.push(v);
            }
            unreachable!("loop always returns at k + 1 == kmax");
        })
    }
}

/// Block Lanczos for the pooled top-`k` subspace.
///
/// Each block expansion costs exactly **one** block round
/// ([`Session::dist_matmat`]): one request/response per live worker
/// carrying `k` vectors each way. The leader maintains the block
/// Krylov basis `[Q_0 | Q_1 | ...]` with full re-orthogonalization
/// (local, free), assembles the block-tridiagonal projection `T`
/// (`A_j` diagonal blocks, `B_j` off-diagonal QR factors), and reads
/// the top-`k` Ritz vectors out of `T`.
#[derive(Clone, Debug)]
pub struct BlockLanczos {
    /// Subspace rank (= block width = vectors per round).
    pub k: usize,
    /// Cap on block expansions (each = 1 round). Also capped so the
    /// Krylov dimension never exceeds `d`.
    pub max_blocks: usize,
    /// Stop when the Ritz residual estimate `||B_j Y_bot||_F` drops
    /// below `tol * |theta_1|`.
    pub tol: f64,
    /// Seed for the random start block.
    pub seed: u64,
}

impl BlockLanczos {
    pub fn new(k: usize) -> Self {
        BlockLanczos { k, max_blocks: 200, tol: 1e-12, seed: 0xb10c5 }
    }

    /// Run on a cluster; returns the subspace estimate with the
    /// communication bill attached.
    pub fn run_mat(&self, session: &Session<'_>) -> Result<SubspaceEstimate> {
        let d = session.d();
        let k = self.k;
        if k == 0 || k > d {
            bail!("invalid subspace rank k={k} for d={d}");
        }
        instrumented_mat(session, k, || {
            let max_blocks = self.max_blocks.min(d / k).max(1);
            let mut rng = Pcg64::new(self.seed);
            let g = Matrix::from_vec(d, k, (0..d * k).map(|_| rng.next_gaussian()).collect());
            let (q0, _) = qr_thin(&g);
            let mut blocks: Vec<Matrix> = vec![q0];
            let mut a_blocks: Vec<Matrix> = Vec::new();
            let mut b_blocks: Vec<Matrix> = Vec::new();
            loop {
                let j = a_blocks.len();
                // one block round: W = Xhat Q_j
                let mut w = session.dist_matmat(&blocks[j])?;
                let mut aj = blocks[j].transpose().matmul(&w);
                aj.symmetrize();
                w.axpy_mat(-1.0, &blocks[j].matmul(&aj));
                a_blocks.push(aj);
                if j > 0 {
                    w.axpy_mat(-1.0, &blocks[j - 1].matmul(&b_blocks[j - 1].transpose()));
                }
                // full block re-orthogonalization ("twice is enough")
                for _pass in 0..2 {
                    for q in &blocks {
                        let c = q.transpose().matmul(&w);
                        w.axpy_mat(-1.0, &q.matmul(&c));
                    }
                }
                let (qn, bj) = qr_thin(&w);
                // Ritz extraction from the block tridiagonal
                let nb = a_blocks.len();
                let t = assemble_block_tridiag(&a_blocks, &b_blocks);
                let eig = SymEigen::new(&t);
                let mut y = Matrix::zeros(nb * k, k);
                for c in 0..k {
                    y.set_col(c, &eig.eigvec(c));
                }
                // residual estimate: ||B_j * (bottom k x k block of Y)||_F
                let mut ybot = Matrix::zeros(k, k);
                for r in 0..k {
                    for c in 0..k {
                        ybot.set(r, c, y.get((nb - 1) * k + r, c));
                    }
                }
                let resid = bj.matmul(&ybot).fro_norm();
                let theta1 = eig.lambda1().abs().max(1e-30);
                let exhausted = bj.fro_norm() <= 1e-13;
                if resid <= self.tol * theta1
                    || exhausted
                    || nb == max_blocks
                    || (nb + 1) * k > d
                {
                    // W = [Q_0 .. Q_{nb-1}] Y in ambient space, QR polish
                    let mut w_amb = Matrix::zeros(d, k);
                    for (bi, q) in blocks.iter().take(nb).enumerate() {
                        let mut yb = Matrix::zeros(k, k);
                        for r in 0..k {
                            for c in 0..k {
                                yb.set(r, c, y.get(bi * k + r, c));
                            }
                        }
                        w_amb.axpy_mat(1.0, &q.matmul(&yb));
                    }
                    let (qfin, _) = qr_thin(&w_amb);
                    let mut info = BTreeMap::new();
                    info.insert("block_iters".into(), nb as f64);
                    info.insert("ritz_value".into(), eig.lambda1());
                    info.insert("ritz_residual".into(), resid);
                    return Ok((qfin, info));
                }
                b_blocks.push(bj);
                blocks.push(qn);
            }
        })
    }
}

/// Assemble the symmetric block tridiagonal `T` with diagonal blocks
/// `A_j` and sub-diagonal blocks `B_j` (`T_{j+1,j} = B_j`,
/// `T_{j,j+1} = B_j^T`).
fn assemble_block_tridiag(a_blocks: &[Matrix], b_blocks: &[Matrix]) -> Matrix {
    let nb = a_blocks.len();
    let k = a_blocks[0].rows();
    let mut t = Matrix::zeros(nb * k, nb * k);
    for (i, a) in a_blocks.iter().enumerate() {
        for r in 0..k {
            for c in 0..k {
                t.set(i * k + r, i * k + c, a.get(r, c));
            }
        }
    }
    for (i, b) in b_blocks.iter().enumerate() {
        for r in 0..k {
            for c in 0..k {
                t.set((i + 1) * k + r, i * k + c, b.get(r, c));
                t.set(i * k + r, (i + 1) * k + c, b.get(c, r));
            }
        }
    }
    t
}

/// Leading Ritz pair of the symmetric tridiagonal `(alphas, betas)`.
fn top_ritz(alphas: &[f64], betas: &[f64]) -> (f64, Vec<f64>) {
    let k = alphas.len();
    let mut t = Matrix::zeros(k, k);
    for i in 0..k {
        t.set(i, i, alphas[i]);
        if i + 1 < k && i < betas.len() {
            t.set(i, i + 1, betas[i]);
            t.set(i + 1, i, betas[i]);
        }
    }
    let eig = SymEigen::new(&t);
    (eig.lambda1(), eig.leading())
}

/// Assemble the Ritz vector `sum_j y_j q_j` in ambient space.
fn ritz_vector(basis: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let d = basis[0].len();
    let mut w = vec![0.0; d];
    for (b, &c) in basis.iter().zip(y.iter()) {
        axpy(&mut w, c, b);
    }
    normalize(&mut w);
    w
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::{CentralizedErm, DistributedPower};
    use super::*;
    use crate::linalg::vec_ops::alignment_error;

    #[test]
    fn lanczos_converges_to_centralized_erm() {
        let (c, _) = test_cluster(4, 120, 8, 61);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let lan = DistributedLanczos::default().run(&c.session()).unwrap();
        assert!(
            alignment_error(&lan.w, &cen.w) < 1e-9,
            "err={}",
            alignment_error(&lan.w, &cen.w)
        );
    }

    #[test]
    fn lanczos_uses_fewer_rounds_than_power() {
        // small gap to make the contrast visible
        let mut sigma = vec![1.0, 0.95];
        for j in 2..10 {
            sigma.push(sigma[j - 1] * 0.9);
        }
        let dist = crate::data::CovModel::axis_aligned(sigma).gaussian();
        let c = crate::cluster::Cluster::generate(&dist, 4, 300, 63).unwrap();
        let pow = DistributedPower { tol: 1e-20, max_iters: 4000, ..Default::default() }
            .run(&c.session())
            .unwrap();
        let lan = DistributedLanczos { tol: 1e-12, ..Default::default() }.run(&c.session()).unwrap();
        let cen = CentralizedErm.run(&c.session()).unwrap();
        // both must be accurate…
        assert!(alignment_error(&lan.w, &cen.w) < 1e-8);
        assert!(alignment_error(&pow.w, &cen.w) < 1e-8);
        // …but Lanczos in far fewer rounds
        assert!(
            lan.comm.rounds * 2 <= pow.comm.rounds,
            "lanczos {} rounds vs power {}",
            lan.comm.rounds,
            pow.comm.rounds
        );
    }

    #[test]
    fn terminates_at_dimension() {
        let (c, _) = test_cluster(3, 50, 4, 67);
        let est = DistributedLanczos { max_iters: 100, tol: 0.0, seed: 3 }.run(&c.session()).unwrap();
        assert!(est.comm.rounds <= 4, "Krylov dim cannot exceed d=4, rounds={}", est.comm.rounds);
    }

    #[test]
    fn ritz_info_reported() {
        let (c, _) = test_cluster(3, 60, 5, 69);
        let est = DistributedLanczos::default().run(&c.session()).unwrap();
        assert!(est.info["ritz_value"] > 0.0);
        assert!(est.info["iters"] >= 1.0);
    }

    #[test]
    fn block_lanczos_matches_centralized_subspace() {
        use crate::coordinator::subspace::{subspace_error, CentralizedSubspace};
        // d = 12, k = 3: the block Krylov space can reach the full
        // dimension (4 blocks), so the Ritz basis is exact up to rounding
        let (c, _) = test_cluster(4, 250, 12, 71);
        let k = 3;
        let cen = CentralizedSubspace { k }.run_mat(&c.session()).unwrap();
        let blk = BlockLanczos::new(k).run_mat(&c.session()).unwrap();
        let e = subspace_error(&blk.w, &cen.w);
        assert!(e < 1e-8, "block Lanczos should find the pooled top-k: {e:.3e}");
        // basis orthonormal
        assert!(crate::linalg::qr::orthonormality_defect(&blk.w) < 1e-10);
        // one block round per expansion, k matvecs billed per round
        assert_eq!(blk.comm.rounds, blk.info["block_iters"] as u64);
        assert_eq!(blk.comm.matvec_products, blk.comm.rounds * k as u64);
        assert!(blk.comm.rounds <= (12 / k) as u64, "Krylov dim cannot exceed d");
    }

    #[test]
    fn block_lanczos_uses_fewer_rounds_than_block_power() {
        use crate::coordinator::subspace::{subspace_error, DistributedOrthoIteration};
        // slowly decaying spectrum: block power pays ~1/log(ratio) rounds,
        // block Lanczos quadratically fewer
        let mut sigma = vec![1.0, 0.95];
        for j in 2..20 {
            sigma.push(sigma[j - 1] * 0.93);
        }
        let dist = crate::data::CovModel::axis_aligned(sigma).gaussian();
        let c = crate::cluster::Cluster::generate(&dist, 4, 400, 73).unwrap();
        let k = 4;
        let pow =
            DistributedOrthoIteration { k, max_iters: 4000, tol: 1e-24, seed: 0x9, pipeline: true }
                .run_mat(&c.session())
                .unwrap();
        let lan = BlockLanczos { k, tol: 1e-12, ..BlockLanczos::new(k) }.run_mat(&c.session()).unwrap();
        let e = subspace_error(&lan.w, &pow.w);
        assert!(e < 1e-6, "block Lanczos disagrees with converged block power: {e:.3e}");
        assert!(
            lan.comm.rounds * 2 <= pow.comm.rounds,
            "block lanczos {} rounds vs block power {}",
            lan.comm.rounds,
            pow.comm.rounds
        );
    }

    #[test]
    fn block_lanczos_rank_one_block_tracks_scalar_lanczos() {
        let (c, _) = test_cluster(3, 150, 8, 79);
        let lan = DistributedLanczos::default().run(&c.session()).unwrap();
        let blk = BlockLanczos::new(1).run_mat(&c.session()).unwrap();
        let align = crate::linalg::vec_ops::alignment_error(&blk.w.col(0), &lan.w);
        assert!(align < 1e-8, "k=1 block Lanczos should match scalar Lanczos: {align:.3e}");
    }

    #[test]
    fn block_lanczos_rejects_bad_rank() {
        let (c, _) = test_cluster(2, 30, 4, 83);
        assert!(BlockLanczos::new(0).run_mat(&c.session()).is_err());
        assert!(BlockLanczos::new(5).run_mat(&c.session()).is_err());
    }
}
