//! Distributed Lanczos (§2.2.2).
//!
//! Builds a Krylov basis of the pooled covariance with one
//! [`Cluster::dist_matvec`] round per basis vector, with full
//! re-orthogonalization at the leader (local, free). The Ritz vector of
//! the tridiagonal projection converges in
//! `O(sqrt(lambda_1/delta) ln(d/p eps))` rounds — quadratically fewer
//! than the power method, the baseline the S&I algorithm is benchmarked
//! against in Table 1.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Cluster;
use crate::linalg::eigen::SymEigen;
use crate::linalg::vec_ops::{axpy, dot, normalize};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::{instrumented, Algorithm, Estimate};

/// Distributed Lanczos iterations.
#[derive(Clone, Debug)]
pub struct DistributedLanczos {
    /// Max Krylov dimension (each step = 1 round). Also capped at `d`.
    pub max_iters: usize,
    /// Stop when the Ritz-pair residual estimate
    /// `beta_k * |last component of Ritz vector|` drops below `tol`.
    pub tol: f64,
    /// Seed for the random start vector.
    pub seed: u64,
}

impl Default for DistributedLanczos {
    fn default() -> Self {
        DistributedLanczos { max_iters: 400, tol: 1e-14, seed: 0x1a }
    }
}

impl Algorithm for DistributedLanczos {
    fn name(&self) -> &'static str {
        "distributed_lanczos"
    }

    fn run(&self, cluster: &Cluster) -> Result<Estimate> {
        instrumented(cluster, || {
            let d = cluster.d();
            let kmax = self.max_iters.min(d);
            let mut rng = Pcg64::new(self.seed);
            let mut q = rng.gaussian_vec(d);
            normalize(&mut q);

            let mut basis: Vec<Vec<f64>> = vec![q.clone()];
            let mut alphas: Vec<f64> = Vec::new();
            let mut betas: Vec<f64> = Vec::new();
            let mut iters = 0usize;

            for k in 0..kmax {
                let mut v = cluster.dist_matvec(&basis[k])?;
                iters += 1;
                let alpha = dot(&basis[k], &v);
                alphas.push(alpha);
                // v <- v - alpha q_k - beta_{k-1} q_{k-1}
                axpy(&mut v, -alpha, &basis[k]);
                if k > 0 {
                    let beta_prev = betas[k - 1];
                    axpy(&mut v, -beta_prev, &basis[k - 1]);
                }
                // full re-orthogonalization (twice for stability)
                for _pass in 0..2 {
                    for b in &basis {
                        let c = dot(b, &v);
                        axpy(&mut v, -c, b);
                    }
                }
                let beta = normalize(&mut v);
                // convergence check on the current Ritz pair
                let (theta, y) = top_ritz(&alphas, &betas);
                let resid = beta * y.last().copied().unwrap_or(1.0).abs();
                if beta <= 1e-14 || resid <= self.tol * theta.abs().max(1e-30) || k + 1 == kmax {
                    let w = ritz_vector(&basis, &y);
                    let mut info = BTreeMap::new();
                    info.insert("iters".into(), iters as f64);
                    info.insert("ritz_value".into(), theta);
                    info.insert("ritz_residual".into(), resid);
                    return Ok((w, info));
                }
                betas.push(beta);
                basis.push(v);
            }
            unreachable!("loop always returns at k + 1 == kmax");
        })
    }
}

/// Leading Ritz pair of the symmetric tridiagonal `(alphas, betas)`.
fn top_ritz(alphas: &[f64], betas: &[f64]) -> (f64, Vec<f64>) {
    let k = alphas.len();
    let mut t = Matrix::zeros(k, k);
    for i in 0..k {
        t.set(i, i, alphas[i]);
        if i + 1 < k && i < betas.len() {
            t.set(i, i + 1, betas[i]);
            t.set(i + 1, i, betas[i]);
        }
    }
    let eig = SymEigen::new(&t);
    (eig.lambda1(), eig.leading())
}

/// Assemble the Ritz vector `sum_j y_j q_j` in ambient space.
fn ritz_vector(basis: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let d = basis[0].len();
    let mut w = vec![0.0; d];
    for (b, &c) in basis.iter().zip(y.iter()) {
        axpy(&mut w, c, b);
    }
    normalize(&mut w);
    w
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::{CentralizedErm, DistributedPower};
    use super::*;
    use crate::linalg::vec_ops::alignment_error;

    #[test]
    fn lanczos_converges_to_centralized_erm() {
        let (c, _) = test_cluster(4, 120, 8, 61);
        let cen = CentralizedErm.run(&c).unwrap();
        let lan = DistributedLanczos::default().run(&c).unwrap();
        assert!(
            alignment_error(&lan.w, &cen.w) < 1e-9,
            "err={}",
            alignment_error(&lan.w, &cen.w)
        );
    }

    #[test]
    fn lanczos_uses_fewer_rounds_than_power() {
        // small gap to make the contrast visible
        let mut sigma = vec![1.0, 0.95];
        for j in 2..10 {
            sigma.push(sigma[j - 1] * 0.9);
        }
        let dist = crate::data::CovModel::axis_aligned(sigma).gaussian();
        let c = crate::cluster::Cluster::generate(&dist, 4, 300, 63).unwrap();
        let pow = DistributedPower { tol: 1e-20, max_iters: 4000, ..Default::default() }
            .run(&c)
            .unwrap();
        let lan = DistributedLanczos { tol: 1e-12, ..Default::default() }.run(&c).unwrap();
        let cen = CentralizedErm.run(&c).unwrap();
        // both must be accurate…
        assert!(alignment_error(&lan.w, &cen.w) < 1e-8);
        assert!(alignment_error(&pow.w, &cen.w) < 1e-8);
        // …but Lanczos in far fewer rounds
        assert!(
            lan.comm.rounds * 2 <= pow.comm.rounds,
            "lanczos {} rounds vs power {}",
            lan.comm.rounds,
            pow.comm.rounds
        );
    }

    #[test]
    fn terminates_at_dimension() {
        let (c, _) = test_cluster(3, 50, 4, 67);
        let est = DistributedLanczos { max_iters: 100, tol: 0.0, seed: 3 }.run(&c).unwrap();
        assert!(est.comm.rounds <= 4, "Krylov dim cannot exceed d=4, rounds={}", est.comm.rounds);
    }

    #[test]
    fn ritz_info_reported() {
        let (c, _) = test_cluster(3, 60, 5, 69);
        let est = DistributedLanczos::default().run(&c).unwrap();
        assert!(est.info["ritz_value"] > 0.0);
        assert!(est.info["iters"] >= 1.0);
    }
}
