//! Top-`k` subspace estimation — the general problem the paper's
//! Eq. (1)/(2) poses (its algorithmic sections specialize to `k = 1`;
//! Theorem 7 in the appendix proves the Davis-Kahan bound for general
//! `k`, which is exactly the metric used here).
//!
//! The estimator family, mirroring the `k = 1` family — all iterative
//! members now run on the cluster's **block protocol**
//! ([`crate::cluster::Session::dist_matmat`]): one round moves the whole
//! `d x k` basis, instead of the `k` rounds the old column-wise loop
//! paid per iteration.
//!
//! - [`CentralizedSubspace`] — top-`k` eigenvectors of the pooled
//!   covariance (the Lemma-1-style baseline).
//! - [`DistributedOrthoIteration`] — block power (orthogonal) iteration:
//!   each step is exactly **one** `dist_matmat` round followed by a
//!   leader-side thin QR (local, free).
//! - [`crate::coordinator::BlockLanczos`] — block Krylov variant: one
//!   `dist_matmat` round per block expansion, quadratically fewer rounds
//!   than block power on slowly decaying spectra.
//! - [`SubspaceProjectionAverage`] — the natural `k > 1` analog of the §5
//!   heuristic: average the local rank-`k` projectors `W_i W_i^T` and
//!   take the top-`k` eigenvectors. (Sign-fixing does not generalize —
//!   for `k > 1` the ambiguity is a full `O(k)` rotation, which
//!   projector averaging quotients out exactly.)
//! - [`DeflatedShiftInvert`] — Theorem-6 machinery for the leading
//!   component, then the remaining `k - 1` right-hand sides batched into
//!   block power iterations on the deflated operator — one `dist_matmat`
//!   round per iteration for all of them together, where the seed ran
//!   each component's power loop separately.
//!
//! Error metric: `subspace_error(W, V) = k - ||W^T V||_F^2
//! = 0.5 ||P_W - P_V||_F^2` — rotation-invariant, the Theorem-7 quantity.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cluster::Session;
use crate::linalg::eigen::SymEigen;
use crate::linalg::qr::qr_thin;
use crate::linalg::vec_ops;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::{instrumented_mat, SniConfig};

/// Rotation-invariant subspace distance `k - ||W^T V||_F^2`
/// (`= 0.5 ||W W^T - V V^T||_F^2` for orthonormal `W`, `V`).
pub fn subspace_error(w: &Matrix, v: &Matrix) -> f64 {
    assert_eq!(w.rows(), v.rows(), "subspace_error: dim mismatch");
    assert_eq!(w.cols(), v.cols(), "subspace_error: rank mismatch");
    let k = w.cols() as f64;
    let wv = w.transpose().matmul(v);
    (k - wv.fro_norm().powi(2)).max(0.0)
}

/// Top-`k` columns of the population basis (helper for experiments).
pub fn top_k_basis(model: &crate::data::CovModel, k: usize) -> Matrix {
    let d = model.dim();
    assert!(k <= d);
    let mut v = Matrix::zeros(d, k);
    for c in 0..k {
        v.set_col(c, &model.basis().col(c));
    }
    v
}

fn top_k_of(gram: &Matrix, k: usize) -> Matrix {
    let eig = SymEigen::new(gram);
    let d = gram.rows();
    let mut w = Matrix::zeros(d, k);
    for c in 0..k {
        w.set_col(c, &eig.eigvec(c));
    }
    w
}

/// Centralized top-`k` baseline (one heavy round: ships `d x d`).
#[derive(Clone, Debug)]
pub struct CentralizedSubspace {
    pub k: usize,
}

impl CentralizedSubspace {
    pub fn run_mat(&self, session: &Session<'_>) -> Result<SubspaceEstimate> {
        instrumented_mat(session, self.k, || {
            let xhat = session.gram_average()?;
            Ok((top_k_of(&xhat, self.k), BTreeMap::new()))
        })
    }
}

/// Distributed block power iteration with leader-side QR.
///
/// Each iteration is **one block round**: a single
/// [`Session::dist_matmat`] exchange moves the whole `d x k` basis (one
/// request/response per live worker, `k` vectors of traffic each way),
/// and the thin QR re-orthonormalization runs at the leader for free.
/// The seed's column-wise loop paid `k` rounds and `k` message
/// round-trips per worker for the same numerical step.
///
/// **Pipelined by default** (split-phase collectives): instead of
/// waiting for `X W` and only then running QR, the loop submits the
/// round for the *pre-orthonormalization* block `Y_t` and computes
/// `Y_t = Q_t R_t` while the round is in flight; when `X Y_t` arrives,
/// the orthonormalized step is recovered leader-side as
/// `X Q_t = (X Y_t) R_t^{-1}` (a `k x k` triangular solve — exact in
/// exact arithmetic, the classic communication-hiding reformulation).
/// Same iterates up to roundoff, same per-round bill; the leader-side
/// QR is fully hidden behind the wire, plus one speculative round
/// completed-and-discarded when the drift test stops the loop.
#[derive(Clone, Debug)]
pub struct DistributedOrthoIteration {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when the subspace stops rotating:
    /// `subspace_error(W_t, W_{t+1}) <= tol`.
    pub tol: f64,
    pub seed: u64,
    /// Overlap each round with the previous block's QR (default). The
    /// serialized loop is kept for A/B tests and as the fallback shape.
    pub pipeline: bool,
}

impl DistributedOrthoIteration {
    pub fn new(k: usize) -> Self {
        DistributedOrthoIteration { k, max_iters: 500, tol: 1e-16, seed: 0x0b10c, pipeline: true }
    }

    /// The pre-split-phase serialized loop (complete each round before
    /// the QR): for ablations and bill A/Bs.
    pub fn serialized(k: usize) -> Self {
        DistributedOrthoIteration { pipeline: false, ..Self::new(k) }
    }

    pub fn run_mat(&self, session: &Session<'_>) -> Result<SubspaceEstimate> {
        let d = session.d();
        if self.k == 0 || self.k > d {
            bail!("invalid subspace rank k={} for d={d}", self.k);
        }
        instrumented_mat(session, self.k, || {
            let mut rng = Pcg64::new(self.seed);
            let g = Matrix::from_vec(d, self.k, (0..d * self.k).map(|_| rng.next_gaussian()).collect());
            let (mut w, _) = qr_thin(&g);
            let mut info = BTreeMap::new();
            let mut iters = 0usize;
            if !self.pipeline {
                for _ in 0..self.max_iters {
                    // one block round for the whole basis + leader-side QR
                    let xw = session.dist_matmat(&w)?;
                    let (q, _) = qr_thin(&xw);
                    iters += 1;
                    let drift = subspace_error(&q, &w);
                    crate::obs_inc!(SOLVER_ITERATIONS_TOTAL);
                    crate::obs_gauge!(SOLVER_LAST_DRIFT_NANOS, (drift * 1e9) as u64);
                    w = q;
                    if drift <= self.tol {
                        break;
                    }
                }
                info.insert("iters".into(), iters as f64);
                return Ok((w, info));
            }
            // Pipelined: `y` is the pre-QR block X·Q_{t-1}; the round
            // for X·y is in flight while the leader factors y = Q R.
            let mut y = session.dist_matmat(&w)?; // X·Q_0: the priming round
            for t in 0..self.max_iters {
                let ticket = if t + 1 < self.max_iters {
                    Some(session.dist_matmat_submit(&y)?)
                } else {
                    None
                };
                let (q, r) = qr_thin(&y); // overlapped with the round
                iters += 1;
                let drift = subspace_error(&q, &w);
                crate::obs_inc!(SOLVER_ITERATIONS_TOTAL);
                crate::obs_gauge!(SOLVER_LAST_DRIFT_NANOS, (drift * 1e9) as u64);
                w = q;
                if drift <= self.tol {
                    // the speculative round at the stopping boundary is
                    // completed (its replies are real, billed traffic)
                    // and discarded
                    if let Some(ticket) = ticket {
                        ticket.complete()?;
                    }
                    break;
                }
                let Some(ticket) = ticket else { break };
                let mut xy = ticket.complete()?;
                // the QR above ran while this round was on the wire
                crate::obs_inc!(SOLVER_OVERLAP_HITS_TOTAL);
                if !apply_rinv(&mut xy, &r) {
                    bail!("block power iterate lost rank (pipelined R-solve)");
                }
                y = xy; // = X·(X·Q_{t-1})·R^{-1} = X·Q_t
            }
            info.insert("iters".into(), iters as f64);
            Ok((w, info))
        })
    }
}

/// In-place `M <- M R^{-1}` for upper-triangular `R` (column forward
/// substitution) — the leader-side recovery step of the pipelined block
/// iterations. Returns `false` (caller bails) when the factor is rank
/// deficient *relative to its own scale*: dividing by a diagonal entry
/// `~eps` below the largest one would amplify roundoff by `1/|r_jj|`
/// and deliver a garbage block where the serialized loop (which re-QRs
/// the raw product) would recover — better to fail loudly.
fn apply_rinv(m: &mut Matrix, r: &Matrix) -> bool {
    let d = m.rows();
    let k = m.cols();
    debug_assert_eq!(r.rows(), k);
    debug_assert_eq!(r.cols(), k);
    // f64::max ignores NaN, so an all-NaN diagonal lands on 0.0 here
    let max_diag = (0..k).map(|j| r.get(j, j).abs()).fold(0.0f64, f64::max);
    if max_diag <= 0.0 {
        return false; // zero (or NaN) factor
    }
    let floor = 1e-13 * max_diag;
    for j in 0..k {
        let mut col = m.col(j);
        for i in 0..j {
            let rij = r.get(i, j);
            if rij != 0.0 {
                let ci = m.col(i);
                for t in 0..d {
                    col[t] -= rij * ci[t];
                }
            }
        }
        let rjj = r.get(j, j);
        if rjj.is_nan() || rjj.abs() <= floor {
            return false;
        }
        for x in col.iter_mut() {
            *x /= rjj;
        }
        m.set_col(j, &col);
    }
    true
}

/// One-round estimator: leader averages the local rank-`k` projectors and
/// re-extracts a basis. Each machine ships `k` vectors (its local top-`k`
/// eigenbasis), so the round carries `m*k` vectors.
#[derive(Clone, Debug)]
pub struct SubspaceProjectionAverage {
    pub k: usize,
}

impl SubspaceProjectionAverage {
    pub fn run_mat(&self, session: &Session<'_>) -> Result<SubspaceEstimate> {
        let d = session.d();
        if self.k == 0 || self.k > d {
            bail!("invalid subspace rank k={} for d={d}", self.k);
        }
        instrumented_mat(session, self.k, || {
            // reuse the Gram exchange (one round; the shipped object is a
            // d x d projector-equivalent — see module docs for accounting)
            let locals = session.local_top_k(self.k)?;
            let mut pbar = Matrix::zeros(d, d);
            for w in &locals {
                // pbar += W W^T
                for c in 0..self.k {
                    let col = w.col(c);
                    for i in 0..d {
                        let vi = col[i];
                        if vi == 0.0 {
                            continue;
                        }
                        let row = &mut pbar.data_mut()[i * d..(i + 1) * d];
                        for (r, &vj) in row.iter_mut().zip(col.iter()) {
                            *r += vi * vj;
                        }
                    }
                }
            }
            pbar.scale_mut(1.0 / locals.len() as f64);
            let mut info = BTreeMap::new();
            let eig = SymEigen::new(&pbar);
            info.insert("pbar_gap_k".into(), eig.values()[self.k - 1] - eig.values().get(self.k).copied().unwrap_or(0.0));
            let mut w = Matrix::zeros(d, self.k);
            for c in 0..self.k {
                w.set_col(c, &eig.eigvec(c));
            }
            Ok((w, info))
        })
    }
}

/// Top-`k` via repeated Shift-and-Invert with leader-side deflation.
#[derive(Clone, Debug)]
pub struct DeflatedShiftInvert {
    pub k: usize,
    pub config: SniConfig,
}

impl DeflatedShiftInvert {
    pub fn new(k: usize) -> Self {
        DeflatedShiftInvert { k, config: SniConfig::default() }
    }

    pub fn run_mat(&self, session: &Session<'_>) -> Result<SubspaceEstimate> {
        let d = session.d();
        if self.k == 0 || self.k > d {
            bail!("invalid subspace rank k={} for d={d}", self.k);
        }
        instrumented_mat(session, self.k, || {
            let mut info = BTreeMap::new();
            // Component 0: the full Theorem-6 algorithm. The S&I shift
            // machinery needs fresh gap estimates per component, so the
            // trailing components use deflated block power instead.
            let est =
                super::Algorithm::run(&super::ShiftInvert::new(self.config.clone()), session)?;
            info.insert("sni_matvecs_0".into(), est.comm.matvec_products as f64);
            let basis = vec![est.w];
            let mut w = Matrix::zeros(d, self.k);
            w.set_col(0, &basis[0]);
            if self.k > 1 {
                // Components 1..k batched: block power on the deflated
                // operator `(I - P) Xhat (I - P)` with all `k - 1`
                // right-hand sides in one `d x (k-1)` block — one
                // `dist_matmat` round per iteration for the whole batch,
                // where the seed ran a separate power loop (one matvec
                // round per iteration) per component.
                //
                // Pipelined (split-phase): the round for the *pre-QR*
                // deflated block `Y` is in flight while the leader
                // deflates, factors `Y = Q R` and checks drift; on
                // arrival the orthonormalized step is recovered as
                // `(I-P) X Q = ((I-P) X Y) R^{-1}` (deflation is linear,
                // so it commutes with the triangular solve). One
                // speculative round is completed-and-discarded at the
                // convergence boundary.
                let kb = self.k - 1;
                let cap = 2_000usize;
                let mut rng = Pcg64::new(self.config.seed ^ 0xb10c);
                let gauss: Vec<f64> = (0..d * kb).map(|_| rng.next_gaussian()).collect();
                let mut g = Matrix::from_vec(d, kb, gauss);
                for c in 0..kb {
                    let mut col = g.col(c);
                    deflate(&mut col, &basis);
                    g.set_col(c, &col);
                }
                let (mut wb, _) = qr_thin(&g);
                let deflate_cols = |m: &mut Matrix| {
                    for c in 0..kb {
                        let mut col = m.col(c);
                        deflate(&mut col, &basis);
                        m.set_col(c, &col);
                    }
                };
                // priming round: Y_1 = (I-P)·X·Q_0
                let mut y = session.dist_matmat(&wb)?;
                deflate_cols(&mut y);
                let mut iters = 0usize;
                for t in 0..cap {
                    let ticket = if t + 1 < cap {
                        Some(session.dist_matmat_submit(&y)?)
                    } else {
                        None
                    };
                    let (q, r) = qr_thin(&y); // overlapped with the round
                    iters += 1;
                    if (0..kb).any(|c| r.get(c, c) <= 0.0) {
                        bail!("deflated block iterate lost rank");
                    }
                    let drift = subspace_error(&q, &wb);
                    crate::obs_inc!(SOLVER_ITERATIONS_TOTAL);
                    crate::obs_gauge!(SOLVER_LAST_DRIFT_NANOS, (drift * 1e9) as u64);
                    wb = q;
                    if drift < 1e-18 {
                        if let Some(ticket) = ticket {
                            ticket.complete()?; // speculative boundary round
                        }
                        break;
                    }
                    let Some(ticket) = ticket else { break };
                    let mut xy = ticket.complete()?;
                    crate::obs_inc!(SOLVER_OVERLAP_HITS_TOTAL);
                    deflate_cols(&mut xy);
                    if !apply_rinv(&mut xy, &r) {
                        bail!("deflated block iterate lost rank");
                    }
                    y = xy; // = (I-P)·X·Q_t, pre-QR
                }
                info.insert("block_power_iters".into(), iters as f64);
                for c in 0..kb {
                    w.set_col(c + 1, &wb.col(c));
                }
            }
            // final QR polish for strict orthonormality of the combined
            // [v_1 | deflated block] basis
            let (q, _) = qr_thin(&w);
            Ok((q, info))
        })
    }
}

/// Remove the components of `v` along an orthonormal set (twice, for
/// numerical hygiene).
fn deflate(v: &mut [f64], basis: &[Vec<f64>]) {
    for _ in 0..2 {
        for b in basis {
            let c = vec_ops::dot(v, b);
            vec_ops::axpy(v, -c, b);
        }
    }
}

/// Subspace analog of [`Estimate`].
#[derive(Clone, Debug)]
pub struct SubspaceEstimate {
    /// Orthonormal `d x k` basis estimate.
    pub w: Matrix,
    pub comm: crate::cluster::CommStats,
    pub wall: std::time::Duration,
    pub info: BTreeMap<String, f64>,
}

impl SubspaceEstimate {
    /// Theorem-7 metric against a reference basis.
    pub fn error(&self, v: &Matrix) -> f64 {
        subspace_error(&self.w, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::CovModel;

    fn cluster(m: usize, n: usize, d: usize, seed: u64) -> (Cluster, CovModel) {
        let model = CovModel::paper_fig1(d, seed ^ 0x5);
        let dist = model.clone().gaussian();
        (Cluster::generate(&dist, m, n, seed).unwrap(), model)
    }

    #[test]
    fn subspace_error_basics() {
        let i3 = Matrix::identity(3);
        let mut w = Matrix::zeros(3, 2);
        w.set_col(0, &[1.0, 0.0, 0.0]);
        w.set_col(1, &[0.0, 1.0, 0.0]);
        let mut v = Matrix::zeros(3, 2);
        v.set_col(0, &[0.0, 1.0, 0.0]);
        v.set_col(1, &[1.0, 0.0, 0.0]);
        // same subspace, swapped columns -> zero error (rotation invariance)
        assert!(subspace_error(&w, &v) < 1e-15);
        let mut u = Matrix::zeros(3, 2);
        u.set_col(0, &[0.0, 0.0, 1.0]);
        u.set_col(1, &[0.0, 1.0, 0.0]);
        // shares one direction of two -> error 1
        assert!((subspace_error(&w, &u) - 1.0).abs() < 1e-12);
        let _ = i3;
    }

    #[test]
    fn ortho_iteration_matches_centralized() {
        let (c, _) = cluster(4, 300, 10, 31);
        let k = 3;
        let cen = CentralizedSubspace { k }.run_mat(&c.session()).unwrap();
        let blk = DistributedOrthoIteration::new(k).run_mat(&c.session()).unwrap();
        let e = subspace_error(&blk.w, &cen.w);
        assert!(e < 1e-8, "block power should find the pooled top-k: {e:.3e}");
        // block protocol: ONE round per iteration, k matvecs billed per
        // round; the pipelined loop pays exactly one extra round — the
        // speculative block in flight when the drift test fired
        assert_eq!(blk.comm.rounds, blk.info["iters"] as u64 + 1);
        assert_eq!(blk.comm.matvec_products, blk.comm.rounds * k as u64);
    }

    #[test]
    fn pipelined_and_serialized_ortho_agree() {
        // the R^{-1} recovery step must not change what the iteration
        // converges to, and costs exactly one speculative round
        let (c, _) = cluster(4, 300, 10, 47);
        let k = 3;
        let piped = DistributedOrthoIteration::new(k).run_mat(&c.session()).unwrap();
        let serial = DistributedOrthoIteration::serialized(k).run_mat(&c.session()).unwrap();
        let e = subspace_error(&piped.w, &serial.w);
        assert!(e < 1e-10, "pipelined subspace drifted from serialized: {e:.3e}");
        assert!(crate::linalg::qr::orthonormality_defect(&piped.w) < 1e-10);
        assert_eq!(serial.comm.rounds, serial.info["iters"] as u64, "serial: 1 round/iter");
    }

    #[test]
    fn ortho_iteration_one_round_one_message_per_worker_per_iter() {
        // at a fixed iteration budget (tol = 0 never fires the drift
        // stop) the pipelined loop never speculates: bills are the
        // serialized loop's, exactly
        let (c, _) = cluster(5, 60, 12, 41);
        let k = 4;
        let iters = 3;
        let est =
            DistributedOrthoIteration { k, max_iters: iters, tol: 0.0, seed: 0x7, pipeline: true }
                .run_mat(&c.session())
                .unwrap();
        assert_eq!(est.info["iters"], iters as f64);
        assert_eq!(est.comm.rounds, iters as u64);
        assert_eq!(est.comm.requests_sent, (iters * 5) as u64);
        assert_eq!(est.comm.responses_received, (iters * 5) as u64);
        assert_eq!(est.comm.vectors_broadcast, (iters * k) as u64);
        assert_eq!(est.comm.vectors_gathered, (iters * 5 * k) as u64);
    }

    #[test]
    fn deflated_sni_batches_trailing_components_in_block_rounds() {
        let (c, _) = cluster(3, 200, 8, 43);
        let k = 3;
        let est = DeflatedShiftInvert::new(k).run_mat(&c.session()).unwrap();
        let sni_matvecs = est.info["sni_matvecs_0"];
        let block_iters = est.info["block_power_iters"];
        assert!(block_iters >= 1.0);
        // the pipelined block loop pays block_iters rounds plus the one
        // speculative round in flight when the drift test fired
        let block_rounds = block_iters + 1.0;
        // total matvec bill: component-0 solve + (k-1) per block round
        assert_eq!(
            est.comm.matvec_products as f64,
            sni_matvecs + block_rounds * (k - 1) as f64
        );
        // and the block rounds moved k-1 vectors per worker per round
        assert_eq!(
            est.comm.rounds as f64,
            sni_matvecs + block_rounds,
            "every solve matvec and every block round is one round"
        );
    }

    #[test]
    fn projection_average_recovers_population_subspace() {
        let (c, model) = cluster(8, 400, 10, 33);
        let k = 2;
        let est = SubspaceProjectionAverage { k }.run_mat(&c.session()).unwrap();
        let v = top_k_basis(&model, k);
        let e = est.error(&v);
        assert!(e < 0.2, "projection-average subspace error {e:.3e}");
        assert_eq!(est.comm.rounds, 1);
    }

    #[test]
    fn deflated_sni_matches_centralized_topk() {
        let (c, _) = cluster(4, 300, 8, 35);
        let k = 3;
        let cen = CentralizedSubspace { k }.run_mat(&c.session()).unwrap();
        let defl = DeflatedShiftInvert::new(k).run_mat(&c.session()).unwrap();
        let e = subspace_error(&defl.w, &cen.w);
        assert!(e < 1e-6, "deflated S&I subspace error {e:.3e}");
        // basis must be orthonormal
        let defect = crate::linalg::qr::orthonormality_defect(&defl.w);
        assert!(defect < 1e-10);
    }

    #[test]
    fn estimators_reject_bad_rank() {
        let (c, _) = cluster(2, 40, 4, 37);
        assert!(DistributedOrthoIteration::new(0).run_mat(&c.session()).is_err());
        assert!(DistributedOrthoIteration::new(5).run_mat(&c.session()).is_err());
        assert!(SubspaceProjectionAverage { k: 9 }.run_mat(&c.session()).is_err());
    }

    #[test]
    fn subspace_error_decreases_with_n() {
        let k = 2;
        let mut errs = Vec::new();
        for &n in &[50usize, 400] {
            let (c, model) = cluster(6, n, 8, 39);
            let est = SubspaceProjectionAverage { k }.run_mat(&c.session()).unwrap();
            errs.push(est.error(&top_k_basis(&model, k)));
        }
        assert!(errs[1] < errs[0], "more data should help: {errs:?}");
    }
}
