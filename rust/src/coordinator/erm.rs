//! ERM baselines: centralized (Lemma 1) and single-machine.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Session;
use crate::linalg::eigen::SymEigen;

use super::{instrumented, Algorithm, Estimate};

/// The centralized empirical risk minimizer: leading eigenvector of the
/// pooled empirical covariance `Xhat = (1/m) sum_i Xhat_i`.
///
/// This is the paper's **gold baseline** (Lemma 1): a single round, but a
/// heavy one — every machine ships its full `d x d` Gram matrix, i.e.
/// `d` vectors of traffic instead of one. The round-efficient algorithms
/// are judged by how closely they approach its error with `R^d`-sized
/// messages only.
#[derive(Clone, Debug, Default)]
pub struct CentralizedErm;

impl Algorithm for CentralizedErm {
    fn name(&self) -> &'static str {
        "centralized_erm"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let xhat = session.gram_average()?;
            let eig = SymEigen::new(&xhat);
            let mut info = BTreeMap::new();
            info.insert("lambda1_hat".into(), eig.lambda1());
            info.insert("gap_hat".into(), eig.eigengap());
            Ok((eig.leading(), info))
        })
    }
}

/// Machine 1's local ERM alone — the "what a single machine can do"
/// reference curve plotted in Figure 1 ("average loss of the individual
/// ERM solutions"). Zero communication.
#[derive(Clone, Debug, Default)]
pub struct SingleMachineErm;

impl Algorithm for SingleMachineErm {
    fn name(&self) -> &'static str {
        "single_machine_erm"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            // leader *is* machine 1: no communication
            let w = session.leader_shard().local_top_eigvec();
            Ok((w, BTreeMap::new()))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::*;
    use crate::data::Distribution;
    use crate::linalg::vec_ops::alignment_error;

    #[test]
    fn centralized_erm_matches_pooled_eigvec() {
        let (c, dist) = test_cluster(4, 60, 6, 11);
        let est = CentralizedErm.run(&c.session()).unwrap();
        let pooled = pooled_cov(&dist, 4, 60, 11);
        let want = crate::linalg::eigen::leading_eigvec(&pooled);
        assert!(alignment_error(&est.w, &want) < 1e-18);
        assert_eq!(est.comm.rounds, 1);
        // heavy round: m * d vectors
        assert_eq!(est.comm.vectors_gathered, 4 * 6);
    }

    #[test]
    fn centralized_beats_single_machine_on_average() {
        // average over several seeds: mn samples beat n samples
        let mut cen = 0.0;
        let mut single = 0.0;
        let runs = 12;
        for seed in 0..runs {
            let (c, dist) = test_cluster(8, 40, 5, 100 + seed);
            cen += CentralizedErm.run(&c.session()).unwrap().error(dist.v1());
            single += SingleMachineErm.run(&c.session()).unwrap().error(dist.v1());
        }
        assert!(
            cen < single,
            "centralized {:.3e} should beat single-machine {:.3e}",
            cen / runs as f64,
            single / runs as f64
        );
    }

    #[test]
    fn single_machine_no_communication() {
        let (c, _) = test_cluster(3, 30, 4, 13);
        let est = SingleMachineErm.run(&c.session()).unwrap();
        assert_eq!(est.comm.rounds, 0);
        assert_eq!(est.comm.bytes, 0);
    }

    #[test]
    fn centralized_info_reports_spectrum() {
        let (c, _) = test_cluster(3, 80, 4, 17);
        let est = CentralizedErm.run(&c.session()).unwrap();
        assert!(est.info["lambda1_hat"] > 0.0);
        assert!(est.info["gap_hat"] > 0.0);
    }
}
