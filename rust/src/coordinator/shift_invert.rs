//! Shift-and-Invert power iterations with locally-preconditioned linear
//! solves — Algorithm 1 + Algorithm 2, Theorem 6.
//!
//! Structure (faithful to Algorithm 1):
//!
//! 1. **Setup.** Rescale the problem to `b = 1` (paper's w.l.o.g.): all
//!    distributed matvecs are multiplied by `s^2 = 1/b_hat` at the leader,
//!    which rescales the spectrum without touching eigenvectors. The
//!    leader eigendecomposes its *local* covariance once (free) to get the
//!    gap estimate `delta_tilde`, the warm start `w_0` (licensed by the
//!    paper's remark after Lemma 5), and the preconditioner eigenbasis.
//! 2. **Shift search (repeat loop).** Starting from
//!    `lambda_(0) = 1 + delta_tilde`, run inverse power iterations
//!    (each inverse application = one preconditioned CG solve of
//!    `(lambda I - Xhat) z = w`; every CG iteration = one communication
//!    round), then estimate `Delta_s = 1/(2 (w_s^T v_s - eps_tilde))` and
//!    shrink the shift `lambda_(s) = lambda_(s-1) - Delta_s / 2` until
//!    `lambda - lambda_1(Xhat) = Theta(delta_hat)`.
//! 3. **Final phase.** Inverse power iterations at the frozen shift
//!    `lambda_(f)` drive `(w^T vhat_1)^2 >= 1 - eps`.
//!
//! ## Practical deviations from the paper's constants (see DESIGN.md)
//!
//! - The theoretical inner accuracy `eps_tilde ~ (delta/8)^{m_1+1}/16`
//!   underflows f64; solves use per-phase *relative* residual tolerances
//!   (coarse during the shift search, `~eps` in the final phase), the
//!   standard practice for inexact inverse iteration.
//! - `m_1`/`m_2` from Algorithm 1 line 2 are kept as **caps** with the
//!   usual early exit when consecutive iterates stop moving.
//! - `mu` defaults to a *data-driven* local estimate: the leader splits
//!   its shard in half and uses `||Xhat_1^a - Xhat_1^b|| / 2` (an unbiased
//!   proxy for the `n`-sample covariance deviation), times a safety
//!   factor. This preserves Lemma 6's requirement `mu >= ||Xhat - Xhat_1||`
//!   w.h.p. while being ~50x tighter than the worst-case
//!   `4 sqrt(ln(3d/p)/n)` Hoeffding envelope (which is available as
//!   [`MuStrategy::Theorem6`]).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cluster::Session;
use crate::data::Shard;
use crate::linalg::vec_ops::{alignment_error, axpy, dot, normalize, scale};
use crate::linalg::Matrix;

use super::precond::Preconditioner;
use super::solvers::{agd::agd, cg::pcg_with, SolveReport};
use super::{instrumented, Algorithm, Estimate};

/// Which inner solver drives the linear systems (Lemma 7 allows both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SniSolver {
    /// Preconditioned conjugate gradients (default).
    Pcg,
    /// Plain CG — no preconditioner (ablation).
    PlainCg,
    /// Nesterov AGD on the explicitly transformed Problem (13).
    Agd,
}

/// How to pick the Lemma-6 regularizer `mu`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MuStrategy {
    /// Split-sample local estimate (default; see module docs).
    SplitEstimate,
    /// Theorem 6's worst-case `4 sqrt(ln(3d/p)/n)`.
    Theorem6,
    /// Fixed value (ablation).
    Fixed(f64),
}

/// Configuration for [`ShiftInvert`].
#[derive(Clone, Debug)]
pub struct SniConfig {
    /// Target accuracy: `(w^T vhat_1)^2 >= 1 - eps`.
    pub eps: f64,
    /// Failure probability budget (drives `m_1`, `m_2`, Theorem-6 `mu`).
    pub p: f64,
    /// Inner solver.
    pub solver: SniSolver,
    /// Regularizer strategy.
    pub mu: MuStrategy,
    /// Override `m_1` / `m_2` caps (defaults: Algorithm 1 line 2).
    pub m1_override: Option<usize>,
    pub m2_override: Option<usize>,
    /// Cap on shift-search outer rounds.
    pub max_outer: usize,
    /// Per-solve CG/AGD iteration cap.
    pub max_inner: usize,
    /// Start from a random vector instead of machine 1's eigenvector.
    pub random_init: bool,
    /// Seed (only used with `random_init`).
    pub seed: u64,
}

impl Default for SniConfig {
    fn default() -> Self {
        SniConfig {
            eps: 1e-8,
            p: 0.1,
            solver: SniSolver::Pcg,
            mu: MuStrategy::SplitEstimate,
            m1_override: None,
            m2_override: None,
            max_outer: 16,
            max_inner: 2_000,
            random_init: false,
            seed: 0x51,
        }
    }
}

/// The Theorem-6 algorithm.
#[derive(Clone, Debug, Default)]
pub struct ShiftInvert {
    pub config: SniConfig,
}

impl ShiftInvert {
    pub fn new(config: SniConfig) -> Self {
        ShiftInvert { config }
    }

    /// Ablation convenience: same algorithm, solver swapped.
    pub fn with_solver(solver: SniSolver) -> Self {
        ShiftInvert { config: SniConfig { solver, ..Default::default() } }
    }
}

/// Split-sample deviation estimate: `||Xhat^a - Xhat^b|| / 2` over the two
/// halves of the leader shard approximates the spectral deviation of the
/// full-shard covariance from the population (both halves deviate by
/// `~sqrt(2/n) sigma` independently, so their difference has norm
/// `~2 sigma/sqrt(n)`). A 2x safety factor then dominates
/// `||Xhat - Xhat_1||` w.h.p.
fn split_mu_estimate(shard: &Shard, s2: f64) -> f64 {
    let n = shard.n();
    let d = shard.d();
    if n < 4 {
        return 1.0; // degenerate; forces conservative preconditioning
    }
    let half = n / 2;
    let mut a = Matrix::zeros(d, d);
    let mut b = Matrix::zeros(d, d);
    for i in 0..n {
        let target = if i < half { &mut a } else { &mut b };
        shard.add_row_outer(i, target);
    }
    a.scale_mut(s2 / half as f64);
    b.scale_mut(s2 / (n - half) as f64);
    let dev = a.sub(&b).sym_spectral_norm() / 2.0;
    2.0 * dev
}

impl Algorithm for ShiftInvert {
    fn name(&self) -> &'static str {
        match self.config.solver {
            SniSolver::Pcg => "shift_invert_pcg",
            SniSolver::PlainCg => "shift_invert_cg",
            SniSolver::Agd => "shift_invert_agd",
        }
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        let cfg = &self.config;
        instrumented(session, || {
            let d = session.d();
            let n = session.n();

            // ---- setup: rescale to b = 1 --------------------------------
            let b_hat = (session.leader_shard().max_row_norm_sq() * 1.2).max(1e-12);
            let s2 = 1.0 / b_hat;
            let matvec = |v: &[f64]| -> Result<Vec<f64>> {
                let mut out = session.dist_matvec(v)?;
                scale(&mut out, s2);
                Ok(out)
            };

            // leader-local spectral estimates (free, no communication)
            let local_cov = session.leader_shard().empirical_covariance().scale(s2);
            let mu = match cfg.mu {
                MuStrategy::Fixed(m) => m,
                MuStrategy::Theorem6 => Preconditioner::theorem6_mu(d, n, cfg.p),
                MuStrategy::SplitEstimate => split_mu_estimate(session.leader_shard(), s2),
            };
            let pc = Preconditioner::new(&local_cov, mu);
            let lambda1_est = pc.lambda1_local();
            let delta_tilde = (pc.gap_local() * 0.5).max(1e-12);

            // Algorithm 1 line 2: iteration caps
            let m1 = cfg
                .m1_override
                .unwrap_or_else(|| (8.0 * (144.0 * d as f64 / (cfg.p * cfg.p)).ln()).ceil() as usize);
            let m2 = cfg.m2_override.unwrap_or_else(|| {
                (1.5 * (18.0 * d as f64 / (cfg.p * cfg.p * cfg.eps)).ln()).ceil() as usize
            });
            let eps_tilde = (cfg.eps * delta_tilde / 64.0).clamp(1e-13, 1e-4);

            // warm start (paper's remark) or random
            let mut w = if cfg.random_init {
                let mut rng = crate::rng::Pcg64::new(cfg.seed);
                let mut v = rng.gaussian_vec(d);
                normalize(&mut v);
                v
            } else {
                pc.local_top_eigvec()
            };

            let mut solve_count = 0usize;
            let mut inner_iters_total = 0usize;

            // Split-phase pipelining: the CG solvers spend their first
            // operator application on `A x0 = lambda x0 - X' x0` when
            // warm-started — and `X' x0` is lambda-independent, so the
            // outer loops below put that distributed matvec on the wire
            // (`dist_matvec_submit`) the moment the warm start is known,
            // overlap the leader-side bookkeeping (normalize, drift
            // probe, tolerance annealing, shift update) with the
            // in-flight round, and hand the completed product in here.
            // Assembled identically to `apply(x0)`, so the iterate
            // sequence — and the bill — is exactly the serial run's.
            let probes = !matches!(cfg.solver, SniSolver::Agd);

            // one approximate inverse application:
            // solve (lambda I - X') z = rhs to relative residual `rel_tol`
            let mut solve = |lambda: f64,
                             rhs: &[f64],
                             x0: Option<&[f64]>,
                             rel_tol: f64,
                             probe: Option<Vec<f64>>|
             -> Result<(Vec<f64>, SolveReport)> {
                let tol = rel_tol * crate::linalg::vec_ops::norm(rhs).max(1e-300);
                let apply = |v: &[f64]| -> Vec<f64> {
                    let mv = matvec(v).expect("distributed matvec failed");
                    let mut out = v.to_vec();
                    scale(&mut out, lambda);
                    axpy(&mut out, -1.0, &mv);
                    out
                };
                // a prefetched raw matvec of x0 becomes A x0 = lambda
                // x0 - s^2 (X x0): the same arithmetic `apply` performs
                let ax0 = match (x0, probe) {
                    (Some(x0), Some(raw)) => {
                        let mut mv = raw;
                        scale(&mut mv, s2);
                        let mut ax = x0.to_vec();
                        scale(&mut ax, lambda);
                        axpy(&mut ax, -1.0, &mv);
                        Some(ax)
                    }
                    _ => None,
                };
                let (z, rep) = match cfg.solver {
                    SniSolver::Pcg => pcg_with(
                        apply,
                        |r, out| pc.apply_inv(lambda, r, out),
                        rhs,
                        x0,
                        ax0,
                        tol,
                        cfg.max_inner,
                    ),
                    SniSolver::PlainCg => pcg_with(
                        apply,
                        |r, out| out.copy_from_slice(r),
                        rhs,
                        x0,
                        ax0,
                        tol,
                        cfg.max_inner,
                    ),
                    SniSolver::Agd => {
                        // explicit Eq.-(13) transform: H = C^{-1/2} M C^{-1/2}
                        let mut c_rhs = vec![0.0; d];
                        let mut h_apply = |y: &[f64]| -> Vec<f64> {
                            let mut u = vec![0.0; d];
                            pc.apply_inv_sqrt(lambda, y, &mut u);
                            let mu_v = apply(&u);
                            let mut out = vec![0.0; d];
                            pc.apply_inv_sqrt(lambda, &mu_v, &mut out);
                            out
                        };
                        pc.apply_inv_sqrt(lambda, rhs, &mut c_rhs);
                        let kappa = pc.kappa_bound(lambda, lambda1_est);
                        // Lemma 6: beta = 1, alpha = 1/kappa
                        let (y, rep) =
                            agd(&mut h_apply, &c_rhs, None, 1.0 / kappa, 1.0, tol, cfg.max_inner);
                        let mut z = vec![0.0; d];
                        pc.apply_inv_sqrt(lambda, &y, &mut z);
                        (z, rep)
                    }
                };
                solve_count += 1;
                inner_iters_total += rep.iters;
                Ok((z, rep))
            };

            // ---- phase 1: shift search (repeat loop) --------------------
            // Coarse solves: the shift estimates only need ~1% accuracy.
            //
            // Initial shift: Algorithm 1 uses `lambda_(0) = 1 + delta_tilde`
            // (valid since b = 1 implies lambda_1 <= 1). When
            // `n = Omega(delta^-2 ln(d/p))` the paper's remark licenses
            // estimating `lambda_1(Xhat)` from machine 1 alone, so we start
            // just above the local estimate (with a `mu`-sized margin for
            // the local/pooled deviation) instead of walking the shift all
            // the way down from 1 — same guarantees, far fewer rounds.
            let phase1_tol = 1e-2;
            let mut lambda =
                (lambda1_est + delta_tilde.max(2.0 * mu)).min(1.0 + delta_tilde);
            if lambda <= lambda1_est {
                lambda = lambda1_est + delta_tilde; // defensive
            }
            let mut outer = 0usize;
            let mut warm: Option<Vec<f64>> = None;
            // prefetched raw dist_matvec of `warm`, for the next solve's
            // first CG application (see `probes` above)
            let mut prefetched: Option<Vec<f64>> = None;
            loop {
                outer += 1;
                // inverse power iterations with early exit (cap m1)
                for _t in 0..m1 {
                    let (z, _rep) =
                        solve(lambda, &w, warm.as_deref(), phase1_tol, prefetched.take())?;
                    // z is the next warm start whatever happens below
                    // (the next inner solve, or the shift-update solve),
                    // so its matvec round can overlap the drift probe —
                    // never wasted in this loop
                    let ticket =
                        if probes { Some(session.dist_matvec_submit(&z)?) } else { None };
                    let mut znorm = z.clone();
                    let nz = normalize(&mut znorm);
                    if nz == 0.0 {
                        bail!("inverse power iterate vanished");
                    }
                    let drift = alignment_error(&znorm, &w);
                    warm = Some(z);
                    w = znorm;
                    prefetched = match ticket {
                        Some(t) => Some(t.complete()?),
                        None => None,
                    };
                    if drift < 1e-4 {
                        break;
                    }
                }
                // shift update: v_s ~= M^{-1} w_s, w^T v ~= 1/(lambda - lambda_1)
                let (v_s, _rep) = solve(lambda, &w, warm.as_deref(), 1e-3, prefetched.take())?;
                let wv = dot(&w, &v_s) - eps_tilde;
                let delta_s = if wv > 0.0 { 0.5 / wv } else { delta_tilde };
                if delta_s <= delta_tilde || outer >= cfg.max_outer {
                    break; // lambda - lambda_1(Xhat) = Theta(delta_hat)
                }
                lambda -= 0.5 * delta_s;
                if lambda <= lambda1_est + 0.25 * delta_tilde {
                    lambda = lambda1_est + 0.25 * delta_tilde;
                    break;
                }
                // shift moved: previous solution no longer a valid warm start scale
                warm = None;
            }

            // ---- phase 2: final inverse power iterations ----------------
            let matvecs_phase1 = session.stats().matvec_products;
            let lambda_f = lambda;
            // Inexact inverse iteration: the per-solve *relative* accuracy
            // only needs to track the iterate's own convergence — the
            // attainable alignment error scales with the solve error, so a
            // `sqrt(eps)`-floor suffices for a final error of `eps`.
            // Anneal the tolerance with the measured drift instead of
            // paying a machine-precision solve on every iteration.
            let tol_floor = (cfg.eps.sqrt() * 0.03).clamp(1e-12, 1e-2);
            let mut phase2_tol: f64 = 1e-2;
            let mut final_iters = 0usize;
            let mut warm: Option<Vec<f64>> = None;
            let mut prefetched: Option<Vec<f64>> = None;
            for t in 0..m2 {
                let (z, _rep) =
                    solve(lambda_f, &w, warm.as_deref(), phase2_tol, prefetched.take())?;
                // prefetch the next solve's A·z round and overlap it
                // with the drift probe + tolerance annealing below.
                // Speculative at the convergence boundary: if this turns
                // out to be the last iteration, the in-flight round is
                // completed and discarded — one extra matvec round per
                // run, paid identically by solo and concurrent runs.
                let ticket = if probes && t + 1 < m2 {
                    Some(session.dist_matvec_submit(&z)?)
                } else {
                    None
                };
                let mut znorm = z.clone();
                let nz = normalize(&mut znorm);
                final_iters += 1;
                if nz == 0.0 {
                    bail!("inverse power iterate vanished in final phase");
                }
                let drift = alignment_error(&znorm, &w);
                warm = Some(z);
                w = znorm;
                prefetched = match ticket {
                    Some(t) => Some(t.complete()?),
                    None => None,
                };
                // exit only once the solves have annealed to full accuracy
                // AND the iterate has stopped moving — a small drift under
                // coarse solves is not yet evidence of convergence.
                if drift < (cfg.eps * 1e-2).max(1e-16) && phase2_tol <= tol_floor * 1.01 {
                    break;
                }
                phase2_tol = (0.1 * drift).clamp(tol_floor, 1e-2);
            }

            let mut info = BTreeMap::new();
            info.insert("outer_rounds".into(), outer as f64);
            info.insert("final_iters".into(), final_iters as f64);
            info.insert("solves".into(), solve_count as f64);
            info.insert("inner_iters_total".into(), inner_iters_total as f64);
            info.insert("lambda_f".into(), lambda_f);
            info.insert("mu".into(), mu);
            info.insert("delta_tilde".into(), delta_tilde);
            info.insert("m1".into(), m1 as f64);
            info.insert("m2".into(), m2 as f64);
            info.insert("b_hat".into(), b_hat);
            info.insert("matvecs_phase1".into(), matvecs_phase1 as f64);
            info.insert(
                "matvecs_phase2".into(),
                (session.stats().matvec_products - matvecs_phase1) as f64,
            );
            Ok((w, info))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::{CentralizedErm, DistributedLanczos};
    use super::*;
    use crate::coordinator::Algorithm;
    use crate::linalg::vec_ops::alignment_error;

    #[test]
    fn sni_matches_centralized_erm() {
        let (c, _) = test_cluster(4, 200, 6, 81);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let sni = ShiftInvert::default().run(&c.session()).unwrap();
        let err = alignment_error(&sni.w, &cen.w);
        assert!(err < 1e-6, "S&I should find the pooled eigenvector, err={err:.3e}");
    }

    #[test]
    fn sni_all_solvers_agree() {
        let (c, _) = test_cluster(4, 150, 5, 83);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        for solver in [SniSolver::Pcg, SniSolver::PlainCg, SniSolver::Agd] {
            let est = ShiftInvert::with_solver(solver).run(&c.session()).unwrap();
            let err = alignment_error(&est.w, &cen.w);
            assert!(err < 1e-4, "{solver:?} err={err:.3e}");
        }
    }

    /// Spread (linear-decay) spectrum: eigenvalues do not cluster, so CG
    /// cannot converge superlinearly and the Lemma-6 bound is the binding
    /// constraint — the regime where preconditioning pays.
    fn spread_cluster(
        m: usize,
        n: usize,
        d: usize,
        delta: f64,
        seed: u64,
    ) -> crate::cluster::Cluster {
        let mut sigma = vec![1.0, 1.0 - delta];
        for j in 2..d {
            sigma.push((1.0 - delta) * (1.0 - (j as f64 - 1.0) / d as f64));
        }
        let dist = crate::data::CovModel::axis_aligned(sigma).gaussian();
        crate::cluster::Cluster::generate(&dist, m, n, seed).unwrap()
    }

    #[test]
    fn preconditioning_reduces_rounds() {
        // spread spectrum + large n (small mu): preconditioned solves
        // need fewer distributed matvecs (Lemma 6)
        let c = spread_cluster(4, 6000, 48, 0.05, 87);
        let mk = |solver| {
            ShiftInvert::new(SniConfig { solver, random_init: true, ..Default::default() })
                .run(&c.session())
                .unwrap()
        };
        let pcg_est = mk(SniSolver::Pcg);
        let cg_est = mk(SniSolver::PlainCg);
        // End-to-end the effect is muted (late solves have near-eigenvector
        // right-hand sides that plain CG resolves in O(1) iterations — see
        // EXPERIMENTS.md E7); require PCG to be at worst marginally more
        // expensive here and strictly better per worst-case solve below.
        assert!(
            pcg_est.comm.matvec_products <= cg_est.comm.matvec_products * 3 / 2,
            "pcg {} !<= 1.5x cg {}",
            pcg_est.comm.matvec_products,
            cg_est.comm.matvec_products
        );
    }

    #[test]
    fn preconditioner_advantage_grows_with_n() {
        // Lemma 6: kappa <= 1 + 2 mu / (lambda - lambda_1), mu ~ n^{-1/2}
        // -> per-solve iteration count shrinks with n while plain CG's
        // stays put. Checked at the solver level on one explicit system.
        use crate::coordinator::precond::Preconditioner;
        use crate::coordinator::solvers::cg::pcg as pcg_solve;
        use crate::data::Distribution;
        let d = 80;
        let m = 5;
        let mut iters_small = 0;
        let mut iters_large = 0;
        for (n, slot) in [(500usize, &mut iters_small), (8000, &mut iters_large)] {
            let delta = 0.05;
            let mut sigma = vec![1.0, 1.0 - delta];
            for j in 2..d {
                sigma.push((1.0 - delta) * (1.0 - (j as f64 - 1.0) / d as f64));
            }
            let dist = crate::data::CovModel::axis_aligned(sigma).gaussian();
            let mut rng = crate::rng::Pcg64::new(11);
            let shards: Vec<_> = (0..m).map(|_| dist.sample_shard(&mut rng, n)).collect();
            let mut pooled = crate::linalg::Matrix::zeros(d, d);
            for s in &shards {
                pooled.axpy_mat(1.0 / m as f64, s.empirical_covariance());
            }
            let eig = crate::linalg::SymEigen::new(&pooled);
            let lambda = eig.lambda1() + 0.25 * eig.eigengap();
            let local = shards[0].empirical_covariance().clone();
            let mu = 2.0 * pooled.sub(&local).sym_spectral_norm();
            let pc = Preconditioner::new(&local, mu);
            let mut mmat = crate::linalg::Matrix::identity(d).scale(lambda);
            mmat.axpy_mat(-1.0, &pooled);
            let mut rhs = rng.gaussian_vec(d);
            crate::linalg::vec_ops::normalize(&mut rhs);
            let (_, rep) = pcg_solve(
                |v| mmat.matvec(v),
                |r, out| pc.apply_inv(lambda, r, out),
                &rhs,
                None,
                1e-9,
                20_000,
            );
            *slot = rep.iters;
        }
        assert!(
            iters_large < iters_small,
            "PCG iters should shrink with n: n=500 -> {iters_small}, n=8000 -> {iters_large}"
        );
    }

    #[test]
    fn matvec_count_is_round_count() {
        let (c, _) = test_cluster(3, 100, 5, 89);
        let est = ShiftInvert::default().run(&c.session()).unwrap();
        assert_eq!(est.comm.rounds, est.comm.matvec_products);
        assert!(est.comm.rounds > 0);
    }

    #[test]
    fn info_diagnostics_complete() {
        let (c, _) = test_cluster(3, 100, 4, 91);
        let est = ShiftInvert::default().run(&c.session()).unwrap();
        for key in ["outer_rounds", "final_iters", "solves", "lambda_f", "mu", "delta_tilde"] {
            assert!(est.info.contains_key(key), "missing info key {key}");
        }
        assert!(est.info["lambda_f"] > 0.0);
    }

    #[test]
    fn random_init_also_converges() {
        let (c, _) = test_cluster(4, 150, 5, 93);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let cfg = SniConfig { random_init: true, ..Default::default() };
        let est = ShiftInvert::new(cfg).run(&c.session()).unwrap();
        assert!(alignment_error(&est.w, &cen.w) < 1e-5);
    }

    #[test]
    fn split_mu_tracks_sample_size() {
        // mu estimate should shrink ~1/sqrt(n)
        let dist = crate::data::CovModel::paper_fig1(8, 5).gaussian();
        let mut rng = crate::rng::Pcg64::new(7);
        let small = crate::data::Distribution::sample_shard(&dist, &mut rng, 200);
        let large = crate::data::Distribution::sample_shard(&dist, &mut rng, 3200);
        let mu_small = split_mu_estimate(&small, 1.0);
        let mu_large = split_mu_estimate(&large, 1.0);
        let ratio = mu_small / mu_large;
        assert!(ratio > 2.0, "mu should shrink with n: {mu_small:.3e} vs {mu_large:.3e}");
    }

    #[test]
    fn competitive_with_lanczos_at_large_n() {
        // Theorem 6's regime: large n per machine -> S&I's matvec count is
        // in the same ballpark as Lanczos (and scales *down* with n, which
        // Lanczos's does not — see bench_scaling for the full sweep).
        let (c, _) = fig1_cluster(4, 2000, 24, 95);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let lan = DistributedLanczos { tol: 1e-10, ..Default::default() }.run(&c.session()).unwrap();
        let sni = ShiftInvert::new(SniConfig { eps: 1e-6, ..Default::default() }).run(&c.session()).unwrap();
        assert!(alignment_error(&lan.w, &cen.w) < 1e-5);
        assert!(alignment_error(&sni.w, &cen.w) < 1e-5);
        assert!(
            sni.comm.matvec_products <= 8 * lan.comm.matvec_products,
            "sni {} vs lanczos {}",
            sni.comm.matvec_products,
            lan.comm.matvec_products
        );
    }
}
