//! Distributed power method (§2.2.2).
//!
//! Each iteration multiplies the current iterate by the pooled empirical
//! covariance via one [`Session::dist_matvec`] round and renormalizes.
//! Round complexity `O((lambda_1/delta) ln(d / p eps))` to reach
//! `1 - (w^T vhat_1)^2 <= eps`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Session;
use crate::linalg::vec_ops::{alignment_error, normalize};
use crate::rng::Pcg64;

use super::{instrumented, Algorithm, Estimate};

/// Distributed power iterations.
#[derive(Clone, Debug)]
pub struct DistributedPower {
    /// Hard iteration cap (each iteration = 1 round).
    pub max_iters: usize,
    /// Stop when consecutive iterates satisfy
    /// `1 - <w_k, w_{k+1}>^2 <= tol`.
    pub tol: f64,
    /// Seed for the random start vector.
    pub seed: u64,
    /// Start from machine 1's local eigenvector instead of random
    /// (free, and already constant-correlated with `vhat_1` whp — same
    /// warm start the S&I remark licenses).
    pub warm_start: bool,
}

impl Default for DistributedPower {
    fn default() -> Self {
        DistributedPower { max_iters: 2_000, tol: 1e-18, seed: 0x9d, warm_start: false }
    }
}

impl Algorithm for DistributedPower {
    fn name(&self) -> &'static str {
        "distributed_power"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let d = session.d();
            let mut w = if self.warm_start {
                session.leader_shard().local_top_eigvec()
            } else {
                let mut rng = Pcg64::new(self.seed);
                let mut v = rng.gaussian_vec(d);
                normalize(&mut v);
                v
            };
            let mut iters = 0usize;
            for _ in 0..self.max_iters {
                let mut next = session.dist_matvec(&w)?;
                let nn = normalize(&mut next);
                iters += 1;
                if nn == 0.0 {
                    // w orthogonal to range — reseed
                    let mut rng = Pcg64::new(self.seed ^ iters as u64);
                    next = rng.gaussian_vec(d);
                    normalize(&mut next);
                }
                let drift = alignment_error(&next, &w);
                w = next;
                if drift <= self.tol {
                    break;
                }
            }
            let mut info = BTreeMap::new();
            info.insert("iters".into(), iters as f64);
            Ok((w, info))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::CentralizedErm;
    use super::*;

    #[test]
    fn power_converges_to_centralized_erm() {
        let (c, _) = test_cluster(4, 100, 6, 51);
        let cen = CentralizedErm.run(&c.session()).unwrap();
        let pow = DistributedPower::default().run(&c.session()).unwrap();
        assert!(
            alignment_error(&pow.w, &cen.w) < 1e-10,
            "power should find the pooled leading eigenvector, err={}",
            alignment_error(&pow.w, &cen.w)
        );
    }

    #[test]
    fn rounds_equal_iterations() {
        let (c, _) = test_cluster(3, 50, 5, 53);
        let est = DistributedPower { max_iters: 7, tol: 0.0, seed: 1, warm_start: false }
            .run(&c.session())
            .unwrap();
        assert_eq!(est.comm.rounds, 7);
        assert_eq!(est.comm.matvec_products, 7);
        assert_eq!(est.info["iters"], 7.0);
    }

    #[test]
    fn warm_start_converges_faster() {
        let (c, _) = fig1_cluster(4, 300, 8, 57);
        let cold = DistributedPower { tol: 1e-16, ..Default::default() }.run(&c.session()).unwrap();
        let warm = DistributedPower { tol: 1e-16, warm_start: true, ..Default::default() }
            .run(&c.session())
            .unwrap();
        assert!(
            warm.comm.rounds <= cold.comm.rounds,
            "warm {} !<= cold {}",
            warm.comm.rounds,
            cold.comm.rounds
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (c, _) = test_cluster(3, 40, 4, 59);
        let a = DistributedPower::default().run(&c.session()).unwrap();
        let b = DistributedPower::default().run(&c.session()).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.comm.rounds, b.comm.rounds);
    }
}
