//! "Hot-potato" SGD (§2.2.2): Oja's rule passed machine to machine.
//!
//! The iterate makes a full pass over each machine's `n` samples before
//! being handed to the next machine — `m` communication rounds total for
//! one pass over all `mn` points. With the `eta_t ~ 1/(delta t)` schedule
//! of [Jain et al. '16] the final error is `O(b^2 ln d / (delta^2 mn))`,
//! i.e. centralized-ERM order (Eq. (6) in the paper).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Session;
use crate::linalg::vec_ops::normalize;
use crate::rng::Pcg64;

use super::{instrumented, Algorithm, Estimate};

/// Hot-potato Oja SGD.
#[derive(Clone, Debug)]
pub struct HotPotatoOja {
    /// Step size schedule `eta_t = eta0 / (t0 + t)`. When `None`, both
    /// are chosen from machine 1's local spectrum (free): the classical
    /// `eta0 = c / gap_hat` with a burn-in offset `t0` that keeps early
    /// steps below 1.
    pub eta0: Option<f64>,
    pub t0: Option<f64>,
    /// Step-size constant `c` in `eta0 = c / gap_hat` for the auto
    /// schedule.
    pub c: f64,
    /// Seed for the random start vector.
    pub seed: u64,
}

impl Default for HotPotatoOja {
    fn default() -> Self {
        HotPotatoOja { eta0: None, t0: None, c: 2.0, seed: 0x0ca }
    }
}

impl Algorithm for HotPotatoOja {
    fn name(&self) -> &'static str {
        "hot_potato_oja"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let d = session.d();
            // free local estimates from the leader (machine 1)
            let leader_eig = session.leader_shard().local_eigen();
            let gap_hat = leader_eig.eigengap().max(1e-6);
            let eta0 = self.eta0.unwrap_or(self.c / gap_hat);
            // burn-in: keep eta_t <= 1/lambda1_hat at t = 0
            let t0 = self
                .t0
                .unwrap_or_else(|| (eta0 * leader_eig.lambda1()).max(1.0));
            let mut rng = Pcg64::new(self.seed);
            let mut w0 = rng.gaussian_vec(d);
            normalize(&mut w0);
            let w = session.oja_chain(&w0, eta0, t0)?;
            let mut info = BTreeMap::new();
            info.insert("eta0".into(), eta0);
            info.insert("t0".into(), t0);
            Ok((w, info))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::*;
    use crate::data::Distribution;

    #[test]
    fn exactly_m_rounds() {
        let (c, _) = test_cluster(7, 40, 5, 71);
        let est = HotPotatoOja::default().run(&c.session()).unwrap();
        assert_eq!(est.comm.rounds, 7);
    }

    #[test]
    fn error_decreases_with_more_data() {
        // mn doubling should shrink the average error
        let runs = 10;
        let mut small = 0.0;
        let mut large = 0.0;
        for seed in 0..runs {
            let (c1, dist) = test_cluster(4, 100, 5, 500 + seed);
            small += HotPotatoOja::default().run(&c1.session()).unwrap().error(dist.v1());
            let (c2, dist2) = test_cluster(4, 800, 5, 600 + seed);
            large += HotPotatoOja::default().run(&c2.session()).unwrap().error(dist2.v1());
        }
        assert!(
            large < small,
            "avg error with 8x data ({:.3e}) should beat ({:.3e})",
            large / runs as f64,
            small / runs as f64
        );
    }

    #[test]
    fn reaches_reasonable_accuracy() {
        let (c, dist) = test_cluster(8, 500, 6, 73);
        let est = HotPotatoOja::default().run(&c.session()).unwrap();
        let err = est.error(dist.v1());
        assert!(err < 0.05, "oja error {err}");
    }

    #[test]
    fn explicit_schedule_respected() {
        let (c, _) = test_cluster(3, 30, 4, 79);
        let est = HotPotatoOja { eta0: Some(0.25), t0: Some(5.0), ..Default::default() }
            .run(&c.session())
            .unwrap();
        assert_eq!(est.info["eta0"], 0.25);
        assert_eq!(est.info["t0"], 5.0);
    }
}
