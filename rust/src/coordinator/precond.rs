//! Local preconditioning of the shifted linear systems (§4.2, Lemma 6).
//!
//! For the system `M z = w`, `M = lambda I - Xhat`, machine 1 (the
//! leader) builds `C = (lambda + mu) I - Xhat_1` from **its own data
//! only** — no communication — and the solver runs on the transformed
//! problem `C^{-1/2} M C^{-1/2}` whose condition number is bounded by
//! `1 + 2 mu / (lambda - lambda_1(Xhat))` once
//! `mu >= ||Xhat - Xhat_1||` (statistically, `mu ~ 4 sqrt(ln(d/p)/n)`).
//!
//! Key optimization (recorded in DESIGN.md §6): the eigendecomposition of
//! `Xhat_1` is computed **once**; for every new shift `lambda` the maps
//! `C^{-1}` and `C^{-1/2}` are diagonal rescales in that fixed eigenbasis,
//! i.e. `O(d^2)` per application instead of `O(d^3)` per shift.

use crate::linalg::eigen::SymEigen;
use crate::linalg::Matrix;

/// Spectral preconditioner built from machine 1's empirical covariance.
pub struct Preconditioner {
    /// Eigendecomposition of the (rescaled) local covariance `Xhat_1`.
    eig: SymEigen,
    /// Regularizer `mu` (Lemma 6 / Theorem 6).
    mu: f64,
}

impl Preconditioner {
    /// Build from the leader's local covariance matrix.
    pub fn new(local_cov: &Matrix, mu: f64) -> Self {
        assert!(mu >= 0.0);
        Preconditioner { eig: SymEigen::new(local_cov), mu }
    }

    /// Build from a pre-computed eigendecomposition.
    pub fn from_eigen(eig: SymEigen, mu: f64) -> Self {
        Preconditioner { eig, mu }
    }

    pub fn mu(&self) -> f64 {
        self.mu
    }

    /// Leading eigenvalue of the local covariance (the leader's free
    /// estimate of `lambda_1(Xhat)`).
    pub fn lambda1_local(&self) -> f64 {
        self.eig.lambda1()
    }

    /// Local eigengap estimate.
    pub fn gap_local(&self) -> f64 {
        self.eig.eigengap()
    }

    /// Leading local eigenvector — the warm start the paper's remark
    /// licenses when `n = Omega(delta^-2 ln(d/p))`.
    pub fn local_top_eigvec(&self) -> Vec<f64> {
        self.eig.leading()
    }

    /// Eigenvalues of `C = (lambda + mu) I - Xhat_1` are
    /// `lambda + mu - s_i`; all must be positive for `C` to be PD.
    /// Floors at a tiny positive value for numerical safety.
    #[inline]
    fn c_eigval(&self, lambda: f64, s: f64) -> f64 {
        (lambda + self.mu - s).max(1e-12)
    }

    /// `out = C^{-1} r` for the current shift.
    pub fn apply_inv(&self, lambda: f64, r: &[f64], out: &mut [f64]) {
        self.eig.apply_fn_vec(|s| 1.0 / self.c_eigval(lambda, s), r, out);
    }

    /// `out = C^{-1/2} r` (used by the explicit Eq.-(13) transformation in
    /// the AGD solver path).
    pub fn apply_inv_sqrt(&self, lambda: f64, r: &[f64], out: &mut [f64]) {
        self.eig.apply_fn_vec(|s| 1.0 / self.c_eigval(lambda, s).sqrt(), r, out);
    }

    /// `out = C^{1/2} r` (test/diagnostic use).
    pub fn apply_sqrt(&self, lambda: f64, r: &[f64], out: &mut [f64]) {
        self.eig.apply_fn_vec(|s| self.c_eigval(lambda, s).sqrt(), r, out);
    }

    /// Lemma 6 condition-number bound `1 + 2 mu / (lambda - lambda1_hat)`
    /// given an estimate of the pooled `lambda_1`.
    pub fn kappa_bound(&self, lambda: f64, lambda1_hat: f64) -> f64 {
        let gap = (lambda - lambda1_hat).max(1e-12);
        1.0 + 2.0 * self.mu / gap
    }

    /// Theorem 6's statistical choice `mu = 4 sqrt(ln(3d/p)/n)` (for data
    /// rescaled to `b = 1`).
    pub fn theorem6_mu(d: usize, n: usize, p: f64) -> f64 {
        4.0 * ((3.0 * d as f64 / p).ln() / n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn local_cov(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, d, (0..n * d).map(|_| 0.3 * rng.next_gaussian()).collect());
        a.syrk_t().scale(1.0 / n as f64)
    }

    #[test]
    fn inv_matches_explicit_inverse() {
        let cov = local_cov(100, 6, 1);
        let mu = 0.1;
        let lambda = SymEigen::new(&cov).lambda1() + 0.2;
        let pc = Preconditioner::new(&cov, mu);
        // explicit C
        let mut c = Matrix::identity(6).scale(lambda + mu);
        c.axpy_mat(-1.0, &cov);
        let cinv = SymEigen::new(&c).apply_fn(|x| 1.0 / x);
        let mut rng = Pcg64::new(2);
        let r = rng.gaussian_vec(6);
        let want = cinv.matvec(&r);
        let mut got = vec![0.0; 6];
        pc.apply_inv(lambda, &r, &mut got);
        for i in 0..6 {
            assert!((got[i] - want[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn inv_sqrt_squares_to_inv() {
        let cov = local_cov(60, 5, 3);
        let pc = Preconditioner::new(&cov, 0.05);
        let lambda = pc.lambda1_local() + 0.1;
        let mut rng = Pcg64::new(4);
        let r = rng.gaussian_vec(5);
        let mut half = vec![0.0; 5];
        pc.apply_inv_sqrt(lambda, &r, &mut half);
        let mut full = vec![0.0; 5];
        pc.apply_inv_sqrt(lambda, &half.clone(), &mut full);
        let mut direct = vec![0.0; 5];
        pc.apply_inv(lambda, &r, &mut direct);
        for i in 0..5 {
            assert!((full[i] - direct[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn sqrt_inverts_inv_sqrt() {
        let cov = local_cov(60, 4, 5);
        let pc = Preconditioner::new(&cov, 0.02);
        let lambda = pc.lambda1_local() + 0.3;
        let r = vec![1.0, -2.0, 0.5, 3.0];
        let mut down = vec![0.0; 4];
        pc.apply_inv_sqrt(lambda, &r, &mut down);
        let mut back = vec![0.0; 4];
        pc.apply_sqrt(lambda, &down, &mut back);
        for i in 0..4 {
            assert!((back[i] - r[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn shift_change_is_cheap_and_correct() {
        // same eigenbasis reused across shifts — verify a second shift
        let cov = local_cov(80, 5, 7);
        let pc = Preconditioner::new(&cov, 0.05);
        for &lam_off in &[0.1, 0.2, 0.7] {
            let lambda = pc.lambda1_local() + lam_off;
            let mut c = Matrix::identity(5).scale(lambda + pc.mu());
            c.axpy_mat(-1.0, &cov);
            let r = vec![0.2, -1.0, 0.7, 0.1, 2.0];
            let mut got = vec![0.0; 5];
            pc.apply_inv(lambda, &r, &mut got);
            let back = c.matvec(&got);
            for i in 0..5 {
                assert!((back[i] - r[i]).abs() < 1e-8, "shift {lam_off}");
            }
        }
    }

    #[test]
    fn kappa_bound_decreases_with_gap() {
        let cov = local_cov(50, 4, 9);
        let pc = Preconditioner::new(&cov, 0.1);
        let l1 = pc.lambda1_local();
        assert!(pc.kappa_bound(l1 + 0.5, l1) < pc.kappa_bound(l1 + 0.05, l1));
    }

    #[test]
    fn theorem6_mu_scales_as_inverse_sqrt_n() {
        let a = Preconditioner::theorem6_mu(300, 100, 0.1);
        let b = Preconditioner::theorem6_mu(300, 400, 0.1);
        assert!((a / b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mu_dominates_cov_deviation_statistically() {
        // For iid shards, mu = 4 sqrt(ln(3d/p)/n) should exceed
        // ||Xhat - Xhat_1|| with high probability (Lemma 6's condition).
        // Use b<=1-scaled data.
        let d = 4;
        let n = 200;
        let dist = crate::data::CovModel::axis_aligned(vec![0.25, 0.12, 0.06, 0.03]).gaussian();
        let mut rng = Pcg64::new(11);
        let mut pooled = Matrix::zeros(d, d);
        let m = 8;
        let mut first = Matrix::zeros(d, d);
        for i in 0..m {
            let shard = crate::data::Distribution::sample_shard(&dist, &mut rng, n);
            // rescale rows to enforce b ~ 1 style bound
            let cov = shard.empirical_covariance().clone();
            if i == 0 {
                first = cov.clone();
            }
            pooled.axpy_mat(1.0 / m as f64, &cov);
        }
        let dev = pooled.sub(&first).sym_spectral_norm();
        let mu = Preconditioner::theorem6_mu(d, n, 0.1);
        assert!(dev < mu, "||Xhat - Xhat_1|| = {dev} should be < mu = {mu}");
    }
}
