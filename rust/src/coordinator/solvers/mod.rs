//! Convex quadratic solvers over abstract linear operators.
//!
//! Algorithm 1 reduces eigenvector computation to linear systems
//! `(lambda I - Xhat) z = w` (Problem (12)); in the distributed setting
//! each operator application costs **one communication round**
//! (Algorithm 2). These solvers are therefore written against an
//! `apply: &[f64] -> Vec<f64>` closure so the iteration count *is* the
//! round count, and support the Lemma-6 preconditioner as an abstract
//! `precond` closure.
//!
//! - [`cg()`] / [`pcg`] — conjugate gradients, plain and preconditioned.
//!   PCG with SPD preconditioner `C^{-1}` is mathematically equivalent to
//!   plain CG on the transformed problem
//!   `C^{-1/2} M C^{-1/2} y = C^{-1/2} w` of Eq. (13).
//! - [`agd()`] — Nesterov's accelerated gradient for strongly-convex
//!   quadratics, the paper's alternative solver in Lemma 7 (used by the
//!   `bench_solvers` ablation).

pub mod agd;
pub mod cg;

pub use agd::agd;
pub use cg::{cg, pcg, pcg_with};

/// Result of an iterative solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Operator applications performed (== communication rounds when the
    /// operator is the distributed covariance).
    pub iters: usize,
    /// Final residual norm `||b - A x||`.
    pub residual: f64,
    /// Whether the tolerance was met before `max_iters`.
    pub converged: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::propcheck::{run, Config};

    /// Shared test fixture: SPD system with known solution.
    fn spd_system(n: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<f64>) {
        let mut g = crate::propcheck::Config::default();
        g.seed = seed;
        let mut rng = crate::rng::Pcg64::new(seed);
        let b = Matrix::from_vec(n, n, (0..n * n).map(|_| rng.next_gaussian()).collect());
        let mut a = b.syrk_t().scale(1.0 / n as f64);
        a.axpy_mat(1.0, &Matrix::identity(n));
        let xstar: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let rhs = a.matvec(&xstar);
        let _ = g;
        (a, rhs, xstar)
    }

    #[test]
    fn cg_solves_spd_system() {
        let (a, rhs, xstar) = spd_system(12, 1);
        let (x, rep) = cg(|v| a.matvec(v), &rhs, None, 1e-12, 200);
        assert!(rep.converged);
        for i in 0..12 {
            assert!((x[i] - xstar[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn pcg_with_exact_preconditioner_converges_in_one_iter() {
        let (a, rhs, _) = spd_system(10, 2);
        let inv = crate::linalg::SymEigen::new(&a).apply_fn(|x| 1.0 / x);
        let (x, rep) = pcg(
            |v| a.matvec(v),
            |r, out| out.copy_from_slice(&inv.matvec(r)),
            &rhs,
            None,
            1e-10,
            50,
        );
        assert!(rep.converged);
        assert!(rep.iters <= 2, "exact preconditioner should converge immediately, took {}", rep.iters);
        let res = crate::linalg::vec_ops::sub(&rhs, &a.matvec(&x));
        assert!(crate::linalg::vec_ops::norm(&res) < 1e-9);
    }

    #[test]
    fn pcg_beats_cg_on_ill_conditioned_system() {
        // diag(1..1000) system; Jacobi preconditioner kills it instantly
        let n = 64;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 / (n - 1) as f64) * 999.0).collect();
        let a = Matrix::diag(&diag);
        let rhs = vec![1.0; n];
        let (_, rep_plain) = cg(|v| a.matvec(v), &rhs, None, 1e-10, 500);
        let (_, rep_pre) = pcg(
            |v| a.matvec(v),
            |r, out| {
                for i in 0..n {
                    out[i] = r[i] / diag[i];
                }
            },
            &rhs,
            None,
            1e-10,
            500,
        );
        assert!(rep_pre.iters < rep_plain.iters, "pcg {} !< cg {}", rep_pre.iters, rep_plain.iters);
    }

    #[test]
    fn agd_solves_spd_system() {
        let (a, rhs, xstar) = spd_system(8, 3);
        let eig = crate::linalg::SymEigen::new(&a);
        let beta = eig.lambda1();
        let alpha = *eig.values().last().unwrap();
        let (x, rep) = agd(|v| a.matvec(v), &rhs, None, alpha, beta, 1e-10, 5000);
        assert!(rep.converged, "agd residual {}", rep.residual);
        for i in 0..8 {
            assert!((x[i] - xstar[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn cg_iteration_count_scales_with_sqrt_condition() {
        // kappa = 100 -> ~ sqrt(100)*log(1/eps) iterations, much less than n
        let n = 256;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + 99.0 * (i as f64) / (n - 1) as f64).collect();
        let a = Matrix::diag(&diag);
        let rhs = vec![1.0; n];
        let (_, rep) = cg(|v| a.matvec(v), &rhs, None, 1e-8, 1000);
        assert!(rep.converged);
        assert!(rep.iters < 120, "CG took {} iterations", rep.iters);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let (a, rhs, xstar) = spd_system(16, 4);
        let near: Vec<f64> = xstar.iter().map(|x| x + 1e-6).collect();
        let (_, cold) = cg(|v| a.matvec(v), &rhs, None, 1e-10, 200);
        let (_, warm) = cg(|v| a.matvec(v), &rhs, Some(&near), 1e-10, 200);
        assert!(warm.iters <= cold.iters);
    }

    #[test]
    fn prop_cg_residual_below_tolerance() {
        run(Config::default().cases(24), "cg residual", |g| {
            let n = g.usize_in(2, 20);
            let mut a = g.psd_matrix(n, 1.0);
            a.axpy_mat(0.5, &Matrix::identity(n));
            let rhs = g.gaussian_vec(n);
            let (x, rep) = cg(|v| a.matvec(v), &rhs, None, 1e-9, 10 * n + 50);
            assert!(rep.converged, "n={n} residual={}", rep.residual);
            let res = crate::linalg::vec_ops::sub(&rhs, &a.matvec(&x));
            assert!(crate::linalg::vec_ops::norm(&res) <= 1e-8 * (1.0 + crate::linalg::vec_ops::norm(&rhs)));
        });
    }
}
