//! Nesterov's accelerated gradient method for strongly convex quadratics.
//!
//! Lemma 7 allows either CG or Nesterov's method; we carry both so the
//! `bench_solvers` ablation can compare them (and plain GD) at equal
//! communication cost per iteration. For the quadratic
//! `F(x) = x^T A x / 2 - b^T x` the gradient is `A x - b`, so one
//! iteration costs exactly one operator application = one round.

use crate::linalg::vec_ops::{axpy, norm, sub};

use super::SolveReport;

/// Constant-momentum AGD for `A x = b` with `alpha I <= A <= beta I`.
/// Momentum `(sqrt(kappa)-1)/(sqrt(kappa)+1)`, step `1/beta`.
pub fn agd(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    b: &[f64],
    x0: Option<&[f64]>,
    alpha: f64,
    beta: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    assert!(alpha > 0.0 && beta >= alpha, "need 0 < alpha <= beta");
    let d = b.len();
    let kappa = beta / alpha;
    let momentum = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
    let step = 1.0 / beta;

    let mut x = x0.map(|x| x.to_vec()).unwrap_or_else(|| vec![0.0; d]);
    let mut y = x.clone();
    let mut x_prev = x.clone();
    let mut iters = 0usize;
    let mut residual = f64::INFINITY;

    while iters < max_iters {
        // gradient at y: A y - b  (one operator application)
        let ay = apply(&y);
        iters += 1;
        let grad = sub(&ay, b);
        residual = norm(&grad);
        if residual <= tol {
            x = y;
            return (x, SolveReport { iters, residual, converged: true });
        }
        x_prev.copy_from_slice(&x);
        x.copy_from_slice(&y);
        axpy(&mut x, -step, &grad);
        // y = x + momentum (x - x_prev)
        y.copy_from_slice(&x);
        for i in 0..d {
            y[i] += momentum * (x[i] - x_prev[i]);
        }
    }
    (x, SolveReport { iters, residual, converged: residual <= tol })
}

/// Plain gradient descent (ablation baseline): step `1/beta`.
pub fn gd(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    b: &[f64],
    x0: Option<&[f64]>,
    beta: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    let d = b.len();
    let step = 1.0 / beta;
    let mut x = x0.map(|x| x.to_vec()).unwrap_or_else(|| vec![0.0; d]);
    let mut iters = 0usize;
    let mut residual = f64::INFINITY;
    while iters < max_iters {
        let ax = apply(&x);
        iters += 1;
        let grad = sub(&ax, b);
        residual = norm(&grad);
        if residual <= tol {
            return (x, SolveReport { iters, residual, converged: true });
        }
        axpy(&mut x, -step, &grad);
    }
    (x, SolveReport { iters, residual, converged: residual <= tol })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn fixture() -> (Matrix, Vec<f64>, f64, f64) {
        let diag: Vec<f64> = vec![1.0, 2.0, 5.0, 10.0];
        let a = Matrix::diag(&diag);
        let b = vec![1.0; 4];
        (a, b, 1.0, 10.0)
    }

    #[test]
    fn agd_converges() {
        let (a, b, alpha, beta) = fixture();
        let (x, rep) = agd(|v| a.matvec(v), &b, None, alpha, beta, 1e-10, 2000);
        assert!(rep.converged);
        for i in 0..4 {
            assert!((x[i] - b[i] / a.get(i, i)).abs() < 1e-8);
        }
    }

    #[test]
    fn gd_converges_but_slower_than_agd() {
        let n = 32;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + 63.0 * i as f64 / (n - 1) as f64).collect();
        let a = Matrix::diag(&diag);
        let b = vec![1.0; n];
        let (_, r_agd) = agd(|v| a.matvec(v), &b, None, 1.0, 64.0, 1e-8, 100_000);
        let (_, r_gd) = gd(|v| a.matvec(v), &b, None, 64.0, 1e-8, 100_000);
        assert!(r_agd.converged && r_gd.converged);
        assert!(
            r_agd.iters < r_gd.iters,
            "agd {} !< gd {}",
            r_agd.iters,
            r_gd.iters
        );
    }

    #[test]
    #[should_panic]
    fn agd_rejects_bad_constants() {
        let (a, b, _, _) = fixture();
        let _ = agd(|v| a.matvec(v), &b, None, 0.0, 1.0, 1e-8, 10);
    }
}
