//! Conjugate gradients, plain and preconditioned.
//!
//! The preconditioned variant is Algorithm 2 + Lemma 7 in solver form:
//! PCG with the SPD preconditioner `P ~= M^{-1}` generates the same
//! iterates as plain CG on `C^{-1/2} M C^{-1/2}` (Problem (13)), so its
//! iteration count obeys the `sqrt(kappa) = sqrt(1 + 2 mu / (lambda -
//! lambda_1))` bound of Lemma 6 while each iteration still costs exactly
//! one distributed matvec.

use crate::linalg::vec_ops::{axpy, dot, norm, scale};

use super::SolveReport;

/// Plain CG for SPD `A x = b`. `apply` must be a symmetric
/// positive-definite operator. Stops when `||b - A x|| <= tol`.
pub fn cg(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    pcg(apply_adapter(&mut apply), |r, out| out.copy_from_slice(r), b, x0, tol, max_iters)
}

fn apply_adapter<'a>(
    f: &'a mut impl FnMut(&[f64]) -> Vec<f64>,
) -> impl FnMut(&[f64]) -> Vec<f64> + 'a {
    move |v| f(v)
}

/// Preconditioned CG: `precond(r, out)` writes `P r` with `P` SPD
/// (e.g. `C^{-1}` applied through the cached eigenbasis of machine 1's
/// covariance, see [`crate::coordinator::precond`]).
pub fn pcg(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    mut precond: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    let d = b.len();
    let mut x = match x0 {
        Some(x0) => x0.to_vec(),
        None => vec![0.0; d],
    };
    let mut iters = 0usize;

    // r = b - A x (skip the operator call when x0 = 0)
    let mut r = if x.iter().all(|&v| v == 0.0) {
        b.to_vec()
    } else {
        let ax = apply(&x);
        iters += 1;
        let mut r = b.to_vec();
        axpy(&mut r, -1.0, &ax);
        r
    };

    let mut rnorm = norm(&r);
    if rnorm <= tol {
        return (x, SolveReport { iters, residual: rnorm, converged: true });
    }

    let mut z = vec![0.0; d];
    precond(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);

    while iters < max_iters {
        let ap = apply(&p);
        iters += 1;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator not PD at working precision — bail with current x
            break;
        }
        let alpha = rz / pap;
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        rnorm = norm(&r);
        if rnorm <= tol {
            return (x, SolveReport { iters, residual: rnorm, converged: true });
        }
        precond(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        // p = z + beta p
        scale(&mut p, beta);
        axpy(&mut p, 1.0, &z);
    }
    (x, SolveReport { iters, residual: rnorm, converged: rnorm <= tol })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn cg_identity_converges_immediately() {
        let b = vec![1.0, 2.0, 3.0];
        let (x, rep) = cg(|v| v.to_vec(), &b, None, 1e-12, 10);
        assert!(rep.converged);
        assert!(rep.iters <= 2);
        for i in 0..3 {
            assert!((x[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn cg_exact_in_n_iterations() {
        // CG terminates in at most n steps in exact arithmetic
        let a = Matrix::from_vec(3, 3, vec![4., 1., 0., 1., 3., 1., 0., 1., 2.]);
        let b = vec![1., 0., -1.];
        let (x, rep) = cg(|v| a.matvec(v), &b, None, 1e-11, 10);
        assert!(rep.converged);
        assert!(rep.iters <= 4);
        let res = crate::linalg::vec_ops::sub(&b, &a.matvec(&x));
        assert!(norm(&res) < 1e-10);
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let (x, rep) = cg(|v| v.to_vec(), &[0.0, 0.0], None, 1e-12, 10);
        assert!(rep.converged);
        assert_eq!(rep.iters, 0);
        assert_eq!(x, vec![0.0, 0.0]);
    }

    #[test]
    fn respects_max_iters() {
        let n = 50;
        let diag: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let a = Matrix::diag(&diag);
        let b = vec![1.0; n];
        let (_, rep) = cg(|v| a.matvec(v), &b, None, 1e-16, 3);
        assert!(!rep.converged);
        assert_eq!(rep.iters, 3);
    }
}
