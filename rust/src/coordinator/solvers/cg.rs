//! Conjugate gradients, plain and preconditioned.
//!
//! The preconditioned variant is Algorithm 2 + Lemma 7 in solver form:
//! PCG with the SPD preconditioner `P ~= M^{-1}` generates the same
//! iterates as plain CG on `C^{-1/2} M C^{-1/2}` (Problem (13)), so its
//! iteration count obeys the `sqrt(kappa) = sqrt(1 + 2 mu / (lambda -
//! lambda_1))` bound of Lemma 6 while each iteration still costs exactly
//! one distributed matvec.

use crate::linalg::vec_ops::{axpy, dot, norm, scale};

use super::SolveReport;

/// Plain CG for SPD `A x = b`. `apply` must be a symmetric
/// positive-definite operator. Stops when `||b - A x|| <= tol`.
pub fn cg(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    pcg(apply_adapter(&mut apply), |r, out| out.copy_from_slice(r), b, x0, tol, max_iters)
}

fn apply_adapter<'a>(
    f: &'a mut impl FnMut(&[f64]) -> Vec<f64>,
) -> impl FnMut(&[f64]) -> Vec<f64> + 'a {
    move |v| f(v)
}

/// Preconditioned CG: `precond(r, out)` writes `P r` with `P` SPD
/// (e.g. `C^{-1}` applied through the cached eigenbasis of machine 1's
/// covariance, see [`crate::coordinator::precond`]).
pub fn pcg(
    apply: impl FnMut(&[f64]) -> Vec<f64>,
    precond: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    pcg_with(apply, precond, b, x0, None, tol, max_iters)
}

/// [`pcg`] with an optionally **precomputed** first operator
/// application `ax0 = A x0` — the split-phase pipelining hook: a caller
/// that knows the next solve's warm start early can put the
/// distributed matvec for `A x0` on the wire, overlap its own
/// leader-side work with the round, and hand the completed product in
/// here. The iterate sequence (and the reported iteration count, which
/// keeps counting the application — it happened, on the wire) is
/// bit-identical to computing `A x0` inside the solve; `ax0` is
/// ignored when `x0` is absent or zero.
pub fn pcg_with(
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    mut precond: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x0: Option<&[f64]>,
    ax0: Option<Vec<f64>>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, SolveReport) {
    let d = b.len();
    let mut x = match x0 {
        Some(x0) => x0.to_vec(),
        None => vec![0.0; d],
    };
    let mut iters = 0usize;

    // r = b - A x (skip the operator call when x0 = 0)
    let mut r = if x.iter().all(|&v| v == 0.0) {
        b.to_vec()
    } else {
        let ax = match ax0 {
            Some(ax) => {
                debug_assert_eq!(ax.len(), d, "pcg_with: ax0 dimension mismatch");
                ax
            }
            None => apply(&x),
        };
        iters += 1;
        let mut r = b.to_vec();
        axpy(&mut r, -1.0, &ax);
        r
    };

    let mut rnorm = norm(&r);
    if rnorm <= tol {
        return (x, SolveReport { iters, residual: rnorm, converged: true });
    }

    let mut z = vec![0.0; d];
    precond(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);

    while iters < max_iters {
        let ap = apply(&p);
        iters += 1;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator not PD at working precision — bail with current x
            break;
        }
        let alpha = rz / pap;
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        rnorm = norm(&r);
        if rnorm <= tol {
            return (x, SolveReport { iters, residual: rnorm, converged: true });
        }
        precond(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        // p = z + beta p
        scale(&mut p, beta);
        axpy(&mut p, 1.0, &z);
    }
    (x, SolveReport { iters, residual: rnorm, converged: rnorm <= tol })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn cg_identity_converges_immediately() {
        let b = vec![1.0, 2.0, 3.0];
        let (x, rep) = cg(|v| v.to_vec(), &b, None, 1e-12, 10);
        assert!(rep.converged);
        assert!(rep.iters <= 2);
        for i in 0..3 {
            assert!((x[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn cg_exact_in_n_iterations() {
        // CG terminates in at most n steps in exact arithmetic
        let a = Matrix::from_vec(3, 3, vec![4., 1., 0., 1., 3., 1., 0., 1., 2.]);
        let b = vec![1., 0., -1.];
        let (x, rep) = cg(|v| a.matvec(v), &b, None, 1e-11, 10);
        assert!(rep.converged);
        assert!(rep.iters <= 4);
        let res = crate::linalg::vec_ops::sub(&b, &a.matvec(&x));
        assert!(norm(&res) < 1e-10);
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let (x, rep) = cg(|v| v.to_vec(), &[0.0, 0.0], None, 1e-12, 10);
        assert!(rep.converged);
        assert_eq!(rep.iters, 0);
        assert_eq!(x, vec![0.0, 0.0]);
    }

    #[test]
    fn precomputed_ax0_is_bit_identical_to_inline() {
        // the pipelining hook must not perturb the iterate sequence:
        // handing in A·x0 produces the same solution and iteration
        // count as computing it inside the solve
        let a = Matrix::from_vec(3, 3, vec![4., 1., 0., 1., 3., 1., 0., 1., 2.]);
        let b = vec![1., 0., -1.];
        let x0 = vec![0.2, -0.1, 0.4];
        let ident = |r: &[f64], out: &mut [f64]| out.copy_from_slice(r);
        let (x_inline, rep_inline) = pcg(|v| a.matvec(v), ident, &b, Some(&x0), 1e-12, 50);
        let ax0 = a.matvec(&x0);
        let (x_pre, rep_pre) =
            pcg_with(|v| a.matvec(v), ident, &b, Some(&x0), Some(ax0), 1e-12, 50);
        assert_eq!(x_inline, x_pre, "iterates must be bit-identical");
        assert_eq!(rep_inline.iters, rep_pre.iters, "the prefetched matvec still counts");
        assert!(rep_pre.converged);
    }

    #[test]
    fn respects_max_iters() {
        let n = 50;
        let diag: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let a = Matrix::diag(&diag);
        let b = vec![1.0; n];
        let (_, rep) = cg(|v| a.matvec(v), &b, None, 1e-16, 3);
        assert!(!rep.converged);
        assert_eq!(rep.iters, 3);
    }
}
