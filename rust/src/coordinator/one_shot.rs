//! Single-communication-round estimators (§3 + the §5 heuristic).
//!
//! All three gather the `m` local ERM eigenvectors in one round and differ
//! only in how the leader combines them:
//!
//! - [`NaiveAverage`] — plain average + normalize. Theorem 3: with
//!   unbiased (sign-randomized) local solutions this is stuck at
//!   `Omega(1/n)` and does **not** improve with `m`.
//! - [`SignFixedAverage`] — Theorem 4 / Eq. (7): flip each `w_i` to agree
//!   in sign with machine 1's solution before averaging. Error
//!   `O(eps_ERM) + O(b^4 log^2(dm)/delta^4 n^2)`.
//! - [`ProjectionAverage`] — §5: average the rank-one projections
//!   `w_i w_i^T` and take the leading eigenvector; sign-free by
//!   construction and empirically the best one-round estimator in the
//!   paper's Figure 1.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Session;
use crate::linalg::eigen::SymEigen;
use crate::linalg::vec_ops::{axpy, dot};
use crate::linalg::Matrix;

use super::{instrumented, Algorithm, Estimate};

/// Theorem 3's failing estimator: `normalize(mean_i w_i)` over unbiased
/// local eigenvectors.
#[derive(Clone, Debug, Default)]
pub struct NaiveAverage;

impl Algorithm for NaiveAverage {
    fn name(&self) -> &'static str {
        "naive_average"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            // unbiased_signs = true: each machine's ERM output sign is a
            // private fair coin — exactly the premise of Theorem 3.
            let vs = session.local_top_eigvecs(true)?;
            let mut acc = vec![0.0; session.d()];
            for v in &vs {
                axpy(&mut acc, 1.0, v);
            }
            // normalization happens in `instrumented`
            Ok((acc, BTreeMap::new()))
        })
    }
}

/// Theorem 4's estimator, Eq. (7):
/// `w = normalize( sum_i sign(w_i^T w_1) w_i )`.
#[derive(Clone, Debug, Default)]
pub struct SignFixedAverage;

impl Algorithm for SignFixedAverage {
    fn name(&self) -> &'static str {
        "sign_fixed_average"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let vs = session.local_top_eigvecs(true)?;
            let w1 = &vs[0];
            let mut acc = vec![0.0; session.d()];
            let mut flipped = 0u32;
            for v in &vs {
                let s = if dot(v, w1) >= 0.0 { 1.0 } else { -1.0 };
                if s < 0.0 {
                    flipped += 1;
                }
                axpy(&mut acc, s, v);
            }
            let mut info = BTreeMap::new();
            info.insert("flipped".into(), flipped as f64);
            Ok((acc, info))
        })
    }
}

/// The §5 heuristic: leading eigenvector of
/// `Pbar = (1/m) sum_i w_i w_i^T`.
#[derive(Clone, Debug, Default)]
pub struct ProjectionAverage;

impl Algorithm for ProjectionAverage {
    fn name(&self) -> &'static str {
        "projection_average"
    }

    fn run(&self, session: &Session<'_>) -> Result<Estimate> {
        instrumented(session, || {
            let vs = session.local_top_eigvecs(true)?;
            let d = session.d();
            let mut pbar = Matrix::zeros(d, d);
            for v in &vs {
                // rank-one accumulate: signs cancel in w w^T
                for i in 0..d {
                    let vi = v[i];
                    if vi == 0.0 {
                        continue;
                    }
                    let row = &mut pbar.data_mut()[i * d..(i + 1) * d];
                    for (r, &vj) in row.iter_mut().zip(v.iter()) {
                        *r += vi * vj;
                    }
                }
            }
            pbar.scale_mut(1.0 / vs.len() as f64);
            let eig = SymEigen::new(&pbar);
            let mut info = BTreeMap::new();
            info.insert("pbar_lambda1".into(), eig.lambda1());
            info.insert("pbar_gap".into(), eig.eigengap());
            Ok((eig.leading(), info))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::CentralizedErm;
    use super::*;
    use crate::data::{Distribution, Thm3Dist};

    #[test]
    fn all_one_round() {
        let (c, _) = test_cluster(6, 50, 5, 21);
        for alg in [&NaiveAverage as &dyn Algorithm, &SignFixedAverage, &ProjectionAverage] {
            let est = alg.run(&c.session()).unwrap();
            assert_eq!(est.comm.rounds, 1, "{} must be one-round", alg.name());
            assert_eq!(est.comm.vectors_gathered, 6);
        }
    }

    #[test]
    fn sign_fixed_beats_naive_on_thm3_distribution() {
        // Theorem 3 vs Theorem 4, averaged over independent clusters:
        // naive averaging stays ~1/n, sign-fixing concentrates ~1/(mn).
        let dist = Thm3Dist;
        let (m, n) = (24, 60);
        let runs = 24;
        let mut naive = 0.0;
        let mut fixed = 0.0;
        for seed in 0..runs {
            let c = crate::cluster::Cluster::generate(&dist, m, n, 1000 + seed).unwrap();
            naive += NaiveAverage.run(&c.session()).unwrap().error(dist.v1());
            fixed += SignFixedAverage.run(&c.session()).unwrap().error(dist.v1());
        }
        naive /= runs as f64;
        fixed /= runs as f64;
        assert!(
            fixed < naive / 3.0,
            "sign-fixing ({fixed:.3e}) should be far below naive ({naive:.3e})"
        );
    }

    #[test]
    fn projection_average_ignores_signs() {
        let (c, dist) = fig1_cluster(10, 80, 6, 23);
        // run twice: sign randomization differs between runs only through
        // worker RNG; projection must stay consistent regardless
        let e1 = ProjectionAverage.run(&c.session()).unwrap();
        let e2 = ProjectionAverage.run(&c.session()).unwrap();
        assert!(e1.error(dist.v1()) < 0.5);
        assert!(
            (e1.error(dist.v1()) - e2.error(dist.v1())).abs() < 1e-12,
            "projection estimator must be sign-invariant"
        );
    }

    #[test]
    fn sign_fixed_tracks_centralized_for_large_n() {
        // Thm 4: for n >> m the sign-fixed average is consistent with the
        // centralized ERM (same order of error).
        let mut ratio_sum = 0.0;
        let runs = 8;
        for seed in 0..runs {
            let (c, dist) = fig1_cluster(4, 500, 6, 31 + seed);
            let fixed = SignFixedAverage.run(&c.session()).unwrap().error(dist.v1());
            let cen = CentralizedErm.run(&c.session()).unwrap().error(dist.v1());
            ratio_sum += fixed / cen.max(1e-12);
        }
        let ratio = ratio_sum / runs as f64;
        assert!(ratio < 30.0, "sign-fixed / centralized error ratio = {ratio:.1}");
    }

    #[test]
    fn naive_average_fails_even_with_many_machines() {
        // increasing m does NOT rescue the naive estimator (Thm 3)
        let dist = Thm3Dist;
        let n = 40;
        let runs = 30;
        let mut err_small_m = 0.0;
        let mut err_big_m = 0.0;
        for seed in 0..runs {
            let c1 = crate::cluster::Cluster::generate(&dist, 4, n, 2000 + seed).unwrap();
            err_small_m += NaiveAverage.run(&c1.session()).unwrap().error(dist.v1());
            let c2 = crate::cluster::Cluster::generate(&dist, 32, n, 3000 + seed).unwrap();
            err_big_m += NaiveAverage.run(&c2.session()).unwrap().error(dist.v1());
        }
        err_small_m /= runs as f64;
        err_big_m /= runs as f64;
        // both stuck at the same Omega(1/n) floor: within 4x of each other
        let ratio = err_small_m / err_big_m;
        assert!(
            (0.25..4.0).contains(&ratio),
            "naive error should not improve with m: m=4 -> {err_small_m:.3e}, m=32 -> {err_big_m:.3e}"
        );
    }

    #[test]
    fn info_fields_present() {
        let (c, _) = test_cluster(5, 40, 4, 41);
        let f = SignFixedAverage.run(&c.session()).unwrap();
        assert!(f.info.contains_key("flipped"));
        let p = ProjectionAverage.run(&c.session()).unwrap();
        assert!(p.info.contains_key("pbar_lambda1"));
    }
}
