//! Pluggable transport: how leader⇄worker messages physically move.
//!
//! The paper's round model (§2.1) counts rounds and bytes as if vectors
//! crossed a network; until ISSUE 4 the cluster moved typed
//! `Request`/`Response` enums over in-process `mpsc` channels, so the
//! billed frame sizes never hit a wire. This module makes the substrate
//! pluggable: [`Cluster`](crate::cluster::Cluster) talks to a
//! [`Transport`] trait object, and two backends implement it —
//!
//! - [`InProcTransport`]: the original machinery, one OS thread per
//!   machine and an `mpsc` channel pair per worker (refactored out of
//!   `cluster/mod.rs` / `cluster/worker.rs`).
//! - [`TcpTransport`]: real sockets (`std::net` only, no new deps).
//!   Every message is a length-prefixed byte frame carrying the whole
//!   `Request`/`Response` — envelope fields as little-endian integers,
//!   f64 payloads as the issuing session's *materialized
//!   [`WireCodec`](crate::cluster::WireCodec) output* (see
//!   `cluster/wire.rs` for the frame format). The leader connects to
//!   `dspca worker --listen <addr>` processes, ships each worker its
//!   shard once at setup (setup traffic is not part of the §2.1 round
//!   bill), and **one reactor thread** drives every peer's non-blocking
//!   socket, feeding replies into one queue — leader-side reply
//!   plumbing costs a constant thread budget at any peer count
//!   ([`Transport::reader_threads`]), and per-exchange deadlines map
//!   onto the same timeout/straggler paths the in-proc backend uses.
//!
//! **Billing contract.** The transport moves messages; it never bills.
//! `CommStats` is advanced by the session layer from the codec-encoded
//! payload frames — which are exactly the payload bytes the TCP backend
//! puts on the wire — so a collective's bill (rounds, messages, bytes)
//! is **backend-invariant**. The E12 driver
//! (`experiments/transport.rs`), `dspca selftest`, and the loopback
//! integration tests assert this bill-for-bill.
//!
//! **Failure surfacing.** A dead or unreachable peer fails the send
//! with an error naming the worker and its address; a straggling peer
//! trips the receive deadline and the session's straggler accounting
//! takes over, exactly as in-proc. [`Transport::shutdown`] is
//! idempotent and safe in any drop order.

mod inproc;
mod tcp;

pub use inproc::InProcTransport;
pub use tcp::{serve_worker, LoopbackWorkers, TcpTransport};

use std::io::{self, Read, Write};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cluster::{Request, Response, WireDesc};
use crate::sync::{check_io, mpsc};

/// One routed reply as it travels the shared reply stream:
/// `(worker id, echoed sequence number, response)`.
pub type ReplyFrame = (usize, u64, Response);

/// Sequence number used for control messages (`Shutdown`) that are not
/// part of any exchange; real exchanges start at 1.
pub const CONTROL_SEQ: u64 = 0;

/// Default I/O deadline for the byte-shipping backends: the TCP connect
/// handshake (shard + ack) and every socket write on either side. An
/// I/O stall this long on a loopback/LAN path means a wedged peer, not
/// a slow one. Overridable per cluster via [`TransportSpec::Tcp`]'s
/// `io_timeout` (CLI: `--io-timeout-secs`); distinct from the cluster's
/// per-exchange *compute* deadline, which bounds how long a worker may
/// take to answer, not how long a byte may take to move.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(20);

/// Hard cap on one frame body — a corrupt length prefix must not turn
/// into a multi-gigabyte allocation. Generous: the largest legitimate
/// frame is a `Gram` reply, `8·d²` payload bytes plus a small envelope.
pub(crate) const MAX_FRAME_BODY: usize = 1 << 30;

/// How leader⇄worker messages physically move. One implementor per
/// backend; the cluster holds a `Box<dyn Transport>` behind its **send
/// lock** (held only while requests go out — never while waiting for
/// replies), so methods take `&mut self` and implementors need only be
/// [`Send`].
///
/// The receive side is **router-driven**: every backend funnels replies
/// into one [`mpsc`] stream that the cluster's reply router takes at
/// construction ([`Transport::take_reply_stream`]) and drains for all
/// tenants at once, routing each reply by its echoed sequence number.
/// The transport itself never blocks a sender on a reply.
pub trait Transport: Send {
    /// Backend name for reports ("inproc" / "tcp").
    fn name(&self) -> &'static str;

    /// Deliver one sequenced request to peer `worker`. `desc` is the
    /// round's wire descriptor — the resolved format the issuing
    /// session shipped the payload under, its feedback flag, and the
    /// session id keying the worker-side reply accumulator. Byte-
    /// shipping backends encode the payload at exactly that format (the
    /// payload has already passed through the session codec, so the
    /// re-encode is lossless on these values — the quantizers are
    /// re-encode idempotent), and workers echo the format on the reply.
    /// Errors name the peer (`worker 2 at 127.0.0.1:9001 unreachable:
    /// ...`).
    ///
    /// A sequence number identifies exactly one request — the invariant
    /// the straggler protocol rests on — so callers must never send
    /// different requests under one `(seq, desc)`; backends may cache
    /// the encoded broadcast frame per `(seq, desc)` and reuse it for
    /// every peer of the exchange.
    fn send(&mut self, worker: usize, seq: u64, desc: WireDesc, req: &Request) -> Result<()>;

    /// Hand the caller the shared reply stream: every peer's responses,
    /// tagged `(worker, seq, response)`. Called exactly once, by the
    /// cluster's reply router at construction; a second call panics.
    /// After the stream's senders are all gone (shutdown, every peer
    /// dead), receiving on it reports disconnection — the router maps
    /// that onto [`RecvError::Disconnected`] via [`recv_reply`].
    fn take_reply_stream(&mut self) -> mpsc::Receiver<ReplyFrame>;

    /// Tell every peer to stop and release transport resources
    /// (join worker/reader threads, close sockets). **Idempotent**:
    /// calling it twice, or after a peer already died, is a no-op —
    /// never a double-close or a hang.
    fn shutdown(&mut self);

    /// Leader-side threads this backend dedicates to moving replies
    /// into the reply stream. The TCP reactor reports `1` at any peer
    /// count — the E12 driver's constant-thread-budget gate; in-proc
    /// reports the default `0` (its worker threads *are* the simulated
    /// machines, not leader-side reply plumbing).
    fn reader_threads(&self) -> usize {
        0
    }
}

/// Receive one routed reply from a taken reply stream with a deadline,
/// mapping the channel's error modes onto [`RecvError`]. This is the
/// single recv primitive the cluster's router (and the transport unit
/// tests) use on every backend.
pub fn recv_reply(
    rx: &mpsc::Receiver<ReplyFrame>,
    timeout: Duration,
) -> std::result::Result<ReplyFrame, RecvError> {
    // blocking up to the full exchange deadline: the analyze build
    // verifies nothing but the IO-marked driver locks are held here
    check_io("transport::recv_reply");
    rx.recv_timeout(timeout).map_err(|e| match e {
        mpsc::RecvTimeoutError::Timeout => RecvError::TimedOut(timeout),
        mpsc::RecvTimeoutError::Disconnected => {
            RecvError::Disconnected("every peer is gone (all reply senders dropped)".into())
        }
    })
}

/// Why [`recv_reply`] returned no message.
#[derive(Debug)]
pub enum RecvError {
    /// The per-exchange deadline passed with no frame — the worker may
    /// still answer later (straggler) or never.
    TimedOut(Duration),
    /// No peer can ever reply (all channels/sockets closed).
    Disconnected(String),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::TimedOut(t) => {
                write!(f, "timed out after {t:?} waiting for a worker response")
            }
            RecvError::Disconnected(why) => write!(f, "transport disconnected: {why}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Which backend a cluster should run on — the value behind the CLI's
/// `--transport {inproc,tcp}` / `--workers <addr,...>` /
/// `--io-timeout-secs <n>` flags and the experiment configs'
/// `transport` field.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// One OS thread per machine, `mpsc` channels (the default).
    #[default]
    InProc,
    /// Real TCP sockets: one `dspca worker --listen <addr>` peer per
    /// machine, in shard order. The cluster's `m` must equal the
    /// address count.
    Tcp {
        /// Worker addresses (`host:port`), one per machine.
        workers: Vec<String>,
        /// Socket I/O deadline: handshake ack + every write
        /// ([`DEFAULT_IO_TIMEOUT`] unless overridden).
        io_timeout: Duration,
    },
}

impl TransportSpec {
    /// A TCP spec with the default I/O deadline — the common
    /// constructor (`TransportSpec::Tcp { .. }` spelled out is for
    /// callers that override `io_timeout`).
    pub fn tcp(workers: Vec<String>) -> TransportSpec {
        TransportSpec::Tcp { workers, io_timeout: DEFAULT_IO_TIMEOUT }
    }

    /// Backend label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            TransportSpec::InProc => "inproc",
            TransportSpec::Tcp { .. } => "tcp",
        }
    }

    /// Parse the CLI surface: `--transport {inproc,tcp}` plus
    /// `--workers a:p,b:p,...` plus `--io-timeout-secs <n>`.
    /// `--workers` alone implies `tcp`; `tcp` without `--workers`, an
    /// empty worker list, `--workers` under `inproc`, a zero timeout,
    /// or `--io-timeout-secs` under `inproc` are hard errors (never a
    /// silent fallback).
    pub fn from_flags(
        transport: Option<&str>,
        workers: Option<&str>,
        io_timeout_secs: Option<u64>,
    ) -> Result<TransportSpec> {
        let workers: Option<Vec<String>> = workers.map(|w| {
            w.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        });
        if io_timeout_secs == Some(0) {
            bail!("--io-timeout-secs must be >= 1");
        }
        let io_timeout = io_timeout_secs.map(Duration::from_secs);
        match (transport, workers) {
            (None, None) | (Some("inproc"), None) => {
                if io_timeout.is_some() {
                    bail!("--io-timeout-secs only applies to --transport tcp");
                }
                Ok(TransportSpec::InProc)
            }
            (None | Some("tcp"), Some(w)) if !w.is_empty() => Ok(TransportSpec::Tcp {
                workers: w,
                io_timeout: io_timeout.unwrap_or(DEFAULT_IO_TIMEOUT),
            }),
            (None | Some("tcp"), Some(_)) => {
                bail!("--workers list is empty; expected --workers <addr,addr,...>")
            }
            (Some("tcp"), None) => {
                bail!(
                    "--transport tcp requires --workers <addr,addr,...> \
                     (one address per machine)"
                )
            }
            (Some("inproc"), Some(_)) => bail!("--workers only applies to --transport tcp"),
            (Some(other), _) => bail!("unknown transport '{other}' (expected 'inproc' or 'tcp')"),
        }
    }
}

/// Write one length-prefixed frame: `u32` little-endian body length,
/// then the body. A body over the cap is a hard error — shipping it
/// would either be rejected by the receiver's [`read_frame`] after the
/// whole transfer or, past `u32::MAX`, silently truncate the length
/// prefix and desync the protocol.
pub(crate) fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    if body.len() > MAX_FRAME_BODY {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame body of {} bytes exceeds the {MAX_FRAME_BODY}-byte cap", body.len()),
        ));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// How long a deadline-bounded write parks between `WouldBlock`
/// retries. Short enough that a drained socket buffer resumes almost
/// immediately; long enough not to spin a core against a full one.
const WRITE_RETRY_PAUSE: Duration = Duration::from_micros(50);

/// Write all of `buf` to a possibly **non-blocking** writer, parking
/// briefly on `WouldBlock` until `deadline` — the write-side
/// counterpart of the reactor's non-blocking reads (`O_NONBLOCK` is a
/// property of the shared file description, so the leader's send half
/// goes non-blocking the moment the reactor's read half does).
/// `Interrupted` retries immediately; a stall past the deadline is
/// `TimedOut`, matching the old blocking-socket `set_write_timeout`
/// contract.
pub(crate) fn write_all_deadline(
    w: &mut impl Write,
    mut buf: &[u8],
    deadline: std::time::Instant,
) -> io::Result<()> {
    while !buf.is_empty() {
        match w.write(buf) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "socket accepted zero bytes",
                ))
            }
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                crate::obs_inc!(TCP_WRITE_RETRIES_TOTAL);
                if std::time::Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "socket write stalled past the io deadline",
                    ));
                }
                std::thread::sleep(WRITE_RETRY_PAUSE);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// [`write_frame`] for a non-blocking socket: the whole frame (prefix +
/// body) must land within `timeout`, shared across both sections like
/// one blocking write under `set_write_timeout`.
pub(crate) fn write_frame_deadline(
    w: &mut impl Write,
    body: &[u8],
    timeout: Duration,
) -> io::Result<()> {
    if body.len() > MAX_FRAME_BODY {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame body of {} bytes exceeds the {MAX_FRAME_BODY}-byte cap", body.len()),
        ));
    }
    let deadline = std::time::Instant::now() + timeout;
    write_all_deadline(w, &(body.len() as u32).to_le_bytes(), deadline)?;
    write_all_deadline(w, body, deadline)?;
    w.flush()
}

/// Read one length-prefixed frame body. A clean EOF before the length
/// prefix surfaces as `ErrorKind::UnexpectedEof`; an absurd length
/// prefix is `InvalidData` (never a huge allocation).
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_BODY {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame body of {len} bytes exceeds the {MAX_FRAME_BODY}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_io_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        // clean EOF at a frame boundary
        let err = read_frame(&mut r).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn read_frame_rejects_absurd_lengths_and_truncation() {
        // a corrupt length prefix must error out, not allocate wildly
        let huge = (MAX_FRAME_BODY as u32 + 1).to_le_bytes();
        let err = read_frame(&mut &huge[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // a truncated body is an UnexpectedEof
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        let cut = &buf[..buf.len() - 2];
        assert!(read_frame(&mut &cut[..]).is_err());
    }

    #[test]
    fn spec_from_flags_parses_every_surface() {
        assert_eq!(TransportSpec::from_flags(None, None, None).unwrap(), TransportSpec::InProc);
        assert_eq!(
            TransportSpec::from_flags(Some("inproc"), None, None).unwrap(),
            TransportSpec::InProc
        );
        let tcp =
            TransportSpec::tcp(vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()]);
        assert_eq!(
            TransportSpec::from_flags(Some("tcp"), Some("127.0.0.1:9001, 127.0.0.1:9002"), None)
                .unwrap(),
            tcp
        );
        // --workers alone implies tcp
        assert_eq!(
            TransportSpec::from_flags(None, Some("127.0.0.1:9001,127.0.0.1:9002"), None).unwrap(),
            tcp
        );
        assert_eq!(tcp.label(), "tcp");
        assert_eq!(TransportSpec::InProc.label(), "inproc");
        assert_eq!(TransportSpec::default(), TransportSpec::InProc);
    }

    #[test]
    fn spec_from_flags_carries_the_io_timeout() {
        // default: the shared DEFAULT_IO_TIMEOUT constant
        match TransportSpec::from_flags(None, Some("127.0.0.1:9001"), None).unwrap() {
            TransportSpec::Tcp { io_timeout, .. } => assert_eq!(io_timeout, DEFAULT_IO_TIMEOUT),
            other => panic!("expected tcp, got {other:?}"),
        }
        // explicit override rides the spec
        match TransportSpec::from_flags(Some("tcp"), Some("127.0.0.1:9001"), Some(7)).unwrap() {
            TransportSpec::Tcp { io_timeout, .. } => {
                assert_eq!(io_timeout, Duration::from_secs(7))
            }
            other => panic!("expected tcp, got {other:?}"),
        }
    }

    #[test]
    fn spec_from_flags_rejects_bad_combinations() {
        let msg = |t: Option<&str>, w: Option<&str>, io: Option<u64>| {
            TransportSpec::from_flags(t, w, io).unwrap_err().to_string()
        };
        assert!(msg(Some("tcp"), None, None).contains("--workers"));
        assert!(msg(Some("inproc"), Some("127.0.0.1:9001"), None).contains("inproc"));
        assert!(msg(Some("udp"), None, None).contains("udp"));
        assert!(msg(None, Some(" , ,"), None).contains("empty"));
        assert!(msg(Some("tcp"), Some(","), None).contains("empty"));
        // the io-timeout flag is tcp-only and must be positive
        assert!(msg(Some("inproc"), None, Some(30)).contains("--io-timeout-secs"));
        assert!(msg(None, None, Some(30)).contains("--io-timeout-secs"));
        assert!(msg(Some("tcp"), Some("127.0.0.1:9001"), Some(0)).contains(">= 1"));
    }
}
