//! TCP transport: the cluster over real sockets (`std::net` only).
//!
//! **Leader side** ([`TcpTransport`]): connects to one
//! `dspca worker --listen <addr>` peer per machine (in shard order),
//! ships each worker its shard + per-worker RNG seed + oracle spec in a
//! one-time `Init` handshake frame (setup traffic, outside the §2.1
//! round bill), then spawns **one reactor thread** for the whole peer
//! set (ISSUE 8). The reactor polls every peer's non-blocking socket,
//! reassembles length-prefixed frames from per-peer buffers, decodes
//! them, and feeds the single reply stream the cluster's router takes
//! ([`Transport::take_reply_stream`]) — so leader-side reply plumbing
//! is a constant thread budget at any peer count
//! ([`Transport::reader_threads`] == 1), and the router's per-exchange
//! deadline semantics match the in-proc channel: a straggling or dead
//! peer trips the deadline and the straggler accounting takes over
//! unchanged. Because `O_NONBLOCK` lives on the file description the
//! read and write halves share, leader sends go through a
//! deadline-bounded retry loop (`write_frame_deadline`) instead of
//! `set_write_timeout`; the observable contract — every write bounded
//! by `io_timeout` — is identical. When the socket buffers are idle
//! the reactor backs off its poll pause exponentially
//! ([`REACTOR_IDLE_MIN`] → [`REACTOR_IDLE_MAX`]), so a quiet cluster
//! costs microamps, not a spinning core.
//!
//! **Worker side** ([`serve_worker`]): accept a leader connection, read
//! `Init`, ack, then answer request frames with response frames until
//! `Shutdown` or EOF — the same
//! [`handle_request`](crate::cluster::worker) dispatch the in-proc
//! worker thread runs. Replies are compressed **worker-side** at the
//! [`WireDesc`] each request frame carried, through a per-connection
//! [`ReplyBank`] (one error-feedback accumulator per session id, rebuilt
//! purely from request envelopes — no handshake ships codec state), so
//! the leader's router bills reply frames shape-only and bills are
//! backend-invariant.
//!
//! **Framing**: length-prefixed whole-message frames (`cluster/wire.rs`
//! format); payload sections are the materialized `WireCodec` output,
//! i.e. the billed bytes are exactly the payload bytes on the socket.
//!
//! **I/O deadlines**: one knob, the [`TransportSpec::Tcp`]-carried
//! `io_timeout` (default [`DEFAULT_IO_TIMEOUT`], CLI
//! `--io-timeout-secs`), bounds the connect-time handshake (shard +
//! ack) and every socket write on both sides — a peer that stalls a
//! byte that long is wedged, not slow. The per-exchange *compute*
//! deadline (how long a worker may take to answer) stays with the
//! cluster, on the recv path.
//!
//! **Shutdown** is idempotent and drop-order-safe: a `Shutdown` frame
//! is written best-effort to each peer, both socket halves are shut
//! down (which unblocks the reader threads), and the readers are
//! joined. A worker that is mid-compute when the leader vanishes
//! finishes, fails its write, and returns to `accept` — nobody hangs
//! and nothing is double-closed.

use std::io::{self, Read};
use std::net::{Shutdown as SockShutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::sync::atomic::{AtomicBool, Ordering};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cluster::wire::Cursor;
use crate::cluster::worker::{handle_request, worker_rng};
use crate::cluster::{
    decode_request, decode_response, encode_request, encode_response, ComputeOracle, OracleSpec,
    ReplyBank, Request, Response, WireDesc, WireFormat, WirePrecision,
};
use crate::data::Shard;
use crate::sync::{check_io, mpsc};

use super::{
    read_frame, write_frame, write_frame_deadline, ReplyFrame, Transport, TransportSpec,
    CONTROL_SEQ, DEFAULT_IO_TIMEOUT, MAX_FRAME_BODY,
};

/// Handshake magic ("DSPC") so connecting to something that is not a
/// `dspca worker` fails fast with a clear error instead of a timeout.
const INIT_MAGIC: u32 = 0x4453_5043;
/// v2 (ISSUE 6): a storage tag byte after the shape header selects
/// dense rows or a CSR sparse shard. v1 peers fail the version check
/// with a clear error instead of misparsing the frame.
const INIT_VERSION: u8 = 2;
const ORACLE_NATIVE: u8 = 0;
const ORACLE_PJRT: u8 = 1;
const STORE_DENSE: u8 = 0;
const STORE_CSR: u8 = 1;

/// One worker's shard + identity, shipped once at connect time.
struct Init {
    worker_id: usize,
    wseed: u64,
    oracle: OracleSpec,
    shard: Shard,
}

fn encode_init(worker_id: usize, wseed: u64, oracle: &OracleSpec, shard: &Shard) -> Vec<u8> {
    let mut out = Vec::with_capacity(80 + 8 * shard.nnz());
    out.extend_from_slice(&INIT_MAGIC.to_le_bytes());
    out.push(INIT_VERSION);
    out.extend_from_slice(&(worker_id as u64).to_le_bytes());
    out.extend_from_slice(&wseed.to_le_bytes());
    match oracle {
        OracleSpec::Native => out.push(ORACLE_NATIVE),
        OracleSpec::Pjrt { artifact_dir } => {
            out.push(ORACLE_PJRT);
            out.extend_from_slice(&(artifact_dir.len() as u32).to_le_bytes());
            out.extend_from_slice(artifact_dir.as_bytes());
        }
    }
    out.extend_from_slice(&(shard.n() as u64).to_le_bytes());
    out.extend_from_slice(&(shard.d() as u64).to_le_bytes());
    // shard values always ship lossless — this is dataset setup, not a
    // round payload, and never enters the communication bill
    if let Some((indptr, indices, values)) = shard.csr_parts() {
        out.push(STORE_CSR);
        out.extend_from_slice(&(values.len() as u64).to_le_bytes());
        for p in indptr {
            out.extend_from_slice(&(*p as u64).to_le_bytes());
        }
        for j in indices {
            out.extend_from_slice(&j.to_le_bytes());
        }
        for x in values {
            out.extend_from_slice(&x.to_le_bytes());
        }
    } else {
        let data = shard.matrix().data();
        out.push(STORE_DENSE);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for x in data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

fn decode_init(body: &[u8]) -> Result<Init> {
    let mut c = Cursor::new(body);
    let magic = c.u32()?;
    ensure!(magic == INIT_MAGIC, "bad handshake magic 0x{magic:08x} (not a dspca leader?)");
    let version = c.u8()?;
    ensure!(version == INIT_VERSION, "handshake version {version} != {INIT_VERSION}");
    let worker_id = c.usize()?;
    let wseed = c.u64()?;
    let oracle = match c.u8()? {
        ORACLE_NATIVE => OracleSpec::Native,
        ORACLE_PJRT => OracleSpec::Pjrt { artifact_dir: c.string()? },
        other => bail!("unknown oracle tag {other} in handshake"),
    };
    let n = c.usize()?;
    let d = c.usize()?;
    ensure!(n > 0 && d > 0, "init frame: empty shard shape {n}x{d}");
    let shard = match c.u8()? {
        STORE_DENSE => {
            let data = c.payload(WireFormat::Plain(WirePrecision::F64))?;
            ensure!(
                n.checked_mul(d) == Some(data.len()),
                "init frame: shard of {} values != {n}x{d}",
                data.len()
            );
            Shard::new(n, d, data)
        }
        STORE_CSR => {
            let nnz = c.usize()?;
            // take the raw byte sections (bounds-checked) before
            // allocating, so a truncated frame errors without an
            // attacker-controlled huge allocation
            let ip_bytes = n
                .checked_add(1)
                .and_then(|r| r.checked_mul(8))
                .ok_or_else(|| anyhow!("init frame: csr row count {n} overflows"))?;
            let ip_raw = c.take(ip_bytes)?;
            let ix_raw = c.take(
                nnz.checked_mul(4)
                    .ok_or_else(|| anyhow!("init frame: csr nnz {nnz} overflows"))?,
            )?;
            let val_raw = c.take(
                nnz.checked_mul(8)
                    .ok_or_else(|| anyhow!("init frame: csr nnz {nnz} overflows"))?,
            )?;
            // chunks_exact yields exactly-sized slices, so the array
            // conversions below cannot fail; copy_from_slice keeps the
            // decode panic-free without an unwrap
            let mut indptr = Vec::with_capacity(n + 1);
            for b in ip_raw.chunks_exact(8) {
                let mut w = [0u8; 8];
                w.copy_from_slice(b);
                let p = usize::try_from(u64::from_le_bytes(w))
                    .context("csr indptr entry does not fit this platform's usize")?;
                indptr.push(p);
            }
            let mut indices = Vec::with_capacity(nnz);
            for b in ix_raw.chunks_exact(4) {
                let mut w = [0u8; 4];
                w.copy_from_slice(b);
                indices.push(u32::from_le_bytes(w));
            }
            let mut values = Vec::with_capacity(nnz);
            for b in val_raw.chunks_exact(8) {
                let mut w = [0u8; 8];
                w.copy_from_slice(b);
                values.push(f64::from_le_bytes(w));
            }
            // try_from_csr re-validates the structural invariants
            // (monotone indptr, ascending in-range column indices), so a
            // corrupt frame is an error here, never a panic later
            Shard::try_from_csr(n, d, indptr, indices, values)
                .context("init frame: malformed csr shard")?
        }
        other => bail!("unknown shard storage tag {other} in handshake"),
    };
    c.finish()?;
    Ok(Init { worker_id, wseed, oracle, shard })
}

fn encode_ack(worker_id: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(&INIT_MAGIC.to_le_bytes());
    out.push(INIT_VERSION);
    out.extend_from_slice(&(worker_id as u64).to_le_bytes());
    out
}

fn decode_ack(body: &[u8], expect_id: usize) -> Result<()> {
    let mut c = Cursor::new(body);
    let magic = c.u32()?;
    ensure!(magic == INIT_MAGIC, "bad ack magic 0x{magic:08x} (not a dspca worker?)");
    let version = c.u8()?;
    ensure!(version == INIT_VERSION, "ack version {version} != {INIT_VERSION}");
    let id = c.usize()?;
    ensure!(id == expect_id, "ack from worker {id}, expected {expect_id}");
    c.finish()
}

struct Peer {
    addr: String,
    stream: TcpStream,
}

/// How long the reactor parks when a full poll pass over every peer
/// moved no bytes. Doubles per idle pass up to [`REACTOR_IDLE_MAX`] and
/// snaps back to this floor on any progress, so latency under load is
/// one short pause while a quiet cluster costs ~1k wakeups/second.
const REACTOR_IDLE_MIN: Duration = Duration::from_micros(50);
const REACTOR_IDLE_MAX: Duration = Duration::from_millis(1);

/// One peer's read half inside the reactor: the non-blocking socket
/// clone plus the reassembly buffer for frames that arrive in pieces.
struct PeerRead {
    worker: usize,
    stream: TcpStream,
    buf: Vec<u8>,
}

/// Leader-side TCP transport: one socket per worker peer, **one
/// reactor thread for all of them** feeding the shared response queue.
/// Built by
/// [`Cluster::from_shards_on`](crate::cluster::Cluster::from_shards_on)
/// with [`TransportSpec::Tcp`].
pub struct TcpTransport {
    peers: Vec<Peer>,
    /// The shared reply stream the reactor feeds, present until the
    /// cluster's router takes it ([`Transport::take_reply_stream`]).
    rx: Option<mpsc::Receiver<ReplyFrame>>,
    /// One exchange broadcasts the same `(seq, desc, req)` to every
    /// peer (a sequence number identifies exactly one request — the
    /// invariant the whole straggler protocol rests on), so the encoded
    /// body is cached per `(seq, desc)`: a round costs one encode, not
    /// one per worker. The [`WireDesc`] is part of the key because an
    /// adaptive session may re-resolve its width between rounds that
    /// reuse a sequence number window.
    encoded: Option<(u64, WireDesc, Vec<u8>)>,
    /// Write deadline for every leader-side socket write (the sockets
    /// are non-blocking, so `set_write_timeout` no longer applies).
    io_timeout: Duration,
    reactor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    down: bool,
}

impl TcpTransport {
    /// Connect to every worker address (in shard order), ship each its
    /// shard, and wait for the handshake ack; then start the reactor.
    /// Errors name the peer: "worker 2: cannot connect to
    /// 127.0.0.1:9003". On a partial failure the peers already reached
    /// are torn down (sockets closed) before the error returns — the
    /// reactor is only spawned once every peer is up, so there is never
    /// a thread to unwind.
    pub(crate) fn connect(
        addrs: &[String],
        shards: Vec<Arc<Shard>>,
        oracle: &OracleSpec,
        seed: u64,
        io_timeout: Duration,
    ) -> Result<TcpTransport> {
        let (tx, rx) = mpsc::channel::<ReplyFrame>();
        let mut peers = Vec::with_capacity(addrs.len());
        let mut reads = Vec::with_capacity(addrs.len());
        let spawned = Self::connect_all(addrs, shards, oracle, seed, io_timeout, &mut peers, &mut reads)
            .and_then(|()| {
                let stop = Arc::new(AtomicBool::new(false));
                let flag = Arc::clone(&stop);
                let reactor = std::thread::Builder::new()
                    .name("dspca-tcp-reactor".to_string())
                    .spawn(move || reactor_loop(reads, tx, flag))
                    .context("spawning tcp reactor thread")?;
                Ok((stop, reactor))
            });
        match spawned {
            Ok((stop, reactor)) => Ok(TcpTransport {
                peers,
                rx: Some(rx),
                encoded: None,
                io_timeout,
                reactor: Some(reactor),
                stop,
                down: false,
            }),
            Err(e) => {
                for peer in &mut peers {
                    let _ = peer.stream.shutdown(SockShutdown::Both);
                }
                Err(e)
            }
        }
    }

    fn connect_all(
        addrs: &[String],
        shards: Vec<Arc<Shard>>,
        oracle: &OracleSpec,
        seed: u64,
        io_timeout: Duration,
        peers: &mut Vec<Peer>,
        reads: &mut Vec<PeerRead>,
    ) -> Result<()> {
        ensure!(
            addrs.len() == shards.len(),
            "tcp transport: {} worker addresses for m = {} machines \
             (the --workers list must name exactly one address per machine)",
            addrs.len(),
            shards.len()
        );
        // the shared per-worker seed derivation (worker_seeder), so
        // worker sign coins agree across backends at a fixed seed
        let mut seeder = crate::cluster::worker::worker_seeder(seed);
        for (i, shard) in shards.into_iter().enumerate() {
            let addr = &addrs[i];
            let wseed = seeder.next_u64();
            match Self::connect_one(i, addr, wseed, oracle, &shard, io_timeout) {
                Ok((peer, read)) => {
                    crate::obs_inc!(TCP_HANDSHAKES_OK_TOTAL);
                    reads.push(read);
                    peers.push(peer);
                }
                Err(e) => {
                    crate::obs_inc!(TCP_HANDSHAKES_FAILED_TOTAL);
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Connect to one worker and run the `Init` handshake: ship the
    /// shard, wait for the ack, then split the socket into a blocking
    /// write half and a non-blocking reactor read half.
    fn connect_one(
        i: usize,
        addr: &str,
        wseed: u64,
        oracle: &OracleSpec,
        shard: &Shard,
        io_timeout: Duration,
    ) -> Result<(Peer, PeerRead)> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("worker {i}: cannot connect to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(io_timeout));
        let _ = stream.set_read_timeout(Some(io_timeout));
        write_frame(&mut stream, &encode_init(i, wseed, oracle, shard))
            .with_context(|| format!("worker {i} at {addr}: shipping shard failed"))?;
        let ack = read_frame(&mut stream).with_context(|| {
            format!(
                "worker {i} at {addr}: no handshake ack \
                 (is `dspca worker --listen {addr}` running?)"
            )
        })?;
        decode_ack(&ack, i).with_context(|| format!("worker {i} at {addr}: bad handshake"))?;
        let _ = stream.set_read_timeout(None);
        let reader_stream = stream
            .try_clone()
            .with_context(|| format!("worker {i} at {addr}: cloning socket"))?;
        // this flips the shared file description non-blocking:
        // reactor reads AND leader writes — which is why the send
        // path uses the deadline-bounded write loop from here on
        reader_stream
            .set_nonblocking(true)
            .with_context(|| format!("worker {i} at {addr}: setting non-blocking"))?;
        Ok((
            Peer { addr: addr.to_string(), stream },
            PeerRead { worker: i, stream: reader_stream, buf: Vec::new() },
        ))
    }
}

/// What one poll of one peer did — drives peer retention and the
/// reactor's idle backoff.
enum Pump {
    /// Bytes moved (and any complete frames were delivered).
    Progress,
    /// Nothing to read right now.
    Idle,
    /// EOF, socket error, oversized length prefix, or an undecodable
    /// frame: forget the peer. The leader then sees it as a straggler
    /// (deadline) rather than wedging — same semantics as the old
    /// per-peer reader exiting.
    Gone,
    /// The router dropped the reply stream; the whole reactor is done.
    RouterGone,
}

/// The reactor: one thread polling every peer's non-blocking socket,
/// reassembling and decoding response frames, feeding the shared reply
/// stream. Exits when told to stop, when every peer is gone, or when
/// the reply receiver disappears — dropping `tx` either way, which
/// surfaces to the router as disconnection exactly like the last
/// per-peer reader exiting used to.
fn reactor_loop(mut peers: Vec<PeerRead>, tx: mpsc::Sender<ReplyFrame>, stop: Arc<AtomicBool>) {
    let mut scratch = vec![0u8; 64 << 10];
    let mut idle = REACTOR_IDLE_MIN;
    while !stop.load(Ordering::Relaxed) && !peers.is_empty() {
        crate::obs_inc!(TCP_REACTOR_SWEEPS_TOTAL);
        let mut moved = false;
        let mut router_gone = false;
        peers.retain_mut(|p| match pump_peer(p, &mut scratch, &tx) {
            Pump::Progress => {
                moved = true;
                true
            }
            Pump::Idle => true,
            Pump::Gone => {
                moved = true;
                false
            }
            Pump::RouterGone => {
                router_gone = true;
                false
            }
        });
        if router_gone {
            return;
        }
        if moved {
            idle = REACTOR_IDLE_MIN;
        } else {
            std::thread::sleep(idle);
            idle = (idle * 2).min(REACTOR_IDLE_MAX);
        }
        // the gauge tracks where on the MIN..MAX ladder the reactor
        // currently sits — a busy wire reads 50, a quiet one 1000
        crate::obs_gauge!(TCP_REACTOR_IDLE_US, idle.as_micros() as u64);
    }
}

/// One non-blocking read on one peer plus a greedy parse of every
/// complete frame now sitting in its reassembly buffer.
fn pump_peer(p: &mut PeerRead, scratch: &mut [u8], tx: &mpsc::Sender<ReplyFrame>) -> Pump {
    match p.stream.read(scratch) {
        Ok(0) => {
            // clean EOF (normal shutdown) is silent
            Pump::Gone
        }
        Ok(n) => {
            p.buf.extend_from_slice(&scratch[..n]);
            loop {
                if p.buf.len() < 4 {
                    if !p.buf.is_empty() {
                        // a torn length prefix waits for the next read
                        crate::obs_inc!(TCP_REASSEMBLY_STALLS_TOTAL);
                    }
                    return Pump::Progress;
                }
                let len =
                    u32::from_le_bytes([p.buf[0], p.buf[1], p.buf[2], p.buf[3]]) as usize;
                if len > MAX_FRAME_BODY {
                    crate::warn!(
                        "tcp reactor: worker {} sent a {len}-byte frame \
                         (cap {MAX_FRAME_BODY}), dropping the connection",
                        p.worker
                    );
                    return Pump::Gone;
                }
                if p.buf.len() < 4 + len {
                    // partial frame left in this peer's reassembly
                    // buffer — completed on a later sweep
                    crate::obs_inc!(TCP_REASSEMBLY_STALLS_TOTAL);
                    return Pump::Progress;
                }
                match decode_response(&p.buf[4..4 + len]) {
                    Ok((seq, _format, resp)) => {
                        if tx.send((p.worker, seq, resp)).is_err() {
                            return Pump::RouterGone;
                        }
                    }
                    Err(e) => {
                        crate::warn!(
                            "tcp reactor: undecodable response frame from worker {} \
                             (version-mismatched peer?), dropping the connection: {e:#}",
                            p.worker
                        );
                        return Pump::Gone;
                    }
                }
                p.buf.drain(..4 + len);
            }
        }
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => Pump::Idle,
        Err(e) if e.kind() == io::ErrorKind::Interrupted => Pump::Idle,
        Err(e) => {
            crate::debug!("tcp reactor: worker {} socket closed: {e}", p.worker);
            Pump::Gone
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn send(&mut self, worker: usize, seq: u64, desc: WireDesc, req: &Request) -> Result<()> {
        check_io("TcpTransport::send");
        let cached = matches!(&self.encoded, Some((s, d, _)) if *s == seq && *d == desc);
        if !cached {
            self.encoded = Some((seq, desc, encode_request(seq, desc, req)));
        }
        let peer = self
            .peers
            .get_mut(worker)
            .ok_or_else(|| anyhow!("no such worker {worker}"))?;
        let Some((_, _, body)) = self.encoded.as_ref() else {
            bail!("worker {worker} at {}: request body missing after encode", peer.addr);
        };
        write_frame_deadline(&mut peer.stream, body, self.io_timeout)
            .with_context(|| format!("worker {worker} at {} unreachable", peer.addr))
    }

    fn take_reply_stream(&mut self) -> mpsc::Receiver<ReplyFrame> {
        self.rx.take().expect("reply stream already taken")
    }

    fn shutdown(&mut self) {
        if self.down {
            return;
        }
        self.down = true;
        let bye = encode_request(CONTROL_SEQ, WireDesc::lossless(), &Request::Shutdown);
        for peer in &mut self.peers {
            // best effort — a peer that already hung up just fails the
            // write, which is fine
            let _ = write_frame_deadline(&mut peer.stream, &bye, self.io_timeout);
        }
        // the reactor checks the flag every pass (its idle pause is at
        // most REACTOR_IDLE_MAX), and the socket shutdowns below turn
        // its reads into EOFs — either way it exits promptly
        self.stop.store(true, Ordering::Relaxed);
        for peer in &mut self.peers {
            let _ = peer.stream.shutdown(SockShutdown::Both);
        }
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }

    fn reader_threads(&self) -> usize {
        usize::from(self.reactor.is_some())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// Serve leader sessions on `listener`: the body of
/// `dspca worker --listen <addr>`. Each accepted connection is one
/// leader lifetime — `Init` handshake, then request⇄response frames
/// until `Shutdown` or EOF. With `max_conns = Some(k)` the function
/// returns after `k` leader sessions (the CLI's `--once` is `Some(1)`;
/// tests and the loopback harness count connections so worker threads
/// are joinable); `None` serves until the process is killed. Only
/// connections that complete the `Init` handshake count as a leader
/// session — a port scanner or crashed process probing the socket must
/// not consume the `--once` budget. `io_timeout` bounds the handshake
/// read and every response write (the worker-side half of the
/// [`TransportSpec::Tcp`] `io_timeout` contract; CLI
/// `--io-timeout-secs`).
pub fn serve_worker(
    listener: TcpListener,
    max_conns: Option<usize>,
    io_timeout: Duration,
) -> Result<()> {
    let mut served = 0usize;
    loop {
        if let Some(limit) = max_conns {
            if served >= limit {
                return Ok(());
            }
        }
        let (stream, peer) = listener.accept().context("accepting leader connection")?;
        crate::debug!("dspca worker: connection from {peer}");
        match serve_leader(stream, io_timeout) {
            Ok(true) => served += 1,
            // never completed the handshake: not a leader session
            Ok(false) => {}
            Err(e) => {
                crate::warn!("dspca worker: leader session ended with error: {e:#}");
                served += 1;
            }
        }
    }
}

/// One leader connection: handshake, then the request→response loop.
/// Responses are compressed through a per-connection [`ReplyBank`] at
/// the [`WireDesc`] each request frame carried — so a feedback stream's
/// reply residuals live worker-side, keyed by session id, with no
/// handshake. Returns `Ok(false)` if the connection never completed the
/// handshake (not a real leader), `Ok(true)` after a clean session; an
/// `Err` is a session that failed *after* the handshake.
fn serve_leader(mut stream: TcpStream, io_timeout: Duration) -> Result<bool> {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(io_timeout));
    let _ = stream.set_write_timeout(Some(io_timeout));
    let init = match read_frame(&mut stream) {
        Ok(body) => match decode_init(&body) {
            Ok(init) => init,
            Err(e) => {
                crate::warn!("dspca worker: rejected a non-leader connection: {e:#}");
                return Ok(false);
            }
        },
        Err(e) => {
            crate::debug!("dspca worker: connection dropped before handshake: {e}");
            return Ok(false);
        }
    };
    let shard = init.shard;
    let mut rng = worker_rng(init.worker_id, init.wseed);
    // oracle construction failure is surfaced per-request (mirroring the
    // in-proc worker thread) instead of killing the session silently
    let mut oracle: std::result::Result<Box<dyn ComputeOracle>, String> =
        init.oracle.build().map_err(|e| format!("oracle init failed: {e}"));
    write_frame(&mut stream, &encode_ack(init.worker_id)).context("sending handshake ack")?;
    let _ = stream.set_read_timeout(None);
    // per-connection reply compressor: one error-feedback stream per
    // session id, rebuilt purely from the request envelopes — the same
    // ReplyBank path the in-proc worker thread runs
    let mut bank = ReplyBank::new();
    loop {
        let body = match read_frame(&mut stream) {
            Ok(b) => b,
            // leader hung up (cluster dropped, process died): session over
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(true),
            Err(e) => return Err(e).context("reading request frame"),
        };
        let (seq, desc, req) = decode_request(&body)?;
        let mut resp = match &mut oracle {
            Ok(oracle) => match handle_request(oracle.as_mut(), &shard, &mut rng, req) {
                Some(resp) => resp,
                None => return Ok(true), // Shutdown
            },
            Err(msg) => {
                if matches!(req, Request::Shutdown) {
                    return Ok(true);
                }
                Response::Err(msg.clone())
            }
        };
        bank.compress(&desc, &mut resp);
        write_frame(&mut stream, &encode_response(seq, desc.format, &resp))
            .context("writing response frame")?;
    }
}

/// A set of loopback TCP workers on ephemeral localhost ports — the
/// in-one-process stand-in for N `dspca worker --listen <addr>`
/// terminals, used by `dspca selftest`, the E12 driver, the
/// `bench_transport` bench and the loopback integration tests. Each
/// worker thread serves exactly `conns` leader connections and then
/// exits, so [`LoopbackWorkers::join`] always returns.
pub struct LoopbackWorkers {
    addrs: Vec<String>,
    handles: Vec<JoinHandle<Result<()>>>,
    io_timeout: Duration,
}

impl LoopbackWorkers {
    /// Bind `m` ephemeral localhost listeners and serve `conns` leader
    /// connections each on background threads, at the default I/O
    /// deadline.
    pub fn spawn(m: usize, conns: usize) -> Result<LoopbackWorkers> {
        Self::spawn_with(m, conns, DEFAULT_IO_TIMEOUT)
    }

    /// [`LoopbackWorkers::spawn`] with an explicit worker-side
    /// `io_timeout` (pair it with the same value in the cluster's
    /// [`TransportSpec::Tcp`]).
    pub fn spawn_with(m: usize, conns: usize, io_timeout: Duration) -> Result<LoopbackWorkers> {
        let mut addrs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for i in 0..m {
            let listener =
                TcpListener::bind("127.0.0.1:0").context("binding loopback listener")?;
            addrs.push(listener.local_addr().context("loopback local addr")?.to_string());
            let handle = std::thread::Builder::new()
                .name(format!("dspca-loopback-worker-{i}"))
                .spawn(move || serve_worker(listener, Some(conns), io_timeout))
                .context("spawning loopback worker thread")?;
            handles.push(handle);
        }
        Ok(LoopbackWorkers { addrs, handles, io_timeout })
    }

    /// The bound `host:port` addresses, in worker order.
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// A [`TransportSpec::Tcp`] pointing at these workers, carrying the
    /// same `io_timeout` they serve with.
    pub fn spec(&self) -> TransportSpec {
        TransportSpec::Tcp { workers: self.addrs.clone(), io_timeout: self.io_timeout }
    }

    /// Join every worker thread, surfacing the first worker error. Call
    /// after dropping the cluster(s) that connected to them.
    pub fn join(self) -> Result<()> {
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => bail!("loopback worker thread panicked"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tiny_shards(m: usize) -> Vec<Arc<Shard>> {
        let mut rng = Pcg64::new(17);
        (0..m)
            .map(|_| Arc::new(Shard::new(5, 3, (0..15).map(|_| rng.next_gaussian()).collect())))
            .collect()
    }

    #[test]
    fn init_frame_roundtrips_for_both_oracle_specs() {
        let data = vec![1.0, -2.5, 0.25, 3.0, -0.5, 9.0];
        for oracle in [
            OracleSpec::Native,
            OracleSpec::Pjrt { artifact_dir: "artifacts/aot".to_string() },
        ] {
            let shard = Shard::new(2, 3, data.clone());
            let body = encode_init(3, 0xfeed, &oracle, &shard);
            let back = decode_init(&body).unwrap();
            assert_eq!(back.worker_id, 3);
            assert_eq!(back.wseed, 0xfeed);
            assert_eq!((back.shard.n(), back.shard.d()), (2, 3));
            assert!(!back.shard.is_sparse());
            assert_eq!(back.shard.matrix().data(), &data[..]);
            match (&back.oracle, &oracle) {
                (OracleSpec::Native, OracleSpec::Native) => {}
                (
                    OracleSpec::Pjrt { artifact_dir: a },
                    OracleSpec::Pjrt { artifact_dir: b },
                ) => assert_eq!(a, b),
                _ => panic!("oracle spec changed across the handshake"),
            }
            // truncation errors, never panics
            for cut in 0..body.len() {
                assert!(decode_init(&body[..cut]).is_err());
            }
        }
        // ack roundtrip + identity check
        let ack = encode_ack(2);
        assert!(decode_ack(&ack, 2).is_ok());
        assert!(decode_ack(&ack, 1).is_err(), "ack must carry the right worker id");
    }

    #[test]
    fn init_frame_ships_csr_shards_and_rejects_corruption() {
        let shard = Shard::from_csr(
            3,
            4,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 3],
            vec![1.0, -2.0, 0.5, 4.0, -0.25],
        );
        let body = encode_init(1, 0xbeef, &OracleSpec::Native, &shard);
        let back = decode_init(&body).unwrap();
        assert_eq!(back.worker_id, 1);
        assert!(back.shard.is_sparse());
        assert_eq!((back.shard.n(), back.shard.d(), back.shard.nnz()), (3, 4, 5));
        let (indptr, indices, values) = back.shard.csr_parts().unwrap();
        assert_eq!(indptr, &[0, 2, 3, 5]);
        assert_eq!(indices, &[0, 2, 1, 0, 3]);
        assert_eq!(values, &[1.0, -2.0, 0.5, 4.0, -0.25]);
        // the decoded shard computes like its dense expansion
        #[rustfmt::skip]
        let dense = Shard::new(3, 4, vec![
            1.0, 0.0, -2.0,  0.0,
            0.0, 0.5,  0.0,  0.0,
            4.0, 0.0,  0.0, -0.25,
        ]);
        let v = vec![0.3, -1.0, 0.7, 2.0];
        for (a, b) in back.shard.cov_matvec(&v).iter().zip(dense.cov_matvec(&v)) {
            assert!((a - b).abs() <= 1e-12, "{a} vs {b}");
        }
        // truncation errors, never panics
        for cut in 0..body.len() {
            assert!(decode_init(&body[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // a structurally corrupt CSR section is an error, not a panic:
        // clobber indptr[1] (offset = magic 4 + version 1 + worker_id 8 +
        // wseed 8 + oracle tag 1 + n 8 + d 8 + store tag 1 + nnz 8 +
        // one indptr entry 8 = 55) so the row pointers go non-monotone
        let mut bad = body.clone();
        bad[55] = 0xff;
        let err = decode_init(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("csr"), "{err:#}");
    }

    #[test]
    fn leader_and_worker_speak_over_a_real_socket() {
        let workers = LoopbackWorkers::spawn(2, 1).unwrap();
        let mut t = TcpTransport::connect(
            workers.addrs(),
            tiny_shards(2),
            &OracleSpec::Native,
            42,
            Duration::from_secs(30),
        )
        .unwrap();
        assert_eq!(t.name(), "tcp");
        let rx = t.take_reply_stream();
        t.send(0, 7, WireDesc::lossless(), &Request::CovMatVec(vec![1.0, 0.0, 0.0])).unwrap();
        t.send(1, 7, WireDesc::lossless(), &Request::CovMatVec(vec![1.0, 0.0, 0.0])).unwrap();
        let mut got = [false, false];
        for _ in 0..2 {
            let (id, seq, resp) = super::super::recv_reply(&rx, Duration::from_secs(30)).unwrap();
            assert_eq!(seq, 7, "workers echo the sequence number");
            assert!(matches!(resp, Response::Vector(ref v) if v.len() == 3));
            got[id] = true;
        }
        assert!(got[0] && got[1]);
        t.shutdown();
        t.shutdown(); // idempotent
        workers.join().unwrap();
    }

    #[test]
    fn reactor_drives_many_peers_with_one_thread() {
        // the ISSUE 8 reactor claim at unit scale: one leader-side
        // reply thread regardless of peer count, with every peer's
        // replies still delivered and attributed correctly
        let m = 8;
        let workers = LoopbackWorkers::spawn(m, 1).unwrap();
        let mut t = TcpTransport::connect(
            workers.addrs(),
            tiny_shards(m),
            &OracleSpec::Native,
            9,
            Duration::from_secs(30),
        )
        .unwrap();
        assert_eq!(t.reader_threads(), 1, "one reactor thread for {m} peers");
        let rx = t.take_reply_stream();
        for w in 0..m {
            t.send(w, 5, WireDesc::lossless(), &Request::CovMatVec(vec![1.0, 0.0, 0.0])).unwrap();
        }
        let mut got = vec![false; m];
        for _ in 0..m {
            let (id, seq, resp) = super::super::recv_reply(&rx, Duration::from_secs(30)).unwrap();
            assert_eq!(seq, 5, "the reactor preserves echoed sequence numbers");
            assert!(matches!(resp, Response::Vector(ref v) if v.len() == 3));
            got[id] = true;
        }
        assert!(got.iter().all(|g| *g), "every peer's reply arrived, correctly attributed");
        t.shutdown();
        assert_eq!(t.reader_threads(), 0, "shutdown joins the reactor");
        workers.join().unwrap();
    }

    #[test]
    fn connecting_to_a_dead_port_names_the_peer() {
        // bind-then-drop guarantees an unused port
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = TcpTransport::connect(
            &[addr.clone()],
            tiny_shards(1),
            &OracleSpec::Native,
            1,
            Duration::from_secs(5),
        )
        .map(|_| ())
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("worker 0"), "{msg}");
        assert!(msg.contains(&addr), "{msg}");
    }

    #[test]
    fn partial_connect_failure_tears_down_reached_peers() {
        // worker 0 is real, worker 1 is a dead port: connect must fail
        // naming worker 1 AND release worker 0 (socket closed, reader
        // joined) so its serve loop completes instead of wedging
        let good = LoopbackWorkers::spawn(1, 1).unwrap();
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let addrs = vec![good.addrs()[0].clone(), dead];
        let err = TcpTransport::connect(
            &addrs,
            tiny_shards(2),
            &OracleSpec::Native,
            1,
            Duration::from_secs(5),
        )
        .map(|_| ())
        .unwrap_err();
        assert!(format!("{err:#}").contains("worker 1"), "{err:#}");
        good.join().unwrap();
    }

    #[test]
    fn address_count_must_match_machine_count() {
        let err = TcpTransport::connect(
            &["127.0.0.1:1".to_string()],
            tiny_shards(2),
            &OracleSpec::Native,
            1,
            Duration::from_secs(5),
        )
        .map(|_| ())
        .unwrap_err()
        .to_string();
        assert!(err.contains("one address per machine"), "{err}");
    }

    #[test]
    fn worker_replies_at_the_request_frame_precision() {
        let workers = LoopbackWorkers::spawn(1, 1).unwrap();
        let mut t = TcpTransport::connect(
            workers.addrs(),
            tiny_shards(1),
            &OracleSpec::Native,
            3,
            Duration::from_secs(30),
        )
        .unwrap();
        // a bf16 request comes back as a bf16-gridded response: every
        // delivered value must be exactly representable in bf16
        let rx = t.take_reply_stream();
        let mut v = vec![0.731, -0.25, 1.0001];
        WirePrecision::Bf16.quantize(&mut v);
        t.send(0, 1, WireDesc::plain(WirePrecision::Bf16), &Request::CovMatVec(v)).unwrap();
        let (_, _, resp) = super::super::recv_reply(&rx, Duration::from_secs(30)).unwrap();
        let Response::Vector(out) = resp else { panic!("expected a vector reply") };
        for x in &out {
            let mut q = [*x];
            WirePrecision::Bf16.quantize(&mut q);
            assert_eq!(q[0].to_bits(), x.to_bits(), "{x} is not on the bf16 grid");
        }
        t.shutdown();
        workers.join().unwrap();
    }

    #[test]
    fn worker_feedback_streams_telescope_with_no_handshake() {
        use crate::cluster::QuantBits;
        // the worker-side half of the error-feedback contract over a
        // real socket: the per-connection ReplyBank is rebuilt purely
        // from request envelopes (nothing about codec state rides the
        // Init handshake), stateless descriptors stay memoryless, and a
        // feedback stream's reply mean telescopes toward the lossless
        // reply (Σ qₜ = k·raw − r_k, so |mean − raw| = |r_k|/k)
        let workers = LoopbackWorkers::spawn(1, 1).unwrap();
        let mut rng = Pcg64::new(23);
        let shard = Arc::new(Shard::new(6, 8, (0..48).map(|_| rng.next_gaussian()).collect()));
        let mut t = TcpTransport::connect(
            workers.addrs(),
            vec![shard],
            &OracleSpec::Native,
            11,
            Duration::from_secs(30),
        )
        .unwrap();
        let rx = t.take_reply_stream();
        let q4 = WireFormat::Quant(QuantBits::Q4);
        // pre-grid the probe so every round delivers the same degraded
        // vector to the shard math (q4 re-encodes on-grid values
        // losslessly), making the raw reply identical across rounds
        let mut v = vec![0.731, -0.25, 1.0001, 0.4, -0.9, 0.05, 0.61, -0.33];
        q4.quantize(&mut v, 1);
        let mut seq = 0u64;
        let mut ask = |t: &mut TcpTransport, desc: WireDesc| -> Vec<f64> {
            seq += 1;
            t.send(0, seq, desc, &Request::CovMatVec(v.clone())).unwrap();
            let (_, s, resp) = super::super::recv_reply(&rx, Duration::from_secs(30)).unwrap();
            assert_eq!(s, seq, "replies arrive in lockstep on one peer");
            let Response::Vector(out) = resp else { panic!("expected a vector reply") };
            out
        };
        let truth = ask(&mut t, WireDesc::lossless());
        let flat = WireDesc { format: q4, feedback: false, sid: 7 };
        let a1 = ask(&mut t, flat);
        let a2 = ask(&mut t, flat);
        assert_eq!(a1, a2, "a stateless descriptor is memoryless");
        let ef = WireDesc { format: q4, feedback: true, sid: 8 };
        let b1 = ask(&mut t, ef);
        assert_eq!(a1, b1, "a fresh feedback stream starts from a zero residual");
        let rounds = 8usize;
        let mut sum = b1;
        for _ in 1..rounds {
            let b = ask(&mut t, ef);
            for (s, x) in sum.iter_mut().zip(&b) {
                *s += x;
            }
        }
        // the carried residual is at most half a quantizer step, so the
        // k-round mean sits within (step/2)/k of the lossless reply —
        // asserted at 2× slack against the truth-scaled step
        let maxabs = truth.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let step = maxabs / 7.0;
        for (i, (s, x)) in sum.iter().zip(&truth).enumerate() {
            let mean = s / rounds as f64;
            assert!(
                (mean - x).abs() <= step / 4.0,
                "coordinate {i}: ef mean {mean} vs lossless {x} (step {step})"
            );
        }
        t.shutdown();
        workers.join().unwrap();
    }
}
