//! In-process transport: one OS thread per machine, `mpsc` channels.
//!
//! This is the original cluster substrate, refactored out of
//! `cluster/mod.rs` behind the [`Transport`] trait: requests move as
//! typed enums over a per-worker channel, replies funnel into one shared
//! receiver, and the worker threads are owned (and joined) here. No
//! bytes are materialized — the session layer still bills from the
//! codec-encoded payload frames, so the bill is identical to the TCP
//! backend's by construction. The worker threads *are* the simulated
//! machines, not leader-side reply plumbing, so this backend reports
//! the [`Transport::reader_threads`] default of 0 (the TCP reactor
//! reports 1 — see `transport/tcp.rs`).

use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::cluster::worker::worker_main;
use crate::cluster::{OracleSpec, Request, Response, WireDesc};
use crate::data::Shard;
use crate::sync::{check_io, mpsc};

use super::{ReplyFrame, Transport, CONTROL_SEQ};

/// The `mpsc` transport: worker threads owning their shards, typed
/// messages, no serialization. Built by
/// [`Cluster::from_shards_on`](crate::cluster::Cluster::from_shards_on)
/// with [`TransportSpec::InProc`](super::TransportSpec::InProc).
/// Requests carry their [`WireDesc`] across the channel so the worker
/// side can quantize replies (and keep feedback streams) exactly like a
/// TCP worker process would — reply compression is a *worker-side*
/// behavior on every backend.
pub struct InProcTransport {
    senders: Vec<mpsc::Sender<(u64, WireDesc, Request)>>,
    /// The shared reply stream, present until the cluster's router
    /// takes it ([`Transport::take_reply_stream`]).
    receiver: Option<mpsc::Receiver<ReplyFrame>>,
    handles: Vec<Option<JoinHandle<()>>>,
    down: bool,
}

impl InProcTransport {
    /// Spawn one worker thread per shard. `seed` feeds the same
    /// per-worker RNG seed derivation the TCP backend ships in its
    /// handshake, so worker sign coins agree across backends.
    pub fn spawn(
        shards: Vec<Arc<Shard>>,
        oracle: &OracleSpec,
        seed: u64,
    ) -> Result<InProcTransport> {
        let (resp_tx, resp_rx) = mpsc::channel::<ReplyFrame>();
        let mut senders = Vec::with_capacity(shards.len());
        let mut handles = Vec::with_capacity(shards.len());
        let mut seeder = crate::cluster::worker::worker_seeder(seed);
        for (i, shard) in shards.into_iter().enumerate() {
            let (req_tx, req_rx) = mpsc::channel::<(u64, WireDesc, Request)>();
            let tx = resp_tx.clone();
            let spec = oracle.clone();
            let wseed = seeder.next_u64();
            let handle = std::thread::Builder::new()
                .name(format!("dspca-worker-{i}"))
                .spawn(move || worker_main(i, shard, spec, wseed, req_rx, tx))
                .context("spawning worker thread")?;
            senders.push(req_tx);
            handles.push(Some(handle));
        }
        Ok(InProcTransport { senders, receiver: Some(resp_rx), handles, down: false })
    }
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn send(&mut self, worker: usize, seq: u64, desc: WireDesc, req: &Request) -> Result<()> {
        check_io("InProcTransport::send");
        // typed enums cross the channel directly (the session already
        // quantized the request payload); the descriptor rides along so
        // the worker compresses its reply at the round's format and
        // keys its feedback stream on the session id
        self.senders
            .get(worker)
            .ok_or_else(|| anyhow!("no such worker {worker}"))?
            .send((seq, desc, req.clone()))
            .map_err(|_| anyhow!("worker {worker} channel closed"))
    }

    fn take_reply_stream(&mut self) -> mpsc::Receiver<ReplyFrame> {
        self.receiver.take().expect("reply stream already taken")
    }

    fn shutdown(&mut self) {
        if self.down {
            return;
        }
        self.down = true;
        for s in &self.senders {
            // best effort: a worker killed earlier already dropped its
            // receiver and the send just fails
            let _ = s.send((CONTROL_SEQ, WireDesc::lossless(), Request::Shutdown));
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for InProcTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::super::{recv_reply, RecvError};
    use super::*;
    use crate::rng::Pcg64;
    use std::time::Duration;

    fn tiny_transport(m: usize) -> InProcTransport {
        let mut rng = Pcg64::new(9);
        let shards = (0..m)
            .map(|_| {
                Arc::new(Shard::new(4, 3, (0..12).map(|_| rng.next_gaussian()).collect()))
            })
            .collect();
        InProcTransport::spawn(shards, &OracleSpec::Native, 7).unwrap()
    }

    #[test]
    fn send_recv_roundtrip_echoes_sequence_numbers() {
        let mut t = tiny_transport(2);
        assert_eq!(t.reader_threads(), 0, "worker threads are machines, not reply plumbing");
        let rx = t.take_reply_stream();
        t.send(0, 5, WireDesc::lossless(), &Request::CovMatVec(vec![1.0, 0.0, 0.0])).unwrap();
        let (id, seq, resp) = recv_reply(&rx, Duration::from_secs(30)).unwrap();
        assert_eq!((id, seq), (0, 5));
        assert!(matches!(resp, Response::Vector(v) if v.len() == 3));
        t.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_fails_later_sends_cleanly() {
        let mut t = tiny_transport(2);
        let rx = t.take_reply_stream();
        t.shutdown();
        t.shutdown(); // second call is a no-op, not a double-join
        let err =
            t.send(1, 1, WireDesc::lossless(), &Request::Gram).unwrap_err().to_string();
        assert!(err.contains("worker 1"), "{err}");
        // recv after shutdown reports disconnection, not a hang
        assert!(matches!(
            recv_reply(&rx, Duration::from_millis(50)),
            Err(RecvError::Disconnected(_) | RecvError::TimedOut(_))
        ));
    }

    #[test]
    fn send_to_unknown_worker_is_a_clean_error() {
        let mut t = tiny_transport(1);
        let err = t.send(3, 1, WireDesc::lossless(), &Request::Gram).unwrap_err().to_string();
        assert!(err.contains("worker 3"), "{err}");
        t.shutdown();
    }
}
