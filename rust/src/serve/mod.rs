//! `serve` — a multi-tenant scheduler: run many heterogeneous PCA
//! queries concurrently on **one** shared cluster, with exact per-job
//! bills and aggregate throughput/latency metrics.
//!
//! This is the deployment shape of distributed PCA in practice (cf. Fan
//! et al., *Distributed Estimation of Principal Eigenspaces*): the
//! sharded dataset is resident on the machines, and many estimation
//! queries — different algorithms, accuracies, even wire codecs — are
//! answered against it. The session layer makes this safe: every job
//! runs on its own [`Session`](crate::cluster::Session) (own
//! [`CommStats`] bill, own codec, own sequence numbers), so concurrent
//! jobs cannot corrupt each other's accounting or wire precision.
//!
//! ## Scheduling & fairness contract
//!
//! - Jobs are taken from a FIFO queue by `tenants` identical worker
//!   ("leader") threads — work-conserving: a tenant thread never idles
//!   while the queue is non-empty, and no job is skipped or reordered
//!   at dequeue time (completion order may differ; [`ServeReport::jobs`]
//!   is returned in submission order regardless).
//! - Tenant rounds genuinely **overlap on the wire** (see
//!   [`crate::cluster`]'s split-phase collectives): one tenant's
//!   submit never waits behind another tenant's in-flight replies, so
//!   batch wallclock drops as tenants are added until the workers
//!   saturate — the E11 driver measures (and asserts) the win.
//!   Concurrency changes *when* a job's rounds happen, never what they
//!   cost.
//!
//! ## Accounting contract
//!
//! - Each [`JobReport::comm`] is exactly the bill the same job would
//!   pay running alone on an idle cluster (same rounds, messages,
//!   bytes).
//! - The sum of all job bills ([`ServeReport::bills_sum`]) equals
//!   [`ServeReport::aggregate`], the delta of the cluster's monotonic
//!   aggregate ledger over the serve window, whenever the batch has
//!   the cluster to itself. [`serve`] records the identity's outcome
//!   in [`ServeReport::accounting_exact`] on every call (traffic from
//!   sessions outside the batch — e.g. a second concurrent `serve` —
//!   lands in the aggregate but in no job's bill); exclusive-use
//!   callers assert it.
//! - A failed job still pays for the traffic it generated before
//!   failing; its partial bill is included in the sum.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, CommStats};
use crate::coordinator::Algorithm;
use crate::sync::Mutex;

/// One queued query: a display name plus the algorithm to run. The
/// algorithm chooses its own wire codec (e.g.
/// [`QuantizedPower`](crate::coordinator::QuantizedPower) installs a
/// lossy codec on its session); everything else runs lossless.
pub struct Job {
    /// Display name for reports (distinct from the algorithm's own
    /// [`Algorithm::name`], so two jobs may run the same algorithm).
    pub name: String,
    /// The query itself.
    pub alg: Box<dyn Algorithm + Send>,
}

impl Job {
    pub fn new(name: impl Into<String>, alg: Box<dyn Algorithm + Send>) -> Job {
        Job { name: name.into(), alg }
    }
}

/// Outcome of one job.
pub struct JobReport {
    /// The job's display name.
    pub name: String,
    /// The algorithm's identifier ([`Algorithm::name`]).
    pub alg: &'static str,
    /// The job's own communication bill — identical to its solo-run
    /// bill; a partial bill if the job failed (including any straggler
    /// replies from its own failed rounds, billed to it on arrival).
    pub comm: CommStats,
    /// Leader-side wallclock of the run itself (excludes queue wait).
    pub wall: Duration,
    /// Submission-to-completion latency (includes queue wait — the
    /// quantity that grows under load).
    pub latency: Duration,
    /// The estimate, if the job succeeded.
    pub w: Option<Vec<f64>>,
    /// The failure, if it did not.
    pub error: Option<String>,
}

impl JobReport {
    pub fn succeeded(&self) -> bool {
        self.error.is_none()
    }
}

/// Outcome of one [`serve`] call.
pub struct ServeReport {
    /// Per-job reports in **submission order**.
    pub jobs: Vec<JobReport>,
    /// End-to-end wallclock of the whole batch.
    pub wall: Duration,
    /// The cluster's aggregate bill over the serve window. When the
    /// batch had the cluster to itself this equals [`ServeReport::bills_sum`]
    /// exactly ([`ServeReport::accounting_exact`]); traffic from
    /// sessions outside the batch (e.g. a second concurrent `serve`
    /// call) lands here but in no job's bill.
    pub aggregate: CommStats,
    /// The sum of the per-job bills.
    pub bills_sum: CommStats,
    /// Whether `bills_sum == aggregate` held for this window — the
    /// accounting identity, exact whenever nothing outside the batch
    /// touched the cluster. Completed work is returned either way.
    pub accounting_exact: bool,
    /// Completed jobs per second of wallclock.
    pub throughput: f64,
}

impl ServeReport {
    /// Mean submission-to-completion latency in seconds.
    pub fn mean_latency_s(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        self.jobs.iter().map(|j| j.latency.as_secs_f64()).sum::<f64>() / self.jobs.len() as f64
    }
}

/// Run `jobs` to completion over `tenants` concurrent leader threads on
/// one shared cluster. Returns per-job bills (each identical to the
/// job's solo-run bill) plus batch metrics; errors only on a bad
/// `tenants` count — individual job failures are reported in their
/// [`JobReport::error`], and completed work is never discarded.
///
/// The Σ-bills == aggregate identity is exact when the serve batch has
/// the cluster to itself for the window; its outcome is recorded in
/// [`ServeReport::accounting_exact`] (see the module docs).
pub fn serve(cluster: &Cluster, jobs: Vec<Job>, tenants: usize) -> Result<ServeReport> {
    ensure!(tenants >= 1, "serve requires at least one tenant thread");
    let n_jobs = jobs.len();
    let agg0 = cluster.aggregate_stats();
    let t_start = Instant::now();
    let queue: Mutex<VecDeque<(usize, Job)>> =
        Mutex::named(jobs.into_iter().enumerate().collect(), "serve.queue");
    let done: Mutex<Vec<(usize, JobReport)>> =
        Mutex::named(Vec::with_capacity(n_jobs), "serve.done");
    std::thread::scope(|s| {
        for _ in 0..tenants.min(n_jobs.max(1)) {
            s.spawn(|| loop {
                let next = queue.lock().pop_front();
                let Some((idx, job)) = next else { break };
                let alg_name = job.alg.name();
                let session = cluster.session();
                let t_run = Instant::now();
                let outcome = job.alg.run(&session);
                // close() rather than a stats() snapshot + drop: closing
                // is race-free, so a straggler from this job's own failed
                // round billed by a concurrent tenant is either in this
                // bill or (once closed) in nobody's — the Σ bills ==
                // aggregate identity below holds under all interleavings
                let comm = session.close();
                let latency = t_start.elapsed();
                let report = match outcome {
                    Ok(est) => JobReport {
                        name: job.name,
                        alg: alg_name,
                        comm,
                        wall: est.wall,
                        latency,
                        w: Some(est.w),
                        error: None,
                    },
                    Err(e) => JobReport {
                        name: job.name,
                        alg: alg_name,
                        // comm above: the traffic the job generated
                        // before failing
                        wall: t_run.elapsed(),
                        latency,
                        w: None,
                        error: Some(format!("{e:#}")),
                        comm,
                    },
                };
                done.lock().push((idx, report));
            });
        }
    });
    let wall = t_start.elapsed();
    let mut reports = done.into_inner();
    reports.sort_by_key(|(idx, _)| *idx);
    let jobs: Vec<JobReport> = reports.into_iter().map(|(_, r)| r).collect();
    let aggregate = cluster.aggregate_stats().delta_since(&agg0);
    // the accounting identity: sum of per-job bills == aggregate
    // window. Recorded rather than enforced — aborting here would
    // discard completed work whenever sessions outside the batch
    // (another concurrent serve(), a hand-rolled tenant) also billed
    // the aggregate during the window. Exclusive-use callers (the E11
    // driver, the tests) assert `accounting_exact` themselves.
    let mut bills_sum = CommStats::default();
    for j in &jobs {
        bills_sum.merge(&j.comm);
    }
    let accounting_exact = bills_sum == aggregate;
    let completed = jobs.iter().filter(|j| j.succeeded()).count();
    Ok(ServeReport {
        jobs,
        wall,
        aggregate,
        bills_sum,
        accounting_exact,
        throughput: completed as f64 / wall.as_secs_f64().max(1e-12),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Session, WirePrecision};
    use crate::coordinator::{
        DistributedLanczos, DistributedPower, Estimate, QuantizedPower, SignFixedAverage,
    };
    use crate::data::CovModel;

    fn small_cluster(m: usize, n: usize, d: usize, seed: u64) -> Cluster {
        let dist = CovModel::paper_fig1(d, seed ^ 0xab).gaussian();
        Cluster::generate(&dist, m, n, seed).unwrap()
    }

    fn mixed_jobs() -> Vec<Job> {
        vec![
            Job::new("power", Box::new(DistributedPower::default())),
            Job::new("quantized-bf16", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
            Job::new("sign-fixed", Box::new(SignFixedAverage)),
            Job::new("lanczos", Box::new(DistributedLanczos::default())),
        ]
    }

    #[test]
    fn serve_runs_all_jobs_and_reports_in_submission_order() {
        let c = small_cluster(3, 60, 8, 1);
        let report = serve(&c, mixed_jobs(), 2).unwrap();
        assert_eq!(report.jobs.len(), 4);
        let names: Vec<&str> = report.jobs.iter().map(|j| j.name.as_str()).collect();
        assert_eq!(names, ["power", "quantized-bf16", "sign-fixed", "lanczos"]);
        for j in &report.jobs {
            assert!(j.succeeded(), "{}: {:?}", j.name, j.error);
            assert!(j.w.is_some());
            assert!(j.comm.rounds >= 1, "{} billed no rounds", j.name);
            assert!(j.latency >= j.wall, "latency includes queue wait");
        }
        assert!(report.accounting_exact, "exclusive batch: Σ bills must equal aggregate");
        assert_eq!(report.bills_sum, report.aggregate);
        assert!(report.throughput > 0.0);
    }

    #[test]
    fn concurrent_bills_match_solo_bills_and_sum_to_aggregate() {
        let c = small_cluster(3, 60, 8, 2);
        // solo reference bills, one quiet session each
        let solo: Vec<CommStats> = mixed_jobs()
            .into_iter()
            .map(|j| j.alg.run(&c.session()).unwrap().comm)
            .collect();
        let agg0 = c.aggregate_stats();
        let report = serve(&c, mixed_jobs(), 4).unwrap();
        for (j, solo_bill) in report.jobs.iter().zip(&solo) {
            assert_eq!(&j.comm, solo_bill, "{}: concurrent bill != solo bill", j.name);
        }
        assert!(report.accounting_exact);
        assert_eq!(c.aggregate_stats().delta_since(&agg0), report.aggregate);
    }

    #[test]
    fn one_tenant_equals_sequential_execution() {
        let c = small_cluster(2, 40, 6, 3);
        let report = serve(&c, mixed_jobs(), 1).unwrap();
        assert_eq!(report.jobs.len(), 4);
        // with one tenant, completion order IS submission order, so each
        // job's latency is at least the previous one's
        for pair in report.jobs.windows(2) {
            assert!(pair[1].latency >= pair[0].latency);
        }
    }

    /// An algorithm that performs one round and then fails.
    struct FailingAlg;
    impl Algorithm for FailingAlg {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn run(&self, session: &Session<'_>) -> Result<Estimate> {
            session.reset_stats();
            let v = vec![1.0; session.d()];
            session.dist_matvec(&v)?;
            anyhow::bail!("synthetic failure after one round")
        }
    }

    #[test]
    fn failed_job_reports_error_and_partial_bill_without_aborting_batch() {
        let c = small_cluster(2, 30, 6, 4);
        let jobs = vec![
            Job::new("ok", Box::new(SignFixedAverage)),
            Job::new("boom", Box::new(FailingAlg)),
            Job::new("ok-2", Box::new(SignFixedAverage)),
        ];
        let report = serve(&c, jobs, 2).unwrap();
        assert_eq!(report.jobs.len(), 3);
        assert!(report.jobs[0].succeeded());
        assert!(!report.jobs[1].succeeded());
        assert!(report.jobs[1].error.as_deref().unwrap().contains("synthetic failure"));
        assert_eq!(report.jobs[1].comm.rounds, 1, "failed job still pays its round");
        assert!(report.accounting_exact, "partial bills keep the identity exact");
        assert!(report.jobs[2].succeeded());
        // throughput counts completed jobs only
        assert!((report.throughput * report.wall.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn more_tenants_than_jobs_is_fine() {
        let c = small_cluster(2, 30, 6, 5);
        let report = serve(&c, vec![Job::new("only", Box::new(SignFixedAverage))], 8).unwrap();
        assert_eq!(report.jobs.len(), 1);
        assert!(report.jobs[0].succeeded());
        assert!(serve(&c, Vec::new(), 2).unwrap().jobs.is_empty());
        assert!(serve(&c, Vec::new(), 0).is_err(), "zero tenants is a config error");
    }
}
