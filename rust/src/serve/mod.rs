//! `serve` — a multi-tenant scheduler: run many heterogeneous PCA
//! queries concurrently on **one** shared cluster, with exact per-job
//! bills and aggregate throughput/latency metrics.
//!
//! This is the deployment shape of distributed PCA in practice (cf. Fan
//! et al., *Distributed Estimation of Principal Eigenspaces*): the
//! sharded dataset is resident on the machines, and many estimation
//! queries — different algorithms, accuracies, even wire codecs — are
//! answered against it. The session layer makes this safe: every job
//! runs on its own [`Session`](crate::cluster::Session) (own
//! [`CommStats`] bill, own codec, own sequence numbers), so concurrent
//! jobs cannot corrupt each other's accounting or wire precision.
//!
//! ## Scheduling & fairness contract
//!
//! - Every job belongs to a **tenant** and a **QoS class**
//!   ([`QosClass`]) and carries a fair-share **weight**. Dispatch is
//!   two-level: strictly by QoS class (`Interactive` > `Standard` >
//!   `Batch`), then weighted-fair within the class — each tenant keeps
//!   a virtual time that advances by `1/weight` per dispatched job, and
//!   the eligible tenant with the smallest virtual time runs next
//!   (ties break by first-submission order, so dispatch is
//!   deterministic). Jobs of one tenant within one class stay FIFO.
//! - **Admission control**: [`ServePolicy::queue_depth`] bounds how many
//!   jobs the batch accepts. Excess jobs are rejected with a typed
//!   [`RejectReason`] in their [`JobReport`] — never a panic, and never
//!   silently dropped: rejected jobs appear in [`ServeReport::jobs`] at
//!   their submission position with an empty bill.
//! - **Rate limits**: [`ServePolicy::max_inflight`] caps how many of a
//!   tenant's jobs run concurrently. A capped tenant's surplus jobs
//!   wait; other tenants' jobs are dispatched around them.
//! - Work-conserving up to the declared limits: a tenant thread never
//!   idles while an *eligible* job (one whose tenant is under its rate
//!   cap) is queued, and [`ServeReport::jobs`] is returned in
//!   submission order regardless of execution order.
//! - Starvation-freedom: a serve batch is finite and
//!   admission-bounded, every dispatch removes one job, and min-vtime
//!   selection within a class serves every tenant with weight ≥ 1
//!   infinitely often — so every admitted job runs. (A continuously-fed
//!   queue would additionally age `Batch` jobs into higher classes;
//!   see DESIGN.md §8.)
//! - Tenant rounds genuinely **overlap on the wire** (see
//!   [`crate::cluster`]'s split-phase collectives): one tenant's
//!   submit never waits behind another tenant's in-flight replies, so
//!   batch wallclock drops as tenants are added until the workers
//!   saturate — the E11 driver measures (and asserts) the win.
//!   Concurrency changes *when* a job's rounds happen, never what they
//!   cost.
//!
//! ## Accounting contract
//!
//! - Each [`JobReport::comm`] is exactly the bill the same job would
//!   pay running alone on an idle cluster (same rounds, messages,
//!   bytes) — scheduling policy, concurrency, and cross-tenant round
//!   fusion ([`Cluster::enable_fusion`](crate::cluster::Cluster::enable_fusion))
//!   never change what a job costs.
//! - The sum of all job bills ([`ServeReport::bills_sum`]) equals
//!   [`ServeReport::aggregate`], the delta of the cluster's monotonic
//!   aggregate ledger over the serve window, whenever the batch has
//!   the cluster to itself. [`serve`] records the identity's outcome
//!   in [`ServeReport::accounting_exact`] on every call (traffic from
//!   sessions outside the batch — e.g. a second concurrent `serve` —
//!   lands in the aggregate but in no job's bill); exclusive-use
//!   callers assert it.
//! - A failed job still pays for the traffic it generated before
//!   failing; its partial bill is included in the sum. A rejected job
//!   never touched the cluster and bills nothing.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, CommStats};
use crate::coordinator::Algorithm;
use crate::sync::{Condvar, Mutex};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Priority class of a job. Dispatch is strict across classes —
/// an eligible `Interactive` job always runs before an eligible
/// `Standard` one — and weighted-fair within a class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum QosClass {
    /// Latency-sensitive foreground queries.
    Interactive,
    /// The default class.
    Standard,
    /// Throughput-oriented background work.
    Batch,
}

impl QosClass {
    /// All classes, highest priority first (dispatch scan order).
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];

    /// Short label for reports and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }
}

/// Why a job was refused admission. Typed so callers can branch on the
/// cause; rejected jobs surface this in [`JobReport::rejected`] rather
/// than panicking or vanishing from the report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The batch already admitted `depth` jobs
    /// ([`ServePolicy::queue_depth`]).
    QueueFull { depth: usize },
    /// The tenant already admitted its per-batch maximum
    /// ([`ServePolicy::max_admitted`]).
    RateLimited { tenant: String, limit: usize },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { depth } => {
                write!(f, "queue full (admission depth {depth})")
            }
            RejectReason::RateLimited { tenant, limit } => {
                write!(f, "tenant '{tenant}' over its admission limit of {limit}")
            }
        }
    }
}

/// Scheduler policy for one [`serve_with`] call. The default is the
/// pre-scheduler behavior: everything admitted, no rate caps, one
/// implicit tenant per job's declared tenant name.
#[derive(Clone, Debug, Default)]
pub struct ServePolicy {
    /// Maximum number of jobs the batch admits (`None` = unbounded).
    /// Jobs beyond the bound are rejected with
    /// [`RejectReason::QueueFull`].
    pub queue_depth: Option<usize>,
    /// Per-tenant admission cap: at most `limit` jobs of `tenant`
    /// are admitted per batch; the rest are rejected with
    /// [`RejectReason::RateLimited`].
    pub max_admitted: Vec<(String, usize)>,
    /// Per-tenant concurrency cap: at most `limit` jobs of `tenant`
    /// run at once. Surplus jobs wait (they are admitted, not
    /// rejected) while other tenants dispatch around them.
    pub max_inflight: Vec<(String, usize)>,
}

impl ServePolicy {
    fn admitted_cap(&self, tenant: &str) -> Option<usize> {
        self.max_admitted.iter().find(|(t, _)| t == tenant).map(|(_, l)| *l)
    }

    fn inflight_cap(&self, tenant: &str) -> Option<usize> {
        self.max_inflight.iter().find(|(t, _)| t == tenant).map(|(_, l)| *l)
    }
}

/// One queued query: a display name plus the algorithm to run, tagged
/// with the scheduling attributes the weighted-fair queue uses. The
/// algorithm chooses its own wire codec (e.g.
/// [`QuantizedPower`](crate::coordinator::QuantizedPower) installs a
/// lossy codec on its session); everything else runs lossless.
pub struct Job {
    /// Display name for reports (distinct from the algorithm's own
    /// [`Algorithm::name`], so two jobs may run the same algorithm).
    pub name: String,
    /// Tenant the job bills its fair share against. Defaults to
    /// `"default"`; jobs sharing a tenant share one FIFO lane per QoS
    /// class and one virtual clock.
    pub tenant: String,
    /// Priority class (default [`QosClass::Standard`]).
    pub qos: QosClass,
    /// Fair-share weight of the job's tenant (≥ 1; a tenant's weight
    /// is the maximum declared across its jobs). Weight 2 receives
    /// twice the dispatch share of weight 1 within a class.
    pub weight: u32,
    /// The query itself.
    pub alg: Box<dyn Algorithm + Send>,
}

impl Job {
    pub fn new(name: impl Into<String>, alg: Box<dyn Algorithm + Send>) -> Job {
        Job {
            name: name.into(),
            tenant: "default".to_string(),
            qos: QosClass::Standard,
            weight: 1,
            alg,
        }
    }

    /// Assign the job to a tenant (fair-share lane).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Job {
        self.tenant = tenant.into();
        self
    }

    /// Assign a priority class.
    pub fn with_qos(mut self, qos: QosClass) -> Job {
        self.qos = qos;
        self
    }

    /// Assign a fair-share weight (clamped to ≥ 1).
    pub fn with_weight(mut self, weight: u32) -> Job {
        self.weight = weight.max(1);
        self
    }
}

/// Outcome of one job.
pub struct JobReport {
    /// The job's display name.
    pub name: String,
    /// The algorithm's identifier ([`Algorithm::name`]).
    pub alg: &'static str,
    /// Tenant the job ran under.
    pub tenant: String,
    /// Priority class the job ran under.
    pub qos: QosClass,
    /// The job's own communication bill — identical to its solo-run
    /// bill; a partial bill if the job failed (including any straggler
    /// replies from its own failed rounds, billed to it on arrival);
    /// empty if the job was rejected at admission.
    pub comm: CommStats,
    /// Leader-side wallclock of the run itself (excludes queue wait).
    pub wall: Duration,
    /// Submission-to-completion latency (includes queue wait — the
    /// quantity that grows under load).
    pub latency: Duration,
    /// The estimate, if the job succeeded.
    pub w: Option<Vec<f64>>,
    /// The failure, if it ran and did not succeed.
    pub error: Option<String>,
    /// Set iff the job was refused admission (it never ran and billed
    /// nothing).
    pub rejected: Option<RejectReason>,
}

impl JobReport {
    pub fn succeeded(&self) -> bool {
        self.error.is_none() && self.rejected.is_none()
    }
}

/// Outcome of one [`serve`] call.
pub struct ServeReport {
    /// Per-job reports in **submission order** (rejected jobs
    /// included, at their submission position).
    pub jobs: Vec<JobReport>,
    /// End-to-end wallclock of the whole batch.
    pub wall: Duration,
    /// The cluster's aggregate bill over the serve window. When the
    /// batch had the cluster to itself this equals [`ServeReport::bills_sum`]
    /// exactly ([`ServeReport::accounting_exact`]); traffic from
    /// sessions outside the batch (e.g. a second concurrent `serve`
    /// call) lands here but in no job's bill.
    pub aggregate: CommStats,
    /// The sum of the per-job bills.
    pub bills_sum: CommStats,
    /// Whether `bills_sum == aggregate` held for this window — the
    /// accounting identity, exact whenever nothing outside the batch
    /// touched the cluster. Completed work is returned either way.
    pub accounting_exact: bool,
    /// Completed jobs per second of wallclock.
    pub throughput: f64,
}

impl ServeReport {
    /// Mean submission-to-completion latency in seconds over the jobs
    /// that actually ran (rejected jobs have no latency).
    pub fn mean_latency_s(&self) -> f64 {
        let ran: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.rejected.is_none())
            .map(|j| j.latency.as_secs_f64())
            .collect();
        if ran.is_empty() {
            return 0.0;
        }
        ran.iter().sum::<f64>() / ran.len() as f64
    }

    /// Latency distribution (p50 = median, p95, mean, …) over the jobs
    /// that ran, optionally restricted to one QoS class. `None` when no
    /// job of the class ran — the scheduler's fairness claims are
    /// observable per class, not just in aggregate.
    pub fn latency_summary(&self, qos: Option<QosClass>) -> Option<Summary> {
        let samples: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.rejected.is_none() && qos.is_none_or(|q| j.qos == q))
            .map(|j| j.latency.as_secs_f64())
            .collect();
        if samples.is_empty() {
            None
        } else {
            Some(Summary::of(&samples))
        }
    }

    /// Number of jobs refused admission.
    pub fn rejected(&self) -> usize {
        self.jobs.iter().filter(|j| j.rejected.is_some()).count()
    }

    /// Rejected-job counts per [`RejectReason`] kind:
    /// `(queue_full, rate_limited)`.
    pub fn rejected_by_reason(&self) -> (usize, usize) {
        let mut queue_full = 0usize;
        let mut rate_limited = 0usize;
        for j in &self.jobs {
            match &j.rejected {
                Some(RejectReason::QueueFull { .. }) => queue_full += 1,
                Some(RejectReason::RateLimited { .. }) => rate_limited += 1,
                None => {}
            }
        }
        (queue_full, rate_limited)
    }

    /// Machine-readable batch report: per-job rows (submission order),
    /// batch metrics, per-QoS latency summaries, and rejected-job
    /// counts broken out per [`RejectReason`].
    pub fn to_json(&self) -> Json {
        fn summary_json(s: &Summary) -> Json {
            let mut o = BTreeMap::new();
            o.insert("n".to_string(), Json::Num(s.n as f64));
            o.insert("mean_s".to_string(), Json::Num(s.mean));
            o.insert("p50_s".to_string(), Json::Num(s.median));
            o.insert("p95_s".to_string(), Json::Num(s.p95));
            Json::Obj(o)
        }
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(j.name.clone()));
                o.insert("alg".to_string(), Json::Str(j.alg.to_string()));
                o.insert("tenant".to_string(), Json::Str(j.tenant.clone()));
                o.insert("qos".to_string(), Json::Str(j.qos.label().to_string()));
                o.insert("ok".to_string(), Json::Bool(j.succeeded()));
                o.insert("rounds".to_string(), Json::Num(j.comm.rounds as f64));
                o.insert("bytes".to_string(), Json::Num(j.comm.bytes as f64));
                o.insert("wall_s".to_string(), Json::Num(j.wall.as_secs_f64()));
                o.insert("latency_s".to_string(), Json::Num(j.latency.as_secs_f64()));
                if let Some(e) = &j.error {
                    o.insert("error".to_string(), Json::Str(e.clone()));
                }
                if let Some(r) = &j.rejected {
                    o.insert("rejected".to_string(), Json::Str(r.to_string()));
                }
                Json::Obj(o)
            })
            .collect();
        let (queue_full, rate_limited) = self.rejected_by_reason();
        let mut rejects = BTreeMap::new();
        rejects.insert("total".to_string(), Json::Num(self.rejected() as f64));
        rejects.insert("queue_full".to_string(), Json::Num(queue_full as f64));
        rejects.insert("rate_limited".to_string(), Json::Num(rate_limited as f64));
        let mut latency = BTreeMap::new();
        if let Some(s) = self.latency_summary(None) {
            latency.insert("overall".to_string(), summary_json(&s));
        }
        for qos in QosClass::ALL {
            if let Some(s) = self.latency_summary(Some(qos)) {
                latency.insert(qos.label().to_string(), summary_json(&s));
            }
        }
        let mut top = BTreeMap::new();
        top.insert("jobs".to_string(), Json::Arr(jobs));
        top.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        top.insert("throughput_jobs_per_s".to_string(), Json::Num(self.throughput));
        top.insert("mean_latency_s".to_string(), Json::Num(self.mean_latency_s()));
        top.insert("accounting_exact".to_string(), Json::Bool(self.accounting_exact));
        top.insert("aggregate_bytes".to_string(), Json::Num(self.aggregate.bytes as f64));
        top.insert("rejects".to_string(), Json::Obj(rejects));
        top.insert("latency".to_string(), Json::Obj(latency));
        Json::Obj(top)
    }
}

/// One tenant's scheduling lane: FIFO subqueues per QoS class plus the
/// weighted-fair virtual clock. Lane index = first-submission order
/// (the deterministic tie-break).
struct Lane {
    tenant: String,
    weight: u32,
    inflight_cap: Option<usize>,
    inflight: usize,
    /// Virtual time: advanced by `1/weight` per dispatched job; the
    /// eligible lane with the smallest vtime dispatches next.
    vtime: f64,
    /// One FIFO per QoS class, indexed as [`QosClass::ALL`].
    queues: [VecDeque<(usize, Job)>; 3],
}

impl Lane {
    fn pending(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    fn eligible(&self) -> bool {
        self.pending() > 0 && self.inflight_cap.is_none_or(|cap| self.inflight < cap)
    }
}

/// Shared scheduler state behind the `serve.queue` lock.
struct Sched {
    lanes: Vec<Lane>,
    /// Queued (not yet dispatched) jobs across all lanes.
    pending: usize,
}

impl Sched {
    /// Pick the next job under the two-level policy: strict QoS class
    /// priority, weighted-fair (min vtime, ties by lane order) within
    /// the class, honoring inflight caps. `None` with `pending > 0`
    /// means every queued tenant is at its rate cap — the caller waits.
    fn pop_next(&mut self) -> Option<(usize, usize, Job)> {
        for (ci, _) in QosClass::ALL.iter().enumerate() {
            let mut best: Option<usize> = None;
            for (li, lane) in self.lanes.iter().enumerate() {
                if !lane.eligible() || lane.queues[ci].is_empty() {
                    continue;
                }
                if best.is_none_or(|b| lane.vtime < self.lanes[b].vtime) {
                    best = Some(li);
                }
            }
            if let Some(li) = best {
                let lane = &mut self.lanes[li];
                if let Some((idx, job)) = lane.queues[ci].pop_front() {
                    lane.inflight += 1;
                    lane.vtime += 1.0 / lane.weight as f64;
                    self.pending -= 1;
                    crate::obs_gauge!(SERVE_QUEUE_DEPTH, self.pending as u64);
                    // fairness telemetry: spread between the fastest and
                    // slowest lane's virtual clock at this dispatch
                    let mut lo = f64::INFINITY;
                    let mut hi = 0.0f64;
                    for l in &self.lanes {
                        lo = lo.min(l.vtime);
                        hi = hi.max(l.vtime);
                    }
                    if lo.is_finite() {
                        crate::obs_gauge!(SERVE_VTIME_LAG_X1000, ((hi - lo) * 1000.0) as u64);
                    }
                    return Some((li, idx, job));
                }
            }
        }
        None
    }
}

/// Run `jobs` over `tenants` concurrent leader threads with the default
/// policy (everything admitted, no rate caps) — the pre-scheduler
/// behavior, kept as the one-line entry point.
pub fn serve(cluster: &Cluster, jobs: Vec<Job>, tenants: usize) -> Result<ServeReport> {
    serve_with(cluster, jobs, tenants, &ServePolicy::default())
}

/// Run `jobs` to completion over `tenants` concurrent leader threads on
/// one shared cluster under `policy`. Returns per-job bills (each
/// identical to the job's solo-run bill) plus batch metrics; errors
/// only on a bad configuration — individual job failures are reported
/// in their [`JobReport::error`], admission rejects in
/// [`JobReport::rejected`], and completed work is never discarded.
///
/// The Σ-bills == aggregate identity is exact when the serve batch has
/// the cluster to itself for the window; its outcome is recorded in
/// [`ServeReport::accounting_exact`] (see the module docs).
pub fn serve_with(
    cluster: &Cluster,
    jobs: Vec<Job>,
    tenants: usize,
    policy: &ServePolicy,
) -> Result<ServeReport> {
    ensure!(tenants >= 1, "serve requires at least one tenant thread");
    for (t, l) in policy.max_inflight.iter().chain(&policy.max_admitted) {
        ensure!(*l >= 1, "serve policy: tenant '{t}' limit must be >= 1 (0 admits nothing)");
    }
    let n_jobs = jobs.len();
    let agg0 = cluster.aggregate_stats();
    let t_start = Instant::now();

    // Admission + lane construction, in submission order. Rejected
    // jobs turn into reports immediately; admitted jobs land in their
    // tenant's per-class FIFO.
    let mut sched = Sched { lanes: Vec::new(), pending: 0 };
    let mut rejects: Vec<(usize, JobReport)> = Vec::new();
    let mut admitted_total = 0usize;
    for (idx, job) in jobs.into_iter().enumerate() {
        let reject = if policy.queue_depth.is_some_and(|cap| admitted_total >= cap) {
            Some(RejectReason::QueueFull { depth: policy.queue_depth.unwrap_or(0) })
        } else {
            policy.admitted_cap(&job.tenant).and_then(|limit| {
                let already = sched
                    .lanes
                    .iter()
                    .find(|l| l.tenant == job.tenant)
                    .map_or(0, |l| l.pending());
                (already >= limit)
                    .then(|| RejectReason::RateLimited { tenant: job.tenant.clone(), limit })
            })
        };
        if let Some(reason) = reject {
            match job.qos {
                QosClass::Interactive => crate::obs_inc!(SERVE_REJECTS_INTERACTIVE_TOTAL),
                QosClass::Standard => crate::obs_inc!(SERVE_REJECTS_STANDARD_TOTAL),
                QosClass::Batch => crate::obs_inc!(SERVE_REJECTS_BATCH_TOTAL),
            }
            crate::obs_trace!(
                "reject",
                tenant = job.tenant.as_str(),
                qos = job.qos.label(),
                reason = reason.to_string()
            );
            rejects.push((
                idx,
                JobReport {
                    name: job.name,
                    alg: job.alg.name(),
                    tenant: job.tenant,
                    qos: job.qos,
                    comm: CommStats::default(),
                    wall: Duration::ZERO,
                    latency: Duration::ZERO,
                    w: None,
                    error: None,
                    rejected: Some(reason),
                },
            ));
            continue;
        }
        admitted_total += 1;
        let lane_idx = match sched.lanes.iter().position(|l| l.tenant == job.tenant) {
            Some(i) => i,
            None => {
                sched.lanes.push(Lane {
                    tenant: job.tenant.clone(),
                    weight: 1,
                    inflight_cap: policy.inflight_cap(&job.tenant),
                    inflight: 0,
                    vtime: 0.0,
                    queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                });
                sched.lanes.len() - 1
            }
        };
        let lane = &mut sched.lanes[lane_idx];
        lane.weight = lane.weight.max(job.weight.max(1));
        let class = QosClass::ALL.iter().position(|c| *c == job.qos).unwrap_or(1);
        lane.queues[class].push_back((idx, job));
        sched.pending += 1;
    }
    crate::obs_gauge!(SERVE_QUEUE_DEPTH, sched.pending as u64);

    let queue: Mutex<Sched> = Mutex::named(sched, "serve.queue");
    let queue_cv = Condvar::new();
    let done: Mutex<Vec<(usize, JobReport)>> =
        Mutex::named(Vec::with_capacity(n_jobs), "serve.done");
    std::thread::scope(|s| {
        for _ in 0..tenants.min(admitted_total.max(1)) {
            s.spawn(|| loop {
                let (lane_idx, idx, job) = {
                    let mut st = queue.lock();
                    loop {
                        match st.pop_next() {
                            Some(next) => break next,
                            None if st.pending == 0 => return,
                            None => {
                                // queued work exists but every tenant
                                // with queued jobs is at its rate cap —
                                // wait for a completion to free a slot
                                crate::obs_inc!(SERVE_RATE_LIMIT_WAITS_TOTAL);
                                let (guard, _) =
                                    queue_cv.wait_timeout(st, Duration::from_millis(50));
                                st = guard;
                            }
                        }
                    }
                };
                let alg_name = job.alg.name();
                let session = cluster.session();
                // observability only: the tenant name groups this
                // session's rounds in the trace timeline
                session.set_trace_label(&job.tenant);
                let t_run = Instant::now();
                let outcome = job.alg.run(&session);
                // close() rather than a stats() snapshot + drop: closing
                // is race-free, so a straggler from this job's own failed
                // round billed by a concurrent tenant is either in this
                // bill or (once closed) in nobody's — the Σ bills ==
                // aggregate identity below holds under all interleavings
                let comm = session.close();
                let latency = t_start.elapsed();
                let report = match outcome {
                    Ok(est) => JobReport {
                        name: job.name,
                        alg: alg_name,
                        tenant: job.tenant,
                        qos: job.qos,
                        comm,
                        wall: est.wall,
                        latency,
                        w: Some(est.w),
                        error: None,
                        rejected: None,
                    },
                    Err(e) => JobReport {
                        name: job.name,
                        alg: alg_name,
                        tenant: job.tenant,
                        qos: job.qos,
                        // comm above: the traffic the job generated
                        // before failing
                        wall: t_run.elapsed(),
                        latency,
                        w: None,
                        error: Some(format!("{e:#}")),
                        rejected: None,
                        comm,
                    },
                };
                done.lock().push((idx, report));
                {
                    let mut st = queue.lock();
                    st.lanes[lane_idx].inflight -= 1;
                }
                queue_cv.notify_all();
            });
        }
    });
    let wall = t_start.elapsed();
    let mut reports = done.into_inner();
    reports.extend(rejects);
    reports.sort_by_key(|(idx, _)| *idx);
    let jobs: Vec<JobReport> = reports.into_iter().map(|(_, r)| r).collect();
    let aggregate = cluster.aggregate_stats().delta_since(&agg0);
    // the accounting identity: sum of per-job bills == aggregate
    // window. Recorded rather than enforced — aborting here would
    // discard completed work whenever sessions outside the batch
    // (another concurrent serve(), a hand-rolled tenant) also billed
    // the aggregate during the window. Exclusive-use callers (the E11
    // driver, the tests) assert `accounting_exact` themselves.
    let mut bills_sum = CommStats::default();
    for j in &jobs {
        bills_sum.merge(&j.comm);
    }
    let accounting_exact = bills_sum == aggregate;
    let completed = jobs.iter().filter(|j| j.succeeded()).count();
    Ok(ServeReport {
        jobs,
        wall,
        aggregate,
        bills_sum,
        accounting_exact,
        throughput: completed as f64 / wall.as_secs_f64().max(1e-12),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Session, WirePrecision};
    use crate::coordinator::{
        DistributedLanczos, DistributedPower, Estimate, QuantizedPower, SignFixedAverage,
    };
    use crate::data::CovModel;

    fn small_cluster(m: usize, n: usize, d: usize, seed: u64) -> Cluster {
        let dist = CovModel::paper_fig1(d, seed ^ 0xab).gaussian();
        Cluster::generate(&dist, m, n, seed).unwrap()
    }

    fn mixed_jobs() -> Vec<Job> {
        vec![
            Job::new("power", Box::new(DistributedPower::default())),
            Job::new("quantized-bf16", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
            Job::new("sign-fixed", Box::new(SignFixedAverage)),
            Job::new("lanczos", Box::new(DistributedLanczos::default())),
        ]
    }

    #[test]
    fn serve_runs_all_jobs_and_reports_in_submission_order() {
        let c = small_cluster(3, 60, 8, 1);
        let report = serve(&c, mixed_jobs(), 2).unwrap();
        assert_eq!(report.jobs.len(), 4);
        let names: Vec<&str> = report.jobs.iter().map(|j| j.name.as_str()).collect();
        assert_eq!(names, ["power", "quantized-bf16", "sign-fixed", "lanczos"]);
        for j in &report.jobs {
            assert!(j.succeeded(), "{}: {:?}", j.name, j.error);
            assert!(j.w.is_some());
            assert!(j.comm.rounds >= 1, "{} billed no rounds", j.name);
            assert!(j.latency >= j.wall, "latency includes queue wait");
            assert_eq!(j.tenant, "default");
            assert_eq!(j.qos, QosClass::Standard);
        }
        assert!(report.accounting_exact, "exclusive batch: Σ bills must equal aggregate");
        assert_eq!(report.bills_sum, report.aggregate);
        assert!(report.throughput > 0.0);
        assert_eq!(report.rejected(), 0);
    }

    #[test]
    fn concurrent_bills_match_solo_bills_and_sum_to_aggregate() {
        let c = small_cluster(3, 60, 8, 2);
        // solo reference bills, one quiet session each
        let solo: Vec<CommStats> = mixed_jobs()
            .into_iter()
            .map(|j| j.alg.run(&c.session()).unwrap().comm)
            .collect();
        let agg0 = c.aggregate_stats();
        let report = serve(&c, mixed_jobs(), 4).unwrap();
        for (j, solo_bill) in report.jobs.iter().zip(&solo) {
            assert_eq!(&j.comm, solo_bill, "{}: concurrent bill != solo bill", j.name);
        }
        assert!(report.accounting_exact);
        assert_eq!(c.aggregate_stats().delta_since(&agg0), report.aggregate);
    }

    #[test]
    fn one_tenant_equals_sequential_execution() {
        let c = small_cluster(2, 40, 6, 3);
        let report = serve(&c, mixed_jobs(), 1).unwrap();
        assert_eq!(report.jobs.len(), 4);
        // with one tenant, completion order IS submission order, so each
        // job's latency is at least the previous one's
        for pair in report.jobs.windows(2) {
            assert!(pair[1].latency >= pair[0].latency);
        }
    }

    /// An algorithm that performs one round and then fails.
    struct FailingAlg;
    impl Algorithm for FailingAlg {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn run(&self, session: &Session<'_>) -> Result<Estimate> {
            session.reset_stats();
            let v = vec![1.0; session.d()];
            session.dist_matvec(&v)?;
            anyhow::bail!("synthetic failure after one round")
        }
    }

    #[test]
    fn failed_job_reports_error_and_partial_bill_without_aborting_batch() {
        let c = small_cluster(2, 30, 6, 4);
        let jobs = vec![
            Job::new("ok", Box::new(SignFixedAverage)),
            Job::new("boom", Box::new(FailingAlg)),
            Job::new("ok-2", Box::new(SignFixedAverage)),
        ];
        let report = serve(&c, jobs, 2).unwrap();
        assert_eq!(report.jobs.len(), 3);
        assert!(report.jobs[0].succeeded());
        assert!(!report.jobs[1].succeeded());
        assert!(report.jobs[1].error.as_deref().unwrap().contains("synthetic failure"));
        assert_eq!(report.jobs[1].comm.rounds, 1, "failed job still pays its round");
        assert!(report.accounting_exact, "partial bills keep the identity exact");
        assert!(report.jobs[2].succeeded());
        // throughput counts completed jobs only
        assert!((report.throughput * report.wall.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn more_tenants_than_jobs_is_fine() {
        let c = small_cluster(2, 30, 6, 5);
        let report = serve(&c, vec![Job::new("only", Box::new(SignFixedAverage))], 8).unwrap();
        assert_eq!(report.jobs.len(), 1);
        assert!(report.jobs[0].succeeded());
        assert!(serve(&c, Vec::new(), 2).unwrap().jobs.is_empty());
        assert!(serve(&c, Vec::new(), 0).is_err(), "zero tenants is a config error");
    }

    /// An algorithm that records its dispatch order into a shared log
    /// before delegating to a real (cheap) estimator.
    struct Recorder {
        tag: &'static str,
        log: std::sync::Arc<Mutex<Vec<&'static str>>>,
    }
    impl Algorithm for Recorder {
        fn name(&self) -> &'static str {
            "recorder"
        }
        fn run(&self, session: &Session<'_>) -> Result<Estimate> {
            self.log.lock().push(self.tag);
            SignFixedAverage.run(session)
        }
    }

    #[test]
    fn weighted_fair_dispatch_follows_virtual_time() {
        use std::sync::Arc;
        let c = small_cluster(2, 30, 6, 6);
        let log = Arc::new(Mutex::named(Vec::new(), "test.dispatch_log"));
        // tenant A at weight 3, tenant B at weight 1, one worker thread:
        // dispatch must interleave 3 A's per B by min-vtime, not FIFO
        let mut jobs = Vec::new();
        for i in 0..6 {
            jobs.push(
                Job::new(format!("a{i}"), Box::new(Recorder { tag: "A", log: Arc::clone(&log) }))
                    .with_tenant("A")
                    .with_weight(3),
            );
        }
        for i in 0..2 {
            jobs.push(
                Job::new(format!("b{i}"), Box::new(Recorder { tag: "B", log: Arc::clone(&log) }))
                    .with_tenant("B"),
            );
        }
        let report = serve(&c, jobs, 1).unwrap();
        assert!(report.jobs.iter().all(|j| j.succeeded()));
        // vtime trace (deterministic): A(0) ties B(0) → lane order picks
        // A; A reaches vtime 1/3, B(0) runs, B jumps to 1; A catches up
        // at 1/3, 2/3, 1 (tie → A), B runs at 1 vs 4/3, then A drains:
        // A B A A A B A A — i.e. 3 A's in the first 4 dispatches and
        // A's tail after B's share exhausts.
        let order = log.lock().clone();
        assert_eq!(order.len(), 8);
        let head_a = order[..4].iter().filter(|t| **t == "A").count();
        assert_eq!(head_a, 3, "weight 3:1 → 3 A's in the first 4 dispatches, got {order:?}");
        assert_eq!(order[8 - 2..], ["A", "A"], "B's share exhausts first: {order:?}");
    }

    #[test]
    fn interactive_class_preempts_batch_class_at_dispatch() {
        use std::sync::Arc;
        let c = small_cluster(2, 30, 6, 7);
        let log = Arc::new(Mutex::named(Vec::new(), "test.qos_log"));
        // submitted batch-first; with one worker thread the interactive
        // job must still dispatch first (strict class priority)
        let jobs = vec![
            Job::new("bg", Box::new(Recorder { tag: "batch", log: Arc::clone(&log) }))
                .with_qos(QosClass::Batch),
            Job::new("fg", Box::new(Recorder { tag: "interactive", log: Arc::clone(&log) }))
                .with_qos(QosClass::Interactive),
        ];
        let report = serve(&c, jobs, 1).unwrap();
        assert!(report.jobs.iter().all(|j| j.succeeded()));
        assert_eq!(*log.lock(), ["interactive", "batch"]);
        // reports stay in submission order regardless of dispatch order
        assert_eq!(report.jobs[0].name, "bg");
        assert_eq!(report.jobs[1].name, "fg");
        // per-class latency summaries are populated
        assert!(report.latency_summary(Some(QosClass::Interactive)).is_some());
        assert!(report.latency_summary(Some(QosClass::Batch)).is_some());
        assert!(report.latency_summary(Some(QosClass::Standard)).is_none());
        let all = report.latency_summary(None).unwrap();
        assert!(all.p95 >= all.median, "p95 >= p50 by construction");
    }

    #[test]
    fn queue_depth_rejects_typed_not_panicking() {
        let c = small_cluster(2, 30, 6, 8);
        let jobs = vec![
            Job::new("in-1", Box::new(SignFixedAverage)),
            Job::new("in-2", Box::new(SignFixedAverage)),
            Job::new("out", Box::new(SignFixedAverage)),
        ];
        let policy = ServePolicy { queue_depth: Some(2), ..Default::default() };
        let report = serve_with(&c, jobs, 2, &policy).unwrap();
        assert_eq!(report.jobs.len(), 3, "rejected jobs stay in the report");
        assert!(report.jobs[0].succeeded() && report.jobs[1].succeeded());
        let r = &report.jobs[2];
        assert!(!r.succeeded());
        assert_eq!(r.rejected, Some(RejectReason::QueueFull { depth: 2 }));
        assert_eq!(r.comm, CommStats::default(), "a rejected job bills nothing");
        assert!(report.accounting_exact);
        assert_eq!(report.rejected(), 1);
        // throughput counts completed jobs only
        assert!((report.throughput * report.wall.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn per_tenant_admission_limit_rejects_surplus() {
        let c = small_cluster(2, 30, 6, 9);
        let jobs = vec![
            Job::new("n1", Box::new(SignFixedAverage)).with_tenant("noisy"),
            Job::new("n2", Box::new(SignFixedAverage)).with_tenant("noisy"),
            Job::new("q1", Box::new(SignFixedAverage)).with_tenant("quiet"),
        ];
        let policy = ServePolicy {
            max_admitted: vec![("noisy".to_string(), 1)],
            ..Default::default()
        };
        let report = serve_with(&c, jobs, 2, &policy).unwrap();
        assert!(report.jobs[0].succeeded());
        assert_eq!(
            report.jobs[1].rejected,
            Some(RejectReason::RateLimited { tenant: "noisy".to_string(), limit: 1 })
        );
        assert!(report.jobs[2].succeeded(), "other tenants are unaffected");
        let shown = report.jobs[1].rejected.as_ref().unwrap().to_string();
        assert!(shown.contains("noisy") && shown.contains('1'), "{shown}");
    }

    #[test]
    fn inflight_cap_serializes_a_tenant_without_losing_work() {
        let c = small_cluster(2, 30, 6, 10);
        let jobs: Vec<Job> = (0..5)
            .map(|i| Job::new(format!("c{i}"), Box::new(SignFixedAverage)).with_tenant("capped"))
            .collect();
        let policy =
            ServePolicy { max_inflight: vec![("capped".to_string(), 1)], ..Default::default() };
        // 4 worker threads but the tenant may only run 1 job at a time:
        // everything still completes (threads wait, never deadlock)
        let report = serve_with(&c, jobs, 4, &policy).unwrap();
        assert_eq!(report.jobs.len(), 5);
        assert!(report.jobs.iter().all(|j| j.succeeded()), "rate cap must not lose work");
        assert!(report.accounting_exact);
    }

    #[test]
    fn empty_batch_latency_metrics_are_defined_not_nan() {
        let c = small_cluster(2, 30, 6, 12);
        let report = serve(&c, Vec::new(), 2).unwrap();
        assert!(report.jobs.is_empty());
        assert_eq!(report.mean_latency_s(), 0.0, "no jobs ran: mean is 0, never NaN");
        assert!(report.mean_latency_s().is_finite());
        assert!(report.latency_summary(None).is_none(), "no samples: None, not a panic");
        for qos in QosClass::ALL {
            assert!(report.latency_summary(Some(qos)).is_none());
        }
        assert!(report.throughput.is_finite());
        let j = report.to_json();
        assert!(!j.to_string().contains("NaN"), "JSON must stay parseable: {j}");
    }

    #[test]
    fn all_rejected_batch_latency_metrics_are_defined_not_nan() {
        let c = small_cluster(2, 30, 6, 13);
        let jobs = vec![
            Job::new("r1", Box::new(SignFixedAverage)),
            Job::new("r2", Box::new(SignFixedAverage)).with_qos(QosClass::Interactive),
        ];
        let policy = ServePolicy { queue_depth: Some(0), ..Default::default() };
        let report = serve_with(&c, jobs, 2, &policy).unwrap();
        assert_eq!(report.rejected(), 2, "queue depth 0 rejects everything");
        assert_eq!(report.mean_latency_s(), 0.0, "no completed jobs: 0, never NaN");
        assert!(report.latency_summary(None).is_none());
        assert!(report.latency_summary(Some(QosClass::Interactive)).is_none());
        assert!(report.throughput.is_finite());
        assert_eq!(report.bills_sum, CommStats::default());
    }

    #[test]
    fn report_json_breaks_rejects_out_per_reason() {
        let c = small_cluster(2, 30, 6, 14);
        let jobs = vec![
            Job::new("n1", Box::new(SignFixedAverage)).with_tenant("noisy"),
            Job::new("n2", Box::new(SignFixedAverage)).with_tenant("noisy"),
            Job::new("q1", Box::new(SignFixedAverage)).with_tenant("quiet"),
            Job::new("q2", Box::new(SignFixedAverage)).with_tenant("quiet"),
        ];
        // Admission in submission order: n1 admitted, n2 rate-limited
        // (noisy cap 1), q1 admitted, q2 queue-full (depth 2).
        let policy = ServePolicy {
            queue_depth: Some(2),
            max_admitted: vec![("noisy".to_string(), 1)],
            ..Default::default()
        };
        let report = serve_with(&c, jobs, 2, &policy).unwrap();
        assert_eq!(report.rejected_by_reason(), (1, 1), "one per reason: {:?}", {
            report.jobs.iter().map(|j| j.rejected.clone()).collect::<Vec<_>>()
        });
        let j = report.to_json();
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text).expect("report JSON parses");
        let rejects = back.get("rejects").expect("rejects object");
        assert_eq!(rejects.get("total").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(rejects.get("queue_full").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(rejects.get("rate_limited").and_then(|v| v.as_f64()), Some(1.0));
        let jobs_arr = back.get("jobs").and_then(|a| a.as_arr()).expect("jobs array");
        assert_eq!(jobs_arr.len(), 4, "rejected jobs stay in the JSON report");
        assert!(back.get("latency").and_then(|l| l.get("overall")).is_some());
    }

    #[test]
    fn zero_limit_policy_is_a_config_error() {
        let c = small_cluster(2, 30, 6, 11);
        let policy =
            ServePolicy { max_inflight: vec![("t".to_string(), 0)], ..Default::default() };
        assert!(serve_with(&c, Vec::new(), 1, &policy).is_err());
        let policy2 =
            ServePolicy { max_admitted: vec![("t".to_string(), 0)], ..Default::default() };
        assert!(serve_with(&c, Vec::new(), 1, &policy2).is_err());
    }
}
