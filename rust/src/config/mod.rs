//! Launcher configuration: a small `--key value` argument parser (no
//! `clap` in the offline image) shared by `main.rs` and the examples.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                out.command = iter.next();
            }
        }
        while let Some(arg) = iter.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            let value = match iter.peek() {
                Some(v) if !v.starts_with("--") => iter.next().unwrap(),
                _ => "true".to_string(), // bare flag
            };
            out.flags.insert(key.to_string(), value);
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse a comma-separated usize list flag.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| Ok(p.trim().parse()?))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["figure1", "--m", "25", "--runs", "40", "--fast"]);
        assert_eq!(a.command.as_deref(), Some("figure1"));
        assert_eq!(a.get_usize("m", 0).unwrap(), 25);
        assert_eq!(a.get_usize("runs", 0).unwrap(), 40);
        assert!(a.get_bool("fast"));
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn defaults_used_when_missing() {
        let a = parse(&[]);
        assert_eq!(a.command, None);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_f64("eps", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn usize_list_parsing() {
        let a = parse(&["x", "--n-list", "10, 20,30"]);
        assert_eq!(a.get_usize_list("n-list", &[1]).unwrap(), vec![10, 20, 30]);
        assert_eq!(a.get_usize_list("other", &[5]).unwrap(), vec![5]);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(["x".to_string(), "y".to_string()]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--m", "abc"]);
        assert!(a.get_usize("m", 0).is_err());
    }
}
