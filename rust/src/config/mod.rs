//! Launcher configuration: a small `--key value` argument parser (no
//! `clap` in the offline image) shared by `main.rs` and the examples.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                out.command = iter.next();
            }
        }
        while let Some(arg) = iter.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            let value = match iter.peek() {
                Some(v) if !v.starts_with("--") => iter.next().unwrap(),
                _ => "true".to_string(), // bare flag
            };
            out.flags.insert(key.to_string(), value);
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Reject flags the subcommand does not accept. A typo'd flag
    /// (`--n-lsit 25`) used to be silently ignored and the run proceeded
    /// with defaults; now every `cmd_*` in `main.rs` declares its flag
    /// set and unknown flags are a hard error listing the accepted ones.
    pub fn ensure_known_flags(&self, subcommand: &str, accepted: &[&str]) -> Result<()> {
        let unknown: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !accepted.contains(&k.as_str()))
            .map(|k| format!("--{k}"))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        let accepted_list: Vec<String> = accepted.iter().map(|k| format!("--{k}")).collect();
        bail!(
            "unknown flag{} for '{subcommand}': {} (accepted: {})",
            if unknown.len() == 1 { "" } else { "s" },
            unknown.join(", "),
            accepted_list.join(", ")
        );
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse a comma-separated usize list flag.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| Ok(p.trim().parse()?))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["figure1", "--m", "25", "--runs", "40", "--fast"]);
        assert_eq!(a.command.as_deref(), Some("figure1"));
        assert_eq!(a.get_usize("m", 0).unwrap(), 25);
        assert_eq!(a.get_usize("runs", 0).unwrap(), 40);
        assert!(a.get_bool("fast"));
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn defaults_used_when_missing() {
        let a = parse(&[]);
        assert_eq!(a.command, None);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_f64("eps", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn usize_list_parsing() {
        let a = parse(&["x", "--n-list", "10, 20,30"]);
        assert_eq!(a.get_usize_list("n-list", &[1]).unwrap(), vec![10, 20, 30]);
        assert_eq!(a.get_usize_list("other", &[5]).unwrap(), vec![5]);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(["x".to_string(), "y".to_string()]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--m", "abc"]);
        assert!(a.get_usize("m", 0).is_err());
    }

    #[test]
    fn typod_flag_is_an_error_listing_accepted_flags() {
        // regression (ISSUE 3 satellite): `--n-lsit 25` used to run with
        // defaults; it must now fail, naming the typo and the real flags
        let a = parse(&["figure1", "--n-lsit", "25", "--m", "4"]);
        let err = a.ensure_known_flags("figure1", &["m", "n-list", "runs"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--n-lsit"), "names the unknown flag: {msg}");
        assert!(msg.contains("figure1"), "names the subcommand: {msg}");
        assert!(msg.contains("--n-list"), "lists the accepted flags: {msg}");
        assert!(msg.contains("--runs"), "lists the accepted flags: {msg}");
    }

    #[test]
    fn known_flags_pass_and_plural_errors_name_every_unknown() {
        let a = parse(&["topk", "--d", "8", "--k-list", "1,2"]);
        assert!(a.ensure_known_flags("topk", &["d", "k-list"]).is_ok());
        let b = parse(&["topk", "--dd", "8", "--klist", "1"]);
        let msg = b.ensure_known_flags("topk", &["d", "k-list"]).unwrap_err().to_string();
        assert!(msg.contains("--dd") && msg.contains("--klist"), "{msg}");
        assert!(msg.contains("flags"), "pluralized: {msg}");
    }
}
