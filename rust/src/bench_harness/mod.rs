//! Benchmark harness (criterion substitute for the offline image).
//!
//! Every `cargo bench` target in `rust/benches/` is a plain binary
//! (`harness = false`) built on this module: warmup, timed iterations,
//! median/p95 reporting, and environment-scaled iteration counts
//! (`DSPCA_BENCH_FAST=1` shrinks everything for CI smoke runs).
//!
//! Fast mode is resolved from the environment **once, at
//! [`Bencher::new`]** and threaded through as a field — tests inject it
//! with [`Bencher::with_fast_mode`] / [`scaled_with`] instead of
//! mutating process env (`cargo test` runs tests on parallel threads;
//! `set_var` races would leak into unrelated tests).
//!
//! Besides the stdout table, every bench finishes with
//! [`Bencher::write_json`]: a machine-readable
//! `bench_<name>.json` (name, params, per-result median/p95
//! nanoseconds, bytes where the workload has a wire cost) written under
//! [`results_dir`] — `$DSPCA_RESULTS_DIR` if set, else
//! `<workspace root>/results/` resolved from the compile-time manifest
//! path, so output lands in the same place no matter the invocation
//! CWD. Committed `BENCH_*.json` snapshots at the repo root are copies
//! of these files; CI's bench-snapshot job regenerates and validates
//! them.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wallclock seconds.
    pub samples: Vec<f64>,
    /// Wire bytes per iteration, where the workload has a wire cost
    /// (collectives, serve batches); `None` for pure-compute benches.
    pub bytes: Option<u64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<44} {:>10} {:>10} {:>10}  (n={})",
            self.name,
            fmt_dur(s.median),
            fmt_dur(s.mean),
            fmt_dur(s.p95),
            s.n
        )
    }

    /// This result as a JSON object (durations in integer nanoseconds).
    fn to_json(&self) -> Json {
        let s = self.summary();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        obj.insert("median_ns".to_string(), Json::Num((s.median * 1e9).round()));
        obj.insert("mean_ns".to_string(), Json::Num((s.mean * 1e9).round()));
        obj.insert("p95_ns".to_string(), Json::Num((s.p95 * 1e9).round()));
        obj.insert("samples".to_string(), Json::Num(s.n as f64));
        obj.insert(
            "bytes".to_string(),
            match self.bytes {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        );
        Json::Obj(obj)
    }
}

/// Human duration formatting.
pub fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// True when `DSPCA_BENCH_FAST=1`: benches shrink workloads for smoke
/// runs. Bench binaries read this once at startup; tests use
/// [`Bencher::with_fast_mode`] / [`scaled_with`] instead of setting the
/// env var.
pub fn fast_mode() -> bool {
    std::env::var("DSPCA_BENCH_FAST").as_deref() == Ok("1")
}

/// Scale an iteration count down in fast mode (env-resolved).
pub fn scaled(n: usize) -> usize {
    scaled_with(n, fast_mode())
}

/// [`scaled`] with fast mode passed explicitly (env-independent).
pub fn scaled_with(n: usize, fast: bool) -> usize {
    if fast {
        (n / 8).max(1)
    } else {
        n
    }
}

/// Deterministic directory bench JSON lands in: `$DSPCA_RESULTS_DIR` if
/// set and non-empty, else `<workspace root>/results` (the workspace
/// root is the parent of this crate's compile-time manifest dir —
/// independent of the invocation CWD).
pub fn results_dir() -> PathBuf {
    match std::env::var("DSPCA_RESULTS_DIR") {
        Ok(dir) if !dir.is_empty() => PathBuf::from(dir),
        _ => {
            let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
            manifest.parent().unwrap_or(manifest).join("results")
        }
    }
}

/// Bench runner: prints a header then each result as it completes.
pub struct Bencher {
    fast: bool,
    header_printed: bool,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Env-resolving constructor: fast mode is read from
    /// `DSPCA_BENCH_FAST` here, once, and never re-read.
    pub fn new() -> Self {
        Self::with_fast_mode(fast_mode())
    }

    /// Env-independent constructor with fast mode injected (tests).
    pub fn with_fast_mode(fast: bool) -> Self {
        Bencher { fast, header_printed: false, results: Vec::new() }
    }

    /// Whether this bencher runs in fast (smoke) mode.
    pub fn fast(&self) -> bool {
        self.fast
    }

    /// Time `f` with automatic calibration: warm up, pick an iteration
    /// count targeting ~`budget` of wall time, then collect `samples`
    /// batches. `f` should return something observable to block dead-code
    /// elimination (use [`std::hint::black_box`] inside if needed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        let budget =
            if self.fast { Duration::from_millis(120) } else { Duration::from_millis(900) };
        // warmup + calibration
        let t0 = Instant::now();
        let mut iters_done = 0u64;
        while t0.elapsed() < budget / 6 || iters_done < 3 {
            std::hint::black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / iters_done as f64;
        let samples_target = 12usize;
        let batch = ((budget.as_secs_f64() / samples_target as f64 / per_iter).ceil() as u64).max(1);
        let mut samples = Vec::with_capacity(samples_target);
        for _ in 0..samples_target {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        self.push(BenchResult { name: name.to_string(), samples, bytes: None })
    }

    /// Record externally-measured samples (seconds per op).
    pub fn record(&mut self, name: &str, samples: Vec<f64>) -> &BenchResult {
        self.push(BenchResult { name: name.to_string(), samples, bytes: None })
    }

    /// [`Bencher::record`] with the per-iteration wire-byte cost
    /// attached (collectives and serve batches have one; pure-compute
    /// benches do not).
    pub fn record_with_bytes(&mut self, name: &str, samples: Vec<f64>, bytes: u64) -> &BenchResult {
        self.push(BenchResult { name: name.to_string(), samples, bytes: Some(bytes) })
    }

    /// Attach the per-iteration wire-byte cost to the most recent
    /// result (for `bench()` workloads whose bill is read off a session
    /// afterwards).
    pub fn set_last_bytes(&mut self, bytes: u64) {
        if let Some(last) = self.results.last_mut() {
            last.bytes = Some(bytes);
        }
    }

    fn push(&mut self, r: BenchResult) -> &BenchResult {
        if !self.header_printed {
            println!(
                "{:<44} {:>10} {:>10} {:>10}",
                "benchmark", "median", "mean", "p95"
            );
            println!("{}", "-".repeat(80));
            self.header_printed = true;
        }
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render everything recorded so far as the machine-readable bench
    /// report: `{bench, fast_mode, params, results: [...]}` with
    /// durations in nanoseconds. `params` carries the workload knobs
    /// the bench ran with (free-form key → number).
    pub fn to_json(&self, bench: &str, params: &[(&str, f64)]) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(bench.to_string()));
        obj.insert("fast_mode".to_string(), Json::Bool(self.fast));
        let mut p = std::collections::BTreeMap::new();
        for (k, v) in params {
            p.insert((*k).to_string(), Json::Num(*v));
        }
        obj.insert("params".to_string(), Json::Obj(p));
        obj.insert(
            "results".to_string(),
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        // process-wide metrics snapshot at report time — ties every
        // bench JSON to the counters its workload drove (additive:
        // readers treat the key as optional, old snapshots stay valid)
        obj.insert("metrics".to_string(), crate::obs::metrics::snapshot().to_json());
        Json::Obj(obj)
    }

    /// Write `bench_<name>.json` under [`results_dir`] (creating it) and
    /// return the path — called by every bench binary after its stdout
    /// table, so the JSON trajectories are populated on each run, fast
    /// mode included, at the same location regardless of CWD.
    pub fn write_json(&self, bench: &str, params: &[(&str, f64)]) -> std::io::Result<String> {
        self.write_json_in(&results_dir(), bench, params)
    }

    /// [`Bencher::write_json`] into an explicit directory (tests use a
    /// temp dir).
    pub fn write_json_in(
        &self,
        dir: &Path,
        bench: &str,
        params: &[(&str, f64)],
    ) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("bench_{bench}.json"));
        std::fs::write(&path, format!("{}\n", self.to_json(bench, params)))?;
        let shown = path.display().to_string();
        println!("wrote {shown}");
        Ok(shown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }

    #[test]
    fn bench_collects_samples() {
        // fast mode injected — never set process env from a test
        let mut b = Bencher::with_fast_mode(true);
        let r = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert!(!r.samples.is_empty());
        assert!(r.summary().median >= 0.0);
    }

    #[test]
    fn record_and_results() {
        let mut b = Bencher::new();
        b.record("ext", vec![0.5, 1.0, 1.5]);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary().median, 1.0);
    }

    #[test]
    fn json_report_is_parseable_and_carries_the_schema() {
        let mut b = Bencher::with_fast_mode(false);
        b.record("plain", vec![1e-3, 2e-3]);
        b.record_with_bytes("wired", vec![5e-4], 4096);
        let j = b.to_json("unit", &[("d", 8.0), ("m", 3.0)]);
        // round-trips through the in-tree parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "unit");
        assert_eq!(back.get("fast_mode").unwrap(), &Json::Bool(false));
        assert_eq!(back.get("params").unwrap().get("d").unwrap().as_f64().unwrap(), 8.0);
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "plain");
        assert_eq!(results[0].get("bytes").unwrap(), &Json::Null);
        // 1.5ms median -> nanoseconds
        assert_eq!(results[0].get("median_ns").unwrap().as_f64().unwrap(), 1.5e6);
        assert_eq!(results[1].get("bytes").unwrap().as_f64().unwrap(), 4096.0);
        assert!(results[1].get("p95_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn json_report_carries_injected_fast_mode() {
        let mut b = Bencher::with_fast_mode(true);
        b.record("x", vec![1.0]);
        let j = b.to_json("unit", &[]);
        assert_eq!(j.get("fast_mode").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn set_last_bytes_attaches_to_most_recent() {
        let mut b = Bencher::new();
        b.record("a", vec![1.0]);
        b.record("b", vec![1.0]);
        b.set_last_bytes(77);
        assert_eq!(b.results()[0].bytes, None);
        assert_eq!(b.results()[1].bytes, Some(77));
    }

    #[test]
    fn scaled_respects_fast_mode() {
        // parameterized — no process-env mutation
        assert_eq!(scaled_with(80, true), 10);
        assert_eq!(scaled_with(4, true), 1);
        assert_eq!(scaled_with(80, false), 80);
    }

    #[test]
    fn results_dir_is_cwd_independent() {
        // without the env override, the default resolves from the
        // compile-time manifest path — absolute, never CWD-relative
        if std::env::var("DSPCA_RESULTS_DIR").is_err() {
            let dir = results_dir();
            assert!(dir.is_absolute(), "results dir must not depend on CWD: {dir:?}");
            assert!(dir.ends_with("results"));
        }
    }

    #[test]
    fn write_json_in_writes_parseable_file() {
        let dir = std::env::temp_dir()
            .join(format!("dspca_bench_harness_test_{}", std::process::id()));
        let mut b = Bencher::with_fast_mode(true);
        b.record("w", vec![2e-3]);
        let path = b.write_json_in(&dir, "unit_write", &[("n", 4.0)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        let j = Json::parse(text.trim_end()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "unit_write");
        std::fs::remove_dir_all(&dir).ok();
    }
}
