//! Benchmark harness (criterion substitute for the offline image).
//!
//! Every `cargo bench` target in `rust/benches/` is a plain binary
//! (`harness = false`) built on this module: warmup, timed iterations,
//! median/p95 reporting, and environment-scaled iteration counts
//! (`DSPCA_BENCH_FAST=1` shrinks everything for CI smoke runs).

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wallclock seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<44} {:>10} {:>10} {:>10}  (n={})",
            self.name,
            fmt_dur(s.median),
            fmt_dur(s.mean),
            fmt_dur(s.p95),
            s.n
        )
    }
}

/// Human duration formatting.
pub fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// True when `DSPCA_BENCH_FAST=1`: benches shrink workloads for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("DSPCA_BENCH_FAST").as_deref() == Ok("1")
}

/// Scale an iteration count down in fast mode.
pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 8).max(1)
    } else {
        n
    }
}

/// Bench runner: prints a header then each result as it completes.
pub struct Bencher {
    header_printed: bool,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher { header_printed: false, results: Vec::new() }
    }

    /// Time `f` with automatic calibration: warm up, pick an iteration
    /// count targeting ~`budget` of wall time, then collect `samples`
    /// batches. `f` should return something observable to block dead-code
    /// elimination (use [`std::hint::black_box`] inside if needed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        let budget = if fast_mode() { Duration::from_millis(120) } else { Duration::from_millis(900) };
        // warmup + calibration
        let t0 = Instant::now();
        let mut iters_done = 0u64;
        while t0.elapsed() < budget / 6 || iters_done < 3 {
            std::hint::black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / iters_done as f64;
        let samples_target = 12usize;
        let batch = ((budget.as_secs_f64() / samples_target as f64 / per_iter).ceil() as u64).max(1);
        let mut samples = Vec::with_capacity(samples_target);
        for _ in 0..samples_target {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        self.push(BenchResult { name: name.to_string(), samples })
    }

    /// Record externally-measured samples (seconds per op).
    pub fn record(&mut self, name: &str, samples: Vec<f64>) -> &BenchResult {
        self.push(BenchResult { name: name.to_string(), samples })
    }

    fn push(&mut self, r: BenchResult) -> &BenchResult {
        if !self.header_printed {
            println!(
                "{:<44} {:>10} {:>10} {:>10}",
                "benchmark", "median", "mean", "p95"
            );
            println!("{}", "-".repeat(80));
            self.header_printed = true;
        }
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }

    #[test]
    fn bench_collects_samples() {
        std::env::set_var("DSPCA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert!(!r.samples.is_empty());
        assert!(r.summary().median >= 0.0);
    }

    #[test]
    fn record_and_results() {
        let mut b = Bencher::new();
        b.record("ext", vec![0.5, 1.0, 1.5]);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary().median, 1.0);
    }

    #[test]
    fn scaled_respects_fast_mode() {
        std::env::set_var("DSPCA_BENCH_FAST", "1");
        assert_eq!(scaled(80), 10);
        assert_eq!(scaled(4), 1);
    }
}
