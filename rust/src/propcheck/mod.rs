//! `propcheck` — a minimal property-based testing harness.
//!
//! The offline image has no `proptest`/`quickcheck`; this module supplies
//! the same methodology: run a property over many pseudo-random inputs
//! drawn from composable generators, with a deterministic per-case seed so
//! any failure message pinpoints the reproducing seed.
//!
//! ```no_run
//! use dspca::propcheck::{Config, Gen, run};
//!
//! run(Config::default().cases(64), "dot is symmetric", |g| {
//!     let n = g.usize_in(1, 32);
//!     let a = g.f64_vec(n, -10.0, 10.0);
//!     let b = g.f64_vec(n, -10.0, 10.0);
//!     let d1 = dspca::linalg::vec_ops::dot(&a, &b);
//!     let d2 = dspca::linalg::vec_ops::dot(&b, &a);
//!     assert!((d1 - d2).abs() <= 1e-12 * (1.0 + d1.abs()));
//! });
//! ```

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // DSPCA_PROP_CASES scales coverage up in long runs.
        let cases = std::env::var("DSPCA_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(48);
        Config { cases, seed: 0x5eed_cafe }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Random input source handed to the property closure.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn f64_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.gaussian_vec(n)
    }

    pub fn unit_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = self.rng.gaussian_vec(n);
        let norm = crate::linalg::vec_ops::normalize(&mut v);
        if norm == 0.0 {
            v[0] = 1.0;
        }
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Random symmetric matrix with entries in `[-scale, scale]`.
    pub fn sym_matrix(&mut self, n: usize, scale: f64) -> crate::linalg::Matrix {
        let mut m = crate::linalg::Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = self.f64_in(-scale, scale);
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    /// Random PSD matrix `B^T B / n` with controlled scale.
    pub fn psd_matrix(&mut self, n: usize, scale: f64) -> crate::linalg::Matrix {
        let b = crate::linalg::Matrix::from_vec(
            n,
            n,
            (0..n * n).map(|_| self.f64_in(-scale, scale)).collect(),
        );
        b.syrk_t().scale(1.0 / n as f64)
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` over `config.cases` random inputs. Panics (failing the
/// enclosing `#[test]`) with the case index + seed on the first failure.
pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(config: Config, name: &str, prop: F) {
    for case in 0..config.cases {
        let case_seed = config.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Pcg64::new(case_seed) };
            prop(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}): {msg}",
                config.cases
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run(Config::default().cases(16), "tautology", |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..=1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = std::panic::catch_unwind(|| {
            run(Config::default().cases(16), "always false", |_g| {
                panic!("boom");
            });
        });
        let err = res.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always false"));
        assert!(msg.contains("seed"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<f64> = Vec::new();
        run(Config::default().cases(4).seed(42), "record", |g| {
            // same seeds -> same draws; record then compare
            let _ = g.f64_in(0.0, 1.0);
        });
        // direct check on Gen determinism
        for case in 0..4u64 {
            let seed = 42 ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let mut g = Gen { rng: Pcg64::new(seed) };
            first.push(g.f64_in(0.0, 1.0));
        }
        for case in 0..4u64 {
            let seed = 42 ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let mut g = Gen { rng: Pcg64::new(seed) };
            assert_eq!(g.f64_in(0.0, 1.0), first[case as usize]);
        }
    }

    #[test]
    fn unit_vec_is_unit() {
        run(Config::default().cases(32), "unit vec", |g| {
            let n = g.usize_in(1, 64);
            let v = g.unit_vec(n);
            let norm = crate::linalg::vec_ops::norm(&v);
            assert!((norm - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn psd_matrix_is_psd() {
        run(Config::default().cases(16), "psd", |g| {
            let n = g.usize_in(1, 10);
            let m = g.psd_matrix(n, 1.0);
            let eig = crate::linalg::SymEigen::new(&m);
            for &v in eig.values() {
                assert!(v > -1e-10, "negative eigenvalue {v}");
            }
        });
    }
}
