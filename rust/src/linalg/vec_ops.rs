//! Hot vector kernels.
//!
//! Every communication round of every algorithm in the paper moves and
//! combines `R^d` vectors; these are the corresponding compute kernels.
//! All of them are allocation-free where an output buffer can be reused.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: breaks the sequential FP dependency
    // chain so the CPU can keep several FMAs in flight.
    let chunks = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut tail = 0.0;
    while i < a.len() {
        tail += a[i] * b[i];
        i += 1;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += s * x`.
#[inline]
pub fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += s * xi;
    }
}

/// `y = s * y`.
#[inline]
pub fn scale(y: &mut [f64], s: f64) {
    for yi in y.iter_mut() {
        *yi *= s;
    }
}

/// Normalize to unit norm in place; returns the original norm.
/// A zero vector is left untouched (returns 0).
#[inline]
pub fn normalize(v: &mut [f64]) -> f64 {
    let n = norm(v);
    if n > 0.0 {
        let inv = 1.0 / n;
        scale(v, inv);
    }
    n
}

/// Normalized copy.
pub fn normalized(v: &[f64]) -> Vec<f64> {
    let mut out = v.to_vec();
    normalize(&mut out);
    out
}

/// `a - b` as a fresh vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// `a + b` as a fresh vector.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

/// Element-wise mean of a non-empty set of equally-sized vectors.
pub fn mean(vs: &[Vec<f64>]) -> Vec<f64> {
    assert!(!vs.is_empty(), "mean of zero vectors");
    let d = vs[0].len();
    let mut out = vec![0.0; d];
    for v in vs {
        assert_eq!(v.len(), d);
        axpy(&mut out, 1.0, v);
    }
    scale(&mut out, 1.0 / vs.len() as f64);
    out
}

/// The paper's estimation-error metric: `1 - <w, v1>^2` for unit vectors.
/// (Sign-invariant: both `w` and `-w` score the same.)
#[inline]
pub fn alignment_error(w: &[f64], v1: &[f64]) -> f64 {
    let c = dot(w, v1);
    (1.0 - c * c).max(0.0)
}

/// Copy `src` into `dst` (lengths must match).
#[inline]
pub fn copy(dst: &mut [f64], src: &[f64]) {
    dst.copy_from_slice(src);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
    }

    #[test]
    fn dot_unroll_tail_cases() {
        // lengths 0..9 cover every remainder class of the 4-way unroll
        for len in 0..9usize {
            let a: Vec<f64> = (0..len).map(|i| i as f64 + 1.0).collect();
            let naive: f64 = a.iter().map(|x| x * x).sum();
            assert_eq!(dot(&a, &a), naive, "len={len}");
        }
    }

    #[test]
    fn norm_345() {
        assert!((norm(&[3., 4.]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_scale_roundtrip() {
        let mut y = vec![1., 1.];
        axpy(&mut y, 2.0, &[1., 2.]);
        assert_eq!(y, vec![3., 5.]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.5]);
    }

    #[test]
    fn normalize_unit_and_zero() {
        let mut v = vec![0., 3., 4.];
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm(&v) - 1.0).abs() < 1e-15);
        let mut z = vec![0., 0.];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, vec![0., 0.]);
    }

    #[test]
    fn mean_of_vectors() {
        let m = mean(&[vec![1., 2.], vec![3., 4.]]);
        assert_eq!(m, vec![2., 3.]);
    }

    #[test]
    fn alignment_error_basics() {
        let e1 = vec![1., 0.];
        let e2 = vec![0., 1.];
        assert!(alignment_error(&e1, &e1) < 1e-15);
        assert!((alignment_error(&e1, &e2) - 1.0).abs() < 1e-15);
        // sign invariance
        let me1 = vec![-1., 0.];
        assert!(alignment_error(&me1, &e1) < 1e-15);
        // 45 degrees -> error 1/2
        let v = normalized(&[1., 1.]);
        assert!((alignment_error(&v, &e1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn add_sub_inverse() {
        let a = vec![1., 2., 3.];
        let b = vec![0.5, 0.25, -1.0];
        assert_eq!(sub(&add(&a, &b), &b), a);
    }
}
