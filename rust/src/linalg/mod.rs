//! Dense linear-algebra substrate.
//!
//! The execution image has no BLAS/LAPACK and no linear-algebra crates, so
//! everything the paper's algorithms need is implemented here from scratch:
//!
//! - [`Matrix`] — row-major dense `f64` matrix with blocked GEMM / GEMV /
//!   SYRK kernels ([`matrix`]).
//! - [`vec_ops`] — the hot vector kernels (dot, axpy, normalize) used in
//!   every communication round.
//! - [`qr`] — Householder QR (thin), used for random orthonormal bases and
//!   Lanczos re-orthogonalization checks.
//! - [`eigen`] — symmetric eigensolver (Householder tridiagonalization +
//!   implicit-shift QL), which backs the local ERM solutions, the
//!   centralized baseline, the `C^{-1/2}` preconditioner of Lemma 6 and the
//!   projection-averaging estimator.
//! - [`jacobi`] — cyclic Jacobi eigensolver, kept as an independent
//!   cross-check oracle for the QL implementation.
//! - [`eigen2x2`] — analytic 2x2 eigenvectors (Thm 3 / Thm 5 constructions).
//! - [`threads`] — the process-global compute-thread budget the blocked
//!   GEMM and the shard covariance kernels honor (`--threads` /
//!   `DSPCA_THREADS`; default 1 = the exact scalar kernels).

pub mod eigen;
pub mod eigen2x2;
pub mod jacobi;
pub mod matrix;
pub mod qr;
pub mod threads;
pub mod vec_ops;

pub use eigen::SymEigen;
pub use matrix::Matrix;
pub use threads::{compute_threads, set_compute_threads};

/// Machine-epsilon-scale tolerance used by the iterative eigensolvers.
pub const EIG_TOL: f64 = 1e-13;

/// Relative tolerance for "is this basically equal" test assertions.
pub const TEST_RTOL: f64 = 1e-9;
