//! Householder QR decomposition (thin form).
//!
//! Used for (a) generating random orthonormal matrices `U` for the paper's
//! §5 covariance model (QR of a gaussian matrix gives a Haar-ish basis),
//! and (b) re-orthogonalization checks of the distributed Lanczos basis.

use super::matrix::Matrix;
use super::vec_ops;

/// Thin QR of an `m x n` matrix (`m >= n`): returns `(Q, R)` with
/// `Q: m x n` having orthonormal columns and `R: n x n` upper triangular,
/// such that `A = Q R`. The decomposition is sign-normalized so every
/// diagonal entry of `R` is non-negative (this makes the `Q` of a gaussian
/// matrix exactly Haar-distributed).
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "qr_thin requires rows >= cols");
    // Modified Gram-Schmidt with one re-orthogonalization pass: simpler
    // than Householder accumulation for the thin form and, with the second
    // pass, equally stable for our sizes (d <= ~1000).
    let mut q = Matrix::zeros(m, n);
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        let mut v = a.col(j);
        // two MGS passes ("twice is enough" — Kahan)
        for _pass in 0..2 {
            for i in 0..j {
                let qi = q.col(i);
                let proj = vec_ops::dot(&qi, &v);
                r.set(i, j, r.get(i, j) + proj);
                vec_ops::axpy(&mut v, -proj, &qi);
            }
        }
        let nv = vec_ops::norm(&v);
        r.set(j, j, nv);
        if nv > 0.0 {
            vec_ops::scale(&mut v, 1.0 / nv);
        }
        q.set_col(j, &v);
    }
    // sign normalization: R diagonal >= 0
    for j in 0..n {
        if r.get(j, j) < 0.0 {
            for i in 0..m {
                q.set(i, j, -q.get(i, j));
            }
            for k in j..n {
                r.set(j, k, -r.get(j, k));
            }
        }
    }
    (q, r)
}

/// Orthonormality defect `||Q^T Q - I||_max` — diagnostic used by tests
/// and the Lanczos re-orthogonalization monitor.
pub fn orthonormality_defect(q: &Matrix) -> f64 {
    let qtq = q.transpose().matmul(q);
    qtq.sub(&Matrix::identity(q.cols())).max_abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_mat(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(m, n, (0..m * n).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn qr_reconstructs() {
        let a = random_mat(12, 7, 1);
        let (q, r) = qr_thin(&a);
        let rec = q.matmul(&r);
        assert!(rec.sub(&a).max_abs() < 1e-10);
    }

    #[test]
    fn q_orthonormal() {
        let a = random_mat(30, 30, 2);
        let (q, _) = qr_thin(&a);
        assert!(orthonormality_defect(&q) < 1e-11);
    }

    #[test]
    fn r_upper_triangular_nonneg_diag() {
        let a = random_mat(9, 9, 3);
        let (_, r) = qr_thin(&a);
        for i in 0..9 {
            assert!(r.get(i, i) >= 0.0);
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_of_orthonormal_is_identity_r() {
        let a = random_mat(8, 8, 4);
        let (q, _) = qr_thin(&a);
        let (q2, r2) = qr_thin(&q);
        assert!(r2.sub(&Matrix::identity(8)).max_abs() < 1e-10);
        assert!(q2.sub(&q).max_abs() < 1e-10);
    }

    #[test]
    fn thin_rectangular_shapes() {
        let a = random_mat(20, 5, 5);
        let (q, r) = qr_thin(&a);
        assert_eq!(q.rows(), 20);
        assert_eq!(q.cols(), 5);
        assert_eq!(r.rows(), 5);
        assert_eq!(r.cols(), 5);
        assert!(orthonormality_defect(&q) < 1e-11);
    }
}
