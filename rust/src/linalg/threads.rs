//! Process-global compute-thread budget for the shard/matrix kernels.
//!
//! The kernels in [`crate::linalg::matrix`] and [`crate::data::shard`]
//! take an explicit thread count in their `*_threads` variants; the
//! plain entry points read this global. Default is **1** (the exact
//! scalar kernels the repo has always had), overridable by the
//! `DSPCA_THREADS` env var at startup or the `--threads` CLI flag via
//! [`set_compute_threads`].
//!
//! Tests never mutate this global implicitly: equivalence suites use
//! the explicit `*_threads` kernel variants so `cargo test` stays
//! order-independent (the ISSUE 6 bench-harness env race must not be
//! reintroduced here).

use std::sync::OnceLock;

use crate::sync::atomic::{AtomicUsize, Ordering};

/// 0 means "not yet initialized"; first read resolves `DSPCA_THREADS`.
static THREADS: AtomicUsize = AtomicUsize::new(0);

fn env_default() -> usize {
    match std::env::var("DSPCA_THREADS") {
        Ok(v) => v.trim().parse::<usize>().ok().filter(|&t| t >= 1).unwrap_or(1),
        Err(_) => 1,
    }
}

/// Current compute-thread budget (`>= 1`).
pub fn compute_threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    static INIT: OnceLock<usize> = OnceLock::new();
    let resolved = *INIT.get_or_init(env_default);
    // Publish only if nobody called `set_compute_threads` in between.
    let _ = THREADS.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed);
    THREADS.load(Ordering::Relaxed).max(1)
}

/// Set the compute-thread budget (clamped to `>= 1`). Wins over
/// `DSPCA_THREADS`.
pub fn set_compute_threads(threads: usize) {
    THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Split `total_rows` into at most `threads` contiguous, near-equal
/// `[start, end)` panels (earlier panels get the remainder). Never
/// returns an empty panel; returns a single panel covering everything
/// when `threads <= 1` or `total_rows` is small.
pub(crate) fn row_panels(total_rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.clamp(1, total_rows.max(1));
    let base = total_rows / t;
    let extra = total_rows % t;
    let mut panels = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        panels.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, total_rows);
    panels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_panels_cover_and_partition() {
        for &(rows, t) in &[(10usize, 3usize), (7, 8), (64, 4), (1, 16), (100, 1)] {
            let p = row_panels(rows, t);
            assert!(p.len() <= t.max(1));
            assert_eq!(p[0].0, 0);
            assert_eq!(p.last().unwrap().1, rows);
            for w in p.windows(2) {
                assert_eq!(w[0].1, w[1].0, "panels must be contiguous");
                assert!(w[0].1 > w[0].0, "panels must be non-empty");
            }
        }
    }

    #[test]
    fn row_panels_near_equal() {
        let p = row_panels(10, 3);
        let sizes: Vec<usize> = p.iter().map(|&(a, b)| b - a).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn compute_threads_is_at_least_one() {
        // Read-only: must not mutate the global (order-independence).
        assert!(compute_threads() >= 1);
    }
}
